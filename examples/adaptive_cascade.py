"""Adaptive cascade demo — the full runtime control plane on a drifting
workload with a mid-episode remote outage.

A stream of synthetic requests flows through the BiSupervised cascade
composed with the ``repro.runtime`` control plane (DESIGN.md):

  1. OFFLINE  — a labelled validation slice is swept into a cost/accuracy
     Pareto frontier; the operating point for a 20% remote budget seeds
     ``(t_local, t_remote, k)``.
  2. ONLINE   — traffic drifts (hard-input rate 10% -> 40%); the
     EMA/PID controller detects the drift on its score histograms and
     retunes the thresholds so the remote bill stays on budget.
  3. OUTAGE   — the remote tier times out for a stretch; the circuit
     breaker opens, escalations degrade to the fallback answer (nobody's
     request is dropped), and the half-open probe restores service.
  4. DEDUP    — duplicate requests are served from the content-keyed
     cache and never re-billed.

    PYTHONPATH=src python examples/adaptive_cascade.py
"""

import jax.numpy as jnp
import numpy as np

from repro.runtime import (AdaptiveController, ControllerConfig,
                           RemoteResponseCache, RemoteTimeout,
                           RemoteTransport, TransportConfig, calibrate)
from repro.serving import ServeConfig
from repro.serving.scheduler import Request

rng = np.random.default_rng(0)
NCLS, BATCH, BUDGET = 8, 32, 0.20


def make_requests(n, hard_frac):
    labels = rng.integers(0, NCLS, n)
    x = rng.normal(0, 0.05, (n, NCLS))
    margin = np.where(rng.random(n) < hard_frac,
                      rng.uniform(0.05, 0.4, n), rng.uniform(2.0, 4.0, n))
    x[np.arange(n), labels] += margin
    return np.float32(x), labels


def local_apply(x):
    return x + 0.3 * jnp.sin(17.0 * x)


clock = {"t": 0.0}
outage = {"on": False}


def remote_apply(x):
    clock["t"] += 0.01
    if outage["on"]:
        raise RemoteTimeout("remote tier unreachable")
    return 5.0 * np.asarray(x)


def softmax_conf(logits):
    e = np.exp(logits - logits.max(-1, keepdims=True))
    return (e / e.sum(-1, keepdims=True)).max(-1)


# ---- 1. offline calibration on a labelled validation slice --------------
val_x, val_y = make_requests(1024, 0.15)
local_logits = np.asarray(local_apply(val_x))
remote_logits = np.asarray(5.0 * val_x)
point, k, frontier = calibrate(
    local_conf=softmax_conf(local_logits),
    local_correct=local_logits.argmax(-1) == val_y,
    remote_conf=softmax_conf(remote_logits),
    remote_correct=remote_logits.argmax(-1) == val_y,
    budget=BUDGET, batch_size=BATCH)
print(f"[calibrate] Pareto frontier: {len(frontier)} points; picked "
      f"t_local={point.t_local:.3f} t_remote={point.t_remote:.3f} k={k} "
      f"(val: {point.remote_fraction:.0%} remote, "
      f"{point.accuracy:.3f} accepted acc)")

# ---- 2. compose the runtime ---------------------------------------------
transport = RemoteTransport(
    remote_apply,
    TransportConfig(max_in_flight=8, timeout_s=1.0, max_retries=1,
                    retry_backoff_s=0.0, breaker_failures=2,
                    breaker_reset_s=0.5),
    clock=lambda: clock["t"], sleep=lambda s: None)
controller = AdaptiveController(ControllerConfig(
    target_remote_fraction=BUDGET, window=256))
# the whole serving stack comes from ONE ServeConfig (DESIGN.md §8)
cfg = ServeConfig(batch_size=BATCH, remote_fraction_budget=BUDGET,
                  t_remote=point.t_remote, t_local=point.t_local)
engine, sched = cfg.build(local_apply, transport=transport,
                          controller=controller,
                          cache=RemoteResponseCache(4096),
                          fallback=lambda r: -1)

uid = 0


def serve(n, hard_frac, dup_frac=0.0):
    global uid
    xs, ys = make_requests(n, hard_frac)
    if dup_frac > 0:       # resubmit a slice of known-hard duplicates
        ndup = int(n * dup_frac)
        xs[:ndup] = xs[rng.integers(n - ndup, n, ndup)]
    for row in xs:
        sched.submit(Request(uid=uid, local_input=row, remote_input=row))
        uid += 1
    rs = sched.flush()
    srcs = {s: sum(r.source == s for r in rs)
            for s in ("local", "remote", "fallback")}
    return srcs


st = engine.stats
print(f"\n[phase 1] calm traffic (10% hard): {serve(2048, 0.10)}")
print(f"          remote fraction {st.remote_fraction:.2f} "
      f"(budget {BUDGET})")

print(f"\n[phase 2] drift! (40% hard): {serve(4096, 0.40)}")
cs = controller.state
print(f"          remote fraction {st.remote_fraction:.2f}, "
      f"controller saw {cs.drift_events} drift event(s), "
      f"t_local -> {cs.t_local:.3f}")

outage["on"] = True
print(f"\n[phase 3] remote outage: {serve(1024, 0.40)}")
outage["on"] = False
clock["t"] += 1.0
print(f"          breaker: {transport.stats.breaker_opens} open(s), "
      f"{transport.stats.short_circuited} short-circuited, "
      f"state={transport.breaker.state}")
print(f"[phase 3b] recovery: {serve(1024, 0.40)} "
      f"(breaker {transport.breaker.state})")

print(f"\n[phase 4] duplicate-heavy: {serve(2048, 0.40, dup_frac=0.5)}")
print(f"          cache: {engine.cache.stats.hits} hits "
      f"(rate {engine.cache.stats.hit_rate or 0.0:.2f})")

print(f"\n[total] {st.requests} requests, {st.escalations} escalations, "
      f"{st.remote_calls} billed remote calls, {st.cache_hits} cache hits, "
      f"{st.transport_failures} transport failures")
print(f"[total] bill ${st.total_cost:.4f} vs remote-only "
      f"${st.requests * engine.cost.remote_cost_per_request:.4f}; "
      f"mean latency {(st.mean_latency_s or 0.0) * 1e3:.0f} ms vs remote-only "
      f"{engine.cost.remote_latency_s * 1e3:.0f} ms")

"""Quickstart: BiSupervised in ~60 lines.

Builds a tiny local classifier + a strong "remote" model on a synthetic
task, wires both supervisors through the cascade engine, and prints the
cost/accuracy trade-off — the paper's Figure 1 in miniature.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import auc_rac, request_accuracy_curve
from repro.core.supervisors import max_softmax
from repro.data.synthetic import make_classification_task
from repro.models import surrogate as S
from repro.serving import ServeConfig
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state

# ---- 1. a task + a small LOCAL surrogate model (paper §4.1) -------------
vocab, seq, ncls, n = 256, 32, 4, 1024
toks, labels, _ = make_classification_task(0, n=n, vocab=vocab,
                                           seq_len=seq, num_classes=ncls)
cfg = S.SurrogateConfig("local", vocab_size=vocab, max_len=seq, d_model=32,
                        num_heads=2, d_ff=32, num_classes=ncls, dropout=0.0)
params = S.init_params(cfg, jax.random.PRNGKey(0))
opt = init_opt_state(params)
ocfg = AdamWConfig(lr=3e-3, warmup_steps=5, weight_decay=0.0)


@jax.jit
def train_step(p, o, tk, lb):
    (loss, _), g = jax.value_and_grad(
        lambda p: S.loss_fn(cfg, p, tk, lb, jax.random.PRNGKey(1)),
        has_aux=True)(p)
    p, o, _ = adamw_update(ocfg, p, g, o)
    return p, o, loss


tk, lb = jnp.asarray(toks[:512]), jnp.asarray(labels[:512])
for i in range(40):
    params, opt, loss = train_step(params, opt, tk, lb)
print(f"local model trained: loss {float(loss):.3f}")

# ---- 2. the REMOTE model (here: an oracle stand-in for GPT-3) -----------
oracle = jax.nn.one_hot(jnp.asarray(labels), ncls) * 8.0

# ---- 3. the cascade: local + 1st supervisor -> remote + 2nd supervisor --
eng = ServeConfig(batch_size=256, remote_fraction_budget=0.3, t_remote=0.5,
                  fused=True).build_engine(
    lambda x: S.apply(cfg, params, x), lambda idx: oracle[idx[:, 0]])

test_toks, test_idx = jnp.asarray(toks[512:768]), jnp.arange(512, 768)
out = eng.serve({"local": test_toks, "remote": test_idx[:, None]})

sys_acc = (np.asarray(out["prediction"]) == labels[512:768]).mean()
loc_acc = (np.asarray(out["local_pred"]) == labels[512:768]).mean()
print(f"local-only accuracy : {loc_acc:.3f}")
print(f"cascade accuracy    : {sys_acc:.3f} "
      f"at {eng.stats.remote_fraction:.0%} remote calls "
      f"(cost saving {1 - eng.stats.remote_fraction:.0%})")

# ---- 4. the paper's RQ1 curve on this system ----------------------------
local_logits = S.apply(cfg, params, jnp.asarray(toks))
conf = np.asarray(max_softmax(local_logits))
local_correct = np.asarray(jnp.argmax(local_logits, -1)) == labels
rac = request_accuracy_curve(conf, local_correct, np.ones_like(labels))
print(f"AUC-RAC             : {auc_rac(rac):.3f} (random supervision = 0.5)")

"""End-to-end training driver: train a ~100M-param local surrogate family
member (a reduced deepseek-v2-lite — MLA + MoE) for a few hundred steps on
the synthetic LM stream, with checkpointing and eval.

This exercises the full training substrate: config system, scanned MoE/MLA
blocks, chunked CE, AdamW + cosine schedule, remat, msgpack checkpoints.

    PYTHONPATH=src python examples/train_surrogate.py [--steps 300]
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.loop import make_train_step
from repro.train.optimizer import AdamWConfig, init_opt_state


def build_cfg(scale: str):
    base = get_config("deepseek-v2-lite-16b")
    if scale == "smoke":         # CI-sized
        return base.reduced()
    # ~100M-param family member: same block structure, narrower dims
    return dataclasses.replace(
        base, name="deepseek-v2-mini-100m", num_layers=6, d_model=768,
        num_heads=8, head_dim=96, d_ff=2048, vocab_size=16384,
        kv_lora_rank=192, qk_nope_head_dim=64, qk_rope_head_dim=32,
        v_head_dim=64, num_experts=8, num_experts_per_tok=2,
        num_shared_experts=1, moe_d_ff=512, first_dense_layers=1,
        dtype="float32")


def data_stream(vocab: int, batch: int, seq: int, seed: int = 0):
    """Markov-chain token stream (learnable structure, not pure noise)."""
    rng = np.random.default_rng(seed)
    trans = rng.dirichlet(np.full(64, 0.05), size=vocab)  # sparse rows
    nxt_choices = np.argsort(-trans, axis=1)[:, :64].astype(np.int32)
    nxt_probs = np.take_along_axis(trans, nxt_choices, axis=1)
    nxt_probs /= nxt_probs.sum(1, keepdims=True)
    while True:
        out = np.empty((batch, seq), np.int32)
        out[:, 0] = rng.integers(0, vocab, batch)
        for t in range(1, seq):
            r = rng.random(batch)
            cum = np.cumsum(nxt_probs[out[:, t - 1]], axis=1)
            pick = (r[:, None] > cum).sum(1)
            out[:, t] = nxt_choices[out[:, t - 1], pick]
        yield {"tokens": jnp.asarray(out)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--scale", choices=("smoke", "100m"), default="100m")
    ap.add_argument("--checkpoint", default="/tmp/surrogate_ckpt.msgpack")
    args = ap.parse_args()

    cfg = build_cfg(args.scale)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"[example] {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"{args.steps} steps of batch {args.batch} x seq {args.seq}")

    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    step = jax.jit(make_train_step(cfg, opt_cfg, remat=False))
    opt = init_opt_state(params)
    stream = data_stream(cfg.vocab_size, args.batch, args.seq)

    t0, losses = time.perf_counter(), []
    for i in range(args.steps):
        params, opt, m = step(params, opt, next(stream))
        losses.append(float(m["ce"]))
        if (i + 1) % 25 == 0 or i == 0:
            dt = time.perf_counter() - t0
            print(f"[example] step {i + 1:4d} ce={losses[-1]:.4f} "
                  f"acc={float(m['acc']):.3f} "
                  f"moe_aux={float(m['moe_aux']):.3f} "
                  f"({dt / (i + 1):.2f} s/step)")

    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"[example] CE {first:.3f} -> {last:.3f} "
          f"({(1 - last / first):.0%} reduction)")
    if args.steps >= 50:
        assert last < first, "training did not reduce loss"

    save_checkpoint(args.checkpoint, params, step=args.steps)
    restored, step_no = load_checkpoint(args.checkpoint, params)
    assert step_no == args.steps
    print(f"[example] checkpoint round-trip OK -> {args.checkpoint}")


if __name__ == "__main__":
    main()

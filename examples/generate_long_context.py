"""Long-context decode example: RWKV6 (attention-free, O(1) state) greedy
generation with per-token likelihoods feeding the paper's sequence
supervisor (min-likelihood reducer, §5.3.4) — the generative analogue of
the classification cascade used for the long_500k serving shape.

    PYTHONPATH=src python examples/generate_long_context.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.supervisors import seq_min_likelihood, seq_prod_likelihood
from repro.models import transformer as T
from repro.serving.generate import greedy_generate

cfg = get_config("rwkv6-1.6b").reduced()
params = T.init_params(cfg, jax.random.PRNGKey(0))

rng = np.random.default_rng(0)
prompt = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 48)), jnp.int32)

toks, liks = greedy_generate(cfg, params, {"tokens": prompt},
                             max_new_tokens=12)
print(f"[gen] generated tokens:\n{np.asarray(toks)}")
print(f"[gen] per-token likelihoods (row 0): "
      f"{np.round(np.asarray(liks[0]), 3)}")

# 2nd-level supervision on the generated answer (paper's QA reducer)
conf_min = seq_min_likelihood(liks)
conf_prod = seq_prod_likelihood(liks)
print(f"[gen] min-reducer confidence : {np.round(np.asarray(conf_min), 4)}")
print(f"[gen] prod-reducer confidence: {np.round(np.asarray(conf_prod), 4)} "
      f"(length-biased — the paper argues for min)")

t_remote = 0.05
accepted = np.asarray(conf_min) > t_remote
print(f"[gen] accepted at t={t_remote}: {accepted.tolist()} "
      f"(rejected answers would trigger the fallback)")

# O(1) state: the RWKV cache is the same size regardless of context length
cache_64 = T.make_cache(cfg, 1, 64)
cache_500k = T.make_cache(cfg, 1, 524_288)
b64 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache_64))
b500k = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache_500k))
print(f"[gen] cache bytes @64 ctx: {b64:,} == @524k ctx: {b500k:,} -> "
      f"long_500k decode is O(1) memory (why this arch runs that shape)")

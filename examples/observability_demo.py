"""Observability demo — a scripted outage, narrated by the telemetry.

The same cascade as ``examples/adaptive_cascade.py``, but this time the
point is what you can SEE (DESIGN.md §9). Two remote backends serve a
pipelined stream behind a ``cheapest-available`` router; mid-run the
cheap primary suffers an outage. Instead of inferring what happened
from aggregate counters, the demo prints:

  * the structured EVENT LOG — every breaker open/half-open/close,
    router failover/fail-back and controller update, in the one global
    sequence order the components actually interleaved in;
  * a PER-REQUEST table built from trace spans — disposition, serving
    backend, realised $ cost, enqueue->hand-back latency and the
    dominant stage of each request's timeline;
  * the METRICS snapshot — and the proof that its commit-order cost
    counter reconciles bitwise with ``CascadeStats`` billing.

    PYTHONPATH=src python examples/observability_demo.py
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.runtime import (RemoteBackend, RemoteRouter, RemoteTimeout,
                           TransportConfig)
from repro.serving import ServeConfig
from repro.serving.scheduler import Request

rng = np.random.default_rng(0)
NCLS, BATCH = 8, 16


def make_requests(n, hard_frac=0.4):
    labels = rng.integers(0, NCLS, n)
    x = rng.normal(0, 0.05, (n, NCLS))
    margin = np.where(rng.random(n) < hard_frac,
                      rng.uniform(0.05, 0.4, n), rng.uniform(2.0, 4.0, n))
    x[np.arange(n), labels] += margin
    return np.float32(x)


def local_apply(x):
    return x + 0.3 * jnp.sin(17.0 * x)


outage = {"on": False}


def primary_fn(x):
    if outage["on"]:
        raise RemoteTimeout("primary brownout")
    time.sleep(0.03)
    return 5.0 * np.asarray(x)


def secondary_fn(x):
    time.sleep(0.01)
    return 5.0 * np.asarray(x)


tconf = TransportConfig(max_in_flight=BATCH, max_retries=0,
                        retry_backoff_s=0.0, timeout_s=5.0,
                        breaker_failures=2, breaker_reset_s=0.25)
router = RemoteRouter(
    [RemoteBackend("cheap-slow", primary_fn, tconf,
                   cost_per_request=0.002, latency_s=0.03),
     RemoteBackend("pricey-fast", secondary_fn, tconf,
                   cost_per_request=0.008, latency_s=0.01)],
    policy="cheapest-available")

# one flag turns the whole telemetry layer on (DESIGN.md §9)
cfg = ServeConfig(batch_size=BATCH, remote_fraction_budget=0.4,
                  t_remote=0.0, pipeline_depth=2, observability=True)
engine, sched = cfg.build(local_apply, transport=router,
                          fallback=lambda r: -1)
obs = engine.observability

uid = 0


def serve(n):
    global uid
    for row in make_requests(n):
        sched.submit(Request(uid=uid, local_input=row, remote_input=row))
        uid += 1
    return sched.flush()


responses = []
print("[phase 1] calm traffic ...")
responses += serve(3 * BATCH)
print("[phase 2] primary outage!")
outage["on"] = True
responses += serve(3 * BATCH)
print("[phase 3] recovery ...")
outage["on"] = False
time.sleep(0.3)                 # let the breaker reset elapse
responses += serve(3 * BATCH)
engine.close()

# ---- the event log: silent transitions, in global sequence order -------
print("\n=== EVENT LOG (what actually happened, in order) ===")
t0 = min(e["ts"] for e in obs.events.events())
for e in obs.events.events():
    if e["event"] == "controller_update":
        continue                # one per window; too chatty for a demo
    extra = {k: v for k, v in e.items()
             if k not in ("event", "seq", "ts", "window", "backend")
             and v is not None}
    print(f"  seq {e['seq']:3d}  +{e['ts'] - t0:6.3f}s  "
          f"window {e['window'] if e['window'] is not None else '-':>3}  "
          f"{e['event']:<18} backend={e['backend'] or '-':<12} "
          + " ".join(f"{k}={v}" for k, v in sorted(extra.items())))

# ---- per-request cost/latency table from the trace spans ---------------
spans = {s["uid"]: s for s in obs.trace.spans()}
print(f"\n=== PER-REQUEST TABLE ({len(responses)} requests; "
      f"one span each) ===")
print(f"  {'uid':>4} {'disposition':<12} {'backend':<12} {'cost':>8} "
      f"{'latency':>9}  dominant stage")
shown = {r.uid: r for r in
         [r for r in responses if r.disposition != "LOCAL"][:6]
         + responses[:3]}
for r in sorted(shown.values(), key=lambda r: r.uid):
    s = spans[r.uid]
    stages = s["stages"]
    gaps = [(b[0], b[1] - a[1]) for a, b in zip(stages, stages[1:])]
    stage, dt = max(gaps, key=lambda g: g[1])
    print(f"  {r.uid:>4} {r.disposition:<12} {r.backend or '-':<12} "
          f"${r.cost:7.4f} {r.latency_s * 1e3:7.1f}ms  "
          f"{stage} ({dt * 1e3:.1f}ms)")
print(f"  ... ({len(responses) - len(shown)} more; full timelines go to "
      f"--trace / --trace-chrome in launch/serve.py)")

# ---- metrics snapshot reconciles bitwise with billing ------------------
snap = obs.metrics.snapshot()
c = snap["counters"]
st = engine.stats
by_backend = {u: round(v.cost, 4) for u, v in st.per_backend.items()}
print("\n=== METRICS ===")
print(f"  requests={c['cascade_requests_total']} "
      f"escalations={c['cascade_escalations_total']} "
      f"remote_calls={c['cascade_remote_calls_total']} "
      f"transport_failures={c['cascade_transport_failures_total']}")
print(f"  cost counter ${c['cascade_cost_dollars_total']:.4f} "
      f"== stats.total_cost ${st.total_cost:.4f} (bitwise: "
      f"{c['cascade_cost_dollars_total'] == st.total_cost}) "
      f"per-backend {by_backend}")
print(f"  span costs sum ${sum(s['cost'] for s in spans.values()):.4f}; "
      f"events={dict(sorted(obs.events.counts().items()))}")

"""Cascade serving example — the wildlife-camera story (paper Example 4.1)
as a runnable system.

A stream of synthetic "camera frames" (easy / rare / invalid) flows
through the full BiSupervised stack: local surrogate + MaxSoftmax
1st-level supervisor -> escalation -> remote tier (a real reduced
transformer) + 2nd-level supervisor -> fallback ("notify the ranger").

    PYTHONPATH=src python examples/serve_cascade.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.thresholds import nominal_quantile_threshold
from repro.data.synthetic import make_classification_task
from repro.models import surrogate as S
from repro.serving import ServeConfig
from repro.serving.engine import CostModel
from repro.serving.scheduler import Request
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state

rng = np.random.default_rng(0)
NCLS = 5    # no-animal, deer, wolf, human, beaver
CLASSES = ["no-animal", "deer", "wolf", "human", "beaver"]

# ---- data: nominal frames + rare (hard) + invalid (mud on the lens) -----
vocab, seq = 256, 24
toks, labels, difficulty = make_classification_task(
    3, n=1024, vocab=vocab, seq_len=seq, num_classes=NCLS)
invalid = rng.random(1024) < 0.08
toks[invalid] = rng.integers(vocab - 8, vocab, (invalid.sum(), seq))  # junk

# ---- local tier: tiny surrogate trained on nominal frames only ----------
cfg = S.SurrogateConfig("camera", vocab_size=vocab, max_len=seq, d_model=32,
                        num_heads=2, d_ff=48, num_classes=NCLS, dropout=0.1)
params = S.init_params(cfg, jax.random.PRNGKey(0))
opt = init_opt_state(params)
ocfg = AdamWConfig(lr=3e-3, warmup_steps=5, weight_decay=0.0)


@jax.jit
def train_step(p, o, tk, lb, key):
    (loss, _), g = jax.value_and_grad(
        lambda p: S.loss_fn(cfg, p, tk, lb, key), has_aux=True)(p)
    p, o, _ = adamw_update(ocfg, p, g, o)
    return p, o, loss


nominal = ~invalid[:512]
tk = jnp.asarray(toks[:512][nominal])
lb = jnp.asarray(labels[:512][nominal])
for i in range(60):
    params, opt, loss = train_step(params, opt, tk, lb,
                                   jax.random.PRNGKey(i))
print(f"[camera] local model trained (loss {float(loss):.3f})")

# ---- remote tier: a real (reduced) yi-6b with an accurate task head -----
rcfg = get_config("yi-6b").reduced()
rparams = __import__("repro.models.transformer", fromlist=["x"]) \
    .init_params(rcfg, jax.random.PRNGKey(9))
from repro.models import transformer as T  # noqa: E402

oracle = jax.nn.one_hot(jnp.asarray(labels), NCLS) * 6.0
# the remote model CANNOT solve invalid frames either (paper: mud) — its
# oracle head goes flat there
oracle = jnp.where(jnp.asarray(invalid)[:, None], 0.05 * oracle, oracle)


def remote_apply(batch):
    logits, _ = T.prefill(rcfg, rparams, {"tokens": batch["tokens"]})
    return oracle[batch["idx"][:, 0]] + 0.02 * logits[:, :NCLS]


# ---- calibrate both supervisors on a nominal validation set (§4.5) ------
val_logits = S.apply(cfg, params, jnp.asarray(toks[512:640]))
val_conf = np.asarray(jnp.max(jax.nn.softmax(val_logits, -1), -1))
rem = remote_apply({"tokens": jnp.asarray(toks[512:640] % rcfg.vocab_size),
                    "idx": jnp.arange(512, 640)[:, None]})
rem_conf = np.asarray(jnp.max(jax.nn.softmax(rem, -1), -1))
t_remote = nominal_quantile_threshold(rem_conf[~invalid[512:640]], 0.05)

ranger_notifications = []
eng, sched = ServeConfig(
    batch_size=64, remote_fraction_budget=0.35, t_remote=t_remote,
    cost=CostModel(), fused=True,
).build(lambda x: S.apply(cfg, params, x), remote_apply,
        fallback=lambda req: ranger_notifications.append(req.uid) or -1)

# ---- serve the last 256 frames ------------------------------------------
test = slice(768, 1024)
for i in range(*test.indices(1024)):
    sched.submit(Request(
        uid=i, local_input=toks[i],
        remote_input={"tokens": toks[i] % rcfg.vocab_size,
                      "idx": np.array([i], np.int32)}))
responses = sched.flush()

by_src = {"local": [], "remote": [], "fallback": []}
for r in responses:
    by_src[r.source].append(r)
acc = {s: np.mean([r.prediction == labels[r.uid] for r in rs])
       if rs else float("nan") for s, rs in by_src.items()}
inv_rate = {s: np.mean([invalid[r.uid] for r in rs]) if rs else 0.0
            for s, rs in by_src.items()}

print(f"[camera] routing: { {k: len(v) for k, v in by_src.items()} }")
print(f"[camera] accuracy by source: local={acc['local']:.2f} "
      f"remote={acc['remote']:.2f}")
print(f"[camera] invalid-frame share: local={inv_rate['local']:.2f} "
      f"remote={inv_rate['remote']:.2f} "
      f"fallback={inv_rate['fallback']:.2f} "
      f"(mud ends up at the ranger, as designed)")
print(f"[camera] {len(ranger_notifications)} ranger notifications")
st = eng.stats
print(f"[camera] cost: ${st.total_cost:.4f} vs remote-only "
      f"${st.requests * eng.cost.remote_cost_per_request:.4f} "
      f"({1 - st.remote_fraction:.0%} saved); "
      f"mean latency {(st.mean_latency_s or 0.0) * 1e3:.0f}ms vs "
      f"{eng.cost.remote_latency_s * 1e3:.0f}ms remote-only")

"""Checkpointing: msgpack-serialised params/opt-state pytrees (no orbax).

Leaves are stored as (dtype, shape, raw bytes); the tree structure as
nested dicts/lists. Deterministic, dependency-light, restartable.
"""

from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _pack_leaf(x) -> dict:
    a = np.asarray(jax.device_get(x))
    if a.dtype == jnp.bfloat16:
        return {"dtype": "bfloat16", "shape": list(a.shape),
                "data": a.view(np.uint16).tobytes()}
    return {"dtype": str(a.dtype), "shape": list(a.shape),
            "data": a.tobytes()}


def _unpack_leaf(d: dict):
    if d["dtype"] == "bfloat16":
        a = np.frombuffer(d["data"], np.uint16).reshape(d["shape"])
        return jnp.asarray(a.view(jnp.bfloat16))
    return jnp.asarray(np.frombuffer(d["data"], d["dtype"])
                       .reshape(d["shape"]))


def save_checkpoint(path: str, tree: Any, step: int = 0) -> None:
    leaves, treedef = jax.tree.flatten(tree)
    payload = {"step": step,
               "treedef": str(treedef),
               "leaves": [_pack_leaf(x) for x in leaves]}
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)  # atomic


def load_checkpoint(path: str, like: Any) -> tuple[Any, int]:
    """Restore into the structure of `like` (shape/dtype verified)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves, treedef = jax.tree.flatten(like)
    restored = [_unpack_leaf(d) for d in payload["leaves"]]
    assert len(restored) == len(leaves), "checkpoint/tree leaf mismatch"
    for r, l in zip(restored, leaves):
        assert r.shape == l.shape, (r.shape, l.shape)
    return treedef.unflatten(restored), payload["step"]

"""Training substrate: optimizer, loop, checkpointing."""

from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.loop import make_train_step, train_loop
from repro.train.optimizer import (AdamWConfig, adamw_update, init_opt_state,
                                   lr_schedule)

__all__ = ["AdamWConfig", "adamw_update", "init_opt_state", "lr_schedule",
           "make_train_step", "train_loop", "save_checkpoint",
           "load_checkpoint"]

"""Training step builder + host loop.

`make_train_step(cfg, opt_cfg)` returns the pure (params, opt_state, batch)
-> (params, opt_state, metrics) function that launch/train.py jits with
mesh shardings — the same function the multi-pod dry-run lowers.
"""

from __future__ import annotations

import time
from typing import Callable

import jax

from repro.configs.base import ModelConfig
from repro.models import loss_fn
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    remat: bool = True) -> Callable:
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, remat=remat), has_aux=True
        )(params)
        params, opt_state, stats = adamw_update(opt_cfg, params, grads,
                                                opt_state)
        metrics = dict(metrics, loss=loss, **stats)
        return params, opt_state, metrics

    return train_step


def train_loop(cfg: ModelConfig, params, batches, opt_cfg: AdamWConfig,
               steps: int, log_every: int = 10, jit: bool = True,
               callback: Callable[[int, dict], None] | None = None):
    """Single-host training loop (examples / smoke tests)."""
    step_fn = make_train_step(cfg, opt_cfg)
    if jit:
        step_fn = jax.jit(step_fn)
    opt_state = init_opt_state(params)
    it = iter(batches)
    history = []
    t0 = time.perf_counter()
    for i in range(steps):
        params, opt_state, metrics = step_fn(params, opt_state, next(it))
        if (i + 1) % log_every == 0 or i == 0:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i + 1
            m["elapsed_s"] = time.perf_counter() - t0
            history.append(m)
            if callback:
                callback(i + 1, m)
    return params, opt_state, history

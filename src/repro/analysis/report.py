"""Render the dry-run matrix JSONL into EXPERIMENTS.md §Dry-run/§Roofline
markdown tables.

    PYTHONPATH=src python -m repro.analysis.report results/dryrun_matrix.jsonl
"""

from __future__ import annotations

import json
import sys


def fmt_bytes(n) -> str:
    if n is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"


def fmt_s(x) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.0f}us"
    if x < 1:
        return f"{x * 1e3:.1f}ms"
    return f"{x:.2f}s"


def dryrun_table(rows) -> str:
    out = ["| arch | shape | mesh | status | compile | args/chip | "
           "bottleneck |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "ok":
            mem = r.get("memory", {})
            rf = r.get("roofline", {})
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                f"{r.get('compile_s', '-')}s | "
                f"{fmt_bytes(mem.get('argument_size_in_bytes'))} | "
                f"{rf.get('bottleneck', '-')} |")
        elif r["status"] == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"skip | - | - | {r['reason']} |")
        else:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"FAIL | - | - | - |")
    return "\n".join(out)


def roofline_table(rows) -> str:
    out = ["| arch | shape | compute | memory | collective | bottleneck | "
           "MODEL_FLOPS | useful |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok" or r["mesh"] != "single":
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rf['compute_s'])} | "
            f"{fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} | "
            f"**{rf['bottleneck']}** | {rf['model_flops']:.2e} | "
            f"{rf['useful_ratio']:.2f} |")
    return "\n".join(out)


def interesting_pairs(rows) -> list[dict]:
    """The three hillclimb candidates: worst useful-ratio (roofline
    fraction), most collective-bound, most paper-representative
    (the decode shape of the biggest remote-tier model)."""
    ok = [r for r in rows
          if r["status"] == "ok" and r["mesh"] == "single"]
    worst = min(ok, key=lambda r: r["roofline"]["useful_ratio"]
                if r["roofline"]["useful_ratio"] == r["roofline"]
                ["useful_ratio"] else 9e9)
    coll = max(ok, key=lambda r: (r["roofline"]["collective_s"]
                                  / max(max(r["roofline"]["compute_s"],
                                            r["roofline"]["memory_s"]),
                                        1e-12)))
    return [worst, coll]


def main(path: str) -> None:
    rows = [json.loads(l) for l in open(path)]
    n_ok = sum(r["status"] == "ok" for r in rows)
    n_skip = sum(r["status"] == "skip" for r in rows)
    print(f"## §Dry-run ({n_ok} compiled, {n_skip} principled skips, "
          f"{sum(r['status'] == 'fail' for r in rows)} failures)\n")
    print(dryrun_table(rows))
    print("\n## §Roofline (single-pod 16x16 = 256 chips)\n")
    print(roofline_table(rows))


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else
         "results/dryrun_matrix.jsonl")

"""Roofline terms from the compiled dry-run artifact (assignment §Roofline).

This container is CPU-only (TPU v5e is the TARGET, not the runtime), so the
three terms are *derived* from the compiled module rather than measured:

    compute term    = HLO_FLOPs / (chips * peak FLOP/s)
    memory term     = HLO_bytes / (chips * HBM bandwidth)
    collective term = collective bytes / (chips * ICI link bandwidth)

Sources: ``compiled.cost_analysis()`` for FLOPs/bytes;
``compiled.as_text()`` (the post-SPMD, per-device module) for collective
operand bytes — all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.  Shapes in the partitioned module are PER-DEVICE, so
cost_analysis flops/bytes and the collective tally are per-chip; dividing
the global quantity by ``chips`` (the assignment formula) is equivalent to
using the per-chip numbers directly, which is what we do.

MODEL_FLOPS uses the 6·N·D rule (6·N_active·D for MoE; 2·N·D forward-only
for prefill/decode) so the "useful compute" ratio catches remat/redundancy
waste.
"""

from __future__ import annotations

import re
from dataclasses import dataclass

import jax
import numpy as np

# ---- TPU v5e hardware constants (assignment) -----------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
FP32_PENALTY = 4.0           # fp32 dots run at ~1/4 the bf16 MXU rate

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _line_collective(stripped: str) -> tuple[str, int] | None:
    """(kind, bytes) for one HLO instruction line, else None.

    Sums the OPERAND shapes when the printer inlines them; otherwise falls
    back to the result shape(s) (which lie inside the match span,
    "= f32[..] all-reduce(")."""
    m = re.search(r"=\s*[a-z0-9]+\[[0-9,]*\][^=]*?\s("
                  + "|".join(_COLLECTIVES) + r")[\.\(]", stripped)
    if not m:
        # tuple-result collectives: "= (f32[..], f32[..]) all-reduce("
        m = re.search(r"=\s*\(.*\)\s(" + "|".join(_COLLECTIVES)
                      + r")[\.\(]", stripped)
        if not m:
            return None
    kind = m.group(1)
    operand_shapes = _SHAPE_RE.findall(stripped[m.end():])
    if operand_shapes:
        b = sum(_shape_bytes(d, s) for d, s in operand_shapes)
    else:
        res = _SHAPE_RE.findall(stripped[m.start():m.end()])
        b = sum(_shape_bytes(d, s) for d, s in res)
    return kind, b


_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->")
_WHILE_RE = re.compile(
    r"while\(.*?\), condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_TRIP_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> tuple[dict, str | None]:
    """name -> list of instruction lines; also returns the ENTRY name."""
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        if not line:
            continue
        if not line.startswith(" "):
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
                continue
            cur = None
        elif cur is not None:
            comps[cur].append(line.strip())
    return comps, entry


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind operand bytes for ONE EXECUTION of a
    (per-device) HLO module.

    Collectives inside ``while`` bodies (lax.scan over layers, chunked CE,
    q-chunk scans) execute trip-count times but are printed once, so the
    tally walks the call graph: bytes(comp) = own + called comps +
    trip_count x while-body comps. Trip counts are read from the loop
    condition's comparison constant (a conservative max over its integer
    constants)."""
    comps, entry = _split_computations(hlo_text)
    if entry is None:                      # fall back: flat line scan
        out = {k: 0 for k in _COLLECTIVES}
        for line in hlo_text.splitlines():
            r = _line_collective(line.strip())
            if r:
                out[r[0]] += r[1]
        return out

    memo: dict[str, dict[str, float]] = {}

    def trip_count(cond_name: str) -> int:
        consts = [int(x) for x in _TRIP_RE.findall(
            "\n".join(comps.get(cond_name, [])))]
        return max(consts) if consts else 1

    def resolve(name: str, stack: tuple = ()) -> dict[str, float]:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return {k: 0.0 for k in _COLLECTIVES}
        total = {k: 0.0 for k in _COLLECTIVES}
        for line in comps[name]:
            r = _line_collective(line)
            if r:
                total[r[0]] += r[1]
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                n = trip_count(cond)
                sub = resolve(body, stack + (name,))
                for k in total:
                    total[k] += n * sub[k]
                continue
            for callee in _CALL_RE.findall(line):
                sub = resolve(callee, stack + (name,))
                for k in total:
                    total[k] += sub[k]
        memo[name] = total
        return total

    out = resolve(entry)
    return {k: int(v) for k, v in out.items()}


# --------------------------------------------------------------------------
# dtype-aware dot accounting (fp32 dots pay a ~4x MXU penalty on v5e)
# --------------------------------------------------------------------------

_INSTR_RE = re.compile(r"^%?([\w.\-]+)\s*=\s*([a-z0-9]+)\[([0-9,]*)\]")
_DOT_RE = re.compile(r"\b(dot|convolution)\(([^)]*)\)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def fp32_dot_flops(hlo_text: str) -> tuple[float, float]:
    """(fp32_dot_flops, total_dot_flops) for ONE execution of a per-device
    module — trip-count-aware like collective_bytes.

    A dot's flops = 2 * prod(result dims) * prod(lhs contracting dims);
    it is charged the fp32 penalty when its LHS operand is f32/f64 (the
    MXU runs bf16; fp32 matmuls decompose into multiple passes)."""
    comps, entry = _split_computations(hlo_text)
    if entry is None:
        comps, entry = {"__all__": hlo_text.splitlines()}, "__all__"

    # per-computation symbol tables: name -> (dtype, dims)
    tables: dict[str, dict[str, tuple[str, list[int]]]] = {}
    for cname, lines in comps.items():
        t = {}
        for line in lines:
            m = _INSTR_RE.match(line.strip())
            if m:
                dims = [int(x) for x in m.group(3).split(",") if x]
                t[m.group(1)] = (m.group(2), dims)
        tables[cname] = t

    memo: dict[str, tuple[float, float]] = {}

    def line_dot(cname: str, line: str) -> tuple[float, float]:
        m = _DOT_RE.search(line)
        if not m or "= " not in line:
            return 0.0, 0.0
        hdr = _INSTR_RE.match(line.strip())
        if not hdr:
            return 0.0, 0.0
        out_dims = [int(x) for x in hdr.group(3).split(",") if x]
        out_n = 1
        for d in out_dims:
            out_n *= d
        ops = _OPERAND_RE.findall(m.group(2))
        lhs = tables[cname].get(ops[0]) if ops else None
        k = 1
        cm = _CONTRACT_RE.search(line)
        if lhs and cm:
            for ci in (int(x) for x in cm.group(1).split(",") if x):
                if ci < len(lhs[1]):
                    k *= lhs[1][ci]
        flops = 2.0 * out_n * k
        is_fp32 = bool(lhs) and lhs[0] in ("f32", "f64")
        return (flops if is_fp32 else 0.0), flops

    def trip_count(cond_name: str) -> int:
        consts = [int(x) for x in _TRIP_RE.findall(
            "\n".join(comps.get(cond_name, [])))]
        return max(consts) if consts else 1

    def resolve(name: str, stack: tuple = ()) -> tuple[float, float]:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return 0.0, 0.0
        f32, tot = 0.0, 0.0
        for line in comps[name]:
            a, b = line_dot(name, line)
            f32 += a
            tot += b
            wm = _WHILE_RE.search(line)
            if wm:
                n = trip_count(wm.group(1))
                sa, sb = resolve(wm.group(2), stack + (name,))
                f32 += n * sa
                tot += n * sb
                continue
            for callee in _CALL_RE.findall(line):
                sa, sb = resolve(callee, stack + (name,))
                f32 += sa
                tot += sb
        memo[name] = (f32, tot)
        return memo[name]

    return resolve(entry)


def param_counts(cfg) -> tuple[int, int]:
    """(total params, active params). Active discounts routed experts by
    top_k/E (MoE); equal for dense archs."""
    from repro.models import transformer as T
    shapes = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    total = 0
    active = 0.0
    routed = {"w_gate", "w_up", "w_down"}

    def visit(path, leaf):
        nonlocal total, active
        names = [str(getattr(k, "key", "")) for k in path]
        n = int(np.prod(leaf.shape))
        total += n
        if cfg.is_moe and "moe" in names and names[-1] in routed:
            active += n * cfg.num_experts_per_tok / cfg.num_experts
        else:
            active += n

    jax.tree_util.tree_map_with_path(visit, shapes)
    return total, int(active)


def model_flops(cfg, shape) -> float:
    """6·N·D (train), 2·N·D (forward-only prefill / decode); N = active."""
    _, n_active = param_counts(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: ONE token per sequence
    return 2.0 * n_active * shape.global_batch


@dataclass
class RooflineTerms:
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    useful_ratio: float
    coll_breakdown: dict


def roofline_from_lowered(lowered, compiled, cfg, shape, mesh) -> dict:
    """The §Roofline record for one (arch, shape, mesh) combination."""
    chips = mesh.size
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    coll_total = float(sum(coll.values()))

    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = coll_total / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    mf = model_flops(cfg, shape)
    useful = mf / (flops * chips) if flops > 0 else float("nan")
    return {
        "chips": chips,
        "flops_per_chip": flops,
        "bytes_per_chip": byts,
        "coll_bytes_per_chip": coll_total,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": bottleneck,
        "model_flops": mf,
        "useful_ratio": useful,
        "coll_breakdown": {k: v for k, v in coll.items() if v},
    }


def bound_step_time(rec: dict) -> float:
    """Lower-bound step time: max of the three terms (no overlap model)."""
    return max(rec["compute_s"], rec["memory_s"], rec["collective_s"])


# --------------------------------------------------------------------------
# depth-extrapolated roofline (the accurate path)
# --------------------------------------------------------------------------
#
# cost_analysis() visits a `while` body ONCE, so the layer-stacked scan that
# keeps the official dry-run HLO compact makes FLOPs/bytes under-report by
# ~num_layers x. For the roofline we therefore lower REDUCED-depth variants
# with structural scans fully unrolled (models.scan_config) at two depths
# L1 < L2, fit cost(L) = a + b*L exactly, and extrapolate to the real
# depth. Dims, batch, sequence and mesh are the real ones — only the layer
# count is reduced, so the per-layer HLO (and its collectives) is the real
# per-layer program.

def _analysis_depths(cfg) -> tuple[int, int]:
    if cfg.shared_attn_period:                 # zamba: whole groups
        return cfg.shared_attn_period, 2 * cfg.shared_attn_period
    fd = cfg.first_dense_layers
    return fd + 2, fd + 4


def _measure(cfg, shape, mesh, *, fsdp: bool | None, remat: bool) -> dict:
    import dataclasses

    from repro.launch.specs import lower_step
    from repro.models import scan_config

    with scan_config.unrolled():
        lowered = lower_step(cfg, shape, mesh, fsdp=fsdp, remat=remat)
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    text = compiled.as_text()
    coll = collective_bytes(text)
    f32_dots, _ = fp32_dot_flops(text)
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "f32_dots": f32_dots,
            "coll": coll}


def roofline_extrapolated(cfg, shape, mesh, *, fsdp: bool | None = None,
                          remat: bool = True) -> dict:
    """§Roofline record via two reduced-depth unrolled lowerings."""
    import dataclasses

    l1, l2 = _analysis_depths(cfg)
    l_full = cfg.num_layers
    m1 = _measure(dataclasses.replace(cfg, num_layers=l1), shape, mesh,
                  fsdp=fsdp, remat=remat)
    m2 = _measure(dataclasses.replace(cfg, num_layers=l2), shape, mesh,
                  fsdp=fsdp, remat=remat)

    def extrap(v1: float, v2: float) -> float:
        b = (v2 - v1) / (l2 - l1)
        a = v1 - b * l1
        return max(a + b * l_full, v2)       # clamp: cost grows with depth

    flops = extrap(m1["flops"], m2["flops"])
    byts = extrap(m1["bytes"], m2["bytes"])
    f32_dots = extrap(m1["f32_dots"], m2["f32_dots"])
    coll = {k: extrap(m1["coll"].get(k, 0), m2["coll"].get(k, 0))
            for k in set(m1["coll"]) | set(m2["coll"])}
    coll_total = float(sum(coll.values()))

    # dtype-aware compute term: fp32 dots pay the MXU penalty
    compute_s = (flops + f32_dots * (FP32_PENALTY - 1.0)) / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = coll_total / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    chips = mesh.size
    return {
        "chips": chips,
        "method": f"unrolled-extrapolated(L={l1},{l2}->{l_full})",
        "f32_dot_flops_per_chip": f32_dots,
        "f32_dot_share": f32_dots / flops if flops else 0.0,
        "flops_per_chip": flops,
        "bytes_per_chip": byts,
        "coll_bytes_per_chip": coll_total,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": bottleneck,
        "model_flops": mf,
        "useful_ratio": mf / (flops * chips) if flops else float("nan"),
        "coll_breakdown": {k: int(v) for k, v in coll.items() if v},
    }

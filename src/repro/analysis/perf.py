"""Perf-iteration driver (§Perf): measure one (arch, shape) pair's roofline
terms under configurable knobs, for the hypothesis->change->measure loop.

    PYTHONPATH=src python -m repro.analysis.perf --arch deepseek-67b \
        --shape decode_32k [--no-fsdp] [--no-remat] [--json out.jsonl]

Must run in its own process (sets the 512-device XLA flag on import, like
dryrun.py).
"""

import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import json
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--fsdp", action="store_true",
                    help="force FSDP (default: the lower_step policy)")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--fp32-scores", action="store_true",
                    help="ablation: the pre-C1 fp32 attention-score path")
    ap.add_argument("--label", default="")
    ap.add_argument("--json", default="")
    args = ap.parse_args(argv)

    from repro.analysis.roofline import roofline_extrapolated
    from repro.configs import INPUT_SHAPES, get_config
    from repro.launch.mesh import make_production_mesh

    if args.fp32_scores:
        from repro.models.layers import set_scores_fp32
        set_scores_fp32(True)

    cfg = get_config(args.arch)
    shape = INPUT_SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    fsdp = True if args.fsdp else (False if args.no_fsdp else None)
    rec = roofline_extrapolated(cfg, shape, mesh, fsdp=fsdp,
                                remat=not args.no_remat)
    rec.update(arch=args.arch, shape=args.shape, label=args.label,
               fsdp=fsdp, remat=not args.no_remat)
    print(f"[perf] {args.arch} x {args.shape} "
          f"({args.label or 'baseline'}; fsdp={rec['fsdp']}):")
    print(f"  compute={rec['compute_s']:.4e}s "
          f"(fp32-dot share {rec['f32_dot_share']:.0%}) "
          f"memory={rec['memory_s']:.4e}s "
          f"collective={rec['collective_s']:.4e}s "
          f"-> {rec['bottleneck']}")
    print(f"  coll breakdown: {rec['coll_breakdown']}")
    print(f"  useful={rec['useful_ratio']:.3f}")
    if args.json:
        with open(args.json, "a") as f:
            f.write(json.dumps(rec) + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())

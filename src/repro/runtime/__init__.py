"""Adaptive cascade runtime — the control plane around the serving engine.

Composes with ``repro.serving.engine.CascadeEngine`` (see DESIGN.md):
  * calibration — offline (t_local, t_remote, k) selection on a Pareto sweep
  * controller  — online EMA/PID budget tracking + drift detection
  * transport   — fault-aware remote tiers (windows, retries, breakers) and
    the multi-remote registry/router (named backends, cost/latency-aware
    policies, breaker-driven failover)
  * cache       — content-keyed dedup of billed remote calls (entries
    remember which backend filled them, so hits attribute correctly)
  * observability — zero-dependency metrics registry, per-request trace
    spans and the structured event log (DESIGN.md §9)
"""

from repro.runtime.cache import (CacheStats, RemoteResponseCache,
                                 content_key, content_keys)
from repro.runtime.chaos import (CHAOS_KINDS, ChaosEpisode, ChaosFault,
                                 ChaosRemote, ChaosSchedule, ChaosStats,
                                 ChaosTimeout, VirtualClock)
from repro.runtime.observability import (EventLog, MetricsRegistry,
                                         Observability, TraceSink)
from repro.runtime.calibration import (EscalationPrior, JointOperatingPoint,
                                       OperatingPoint, calibrate,
                                       fit_escalation_prior,
                                       joint_pareto_frontier,
                                       pareto_frontier,
                                       select_joint_operating_point,
                                       select_operating_point,
                                       sweep_joint_operating_points,
                                       sweep_operating_points)
from repro.runtime.controller import (AdaptiveController, ControllerConfig,
                                      ControllerState,
                                      TieredBudgetController,
                                      population_stability_index)
from repro.runtime.hierarchy import (CascadeStage, StageStats, TieredCascade,
                                     build_stage_chain)
from repro.runtime.cluster import (CacheUpdate, ClusterBudgetConfig,
                                   ClusterBudgetController,
                                   ClusterBudgetState, ClusterHarness,
                                   ClusterReplica, ReplicaCacheView,
                                   SharedCacheStats, SharedResponseCache,
                                   cluster_billing)
from repro.runtime.transport import (ROUTE_POLICIES, CircuitBreaker,
                                     CircuitOpenError, RemoteBackend,
                                     RemoteCallError, RemoteRouter,
                                     RemoteTimeout, RemoteTransport,
                                     RouteConstraint, RouterStats,
                                     TransportConfig, TransportFuture,
                                     TransportStats)

__all__ = [
    "CHAOS_KINDS", "ROUTE_POLICIES", "AdaptiveController", "CacheStats",
    "CacheUpdate", "CascadeStage", "ChaosEpisode", "ChaosFault",
    "ChaosRemote", "ChaosSchedule", "ChaosStats", "ChaosTimeout",
    "CircuitBreaker", "CircuitOpenError", "ClusterBudgetConfig",
    "ClusterBudgetController", "ClusterBudgetState", "ClusterHarness",
    "ClusterReplica", "ControllerConfig", "ControllerState",
    "EscalationPrior", "EventLog", "JointOperatingPoint",
    "MetricsRegistry", "Observability", "OperatingPoint", "RemoteBackend",
    "RemoteCallError", "RemoteResponseCache", "RemoteRouter",
    "RemoteTimeout", "RemoteTransport", "ReplicaCacheView",
    "RouteConstraint", "RouterStats", "SharedCacheStats",
    "SharedResponseCache", "StageStats", "TieredBudgetController",
    "TieredCascade", "TraceSink", "TransportConfig", "TransportFuture",
    "TransportStats", "VirtualClock", "build_stage_chain", "calibrate",
    "cluster_billing", "content_key", "content_keys",
    "fit_escalation_prior", "joint_pareto_frontier", "pareto_frontier",
    "population_stability_index", "select_joint_operating_point",
    "select_operating_point", "sweep_joint_operating_points",
    "sweep_operating_points",
]

"""Adaptive cascade runtime — the control plane around the serving engine.

Composes with ``repro.serving.engine.CascadeEngine`` (see DESIGN.md):
  * calibration — offline (t_local, t_remote, k) selection on a Pareto sweep
  * controller  — online EMA/PID budget tracking + drift detection
  * transport   — fault-aware remote tier (windows, retries, breaker)
  * cache       — content-keyed dedup of billed remote calls
"""

from repro.runtime.cache import (CacheStats, RemoteResponseCache,
                                 content_key, content_keys)
from repro.runtime.calibration import (OperatingPoint, calibrate,
                                       pareto_frontier,
                                       select_operating_point,
                                       sweep_operating_points)
from repro.runtime.controller import (AdaptiveController, ControllerConfig,
                                      ControllerState,
                                      population_stability_index)
from repro.runtime.transport import (CircuitBreaker, CircuitOpenError,
                                     RemoteCallError, RemoteTimeout,
                                     RemoteTransport, TransportConfig,
                                     TransportFuture, TransportStats)

__all__ = [
    "AdaptiveController", "CacheStats", "CircuitBreaker", "CircuitOpenError",
    "ControllerConfig", "ControllerState", "OperatingPoint",
    "RemoteCallError", "RemoteResponseCache", "RemoteTimeout",
    "RemoteTransport", "TransportConfig", "TransportFuture",
    "TransportStats", "calibrate", "content_key", "content_keys",
    "pareto_frontier", "population_stability_index",
    "select_operating_point", "sweep_operating_points",
]

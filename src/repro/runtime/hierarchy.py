"""N-tier cascade hierarchy: device → edge → cloud stages (DESIGN.md §13).

The paper's BiSupervised architecture is a two-level cascade — one local
model behind a 1st-level supervisor, one remote behind a 2nd. The DDNN
line of work (Teerapittayanon et al., PAPERS.md) generalizes exactly this
shape into a *hierarchy* of exit points: a cheap tier answers the rows
its supervisor trusts and escalates the residual to the next tier, each
hop with its own supervisor/threshold pair, until the last hop — whose
supervisor is the paper's 2nd-level supervisor, deciding trust vs the
raise-exception/fallback path.

``CascadeStage`` is a ``RemoteBackend`` that is itself a supervised
predictor: it wraps a model-apply (through its own ``RemoteTransport`` —
retries, breaker, billing) or an existing backend's transport, owns a
supervisor score function from ``core.supervisors``, a threshold, and an
optional ``next_stage`` reference. Because it *is* a backend, the
existing ``RemoteRouter``/``CascadeEngine`` machinery routes to it
unchanged; because it may chain, a single routed "backend" can hide an
arbitrary device→edge→cloud ladder behind the engine's 2-level shape.

The bitwise 2-tier identity argument: a **terminal** stage (no
``next_stage``) never intercepts anything — ``call``/``submit`` delegate
straight to ``RemoteBackend`` and ``take_detail`` returns ``None`` — so
an engine routed at a terminal stage executes byte-for-byte the code
path it executes for a plain backend. Only a *chained* stage produces a
per-call ``StageDetail`` (which hop answered each row, at what
confidence, billed what), and only then does the engine switch to
per-stage attribution. The degenerate 2-stage configuration therefore
reproduces today's engine path exactly (predictions, billing, controller
observations) — the property ``hierarchy_bench`` gates in CI.

``TieredCascade`` drives a full stage chain standalone (calibration,
benches, and the collapse/property tests): stage 0 is the device tier,
the last stage's threshold is applied as the trust-vs-REJECTED gate.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.supervisors import SOFTMAX_SUPERVISORS

from .transport import RemoteBackend, TransportConfig

__all__ = [
    "CascadeStage",
    "StageStats",
    "TieredCascade",
    "build_stage_chain",
]


@dataclass
class StageStats:
    """Per-stage slice of the hierarchy accounting, over rows that
    *reached* the stage. ``requests = answered + escalated + failures``
    and ``cost`` bills every row the hop's own transport served —
    answered *or* escalated — matching the joint-calibration cost model
    (`TransportStats` on the stage's own transport still counts
    windows/retries underneath)."""
    requests: int = 0       # rows that reached this stage
    answered: int = 0       # rows this stage's supervisor trusted
    escalated: int = 0      # rows handed to the next hop
    failures: int = 0       # rows lost here with no next hop to try
    cost: float = 0.0       # realised $ for rows this hop's model served


def _tree_rows(batch: Any) -> int:
    return jax.tree.leaves(batch)[0].shape[0]


def _tree_take(batch: Any, mask_or_idx: np.ndarray) -> Any:
    return jax.tree.map(lambda a: a[mask_or_idx], batch)


def _resolve_supervisor(supervisor) -> Callable:
    return (supervisor if callable(supervisor)
            else SOFTMAX_SUPERVISORS[supervisor])


class CascadeStage(RemoteBackend):
    """One hop of an N-tier cascade, presented as a ``RemoteBackend``.

    Construct around a model-apply (it gets its own transport — per-hop
    retries, breaker, stats) or around an existing ``RemoteBackend``
    (``backend=...`` — the stage shares its transport, so breaker state
    and ``TransportStats`` stay one per physical tier)::

        cloud = CascadeStage("cloud", cloud_apply, threshold=0.9,
                             cost_per_request=0.0048)
        edge  = CascadeStage("edge", edge_apply, threshold=0.7,
                             cost_per_request=0.001, next_stage=cloud)

    ``threshold`` gates this stage's own answers when the stage is *not*
    the last word: a chained stage answers the rows its supervisor
    scores above the threshold and escalates the rest. A terminal stage
    (``next_stage=None``) applies NO gate of its own inside the engine —
    the engine's 2nd-level supervisor (``t_remote``) is the trust gate
    for whatever comes back, which is exactly what keeps the degenerate
    2-stage configuration bitwise-identical to a plain backend. Driven
    standalone through ``TieredCascade``, the last stage's threshold is
    applied by the cascade as the trust-vs-REJECTED gate.

    An optional per-hop ``controller`` (an ``AdaptiveController``) makes
    the threshold live: when attached and warmed up, its ``t_local``
    replaces the static threshold and every chained call feeds it one
    observation — the per-tier budget loop of
    ``controller.TieredBudgetController``.
    """

    def __init__(self, name: str, apply_fn: Callable | None = None,
                 config: TransportConfig = TransportConfig(), *,
                 backend: RemoteBackend | None = None,
                 supervisor="max_softmax", threshold: float = 0.0,
                 next_stage: "CascadeStage | None" = None,
                 cost_per_request: float | None = None,
                 latency_s: float | None = None,
                 controller=None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep):
        if next_stage is not None and not isinstance(next_stage,
                                                     CascadeStage):
            raise TypeError("next_stage must be a CascadeStage (wrap "
                            "plain backends so every hop has a "
                            "supervisor)")
        if backend is not None:
            if cost_per_request is None:
                cost_per_request = backend.cost_per_request
            if latency_s is None:
                latency_s = backend.latency_s
            super().__init__(name, transport=backend.transport,
                             cost_per_request=cost_per_request,
                             latency_s=latency_s)
        else:
            super().__init__(name, apply_fn, config,
                             cost_per_request=cost_per_request,
                             latency_s=latency_s, clock=clock, sleep=sleep)
        self.supervisor = supervisor
        self._score = _resolve_supervisor(supervisor)
        self.threshold = float(threshold)
        self.next = next_stage
        self.controller = controller
        self.stage_stats = StageStats()
        self._stage_lock = threading.Lock()
        self._details: dict[Any, dict] = {}
        self._chain_pool: ThreadPoolExecutor | None = None

    # -- per-call detail handoff (engine integration) -------------------
    def take_detail(self, tag) -> dict | None:
        """Pop the per-row stage attribution recorded by the last chained
        ``call`` under ``tag``. ``None`` for terminal stages (which never
        record one) — the engine's signal to stay on the plain-backend
        accounting path."""
        with self._stage_lock:
            return self._details.pop(tag, None)

    # -- chain walk -----------------------------------------------------
    def effective_threshold(self) -> float:
        if self.controller is not None and self.controller.t_local is not None:
            return float(self.controller.t_local)
        return self.threshold

    def score_rows(self, logits: np.ndarray, ok: np.ndarray) -> np.ndarray:
        """Supervisor confidence per row; failed rows score -inf (a lost
        row can never be trusted — Algorithm 1's exception path)."""
        conf = np.full(len(ok), -np.inf, np.float64)
        if ok.any():
            conf[ok] = np.asarray(
                self._score(jnp.asarray(np.asarray(logits)[ok])),
                np.float64)
        return conf

    def call_scored(self, batch: Any, tag=None
                    ) -> tuple[np.ndarray, np.ndarray, dict]:
        """Run the chain from this hop down and return
        ``(logits, ok, detail)`` where ``detail`` carries, per row: the
        answering stage's name, its supervisor confidence, and the row's
        cumulative price/latency over every hop that served it (``nan``
        = some serving hop is unpriced -> resolve the row to the
        engine's ``CostModel`` default). Rows failed at every reachable
        hop come back ``ok=False`` with the deepest attempted stage's
        name."""
        n = _tree_rows(batch)
        logits, ok = RemoteBackend.call(self, batch, tag)
        logits = np.asarray(logits)
        ok = np.asarray(ok, bool)
        conf = self.score_rows(logits, ok)
        detail = {
            "stage": np.full(n, self.name, object),
            "conf": conf.copy(),
            "cost": np.full(n, np.nan if self.cost_per_request is None
                            else float(self.cost_per_request), np.float64),
            "latency": np.full(n, np.nan if self.latency_s is None
                               else float(self.latency_s), np.float64),
        }
        if self.next is None:
            self._record(n, answered=int(ok.sum()),
                         escalated=0, failures=int((~ok).sum()),
                         served=int(ok.sum()))
            self._observe(conf, escalated=0, requests=n)
            return logits, ok, detail

        threshold = self.effective_threshold()
        trusted = ok & (conf > threshold)
        resid = ~trusted
        n_resid = int(resid.sum())
        self._record(n, answered=n - n_resid, escalated=n_resid,
                     failures=0, served=int(ok.sum()))
        self._observe(conf, escalated=n_resid, requests=n)
        if n_resid:
            sub = _tree_take(batch, resid)
            nl, nok, ndet = self.next.call_scored(sub, tag)
            idx = np.flatnonzero(resid)
            if nl.shape[1:] != logits.shape[1:]:
                raise ValueError(
                    f"stage {self.next.name!r} logits shape {nl.shape[1:]}"
                    f" != stage {self.name!r} {logits.shape[1:]} — tiers "
                    "must share one label space")
            # rows this hop's own transport served before escalating keep
            # paying this hop on top of whatever deeper hops bill — the
            # runtime analogue of the joint-calibration cost model, where
            # every stage a row *reaches* charges its stage cost. An
            # unpriced hop (cost_per_request=None) poisons the sum to
            # nan, which the engine resolves to its CostModel default.
            served_here = ok[idx]
            own_c = (np.nan if self.cost_per_request is None
                     else float(self.cost_per_request))
            own_l = (np.nan if self.latency_s is None
                     else float(self.latency_s))
            logits = logits.copy()
            logits[idx] = nl
            ok = trusted.copy()
            ok[idx] = nok
            detail["stage"][idx] = ndet["stage"]
            detail["conf"][idx] = ndet["conf"]
            detail["cost"][idx] = (ndet["cost"]
                                   + np.where(served_here, own_c, 0.0))
            detail["latency"][idx] = (ndet["latency"]
                                      + np.where(served_here, own_l, 0.0))
        else:
            ok = trusted
        return logits, ok, detail

    # -- RemoteBackend surface ------------------------------------------
    def call(self, batch: Any, tag=None):
        if self.next is None:
            # terminal: pure delegation — the degenerate 2-stage config
            # executes the plain-backend path byte for byte
            return RemoteBackend.call(self, batch, tag)
        logits, ok, detail = self.call_scored(batch, tag)
        with self._stage_lock:
            self._details[tag] = detail
        return logits, ok

    def submit(self, batch: Any, tag=None):
        if self.next is None:
            return RemoteBackend.submit(self, batch, tag)
        # the chain walk (own hop -> supervisor -> residual downstream)
        # runs on a stage-owned pool thread; per-hop transport semantics
        # are untouched because the walk goes through each hop's own
        # call(). concurrent.futures.Future already speaks the
        # TransportFuture drain API (done/result/add_done_callback).
        if self._chain_pool is None:
            self._chain_pool = ThreadPoolExecutor(
                max_workers=self.config.max_concurrent,
                thread_name_prefix=f"stage-{self.name}")
        return self._chain_pool.submit(self.call, batch, tag)

    def poll(self, future) -> bool:
        return future.done()

    def shutdown(self, wait: bool = True) -> None:
        if self._chain_pool is not None:
            self._chain_pool.shutdown(wait=wait)
            self._chain_pool = None
        RemoteBackend.shutdown(self, wait=wait)
        if self.next is not None:
            self.next.shutdown(wait=wait)

    # -- internal -------------------------------------------------------
    def _record(self, requests, *, answered, escalated, failures,
                served) -> None:
        with self._stage_lock:
            st = self.stage_stats
            st.requests += requests
            st.answered += answered
            st.escalated += escalated
            st.failures += failures
            if self.cost_per_request is not None:
                st.cost += served * self.cost_per_request

    def _observe(self, conf, *, escalated: int, requests: int) -> None:
        if self.controller is not None:
            self.controller.observe(conf, escalated, requests)

    def chain(self) -> "list[CascadeStage]":
        """This stage and everything below it, outermost first."""
        out, s = [], self
        while s is not None:
            out.append(s)
            s = s.next
        return out

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        nxt = self.next.name if self.next is not None else None
        return (f"CascadeStage({self.name!r}, threshold={self.threshold},"
                f" cost={self.cost_per_request}, next={nxt!r})")


def build_stage_chain(specs, *, clock=time.monotonic, sleep=time.sleep,
                      config: TransportConfig = TransportConfig()
                      ) -> CascadeStage:
    """Chain stage specs (outermost first) into one routed backend.

    Each spec is a mapping with ``name`` and ``apply`` (or ``backend``),
    plus optional ``supervisor``/``threshold``/``cost_per_request``/
    ``latency_s``/``config``. Returns the head stage."""
    if not specs:
        raise ValueError("need at least one stage spec")
    head: CascadeStage | None = None
    for spec in reversed(list(specs)):
        spec = dict(spec)
        name = spec.pop("name")
        apply_fn = spec.pop("apply", None)
        backend = spec.pop("backend", None)
        head = CascadeStage(name, apply_fn,
                            spec.pop("config", config),
                            backend=backend,
                            supervisor=spec.pop("supervisor",
                                                "max_softmax"),
                            threshold=spec.pop("threshold", 0.0),
                            cost_per_request=spec.pop("cost_per_request",
                                                      None),
                            latency_s=spec.pop("latency_s", None),
                            controller=spec.pop("controller", None),
                            next_stage=head, clock=clock, sleep=sleep)
        if spec:
            raise ValueError(f"unknown stage spec keys {sorted(spec)}")
    return head


@dataclass
class TieredResult:
    """Standalone cascade output for one batch (row-aligned arrays)."""
    prediction: np.ndarray      # final argmax (answering stage's logits)
    stage: np.ndarray           # answering stage name per row (object)
    conf: np.ndarray            # answering stage's supervisor confidence
    accepted: np.ndarray        # False = REJECTED -> fallback (last gate)
    cost: np.ndarray            # realised $ per row
    stage_index: np.ndarray     # answering stage's position in the chain


class TieredCascade:
    """An ordered device → edge → cloud chain driven standalone.

    Wraps a ``CascadeStage`` head (stage 0 is the *device* tier — in the
    engine path that tier is the engine's local model, here it is an
    explicit stage) and applies the last stage's threshold as the
    trust-vs-REJECTED gate, i.e. the paper's 2nd-level supervisor. With
    every non-final threshold at ``+inf`` the cascade degenerates to
    always-escalate: each hop trusts nothing and the last stage answers
    everything (the collapse property the tests pin down).
    """

    def __init__(self, head: CascadeStage, *, default_cost: float = 0.0):
        self.head = head
        self.stages = head.chain()
        self.default_cost = float(default_cost)
        self._tag = 0

    @property
    def last(self) -> CascadeStage:
        return self.stages[-1]

    def serve(self, batch: Any) -> TieredResult:
        self._tag += 1
        logits, ok, detail = self.head.call_scored(batch, self._tag)
        pred = np.asarray(jnp.argmax(jnp.asarray(logits), -1))
        names = [s.name for s in self.stages]
        index = {n: i for i, n in enumerate(names)}
        stage_idx = np.array([index[s] for s in detail["stage"]], np.int64)
        last_rows = detail["stage"] == self.last.name
        gate = self.last.effective_threshold()
        accepted = ok & (~last_rows | (detail["conf"] > gate))
        cost = np.where(np.isnan(detail["cost"]), self.default_cost,
                        detail["cost"])
        cost = np.where(accepted | last_rows, cost, 0.0)
        cost[~ok] = 0.0                       # lost rows bill nothing
        return TieredResult(prediction=pred, stage=detail["stage"],
                            conf=detail["conf"], accepted=accepted,
                            cost=cost, stage_index=stage_idx)

    __call__ = serve

    def stats(self) -> dict[str, StageStats]:
        return {s.name: s.stage_stats for s in self.stages}

    def shutdown(self, wait: bool = True) -> None:
        self.head.shutdown(wait=wait)

"""Offline cascade calibration: cost-vs-accuracy Pareto sweeps
(runtime control plane, DESIGN.md §1).

Given a labelled validation set scored by both tiers — 1st-level
supervisor confidences + correctness for the local model, 2nd-level
confidences + correctness for the remote model — sweep the
``(t_local, t_remote)`` grid with exact Algorithm-1 semantics
(``core.cascade.bisupervised_batch``, paper RQ1/RQ2 style), build the
Pareto frontier over (remote fraction, accepted accuracy, rejection rate),
and select the operating point for a target remote-call budget. The
selected point is returned with the serving-mode capacity
``k = ceil(rho * B)`` so it can be handed straight to the engine, and is
also the recommended warm start for the online ``AdaptiveController``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.core.cascade import escalation_capacity


@dataclass(frozen=True)
class OperatingPoint:
    t_local: float
    t_remote: float
    remote_fraction: float    # realised escalation rate on the val set
    rejection_rate: float     # REJECTED fraction (fallback path)
    accuracy: float           # accuracy over accepted inputs
    system_accuracy: float    # accuracy over ALL inputs (rejected = wrong)
    cost_per_request: float

    def capacity(self, batch_size: int) -> int:
        """Serving-mode escalation cap for this point (DESIGN.md §2)."""
        return escalation_capacity(batch_size, max(self.remote_fraction,
                                                   1e-6))


def _quantile_grid(values: np.ndarray, n: int) -> np.ndarray:
    """Candidate thresholds at n evenly spaced quantiles, plus the open
    ends (never/always escalate or reject)."""
    v = np.asarray(values, np.float64)
    qs = np.quantile(v, np.linspace(0.0, 1.0, n))
    return np.unique(np.concatenate(
        [[v.min() - 1e-9], qs, [v.max() + 1e-9]]))


def sweep_operating_points(local_conf: np.ndarray, local_correct: np.ndarray,
                           remote_conf: np.ndarray, remote_correct: np.ndarray,
                           *, grid: int = 33,
                           remote_cost_per_request: float = 0.0048
                           ) -> list[OperatingPoint]:
    """Exhaustive (t_local, t_remote) sweep with Algorithm-1 semantics.

    All arrays are [n] over the validation set; correctness is 0/1.
    Vectorised: for each t_local the escalated set is fixed, and every
    t_remote candidate only re-partitions it into REMOTE vs REJECTED.
    """
    lc = np.asarray(local_conf, np.float64)
    lok = np.asarray(local_correct, bool)
    rc = np.asarray(remote_conf, np.float64)
    rok = np.asarray(remote_correct, bool)
    n = lc.shape[0]

    points: list[OperatingPoint] = []
    for tl in _quantile_grid(lc, grid):
        use_local = lc > tl
        esc = ~use_local
        n_esc = int(esc.sum())
        local_hits = int(lok[use_local].sum())
        for tr in _quantile_grid(rc[esc] if n_esc else rc, grid):
            remote_ok = esc & (rc > tr)
            accepted = use_local | remote_ok
            n_acc = int(accepted.sum())
            hits = local_hits + int(rok[remote_ok].sum())
            points.append(OperatingPoint(
                t_local=float(tl), t_remote=float(tr),
                remote_fraction=n_esc / n,
                rejection_rate=1.0 - n_acc / n,
                accuracy=hits / max(n_acc, 1),
                system_accuracy=hits / n,
                cost_per_request=n_esc / n * remote_cost_per_request))
    return points


def pareto_frontier(points: Sequence[OperatingPoint]
                    ) -> list[OperatingPoint]:
    """Non-dominated subset: maximise accepted accuracy, minimise remote
    fraction and rejection rate. Sorted by ascending remote fraction."""
    # distinct threshold pairs can land on identical metrics; keep one
    seen: set[tuple] = set()
    pts = []
    for p in sorted(points, key=lambda p: (p.remote_fraction,
                                           p.rejection_rate, -p.accuracy)):
        m = (p.remote_fraction, p.rejection_rate, p.accuracy)
        if m not in seen:
            seen.add(m)
            pts.append(p)
    front: list[OperatingPoint] = []
    for p in pts:
        dominated = any(q.accuracy >= p.accuracy
                        and q.remote_fraction <= p.remote_fraction
                        and q.rejection_rate <= p.rejection_rate
                        and (q.accuracy > p.accuracy
                             or q.remote_fraction < p.remote_fraction
                             or q.rejection_rate < p.rejection_rate)
                        for q in pts)
        if not dominated:
            front.append(p)
    return front


def select_operating_point(points: Sequence[OperatingPoint],
                           budget: float | None = None, *,
                           cost_budget: float | None = None,
                           max_rejection_rate: float | None = None
                           ) -> OperatingPoint:
    """Best accepted accuracy subject to a budget (and an optional
    rejection-rate ceiling); ties broken toward cheaper points. The budget
    is either a remote *fraction* (``budget``) or a **dollar** ceiling on
    modelled $ per request (``cost_budget`` — per-backend pricing enters
    via ``remote_cost_per_request`` at sweep time, e.g. the router's
    ``expected_cost_per_escalation``). Falls back to the cheapest point if
    the budget excludes everything."""
    if (budget is None) == (cost_budget is None):
        raise ValueError("give exactly one of budget / cost_budget")
    if cost_budget is not None:
        feasible = [p for p in points
                    if p.cost_per_request <= cost_budget + 1e-12]
    else:
        feasible = [p for p in points if p.remote_fraction <= budget + 1e-12]
    if max_rejection_rate is not None:
        hard = [p for p in feasible
                if p.rejection_rate <= max_rejection_rate + 1e-12]
        feasible = hard or feasible
    if not feasible:
        feasible = [min(points, key=lambda p: p.remote_fraction)]
    return max(feasible, key=lambda p: (p.accuracy, -p.remote_fraction,
                                        -p.rejection_rate))


# ---------------------------------------------------------------------------
# Joint (t_1, ..., t_n) calibration for N-tier hierarchies (DESIGN.md §13)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class JointOperatingPoint:
    """One point on the joint (t_1, ..., t_n) operating surface of an
    N-tier cascade. ``stage_fractions[i]`` is the fraction of rows that
    *reach* stage i (``[0] == 1.0``); ``cost_per_request`` prices each
    reach at that stage's per-row cost. The 2-stage case carries exactly
    the ``OperatingPoint`` metrics (the exact-reproduction property the
    tests pin down)."""
    thresholds: tuple
    stage_fractions: tuple
    rejection_rate: float
    accuracy: float           # accuracy over accepted inputs
    system_accuracy: float    # accuracy over ALL inputs (rejected = wrong)
    cost_per_request: float

    @property
    def remote_fraction(self) -> float:
        """Fraction leaving the device tier (2-tier compatibility)."""
        return self.stage_fractions[1] if len(self.stage_fractions) > 1 \
            else 0.0

    def capacity(self, batch_size: int) -> int:
        return escalation_capacity(batch_size, max(self.remote_fraction,
                                                   1e-6))


def _stage_grids(grid, n_stages: int) -> list[int]:
    if isinstance(grid, int):
        return [grid] * n_stages
    grids = list(grid)
    if len(grids) != n_stages:
        raise ValueError(f"grid must be an int or one per stage "
                         f"({n_stages}), got {len(grids)}")
    return grids


def sweep_joint_operating_points(confs, corrects, *, grid=17,
                                 stage_costs=None, prune: bool = True
                                 ) -> list[JointOperatingPoint]:
    """Exhaustive sweep of the joint (t_1, ..., t_n) threshold surface.

    ``confs``/``corrects`` are n_stages row-aligned arrays over the
    validation set: stage i's supervisor confidence and 0/1 correctness
    for every row *as if* it reached stage i. ``stage_costs[i]`` is the
    per-row price of reaching stage i (``[0]`` is the device tier,
    conventionally 0). Semantics per stage mirror the 2-level sweep
    exactly — strict ``>`` comparisons, quantile grids conditioned on
    the rows actually reaching the stage — so with ``n_stages == 2``
    this reproduces ``sweep_operating_points`` point for point (tested).

    ``grid`` is an int (same per stage) or one int per stage. With
    ``prune=True`` an *intermediate* stage that nothing reaches stops
    branching (every deeper threshold choice is metrically identical);
    the final stage always enumerates its full grid, matching the
    2-level sweep's behaviour on empty escalation sets.
    """
    confs = [np.asarray(c, np.float64) for c in confs]
    oks = [np.asarray(c, bool) for c in corrects]
    n_stages = len(confs)
    if n_stages < 2:
        raise ValueError("need at least 2 stages")
    if len(oks) != n_stages:
        raise ValueError("confs and corrects must align per stage")
    n = confs[0].shape[0]
    grids = _stage_grids(grid, n_stages)
    if stage_costs is None:
        stage_costs = [0.0] * (n_stages - 1) + [0.0048]
    stage_costs = [float(c) for c in stage_costs]
    if len(stage_costs) != n_stages:
        raise ValueError("stage_costs must give one price per stage")

    points: list[JointOperatingPoint] = []

    def rec(i, reach, thresholds, fracs, hits, answered_count, cost):
        ci = confs[i]
        n_reach = int(reach.sum())
        cand = _quantile_grid(ci[reach] if n_reach else ci, grids[i])
        if i == n_stages - 1:
            for t in cand:
                ok_rows = reach & (ci > t)
                n_acc = answered_count + int(ok_rows.sum())
                h = hits + int(oks[i][ok_rows].sum())
                points.append(JointOperatingPoint(
                    thresholds=(*thresholds, float(t)),
                    stage_fractions=(*fracs,),
                    rejection_rate=1.0 - n_acc / n,
                    accuracy=h / max(n_acc, 1),
                    system_accuracy=h / n,
                    cost_per_request=cost))
            return
        if prune and n_reach == 0 and i > 0:
            cand = cand[:1]        # every branch below is identical
        for t in cand:
            ans = reach & (ci > t)
            resid = reach & ~ans
            n_resid = int(resid.sum())
            rec(i + 1, resid, (*thresholds, float(t)),
                (*fracs, n_resid / n),
                hits + int(oks[i][ans].sum()),
                answered_count + int(ans.sum()),
                cost + n_resid / n * stage_costs[i + 1])
        return

    rec(0, np.ones(n, bool), (), (1.0,), 0, 0, 0.0)
    return points


def joint_pareto_frontier(points: "Sequence[JointOperatingPoint]"
                          ) -> list[JointOperatingPoint]:
    """Non-dominated subset over ($/request, system accuracy), sorted by
    ascending cost. System accuracy folds the rejection rate in (a
    rejected row is a wrong row), so the frontier is strictly monotone:
    each successive point costs strictly more and answers strictly more
    of the workload correctly."""
    front: list[JointOperatingPoint] = []
    best = -1.0
    for p in sorted(points, key=lambda p: (p.cost_per_request,
                                           -p.system_accuracy,
                                           p.rejection_rate)):
        if p.system_accuracy > best:
            best = p.system_accuracy
            front.append(p)
    return front


def select_joint_operating_point(points, *, budget: float | None = None,
                                 cost_budget: float | None = None,
                                 max_rejection_rate: float | None = None
                                 ) -> JointOperatingPoint:
    """Best system accuracy under a budget: either a fraction budget on
    rows leaving the device tier (``budget``) or a dollar ceiling on the
    per-stage-priced $/request (``cost_budget``). Mirrors
    ``select_operating_point``: the rejection ceiling is soft, and an
    infeasible budget falls back to the cheapest point."""
    if (budget is None) == (cost_budget is None):
        raise ValueError("give exactly one of budget / cost_budget")
    if cost_budget is not None:
        feasible = [p for p in points
                    if p.cost_per_request <= cost_budget + 1e-12]
    else:
        feasible = [p for p in points
                    if p.remote_fraction <= budget + 1e-12]
    if max_rejection_rate is not None:
        hard = [p for p in feasible
                if p.rejection_rate <= max_rejection_rate + 1e-12]
        feasible = hard or feasible
    if not feasible:
        feasible = [min(points, key=lambda p: p.cost_per_request)]
    return max(feasible, key=lambda p: (p.system_accuracy,
                                        -p.cost_per_request,
                                        -p.rejection_rate))


class EscalationPrior:
    """P(escalate | proxy score): the calibration-table prior behind the
    scheduler's policy-aware window packing (DESIGN.md §8).

    Fit from calibration-time pairs of a *request-observable* proxy score
    (anything cheap the caller can compute before the local forward — a
    feature margin, input length, a stale cached confidence; the 1st-level
    supervisor confidence itself when scoring offline) and the escalation
    outcome under the selected ``t_local``. Scores are bucketed at
    quantile edges; calling the prior with a new proxy score returns the
    bucket's empirical escalation rate. Monotone inputs give a monotone
    table, but nothing requires the proxy to be the confidence itself.
    """

    def __init__(self, edges: np.ndarray, rates: np.ndarray):
        self.edges = np.asarray(edges, np.float64)      # [bins+1]
        self.rates = np.asarray(rates, np.float64)      # [bins]

    def __call__(self, score: float) -> float:
        i = int(np.searchsorted(self.edges, score, side="right")) - 1
        return float(self.rates[np.clip(i, 0, self.rates.size - 1)])

    def batch(self, scores: np.ndarray) -> np.ndarray:
        idx = np.searchsorted(self.edges, np.asarray(scores, np.float64),
                              side="right") - 1
        return self.rates[np.clip(idx, 0, self.rates.size - 1)]


def fit_escalation_prior(proxy_scores: np.ndarray,
                         escalated: np.ndarray, *,
                         bins: int = 16) -> EscalationPrior:
    """Bucket ``proxy_scores`` at quantile edges and record each bucket's
    empirical escalation rate. ``escalated`` is the 0/1 outcome under the
    chosen operating point (e.g. ``local_conf <= t_local``). Empty
    buckets inherit the global rate."""
    s = np.asarray(proxy_scores, np.float64).ravel()
    e = np.asarray(escalated, bool).ravel()
    if s.size != e.size or s.size == 0:
        raise ValueError("need matching, non-empty proxy/escalated arrays")
    edges = np.unique(np.quantile(s, np.linspace(0.0, 1.0, bins + 1)))
    if edges.size < 2:                      # constant proxy: one bucket
        edges = np.array([s[0] - 1e-9, s[0] + 1e-9])
    idx = np.clip(np.searchsorted(edges, s, side="right") - 1,
                  0, edges.size - 2)
    rates = np.full(edges.size - 1, float(e.mean()))
    for b in range(edges.size - 1):
        m = idx == b
        if m.any():
            rates[b] = float(e[m].mean())
    return EscalationPrior(edges, rates)


def calibrate(local_conf, local_correct, remote_conf, remote_correct, *,
              budget: float | None = None, batch_size: int, grid: int = 33,
              cost_budget: float | None = None,
              max_rejection_rate: float | None = None,
              remote_cost_per_request: float = 0.0048
              ) -> tuple[OperatingPoint, int, list[OperatingPoint]]:
    """One-call calibration: sweep, take the frontier, pick the budget
    point — a remote-fraction ``budget`` or a dollar ``cost_budget``
    (price escalations with the deployment's real per-call cost, e.g.
    ``router.expected_cost_per_escalation``). Returns (point, capacity k
    for ``batch_size``, frontier)."""
    pts = sweep_operating_points(
        local_conf, local_correct, remote_conf, remote_correct,
        grid=grid, remote_cost_per_request=remote_cost_per_request)
    front = pareto_frontier(pts)
    best = select_operating_point(front, budget, cost_budget=cost_budget,
                                  max_rejection_rate=max_rejection_rate)
    return best, best.capacity(batch_size), front

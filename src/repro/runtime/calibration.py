"""Offline cascade calibration: cost-vs-accuracy Pareto sweeps
(runtime control plane, DESIGN.md §1).

Given a labelled validation set scored by both tiers — 1st-level
supervisor confidences + correctness for the local model, 2nd-level
confidences + correctness for the remote model — sweep the
``(t_local, t_remote)`` grid with exact Algorithm-1 semantics
(``core.cascade.bisupervised_batch``, paper RQ1/RQ2 style), build the
Pareto frontier over (remote fraction, accepted accuracy, rejection rate),
and select the operating point for a target remote-call budget. The
selected point is returned with the serving-mode capacity
``k = ceil(rho * B)`` so it can be handed straight to the engine, and is
also the recommended warm start for the online ``AdaptiveController``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.core.cascade import escalation_capacity


@dataclass(frozen=True)
class OperatingPoint:
    t_local: float
    t_remote: float
    remote_fraction: float    # realised escalation rate on the val set
    rejection_rate: float     # REJECTED fraction (fallback path)
    accuracy: float           # accuracy over accepted inputs
    system_accuracy: float    # accuracy over ALL inputs (rejected = wrong)
    cost_per_request: float

    def capacity(self, batch_size: int) -> int:
        """Serving-mode escalation cap for this point (DESIGN.md §2)."""
        return escalation_capacity(batch_size, max(self.remote_fraction,
                                                   1e-6))


def _quantile_grid(values: np.ndarray, n: int) -> np.ndarray:
    """Candidate thresholds at n evenly spaced quantiles, plus the open
    ends (never/always escalate or reject)."""
    v = np.asarray(values, np.float64)
    qs = np.quantile(v, np.linspace(0.0, 1.0, n))
    return np.unique(np.concatenate(
        [[v.min() - 1e-9], qs, [v.max() + 1e-9]]))


def sweep_operating_points(local_conf: np.ndarray, local_correct: np.ndarray,
                           remote_conf: np.ndarray, remote_correct: np.ndarray,
                           *, grid: int = 33,
                           remote_cost_per_request: float = 0.0048
                           ) -> list[OperatingPoint]:
    """Exhaustive (t_local, t_remote) sweep with Algorithm-1 semantics.

    All arrays are [n] over the validation set; correctness is 0/1.
    Vectorised: for each t_local the escalated set is fixed, and every
    t_remote candidate only re-partitions it into REMOTE vs REJECTED.
    """
    lc = np.asarray(local_conf, np.float64)
    lok = np.asarray(local_correct, bool)
    rc = np.asarray(remote_conf, np.float64)
    rok = np.asarray(remote_correct, bool)
    n = lc.shape[0]

    points: list[OperatingPoint] = []
    for tl in _quantile_grid(lc, grid):
        use_local = lc > tl
        esc = ~use_local
        n_esc = int(esc.sum())
        local_hits = int(lok[use_local].sum())
        for tr in _quantile_grid(rc[esc] if n_esc else rc, grid):
            remote_ok = esc & (rc > tr)
            accepted = use_local | remote_ok
            n_acc = int(accepted.sum())
            hits = local_hits + int(rok[remote_ok].sum())
            points.append(OperatingPoint(
                t_local=float(tl), t_remote=float(tr),
                remote_fraction=n_esc / n,
                rejection_rate=1.0 - n_acc / n,
                accuracy=hits / max(n_acc, 1),
                system_accuracy=hits / n,
                cost_per_request=n_esc / n * remote_cost_per_request))
    return points


def pareto_frontier(points: Sequence[OperatingPoint]
                    ) -> list[OperatingPoint]:
    """Non-dominated subset: maximise accepted accuracy, minimise remote
    fraction and rejection rate. Sorted by ascending remote fraction."""
    # distinct threshold pairs can land on identical metrics; keep one
    seen: set[tuple] = set()
    pts = []
    for p in sorted(points, key=lambda p: (p.remote_fraction,
                                           p.rejection_rate, -p.accuracy)):
        m = (p.remote_fraction, p.rejection_rate, p.accuracy)
        if m not in seen:
            seen.add(m)
            pts.append(p)
    front: list[OperatingPoint] = []
    for p in pts:
        dominated = any(q.accuracy >= p.accuracy
                        and q.remote_fraction <= p.remote_fraction
                        and q.rejection_rate <= p.rejection_rate
                        and (q.accuracy > p.accuracy
                             or q.remote_fraction < p.remote_fraction
                             or q.rejection_rate < p.rejection_rate)
                        for q in pts)
        if not dominated:
            front.append(p)
    return front


def select_operating_point(points: Sequence[OperatingPoint],
                           budget: float | None = None, *,
                           cost_budget: float | None = None,
                           max_rejection_rate: float | None = None
                           ) -> OperatingPoint:
    """Best accepted accuracy subject to a budget (and an optional
    rejection-rate ceiling); ties broken toward cheaper points. The budget
    is either a remote *fraction* (``budget``) or a **dollar** ceiling on
    modelled $ per request (``cost_budget`` — per-backend pricing enters
    via ``remote_cost_per_request`` at sweep time, e.g. the router's
    ``expected_cost_per_escalation``). Falls back to the cheapest point if
    the budget excludes everything."""
    if (budget is None) == (cost_budget is None):
        raise ValueError("give exactly one of budget / cost_budget")
    if cost_budget is not None:
        feasible = [p for p in points
                    if p.cost_per_request <= cost_budget + 1e-12]
    else:
        feasible = [p for p in points if p.remote_fraction <= budget + 1e-12]
    if max_rejection_rate is not None:
        hard = [p for p in feasible
                if p.rejection_rate <= max_rejection_rate + 1e-12]
        feasible = hard or feasible
    if not feasible:
        feasible = [min(points, key=lambda p: p.remote_fraction)]
    return max(feasible, key=lambda p: (p.accuracy, -p.remote_fraction,
                                        -p.rejection_rate))


def calibrate(local_conf, local_correct, remote_conf, remote_correct, *,
              budget: float | None = None, batch_size: int, grid: int = 33,
              cost_budget: float | None = None,
              max_rejection_rate: float | None = None,
              remote_cost_per_request: float = 0.0048
              ) -> tuple[OperatingPoint, int, list[OperatingPoint]]:
    """One-call calibration: sweep, take the frontier, pick the budget
    point — a remote-fraction ``budget`` or a dollar ``cost_budget``
    (price escalations with the deployment's real per-call cost, e.g.
    ``router.expected_cost_per_escalation``). Returns (point, capacity k
    for ``batch_size``, frontier)."""
    pts = sweep_operating_points(
        local_conf, local_correct, remote_conf, remote_correct,
        grid=grid, remote_cost_per_request=remote_cost_per_request)
    front = pareto_frontier(pts)
    best = select_operating_point(front, budget, cost_budget=cost_budget,
                                  max_rejection_rate=max_rejection_rate)
    return best, best.capacity(batch_size), front

"""Fault-aware remote-tier transport (runtime control plane, DESIGN.md §3).

The paper treats the remote DNN as an infallible local callable; real
deployments (DDNN-style cloud/edge tiers, CheapET-3's billed web API) see
timeouts, transient errors and outages. This module wraps the remote
callable in:

  * bounded in-flight windows — the escalated sub-batch is shipped in
    chunks of at most ``max_in_flight`` requests, so a single failure only
    degrades its window, never the whole batch;
  * per-window deadline + bounded retries with backoff;
  * a circuit breaker: after ``breaker_failures`` consecutive window
    failures the breaker opens and remote calls short-circuit locally for
    ``breaker_reset_s``; a single half-open probe then decides whether to
    close it again.

A failed window does NOT drop its requests: the engine maps them to the
REJECTED/fallback path of Algorithm 1 (the 2nd-level supervisor's "raise
Exception" branch), which the scheduler resolves via the fallback callable.

For the pipelined serving path (DESIGN.md §5) the transport also exposes a
non-blocking futures API: ``submit(batch)`` schedules the same windowed /
retried / breaker-guarded ``call`` on a thread pool and returns a
``TransportFuture``; ``poll``/``result`` drain it. Breaker and stats
mutations are lock-protected so concurrent in-flight windows stay
consistent; the remote callable itself runs unlocked and must be
thread-safe when ``max_concurrent > 1``.

The clock and sleep functions are injectable so tests and benchmarks can
run outage episodes deterministically without wall-clock waits.

Multi-remote routing (DESIGN.md §6): real deployments see a *market* of
remote models at different per-call prices and latencies (CheapET-3), and
tiered escalation across multiple upstream endpoints (DDNN). A
``RemoteBackend`` is one named remote tier — its own transport (config,
breaker, pool, stats) plus routing metadata (``cost_per_request``,
modelled ``latency_s``) — and a ``RemoteRouter`` owns N backends and picks
one per escalation window under a pluggable policy:

  * ``primary-failover``    — registration order; later backends are hot
    standbys;
  * ``cheapest-available``  — ascending ``cost_per_request``;
  * ``latency-ema``         — ascending measured latency EMA (seeded from
    the modelled ``latency_s`` until a backend has observations);
  * ``weighted``            — spread windows across equally-priced healthy
    backends by inverse in-flight count (load balancing — DESIGN.md §8).

Per-request policy (DESIGN.md §8): ``pick``/``redeem_replay`` accept a
``RouteConstraint`` merged from the window's escalated rows — a cost
ceiling, a remaining-deadline latency ceiling and an advisory backend
hint — and ``min_available_cost``/``min_latency_estimate`` expose the
feasibility signals the engine's deadline/cost downgrades consult.

``pick()`` skips any backend whose breaker would refuse the call *at
submit time* (the speculative-failover fast path: an open breaker reroutes
the window immediately instead of waiting for the drain to observe the
failure). When NO backend is available the window may park with a bounded
replay ticket (``acquire_replay_slot``/``redeem_replay``): at drain time
it gets one more pick, so a breaker that half-opens while the window rides
the pipeline serves it — the replay doubles as the half-open probe —
instead of the escalation degrading to REJECTED (DESIGN.md §7).
"""

from __future__ import annotations

import random
import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutureTimeout
from dataclasses import dataclass, field
from typing import Any, Callable, ClassVar

import numpy as np

from .observability import (
    EV_BREAKER_CLOSE,
    EV_BREAKER_HALF_OPEN,
    EV_BREAKER_OPEN,
    EV_REPLAY_DROPPED,
    EV_REPLAY_PARKED,
    EV_REPLAY_SERVED,
    EV_ROUTER_FAILBACK,
    EV_ROUTER_FAILOVER,
)


class RemoteCallError(Exception):
    """Remote tier invocation failed (transient or terminal)."""


class RemoteTimeout(RemoteCallError):
    """Remote tier exceeded its deadline (raise from fault hooks too)."""


class CircuitOpenError(RemoteCallError):
    """Call short-circuited: the breaker is open."""


CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"


@dataclass(frozen=True)
class TransportConfig:
    max_in_flight: int = 8        # requests per transport window
    timeout_s: float = 2.0        # per-window deadline
    max_retries: int = 2          # retries per window (beyond first try)
    retry_backoff_s: float = 0.02   # base of the exponential backoff
    retry_backoff_cap_s: float = 1.0  # backoff ceiling (pre-jitter)
    retry_jitter_seed: int = 0    # per-transport seed for backoff jitter
    breaker_failures: int = 3     # consecutive window failures to open
    breaker_reset_s: float = 5.0  # open -> half-open after this long
    max_concurrent: int = 8       # submit() thread-pool width


@dataclass
class TransportStats:
    windows: int = 0
    requests: int = 0
    failed_requests: int = 0
    retries: int = 0
    timeouts: int = 0
    errors: int = 0
    short_circuited: int = 0      # requests rejected while breaker open
    breaker_opens: int = 0
    # measured per-window remote latency (successful windows only): the
    # EMA feeds the router's latency-ema policy, the ring buffer feeds
    # the per-backend p95 reported by the serving/routing benchmarks
    latency_sum_s: float = 0.0
    latency_windows: int = 0
    latency_ema_s: float | None = None
    latency_samples: deque = field(
        default_factory=lambda: deque(maxlen=4096), repr=False)

    LATENCY_EMA_ALPHA: ClassVar[float] = 0.2

    def record_latency(self, window_s: float) -> None:
        self.latency_sum_s += window_s
        self.latency_windows += 1
        self.latency_ema_s = (window_s if self.latency_ema_s is None else
                              self.LATENCY_EMA_ALPHA * window_s
                              + (1 - self.LATENCY_EMA_ALPHA)
                              * self.latency_ema_s)
        self.latency_samples.append(float(window_s))

    @property
    def mean_latency_s(self) -> float | None:
        """Mean per-window remote latency; None before any successful
        window — a transport that never measured anything must not
        report a flattering 0.0 (DESIGN.md §9 empty-stats contract)."""
        if self.latency_windows == 0:
            return None
        return self.latency_sum_s / self.latency_windows

    def latency_percentile(self, q: float) -> float | None:
        """q-th percentile (0-100) of recent per-window remote latency;
        None when no window has succeeded yet."""
        if not self.latency_samples:
            return None
        return float(np.percentile(np.fromiter(self.latency_samples,
                                               np.float64), q))


class CircuitBreaker:
    """Consecutive-failure breaker with a single half-open probe."""

    def __init__(self, failures: int, reset_s: float,
                 clock: Callable[[], float] = time.monotonic):
        self.failure_threshold = max(1, failures)
        self.reset_s = reset_s
        self._clock = clock
        self.state = CLOSED
        self.consecutive_failures = 0
        self.opens = 0
        self._opened_at = 0.0

    def allow(self) -> bool:
        if self.state == OPEN:
            if self._clock() - self._opened_at >= self.reset_s:
                self.state = HALF_OPEN     # admit one probe
                return True
            return False
        return True

    def would_allow(self) -> bool:
        """Non-mutating peek: should the router hand this breaker a new
        window right now? OPEN admits once the reset has elapsed (that
        pick becomes the probe); HALF_OPEN refuses — a probe is already
        in flight, and routing more windows at a still-unproven backend
        would burn them if the probe fails (``allow()`` itself stays
        permissive in HALF_OPEN so the in-flight probe's retries pass)."""
        if self.state == OPEN:
            return self._clock() - self._opened_at >= self.reset_s
        if self.state == HALF_OPEN:
            return False
        return True

    def try_probe(self) -> bool:
        """OPEN -> HALF_OPEN when the reset window has elapsed; the caller
        becomes the single in-flight probe. The router calls this at pick
        time so the half-open transition is *sequenced before* the events
        the probe causes (router_failback, breaker_close) — DESIGN.md §9's
        causal ordering would otherwise break because ``would_allow()``
        only peeks. Returns True iff the transition happened here."""
        if (self.state == OPEN
                and self._clock() - self._opened_at >= self.reset_s):
            self.state = HALF_OPEN
            return True
        return False

    def record_success(self) -> None:
        self.state = CLOSED
        self.consecutive_failures = 0

    def record_failure(self) -> None:
        self.consecutive_failures += 1
        if (self.state == HALF_OPEN
                or self.consecutive_failures >= self.failure_threshold):
            if self.state != OPEN:
                self.opens += 1
            self.state = OPEN
            self._opened_at = self._clock()


def _rows(batch: Any) -> int:
    if isinstance(batch, dict):
        return _rows(next(iter(batch.values())))
    return int(np.asarray(batch).shape[0])


def _slice(batch: Any, lo: int, hi: int) -> Any:
    if isinstance(batch, dict):
        return {k: _slice(v, lo, hi) for k, v in batch.items()}
    return batch[lo:hi]


class TransportFuture:
    """Handle for one in-flight ``submit``; resolves to ``(logits, ok)``.

    ``result`` never raises for remote faults — failures surface as
    ``ok == False`` rows, exactly like the synchronous ``call``.
    """

    def __init__(self, future: Future, n: int):
        self._future = future
        self.n = n                # requests riding on this future

    def done(self) -> bool:
        return self._future.done()

    def result(self, timeout: float | None = None):
        return self._future.result(timeout)

    def add_done_callback(self, fn: Callable[["TransportFuture"], Any]
                          ) -> None:
        """Invoke ``fn(self)`` (from the pool thread) once the future
        resolves. The streaming drain (DESIGN.md §7) registers a wakeup
        here so it can park on an event covering EVERY in-flight window
        across every backend, instead of polling the head-of-line future
        — any window resolving, on any backend's pool, wakes the drain.
        Exceptions in ``fn`` are swallowed by the executor; keep it to a
        flag/event set."""
        self._future.add_done_callback(lambda _f: fn(self))


class RemoteTransport:
    """Windowed, retried, breaker-guarded wrapper over a remote callable.

    ``call(batch)`` returns ``(logits [n, C] float32, ok [n] bool)``:
    per-request success flags instead of an exception, so partial failures
    degrade to per-request fallback rather than batch loss. Rows with
    ``ok == False`` have zero logits and must not be trusted.

    ``submit(batch)`` is the non-blocking variant: the same call runs on
    a thread pool and the returned ``TransportFuture`` resolves to the
    identical ``(logits, ok)`` pair — the pipelined engine keeps several
    microbatches in flight this way (DESIGN.md §5).
    """

    def __init__(self, remote_apply: Callable, config: TransportConfig
                 = TransportConfig(), *,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep):
        self.remote_apply = remote_apply
        self.config = config
        self.stats = TransportStats()
        self._clock = clock
        self._sleep = sleep
        self._lock = threading.RLock()
        self._pool: ThreadPoolExecutor | None = None
        # attempts run on their own pool so the bounded result() wait can
        # abandon a hung remote_apply without wedging the caller — which
        # may itself be a submit()-pool thread (same pool would deadlock).
        # Created (and one worker pre-spawned) eagerly: the first window
        # attempt must not pay pool/thread start-up inside its deadline.
        self._attempt_pool: ThreadPoolExecutor | None = None
        self._attempts()
        # deterministic backoff jitter: seeded per transport, drawn under
        # the lock so a fixed seed gives a reproducible delay sequence
        self._backoff_rng = random.Random(config.retry_jitter_seed)
        self.breaker = CircuitBreaker(config.breaker_failures,
                                      config.breaker_reset_s, clock=clock)
        # observability (DESIGN.md §9): an EventLog installed by the
        # Observability facade; None = disabled, every hook short-circuits
        # on one attribute test. ``event_source`` is the backend name the
        # router wires in (a bare transport reports as "remote").
        self.events: Any = None
        self.event_source = "remote"

    _BREAKER_EVENTS: ClassVar[dict] = {OPEN: EV_BREAKER_OPEN,
                                       HALF_OPEN: EV_BREAKER_HALF_OPEN,
                                       CLOSED: EV_BREAKER_CLOSE}

    def _emit_breaker(self, prev: str, cur: str, tag: int | None) -> None:
        """Emit a breaker state-transition event (call OUTSIDE the
        transport lock; prev/cur were captured inside it)."""
        if self.events is None or cur == prev:
            return
        self.events.emit(self._BREAKER_EVENTS[cur], window=tag,
                         backend=self.event_source, prev=prev,
                         failures=self.breaker.consecutive_failures)

    # -- single window -----------------------------------------------------
    def _attempts(self) -> ThreadPoolExecutor:
        with self._lock:
            if self._attempt_pool is None:
                # +2 slack: a timed-out attempt abandons its thread until
                # the hung remote_apply returns; a couple of stragglers
                # must not starve fresh attempts (if more pile up, queued
                # attempts time out in result() and the breaker opens)
                self._attempt_pool = ThreadPoolExecutor(
                    max_workers=max(1, self.config.max_concurrent) + 2,
                    thread_name_prefix="remote-attempt")
                # pre-spawn one worker: the first real attempt must not
                # pay thread-start latency inside the window deadline
                self._attempt_pool.submit(lambda: None)
            return self._attempt_pool

    def _call_window(self, window: Any) -> np.ndarray:
        """One attempt, with the deadline enforced both ways: the attempt
        runs on a dedicated pool and the wait is bounded in *wall* time
        (a hung remote_apply is abandoned, not awaited forever), and the
        elapsed time on the injectable clock is checked after the fact so
        chaos schedules driving a virtual clock still produce timeouts
        without real waits."""
        t0 = self._clock()
        fut = self._attempts().submit(self.remote_apply, window)
        try:
            out = np.asarray(fut.result(timeout=self.config.timeout_s))
        except FutureTimeout:
            fut.cancel()        # not started -> never runs; else abandoned
            raise RemoteTimeout(
                f"remote window exceeded {self.config.timeout_s}s "
                f"deadline (attempt abandoned)") from None
        if self._clock() - t0 > self.config.timeout_s:
            raise RemoteTimeout(
                f"remote window exceeded {self.config.timeout_s}s deadline")
        return out

    def _backoff(self, attempt: int) -> float:
        """Capped exponential backoff with seeded jitter: base * 2^attempt
        clipped at the cap, then scaled into [0.5, 1.0) so windows that
        failed together don't retry in lockstep against a recovering
        backend (linear backoff synchronized them). The rng is seeded per
        transport (``retry_jitter_seed``), so tests replaying a schedule
        see the same delay sequence."""
        raw = min(self.config.retry_backoff_s * (2 ** attempt),
                  self.config.retry_backoff_cap_s)
        with self._lock:
            return raw * (0.5 + 0.5 * self._backoff_rng.random())

    def _call_with_retries(self, window: Any,
                           tag: int | None = None) -> np.ndarray:
        """One window: retries absorb transient faults; only a window that
        exhausts its retries counts as a breaker failure (so a single
        flaky window never opens the breaker on its own)."""
        last: Exception | None = None
        t0 = self._clock()      # latency = time-to-success incl. retries,
        for attempt in range(1 + self.config.max_retries):  # so a flaky
            # backend can't report a flattering EMA/p95 to the router
            with self._lock:
                prev = self.breaker.state
                allowed = self.breaker.allow()
                cur = self.breaker.state
            self._emit_breaker(prev, cur, tag)
            if not allowed:
                raise CircuitOpenError("circuit breaker open")
            try:
                out = self._call_window(window)
            except RemoteTimeout as e:
                with self._lock:
                    self.stats.timeouts += 1
                last = e
            except CircuitOpenError:
                raise
            except Exception as e:  # transient transport / remote error
                with self._lock:
                    self.stats.errors += 1
                last = e
            else:
                with self._lock:
                    self.stats.record_latency(self._clock() - t0)
                    prev = self.breaker.state
                    self.breaker.record_success()
                    cur = self.breaker.state
                self._emit_breaker(prev, cur, tag)
                return out
            if attempt < self.config.max_retries:
                with self._lock:
                    self.stats.retries += 1
                if self.config.retry_backoff_s > 0:
                    self._sleep(self._backoff(attempt))
        with self._lock:
            prev = self.breaker.state
            self.breaker.record_failure()
            cur = self.breaker.state
        self._emit_breaker(prev, cur, tag)
        raise RemoteCallError(f"remote window failed after "
                              f"{1 + self.config.max_retries} attempts: "
                              f"{last!r}") from last

    # -- public API --------------------------------------------------------
    def call(self, batch: Any, tag: int | None = None
             ) -> tuple[np.ndarray | None, np.ndarray]:
        n = _rows(batch)
        ok = np.zeros((n,), bool)
        outs: list[tuple[int, np.ndarray]] = []
        w = max(1, self.config.max_in_flight)
        for lo in range(0, n, w):
            hi = min(lo + w, n)
            with self._lock:
                self.stats.windows += 1
                self.stats.requests += hi - lo
                prev = self.breaker.state
                allowed = self.breaker.allow()
                cur = self.breaker.state
            self._emit_breaker(prev, cur, tag)
            if not allowed:
                with self._lock:
                    self.stats.short_circuited += hi - lo
                    self.stats.failed_requests += hi - lo
                continue
            try:
                out = self._call_with_retries(_slice(batch, lo, hi), tag)
            except CircuitOpenError:
                with self._lock:
                    self.stats.short_circuited += hi - lo
                    self.stats.failed_requests += hi - lo
                continue
            except RemoteCallError:
                with self._lock:
                    self.stats.failed_requests += hi - lo
                continue
            ok[lo:hi] = True
            outs.append((lo, out))
        with self._lock:
            self.stats.breaker_opens = self.breaker.opens
        if not outs:
            return None, ok
        width = outs[0][1].shape[1:]
        logits = np.zeros((n,) + width, np.float32)
        for lo, out in outs:
            logits[lo:lo + out.shape[0]] = out
        return logits, ok

    def submit(self, batch: Any, tag: int | None = None) -> TransportFuture:
        """Non-blocking ``call``: schedule the batch on the thread pool and
        return a future resolving to the same ``(logits, ok)`` pair."""
        with self._lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=max(1, self.config.max_concurrent),
                    thread_name_prefix="remote-transport")
            pool = self._pool
        return TransportFuture(pool.submit(self.call, batch, tag),
                               _rows(batch))

    def poll(self, future: TransportFuture) -> bool:
        """True iff the future's (logits, ok) is ready to drain."""
        return future.done()

    def grant_probe(self, tag: int | None = None) -> None:
        """Transition an elapsed OPEN breaker to HALF_OPEN *now* and emit
        the transition. The router calls this for the backend it picked,
        so ``breaker_half_open`` is sequenced before any failback/close
        event the probe window goes on to cause (DESIGN.md §9)."""
        with self._lock:
            prev = self.breaker.state
            granted = self.breaker.try_probe()
        if granted:
            self._emit_breaker(prev, self.breaker.state, tag)

    def shutdown(self, wait: bool = True) -> None:
        """Tear down the submit() pool (in-flight calls finish if wait)."""
        with self._lock:
            pool, self._pool = self._pool, None
            attempts, self._attempt_pool = self._attempt_pool, None
        if pool is not None:
            pool.shutdown(wait=wait)
        if attempts is not None:
            # never wait on the attempt pool: an abandoned hung attempt
            # would block shutdown forever (the bug this pool fixes)
            attempts.shutdown(wait=False, cancel_futures=True)


# ---------------------------------------------------------------------------
# Multi-remote tier registry + routing (DESIGN.md §6)
# ---------------------------------------------------------------------------

class RemoteBackend:
    """One named remote tier in the registry.

    Owns a full ``RemoteTransport`` (per-backend config, breaker, thread
    pool, stats) plus the routing/billing metadata the engine and router
    need: ``cost_per_request`` (per-call price; None = use the engine's
    ``CostModel`` default) and ``latency_s`` (modelled round trip; None =
    CostModel default). Construct either around a callable::

        RemoteBackend("gpt-large", remote_apply, TransportConfig(...),
                      cost_per_request=0.0048, latency_s=0.32)

    or around an existing transport (``transport=...``) — the adapter the
    engine uses to keep a bare single-transport construction working.
    """

    def __init__(self, name: str, remote_apply: Callable | None = None,
                 config: TransportConfig = TransportConfig(), *,
                 cost_per_request: float | None = None,
                 latency_s: float | None = None,
                 transport: RemoteTransport | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep):
        if transport is None:
            if remote_apply is None:
                raise ValueError("RemoteBackend needs remote_apply or "
                                 "transport")
            transport = RemoteTransport(remote_apply, config,
                                        clock=clock, sleep=sleep)
        self.name = name
        self.transport = transport
        self.cost_per_request = cost_per_request
        self.latency_s = latency_s
        # windows handed to this backend and not yet resolved — the
        # `weighted` routing policy's load signal
        self._inflight = 0
        self._inflight_lock = threading.Lock()

    # -- delegation to the owned transport -----------------------------
    @property
    def config(self) -> TransportConfig:
        return self.transport.config

    @property
    def breaker(self) -> CircuitBreaker:
        return self.transport.breaker

    @property
    def stats(self) -> TransportStats:
        return self.transport.stats

    def call(self, batch: Any, tag: int | None = None):
        self._track(+1)
        try:
            return self.transport.call(batch, tag)
        finally:
            self._track(-1)

    def submit(self, batch: Any, tag: int | None = None) -> TransportFuture:
        self._track(+1)
        try:
            fut = self.transport.submit(batch, tag)
        except BaseException:
            self._track(-1)     # pool-shutdown race etc.: don't leak the
            raise               # counter and skew `weighted` routing
        fut.add_done_callback(lambda _f: self._track(-1))
        return fut

    def poll(self, future: TransportFuture) -> bool:
        return self.transport.poll(future)

    def shutdown(self, wait: bool = True) -> None:
        self.transport.shutdown(wait=wait)

    def _track(self, delta: int) -> None:
        with self._inflight_lock:
            self._inflight = max(0, self._inflight + delta)

    @property
    def inflight(self) -> int:
        """Windows routed here and not yet resolved (load signal)."""
        with self._inflight_lock:
            return self._inflight

    # -- routing signals ------------------------------------------------
    def available(self) -> bool:
        """Would this backend's breaker admit a call right now?"""
        return self.breaker.would_allow()

    def latency_estimate(self) -> float:
        """Measured latency EMA; falls back to the modelled ``latency_s``
        prior (0.0 if neither — an untried backend is worth probing)."""
        if self.stats.latency_ema_s is not None:
            return self.stats.latency_ema_s
        return self.latency_s if self.latency_s is not None else 0.0

    def __repr__(self) -> str:
        return (f"RemoteBackend({self.name!r}, "
                f"cost={self.cost_per_request}, "
                f"latency={self.latency_s})")


ROUTE_POLICIES = ("primary-failover", "cheapest-available", "latency-ema",
                  "weighted")


@dataclass(frozen=True)
class RouteConstraint:
    """Per-window routing constraint merged from the escalated rows'
    ``RequestPolicy`` objects (DESIGN.md §8). One window is served by one
    backend, so the backend must satisfy the *tightest* row: ``max_cost``
    is the smallest ``cost_cap`` present, ``max_latency_s`` the smallest
    remaining deadline. ``hint`` is advisory — the hinted backend is
    preferred when available and satisfying; ``default_cost`` prices
    backends that carry no ``cost_per_request`` of their own (the
    engine's CostModel constant)."""
    max_cost: float | None = None
    max_latency_s: float | None = None
    hint: str | None = None
    default_cost: float | None = None

    def admits(self, backend: "RemoteBackend") -> bool:
        if self.max_cost is not None:
            cost = (backend.cost_per_request
                    if backend.cost_per_request is not None
                    else self.default_cost)
            if cost is not None and cost > self.max_cost + 1e-12:
                return False
        if (self.max_latency_s is not None
                and backend.latency_estimate() > self.max_latency_s):
            return False
        return True


@dataclass
class RouterStats:
    picks: dict = field(default_factory=dict)   # backend name -> windows
    failovers: int = 0          # picks that skipped the preferred backend
    unrouted: int = 0           # windows with NO available backend
    # bounded replay of (unrouted) windows (DESIGN.md §7): instead of
    # degrading straight to REJECTED, up to ``replay_max`` windows park
    # until their drain and get one more pick — served iff some breaker
    # has half-opened in the meantime
    replay_enqueued: int = 0    # windows parked with a replay ticket
    replay_served: int = 0      # redeemed by a recovered backend
    replay_dropped: int = 0     # queue full at park, or still no backend


class RemoteRouter:
    """Registry of ``RemoteBackend``s + a routing policy.

    ``pick()`` returns the first *available* backend in policy order —
    a backend whose breaker is open (and not yet due a half-open probe)
    is skipped at submit time, so an outage fails over within the same
    escalation window (speculative failover). Returns None only when no
    backend is available; the engine then maps the window straight to the
    REJECTED/fallback path without touching any transport.

    Candidate order per policy:
      * primary-failover   — registration order;
      * cheapest-available — ascending ``cost_per_request`` (unknown cost
        sorts last; registration order breaks ties);
      * latency-ema        — ascending ``latency_estimate()`` (measured
        EMA, modelled prior until observations arrive).
    """

    def __init__(self, backends: list[RemoteBackend],
                 policy: str = "primary-failover", *,
                 replay_max: int = 8):
        backends = list(backends)
        if not backends:
            raise ValueError("router needs at least one backend")
        names = [b.name for b in backends]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate backend names: {names}")
        if policy not in ROUTE_POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"choose from {ROUTE_POLICIES}")
        self.backends = backends
        self.policy = policy
        self.replay_max = max(0, replay_max)
        self._replay_slots = 0      # tickets currently parked with windows
        self.stats = RouterStats(picks={b.name: 0 for b in backends})
        # observability (DESIGN.md §9): shared EventLog, installed by the
        # Observability facade (None = disabled). ``_failed_over`` tracks
        # whether routing has drifted off the policy-preferred backend so
        # the return to it is emitted as one fail-back event.
        self.events: Any = None
        self._failed_over = False

    def __len__(self) -> int:
        return len(self.backends)

    def __iter__(self):
        return iter(self.backends)

    def attach_events(self, events: Any) -> None:
        """Wire this router and every backend transport into one event
        log. Idempotent; the Observability facade calls it at install
        time, and the cluster harness re-points a shared router at the
        raw fleet-level log after per-replica installs (DESIGN.md §12)."""
        self.events = events
        for b in self.backends:
            b.transport.events = events
            b.transport.event_source = b.name

    def backend(self, name: str) -> RemoteBackend:
        for b in self.backends:
            if b.name == name:
                return b
        raise KeyError(name)

    def candidates(self) -> list[RemoteBackend]:
        """All backends in policy preference order (availability is NOT
        applied here — ``pick`` filters on breaker state)."""
        if self.policy == "cheapest-available":
            return sorted(self.backends,
                          key=lambda b: (b.cost_per_request is None,
                                         b.cost_per_request or 0.0))
        if self.policy == "latency-ema":
            return sorted(self.backends, key=RemoteBackend.latency_estimate)
        if self.policy == "weighted":
            # spread windows across equally-priced backends by inverse
            # in-flight count (least-loaded first; price still dominates,
            # registration order breaks the remaining ties)
            return sorted(self.backends,
                          key=lambda b: (b.cost_per_request is None,
                                         b.cost_per_request or 0.0,
                                         b.inflight))
        return list(self.backends)

    def _ordered(self, constraint: RouteConstraint | None
                 ) -> list[RemoteBackend]:
        """Policy order with an advisory routing hint applied: the hinted
        backend (if registered) moves to the front of the candidate
        list; constraint filtering still applies to it."""
        cands = self.candidates()
        if constraint is not None and constraint.hint is not None:
            hinted = [b for b in cands if b.name == constraint.hint]
            if hinted:
                cands = hinted + [b for b in cands if b is not hinted[0]]
        return cands

    def pick(self, constraint: RouteConstraint | None = None, *,
             window: int | None = None) -> RemoteBackend | None:
        """First available backend in policy order that satisfies the
        window's merged ``RouteConstraint`` (None = unconstrained); None
        when every breaker (or the constraint) refuses — the window
        degrades to REJECTED/fallback. ``failovers`` counts picks that
        skipped a breaker-refused preferred backend (constraint skips are
        policy, not failure)."""
        skipped_unavailable = False
        ordered = self._ordered(constraint)
        for b in ordered:
            if not b.available():
                skipped_unavailable = True
                continue
            if constraint is not None and not constraint.admits(b):
                continue
            # an elapsed OPEN breaker half-opens HERE, not when the call
            # hits the wire: the half_open event must be sequenced before
            # the failback/close events this probe window causes
            b.transport.grant_probe(window)
            self.stats.picks[b.name] += 1
            if skipped_unavailable:
                self.stats.failovers += 1
                self._failed_over = True
                if self.events is not None:
                    self.events.emit(EV_ROUTER_FAILOVER, window=window,
                                     backend=b.name, policy=self.policy)
            elif self._failed_over and b is ordered[0]:
                self._failed_over = False
                if self.events is not None:
                    self.events.emit(EV_ROUTER_FAILBACK, window=window,
                                     backend=b.name, policy=self.policy)
            return b
        self.stats.unrouted += 1
        return None

    # -- policy-layer feasibility signals (DESIGN.md §8) ----------------
    def min_available_cost(self, default: float) -> float | None:
        """Cheapest per-call price among currently-available backends
        (``default`` prices backends without their own); None when no
        backend is available. The engine's cost-cap feasibility check."""
        costs = [b.cost_per_request if b.cost_per_request is not None
                 else default for b in self.backends if b.available()]
        return min(costs) if costs else None

    def min_latency_estimate(self, *, max_cost: float | None = None,
                             default_cost: float | None = None
                             ) -> float | None:
        """Fastest round-trip estimate among available backends (optional
        cost ceiling applied first); None when no backend qualifies. The
        engine's deadline-vs-EMA feasibility check (DESIGN.md §8)."""
        ests = []
        for b in self.backends:
            if not b.available():
                continue
            if max_cost is not None:
                cost = (b.cost_per_request
                        if b.cost_per_request is not None else default_cost)
                if cost is not None and cost > max_cost + 1e-12:
                    continue
            ests.append(b.latency_estimate())
        return min(ests) if ests else None

    # -- bounded replay of (unrouted) windows (DESIGN.md §7) ------------
    def acquire_replay_slot(self, *, window: int | None = None) -> bool:
        """Park an (unrouted) escalation window for a later replay pick
        instead of degrading it to REJECTED immediately. Bounded: at most
        ``replay_max`` windows may hold a ticket at once — a full queue
        returns False and the window falls back as before. The engine
        redeems the ticket when the window drains (``redeem_replay``)."""
        if self._replay_slots >= self.replay_max:
            self.stats.replay_dropped += 1
            if self.events is not None:
                self.events.emit(EV_REPLAY_DROPPED, window=window,
                                 reason="queue_full")
            return False
        self._replay_slots += 1
        self.stats.replay_enqueued += 1
        if self.events is not None:
            self.events.emit(EV_REPLAY_PARKED, window=window,
                             parked=self._replay_slots)
        return True

    def redeem_replay(self, constraint: RouteConstraint | None = None, *,
                      window: int | None = None) -> RemoteBackend | None:
        """Replay pick for a parked (unrouted) window at drain time: the
        first backend in policy order whose breaker has half-opened since
        submit serves the window — the replay call doubles as the probe —
        and billing attributes to that backend. Returns None when every
        breaker still refuses (the window keeps the REJECTED/fallback
        path). Always releases the ticket's slot."""
        self._replay_slots = max(0, self._replay_slots - 1)
        for b in self._ordered(constraint):
            if b.available() and (constraint is None
                                  or constraint.admits(b)):
                b.transport.grant_probe(window)   # see pick()
                self.stats.picks[b.name] += 1
                self.stats.replay_served += 1
                if self.events is not None:
                    self.events.emit(EV_REPLAY_SERVED, window=window,
                                     backend=b.name)
                return b
        self.stats.replay_dropped += 1
        if self.events is not None:
            self.events.emit(EV_REPLAY_DROPPED, window=window,
                             reason="no_backend")
        return None

    def expected_cost_per_escalation(self, default: float) -> float:
        """Price of the policy-preferred backend (healthy steady state) —
        the offline calibration's per-escalation cost estimate."""
        cands = self.candidates()
        cost = cands[0].cost_per_request if cands else None
        return default if cost is None else cost

    def shutdown(self, wait: bool = True) -> None:
        for b in self.backends:
            b.shutdown(wait=wait)

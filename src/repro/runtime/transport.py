"""Fault-aware remote-tier transport (runtime control plane, DESIGN.md §3).

The paper treats the remote DNN as an infallible local callable; real
deployments (DDNN-style cloud/edge tiers, CheapET-3's billed web API) see
timeouts, transient errors and outages. This module wraps the remote
callable in:

  * bounded in-flight windows — the escalated sub-batch is shipped in
    chunks of at most ``max_in_flight`` requests, so a single failure only
    degrades its window, never the whole batch;
  * per-window deadline + bounded retries with backoff;
  * a circuit breaker: after ``breaker_failures`` consecutive window
    failures the breaker opens and remote calls short-circuit locally for
    ``breaker_reset_s``; a single half-open probe then decides whether to
    close it again.

A failed window does NOT drop its requests: the engine maps them to the
REJECTED/fallback path of Algorithm 1 (the 2nd-level supervisor's "raise
Exception" branch), which the scheduler resolves via the fallback callable.

For the pipelined serving path (DESIGN.md §5) the transport also exposes a
non-blocking futures API: ``submit(batch)`` schedules the same windowed /
retried / breaker-guarded ``call`` on a thread pool and returns a
``TransportFuture``; ``poll``/``result`` drain it. Breaker and stats
mutations are lock-protected so concurrent in-flight windows stay
consistent; the remote callable itself runs unlocked and must be
thread-safe when ``max_concurrent > 1``.

The clock and sleep functions are injectable so tests and benchmarks can
run outage episodes deterministically without wall-clock waits.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np


class RemoteCallError(Exception):
    """Remote tier invocation failed (transient or terminal)."""


class RemoteTimeout(RemoteCallError):
    """Remote tier exceeded its deadline (raise from fault hooks too)."""


class CircuitOpenError(RemoteCallError):
    """Call short-circuited: the breaker is open."""


CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"


@dataclass(frozen=True)
class TransportConfig:
    max_in_flight: int = 8        # requests per transport window
    timeout_s: float = 2.0        # per-window deadline
    max_retries: int = 2          # retries per window (beyond first try)
    retry_backoff_s: float = 0.02
    breaker_failures: int = 3     # consecutive window failures to open
    breaker_reset_s: float = 5.0  # open -> half-open after this long
    max_concurrent: int = 8       # submit() thread-pool width


@dataclass
class TransportStats:
    windows: int = 0
    requests: int = 0
    failed_requests: int = 0
    retries: int = 0
    timeouts: int = 0
    errors: int = 0
    short_circuited: int = 0      # requests rejected while breaker open
    breaker_opens: int = 0


class CircuitBreaker:
    """Consecutive-failure breaker with a single half-open probe."""

    def __init__(self, failures: int, reset_s: float,
                 clock: Callable[[], float] = time.monotonic):
        self.failure_threshold = max(1, failures)
        self.reset_s = reset_s
        self._clock = clock
        self.state = CLOSED
        self.consecutive_failures = 0
        self.opens = 0
        self._opened_at = 0.0

    def allow(self) -> bool:
        if self.state == OPEN:
            if self._clock() - self._opened_at >= self.reset_s:
                self.state = HALF_OPEN     # admit one probe
                return True
            return False
        return True

    def record_success(self) -> None:
        self.state = CLOSED
        self.consecutive_failures = 0

    def record_failure(self) -> None:
        self.consecutive_failures += 1
        if (self.state == HALF_OPEN
                or self.consecutive_failures >= self.failure_threshold):
            if self.state != OPEN:
                self.opens += 1
            self.state = OPEN
            self._opened_at = self._clock()


def _rows(batch: Any) -> int:
    if isinstance(batch, dict):
        return _rows(next(iter(batch.values())))
    return int(np.asarray(batch).shape[0])


def _slice(batch: Any, lo: int, hi: int) -> Any:
    if isinstance(batch, dict):
        return {k: _slice(v, lo, hi) for k, v in batch.items()}
    return batch[lo:hi]


class TransportFuture:
    """Handle for one in-flight ``submit``; resolves to ``(logits, ok)``.

    ``result`` never raises for remote faults — failures surface as
    ``ok == False`` rows, exactly like the synchronous ``call``.
    """

    def __init__(self, future: Future, n: int):
        self._future = future
        self.n = n                # requests riding on this future

    def done(self) -> bool:
        return self._future.done()

    def result(self, timeout: float | None = None):
        return self._future.result(timeout)


class RemoteTransport:
    """Windowed, retried, breaker-guarded wrapper over a remote callable.

    ``call(batch)`` returns ``(logits [n, C] float32, ok [n] bool)``:
    per-request success flags instead of an exception, so partial failures
    degrade to per-request fallback rather than batch loss. Rows with
    ``ok == False`` have zero logits and must not be trusted.

    ``submit(batch)`` is the non-blocking variant: the same call runs on
    a thread pool and the returned ``TransportFuture`` resolves to the
    identical ``(logits, ok)`` pair — the pipelined engine keeps several
    microbatches in flight this way (DESIGN.md §5).
    """

    def __init__(self, remote_apply: Callable, config: TransportConfig
                 = TransportConfig(), *,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep):
        self.remote_apply = remote_apply
        self.config = config
        self.stats = TransportStats()
        self._clock = clock
        self._sleep = sleep
        self._lock = threading.RLock()
        self._pool: ThreadPoolExecutor | None = None
        self.breaker = CircuitBreaker(config.breaker_failures,
                                      config.breaker_reset_s, clock=clock)

    # -- single window -----------------------------------------------------
    def _call_window(self, window: Any) -> np.ndarray:
        """One attempt: invoke the remote tier, enforcing the deadline."""
        t0 = self._clock()
        out = np.asarray(self.remote_apply(window))
        if self._clock() - t0 > self.config.timeout_s:
            raise RemoteTimeout(
                f"remote window exceeded {self.config.timeout_s}s deadline")
        return out

    def _call_with_retries(self, window: Any) -> np.ndarray:
        """One window: retries absorb transient faults; only a window that
        exhausts its retries counts as a breaker failure (so a single
        flaky window never opens the breaker on its own)."""
        last: Exception | None = None
        for attempt in range(1 + self.config.max_retries):
            with self._lock:
                allowed = self.breaker.allow()
            if not allowed:
                raise CircuitOpenError("circuit breaker open")
            try:
                out = self._call_window(window)
            except RemoteTimeout as e:
                with self._lock:
                    self.stats.timeouts += 1
                last = e
            except CircuitOpenError:
                raise
            except Exception as e:  # transient transport / remote error
                with self._lock:
                    self.stats.errors += 1
                last = e
            else:
                with self._lock:
                    self.breaker.record_success()
                return out
            if attempt < self.config.max_retries:
                with self._lock:
                    self.stats.retries += 1
                if self.config.retry_backoff_s > 0:
                    self._sleep(self.config.retry_backoff_s * (attempt + 1))
        with self._lock:
            self.breaker.record_failure()
        raise RemoteCallError(f"remote window failed after "
                              f"{1 + self.config.max_retries} attempts: "
                              f"{last!r}") from last

    # -- public API --------------------------------------------------------
    def call(self, batch: Any) -> tuple[np.ndarray | None, np.ndarray]:
        n = _rows(batch)
        ok = np.zeros((n,), bool)
        outs: list[tuple[int, np.ndarray]] = []
        w = max(1, self.config.max_in_flight)
        for lo in range(0, n, w):
            hi = min(lo + w, n)
            with self._lock:
                self.stats.windows += 1
                self.stats.requests += hi - lo
                allowed = self.breaker.allow()
            if not allowed:
                with self._lock:
                    self.stats.short_circuited += hi - lo
                    self.stats.failed_requests += hi - lo
                continue
            try:
                out = self._call_with_retries(_slice(batch, lo, hi))
            except CircuitOpenError:
                with self._lock:
                    self.stats.short_circuited += hi - lo
                    self.stats.failed_requests += hi - lo
                continue
            except RemoteCallError:
                with self._lock:
                    self.stats.failed_requests += hi - lo
                continue
            ok[lo:hi] = True
            outs.append((lo, out))
        with self._lock:
            self.stats.breaker_opens = self.breaker.opens
        if not outs:
            return None, ok
        width = outs[0][1].shape[1:]
        logits = np.zeros((n,) + width, np.float32)
        for lo, out in outs:
            logits[lo:lo + out.shape[0]] = out
        return logits, ok

    def submit(self, batch: Any) -> TransportFuture:
        """Non-blocking ``call``: schedule the batch on the thread pool and
        return a future resolving to the same ``(logits, ok)`` pair."""
        with self._lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=max(1, self.config.max_concurrent),
                    thread_name_prefix="remote-transport")
            pool = self._pool
        return TransportFuture(pool.submit(self.call, batch), _rows(batch))

    def poll(self, future: TransportFuture) -> bool:
        """True iff the future's (logits, ok) is ready to drain."""
        return future.done()

    def shutdown(self, wait: bool = True) -> None:
        """Tear down the submit() pool (in-flight calls finish if wait)."""
        with self._lock:
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=wait)

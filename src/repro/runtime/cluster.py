"""Horizontal scale-out: N engines behind one logical cascade
(DESIGN.md §12).

The paper prices the cascade per request; the deployment shape it
implies (CheapET-3: a fleet of cheap local predictors gating one
metered remote API) prices it per *fleet*. A single engine already
holds a remote-fraction/$ budget, attributes cache hits to filling
backends and sheds under overload — this module lifts all three to N
replicas without giving up the repo's determinism contract:

* ``SharedResponseCache`` — one logical content-keyed response store
  over N engine-facing views, with a **single-fill ownership rule**:
  the first replica to miss a key claims it and performs the remote
  call; every other replica either waits for the fill or serves the
  hit later at $0 with the filler's backend attribution. Fills are
  published on a seq-ordered update feed, so any merge order of the
  feed reconstructs the same store (per key there is exactly one
  record).

* ``ClusterBudgetController`` — periodically pools the per-replica
  EMA/PI controller states (rolling 1st-level score buffers, traffic
  deltas) into one global remote-fraction or dollar budget, places a
  single pooled score threshold, and pushes each replica's *demand* at
  that threshold back down as its new target. The traffic-weighted
  mean of the pushed targets equals the global target by construction,
  so the fleet budget holds even when one replica sees only hard
  traffic and another only easy. Replicas with zero traffic since the
  last reconcile (blackout) are excluded and degrade to the base
  per-replica budget; iteration is sorted by replica name everywhere,
  so registration/merge order never changes the result.

* ``admission_scale`` — the cluster shed rule: each replica's soft
  admission watermark (DESIGN.md §10) scales with its current budget
  share, so a replica the reconciler squeezed sheds earlier and one
  granted headroom rides closer to its hard bound.

* ``ClusterHarness`` — an in-process cluster: N ``CascadeEngine``
  replicas (each on its own worker thread, with per-replica-labelled
  metrics/events over one shared registry/log) against one shared
  router/chaos schedule and one virtual clock. Replicas flush in a
  seeded-permutation merge order, serialized turn by turn, so a double
  run is bit-identical — the property the cluster bench gates in CI.
"""

from __future__ import annotations

import functools
import queue
import random
import threading
from collections import OrderedDict
from collections.abc import Callable
from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.runtime.cache import CacheStats, _row, content_key, content_keys
from repro.runtime.chaos import VirtualClock
from repro.runtime.controller import AdaptiveController
from repro.runtime.observability import (EV_CLUSTER_RECONCILE, EventLog,
                                         MetricsRegistry, Observability)

__all__ = [
    "CacheUpdate",
    "ClusterBudgetConfig",
    "ClusterBudgetController",
    "ClusterBudgetState",
    "ClusterHarness",
    "ClusterReplica",
    "ReplicaCacheView",
    "SharedCacheStats",
    "SharedResponseCache",
    "cluster_billing",
]


# --------------------------------------------------------------------------
# shared response cache: single-fill protocol over N replica views
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class CacheUpdate:
    """One record of the seq-ordered fill feed: replica ``replica``
    filled ``key`` from backend ``source``. Exactly one record exists
    per key under the single-fill rule (absent evictions), so applying
    the feed in ANY order reconstructs the same store."""
    seq: int
    key: bytes
    value: np.ndarray
    source: str | None
    replica: str


@dataclass
class SharedCacheStats:
    fills: int = 0              # first-fill puts (feed records)
    # a put on an already-filled key by a DIFFERENT replica: evidence of
    # a cross-replica double fetch — the single-fill invariant the
    # cluster bench gates on is duplicate_fills == 0
    duplicate_fills: int = 0
    # a re-put by the SAME replica: duplicate rows inside one window
    # (both rode the one remote call that filled the key) — benign
    redundant_puts: int = 0
    waits: int = 0              # lookups that blocked on a peer's fill
    steals: int = 0             # claims taken over after a wait timeout
    releases: int = 0           # claims dropped by release_unfilled
    evictions: int = 0          # LRU evictions (capacity pressure)


class SharedResponseCache:
    """One logical content-keyed response store shared by N replicas.

    Single-fill ownership (DESIGN.md §12): a ``lookup`` miss on an
    unclaimed key *claims* it for the looking replica, which then
    performs the remote call and ``put``s the value. A concurrent
    lookup of a claimed key on another replica blocks (bounded by
    ``wait_s``) until the owner's fill lands, then serves the hit with
    the owner's backend attribution — the same content is never fetched
    remotely twice. A replica whose fill failed calls
    ``release_unfilled`` so waiting peers can re-claim.

    The store is bounded LRU like ``RemoteResponseCache``; pending
    claims are never evicted. All state transitions happen under one
    condition variable, and fills append to a seq-ordered ``feed``.
    """

    def __init__(self, capacity: int = 4096, *,
                 key_fn: Callable = content_key,
                 key_batch_fn: Callable | None = None,
                 wait_s: float = 30.0):
        if capacity < 1:
            raise ValueError("cache capacity must be >= 1")
        self.capacity = capacity
        self.key_fn = key_fn
        if key_batch_fn is None and key_fn is content_key:
            key_batch_fn = content_keys
        self.key_batch_fn = key_batch_fn
        self.wait_s = wait_s
        self.stats = SharedCacheStats()
        self.feed: list[CacheUpdate] = []
        self._cond = threading.Condition()
        # key -> (value, source backend, filling replica)
        self._store: OrderedDict[
            bytes, tuple[np.ndarray, str | None, str]] = OrderedDict()
        self._pending: dict[bytes, str] = {}    # key -> owning replica
        self._views: dict[str, ReplicaCacheView] = {}

    def view(self, replica: str, *, key_fn: Callable | None = None,
             key_batch_fn: Callable | None = None) -> "ReplicaCacheView":
        """The engine-facing cache handle for one replica (duck-types
        ``RemoteResponseCache``). Key functions default to the shared
        store's; per-view overrides must agree across replicas or keys
        will not collide."""
        if replica in self._views:
            return self._views[replica]
        v = ReplicaCacheView(self, replica,
                             key_fn=key_fn or self.key_fn,
                             key_batch_fn=(key_batch_fn
                                           or self.key_batch_fn))
        self._views[replica] = v
        return v

    def __len__(self) -> int:
        with self._cond:
            return len(self._store)

    def _lookup(self, replica: str, key: bytes
                ) -> tuple[np.ndarray, str | None, str] | None:
        """Hit -> ``(value, source, filler_replica)``; miss -> None and
        the key is claimed by ``replica`` (single-fill). Blocks while a
        *different* replica holds the claim; the owner's own re-lookup
        (duplicate rows inside one window) misses again immediately."""
        with self._cond:
            while True:
                ent = self._store.get(key)
                if ent is not None:
                    self._store.move_to_end(key)
                    return ent
                owner = self._pending.get(key)
                if owner is None or owner == replica:
                    self._pending[key] = replica
                    return None
                self.stats.waits += 1
                if not self._cond.wait(timeout=self.wait_s):
                    # liveness valve: the owner stalled past wait_s —
                    # steal the claim and refetch rather than hang
                    self.stats.steals += 1
                    self._pending[key] = replica
                    return None

    def _fill(self, replica: str, key: bytes, value: np.ndarray,
              source: str | None) -> bool:
        """Publish a fill. First fill per key wins (and is the feed
        record); a duplicate fill is counted and DISCARDED so every
        replica keeps serving the identical first value."""
        with self._cond:
            ent = self._store.get(key)
            if ent is not None:
                if ent[2] == replica:
                    self.stats.redundant_puts += 1
                else:
                    self.stats.duplicate_fills += 1
                self._store.move_to_end(key)
                return False
            self._store[key] = (np.asarray(value), source, replica)
            self._pending.pop(key, None)
            self.feed.append(CacheUpdate(len(self.feed), key,
                                         self._store[key][0], source,
                                         replica))
            self.stats.fills += 1
            while len(self._store) > self.capacity:
                self._store.popitem(last=False)
                self.stats.evictions += 1
            self._cond.notify_all()
            return True

    def release_unfilled(self, replica: str) -> int:
        """Drop every claim ``replica`` still holds (its fills failed or
        were shed) so waiting peers can re-claim. The harness calls this
        after each replica's flush turn; transports call it on teardown."""
        with self._cond:
            stale = [k for k, o in self._pending.items() if o == replica]
            for k in stale:
                del self._pending[k]
            if stale:
                self.stats.releases += len(stale)
                self._cond.notify_all()
            return len(stale)

    def clear(self) -> None:
        with self._cond:
            self._store.clear()
            self._pending.clear()
            self._cond.notify_all()

    @staticmethod
    def materialize(feed: list[CacheUpdate]
                    ) -> dict[bytes, tuple[bytes, str | None, str]]:
        """Reduce a fill feed to ``{key: (value bytes, source,
        replica)}``. First record per key wins — with single-fill intact
        there IS only one, so any permutation of ``feed`` produces the
        identical mapping (the determinism property tests assert)."""
        out: dict[bytes, tuple[bytes, str | None, str]] = {}
        for u in sorted(feed, key=lambda u: u.seq):
            out.setdefault(u.key,
                           (u.value.tobytes(), u.source, u.replica))
        return out


class ReplicaCacheView:
    """Per-replica handle onto a ``SharedResponseCache``; duck-types the
    ``RemoteResponseCache`` surface the engine uses (``stats``,
    ``keys_for``, ``lookup``, ``get``, ``put``, ``clear``, ``len``).
    ``stats`` counts this replica's traffic; ``stats.cross_hits`` counts
    hits served from entries a *different* replica filled."""

    def __init__(self, shared: SharedResponseCache, replica: str, *,
                 key_fn: Callable = content_key,
                 key_batch_fn: Callable | None = None):
        self.shared = shared
        self.replica = replica
        self.key_fn = key_fn
        if key_batch_fn is None and key_fn is content_key:
            key_batch_fn = content_keys
        self.key_batch_fn = key_batch_fn
        self.stats = CacheStats()

    def keys_for(self, batch: Any, rows: int) -> list[bytes]:
        if self.key_batch_fn is not None:
            return self.key_batch_fn(batch, rows)
        return [self.key_fn(_row(batch, i)) for i in range(rows)]

    def lookup(self, key: bytes) -> tuple[np.ndarray, str | None] | None:
        ent = self.shared._lookup(self.replica, key)
        if ent is None:
            self.stats.misses += 1
            return None
        value, source, filler = ent
        self.stats.hits += 1
        if filler != self.replica:
            self.stats.cross_hits += 1
        return value, source

    def get(self, key: bytes) -> np.ndarray | None:
        hit = self.lookup(key)
        return None if hit is None else hit[0]

    def put(self, key: bytes, value: np.ndarray,
            source: str | None = None) -> None:
        self.shared._fill(self.replica, key, value, source)

    def clear(self) -> None:
        self.shared.clear()

    def __len__(self) -> int:
        return len(self.shared)


# --------------------------------------------------------------------------
# cluster budget reconcile
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ClusterBudgetConfig:
    """Knobs of the cluster-level budget reconcile (DESIGN.md §12)."""
    target_remote_fraction: float = 0.2   # global fraction budget
    cost_budget_per_request: float | None = None   # global $; None=frac
    interval_s: float = 2.0               # reconcile cadence
    target_floor: float = 0.02            # min per-replica target pushed
    min_pooled_scores: int = 64           # below -> degraded mode
    share_min: float = 0.25               # admission_scale clamp
    share_max: float = 4.0


@dataclass
class ClusterBudgetState:
    reconciles: int = 0
    mode: str = "warmup"          # warmup | pooled | degraded
    tau: float | None = None      # pooled score threshold placed
    global_target: float | None = None    # effective global fraction
    global_ema_fraction: float | None = None  # traffic-weighted realised
    targets: dict[str, float] = field(default_factory=dict)
    stale: tuple[str, ...] = ()   # replicas excluded this round
    last_now: float | None = None


class ClusterBudgetController:
    """Reconciles N per-replica EMA/PI controllers into one global
    budget and pushes re-weighted targets back down.

    Pooled mode: concatenate every live replica's rolling score buffer
    (buffer sizes are traffic-proportional, so the pool is the fleet's
    score distribution), place the global threshold ``tau`` at the
    target quantile, and push each replica the fraction of *its own*
    scores below ``tau``. The traffic-weighted mean of the pushed
    targets equals the global target by construction — the budget holds
    under skew while hard-traffic replicas legitimately spend more.

    Degraded mode (staleness bound = one reconcile interval): replicas
    with zero eligible traffic since the last reconcile are excluded
    from the pool and reset to the base target, as is everyone when
    fewer than two replicas are live or the pool is too thin — per-
    replica budgets, never silent drops.

    Dollar mode: with ``cost_budget_per_request`` set, the global
    fraction target is re-derived first from the fleet-blended $ per
    escalation (traffic-weighted over live replicas), then the same
    pooled reallocation runs; per-replica controllers stay in fraction
    mode and the cluster holds the dollar budget.

    All iteration is sorted by replica name: registration order and
    reconcile merge order cannot change any output bit.
    """

    def __init__(self, config: ClusterBudgetConfig | None = None):
        self.config = config if config is not None else ClusterBudgetConfig()
        self.state = ClusterBudgetState()
        self._replicas: dict[str, AdaptiveController] = {}
        self._last_requests: dict[str, int] = {}
        self.events: Any = None     # raw shared EventLog (cluster scope)

    def register(self, name: str, controller: AdaptiveController) -> None:
        if name in self._replicas:
            raise ValueError(f"duplicate replica name {name!r}")
        self._replicas[name] = controller
        self._last_requests[name] = controller.lifetime_requests
        self.state.targets[name] = self.config.target_remote_fraction

    def names(self) -> list[str]:
        return sorted(self._replicas)

    def target(self, name: str) -> float:
        return self.state.targets.get(
            name, self.config.target_remote_fraction)

    def admission_scale(self, name: str) -> float:
        """This replica's budget share relative to the global target —
        the scheduler's soft watermark multiplier (cluster shed rule,
        DESIGN.md §12). 1.0 until the first reconcile."""
        cfg = self.config
        base = self.state.global_target or cfg.target_remote_fraction
        if base <= 0.0:
            return 1.0
        scale = self.target(name) / base
        return float(min(max(scale, cfg.share_min), cfg.share_max))

    def _effective_target(self, live: list[str],
                          weights: dict[str, int]) -> float:
        cfg = self.config
        target = cfg.target_remote_fraction
        if cfg.cost_budget_per_request is None:
            return target
        num = den = 0.0
        for name in live:
            c = self._replicas[name].state.ema_cost_per_escalation
            if c is not None:
                num += weights[name] * c
                den += weights[name]
        if den == 0.0:
            return target
        blended = num / den
        if blended <= 0.0:
            return 1.0      # free escalations: the $ budget never binds
        return float(np.clip(
            cfg.cost_budget_per_request / blended, 0.0, 1.0))

    def reconcile(self, now: float) -> ClusterBudgetState:
        """One reconcile pass: weigh replicas by eligible-traffic delta,
        pool live score buffers, place ``tau``, push targets. Returns
        (and keeps) the new state; emits one ``cluster_reconcile``
        event when an event log is attached."""
        cfg, st = self.config, self.state
        live: list[str] = []
        weights: dict[str, int] = {}
        for name in self.names():
            total = self._replicas[name].lifetime_requests
            delta = total - self._last_requests[name]
            self._last_requests[name] = total
            weights[name] = delta
            if delta > 0:
                live.append(name)
        target = self._effective_target(live, weights)
        scores = {name: self._replicas[name].recent_scores()
                  for name in live}
        pooled_n = sum(s.size for s in scores.values())
        targets: dict[str, float] = {}
        tau: float | None = None
        if len(live) >= 2 and pooled_n >= cfg.min_pooled_scores:
            mode = "pooled"
            pool = np.concatenate([scores[n] for n in live])
            tau = float(np.quantile(pool, target))
            for name in live:
                s = scores[name]
                d = float(np.mean(s < tau)) if s.size else target
                targets[name] = float(np.clip(d, cfg.target_floor, 1.0))
        else:
            mode = "degraded"
            for name in live:
                targets[name] = target
        for name in self.names():
            if name not in targets:     # stale (blackout) -> base budget
                targets[name] = cfg.target_remote_fraction
        for name in self.names():
            self._replicas[name].retarget(targets[name])
        # traffic-weighted realised fraction (telemetry + bench check)
        num = den = 0.0
        for name in self.names():
            ctrl = self._replicas[name]
            if ctrl.state.windows > 0 and ctrl.lifetime_requests > 0:
                num += ctrl.lifetime_requests * ctrl.state.ema_fraction
                den += ctrl.lifetime_requests
        st.reconciles += 1
        st.mode = mode
        st.tau = tau
        st.global_target = target
        st.global_ema_fraction = (num / den) if den else None
        st.targets = targets
        st.stale = tuple(n for n in self.names() if n not in live)
        st.last_now = now
        if self.events is not None:
            self.events.emit(
                EV_CLUSTER_RECONCILE, window=st.reconciles, mode=mode,
                tau=tau, global_target=target,
                global_ema_fraction=st.global_ema_fraction,
                targets={n: targets[n] for n in self.names()},
                stale=list(st.stale), now=now)
        return st

    def install_metrics(self, registry: MetricsRegistry) -> None:
        """Register a snapshot-time collector exporting per-replica
        targets and cluster reconcile telemetry."""
        registry.register_collector(self._collect)

    def _collect(self, reg: MetricsRegistry) -> None:
        st = self.state
        reg.gauge("cluster_reconciles").set(st.reconciles)
        reg.gauge("cluster_global_target").set(st.global_target)
        reg.gauge("cluster_global_ema_remote_fraction").set(
            st.global_ema_fraction)
        reg.gauge("cluster_stale_replicas").set(len(st.stale))
        for name in self.names():
            reg.gauge("cluster_target_remote_fraction",
                      replica=name).set(self.target(name))


# --------------------------------------------------------------------------
# per-replica observability proxies (shared registry/log, labelled)
# --------------------------------------------------------------------------

class _ReplicaMetrics:
    """``MetricsRegistry`` facade that stamps ``replica=<name>`` onto
    every series; collectors registered through it run against the
    proxy, so derived gauges label themselves too."""

    def __init__(self, registry: MetricsRegistry, replica: str):
        self._registry = registry
        self.replica = replica

    def counter(self, name: str, **labels: Any):
        return self._registry.counter(name, replica=self.replica,
                                      **labels)

    def gauge(self, name: str, **labels: Any):
        return self._registry.gauge(name, replica=self.replica, **labels)

    def histogram(self, name: str, buckets=None, **labels: Any):
        if buckets is None:
            return self._registry.histogram(
                name, replica=self.replica, **labels)
        return self._registry.histogram(name, buckets,
                                        replica=self.replica, **labels)

    def register_collector(self, fn: Callable) -> None:
        self._registry.register_collector(
            functools.partial(self._run_collector, fn))

    def _run_collector(self, fn: Callable, _reg: MetricsRegistry) -> None:
        fn(self)

    def snapshot(self) -> dict:
        return self._registry.snapshot()

    def render_prometheus(self) -> str:
        return self._registry.render_prometheus()


class _ReplicaEvents:
    """``EventLog`` facade stamping ``replica=<name>`` onto every emit;
    reads pass through to the shared log (global seq order preserved)."""

    def __init__(self, log: EventLog, replica: str):
        self._log = log
        self.replica = replica

    @property
    def _clock(self):
        return self._log._clock

    @_clock.setter
    def _clock(self, clock) -> None:
        self._log._clock = clock

    def emit(self, event: str, *, window: int | None = None,
             backend: str | None = None, **fields: Any) -> dict:
        fields.setdefault("replica", self.replica)
        return self._log.emit(event, window=window, backend=backend,
                              **fields)

    def events(self, event: str | None = None,
               backend: str | None = None) -> list[dict]:
        return self._log.events(event, backend)

    def counts(self) -> dict[str, int]:
        return self._log.counts()

    def first_seq(self, event: str, backend: str | None = None
                  ) -> int | None:
        return self._log.first_seq(event, backend)

    @property
    def dropped(self) -> int:
        return self._log.dropped

    @property
    def total(self) -> int:
        return self._log.total


# --------------------------------------------------------------------------
# in-process cluster harness
# --------------------------------------------------------------------------

class _Worker(threading.Thread):
    """Dedicated per-replica worker: the harness funnels every engine
    interaction for a replica through its thread (production affinity),
    but serializes turns, so determinism is by construction."""

    def __init__(self, name: str):
        super().__init__(name=f"replica-{name}", daemon=True)
        self._jobs: queue.Queue = queue.Queue()
        self.start()

    def run(self) -> None:
        while True:
            job = self._jobs.get()
            if job is None:
                return
            fn, box, done = job
            try:
                box["result"] = fn()
            except Exception as exc:        # surfaced in run_sync
                box["error"] = exc
            done.set()

    def run_sync(self, fn: Callable[[], Any]) -> Any:
        box: dict[str, Any] = {}
        done = threading.Event()
        self._jobs.put((fn, box, done))
        done.wait()
        if "error" in box:
            raise box["error"]
        return box.get("result")

    def stop(self) -> None:
        self._jobs.put(None)
        self.join(timeout=5.0)


@dataclass
class ClusterReplica:
    """One replica's runtime stack inside a ``ClusterHarness``."""
    name: str
    engine: Any
    scheduler: Any
    controller: AdaptiveController
    cache: ReplicaCacheView | None
    worker: _Worker


class ClusterHarness:
    """N ``CascadeEngine`` replicas behind one logical cascade.

    Shared across replicas: the remote router (and any chaos schedule
    wrapped around it), the response store (``SharedResponseCache``),
    the budget reconciler, the metrics registry, the event log and the
    clock. Per replica: engine, scheduler (with the cluster admission
    share wired), adaptive controller, cache view, worker thread, and
    ``replica=<name>`` labels on every metric/event it emits. Fleet-
    scope emitters (router, backend transports, chaos markers,
    reconcile events) write to the raw shared log, unlabelled.

    ``flush()`` drains replicas one at a time in a seeded-permutation
    merge order — adversarial, but deterministic given the seed — and
    runs the budget reconcile on cadence. Two runs with identical
    inputs, seeds and clock advances are bit-identical (the cluster
    bench double-runs and gates on it).
    """

    def __init__(self, config: Any, local_apply: Callable, *,
                 transport: Any, fallback: Callable | None = None,
                 clock: Callable[[], float] | None = None, seed: int = 0,
                 reconcile_interval_s: float = 2.0,
                 cache_key_fn: Callable | None = None,
                 cache_key_batch_fn: Callable | None = None,
                 cluster_config: ClusterBudgetConfig | None = None):
        from repro.serving.engine import CascadeEngine
        from repro.serving.scheduler import MicrobatchScheduler
        if config.replicas < 1:
            raise ValueError("config.replicas must be >= 1")
        if config.build_controller() is None:
            raise ValueError("cluster needs adaptive=True (the reconcile "
                             "re-targets per-replica controllers)")
        self.config = config
        self.router = transport
        self._clock = clock if clock is not None else VirtualClock()
        self._rng = random.Random(seed)
        self.reconcile_interval_s = reconcile_interval_s
        self._last_reconcile = self._clock()
        # shared observability: one registry + one seq-ordered log
        self.metrics: MetricsRegistry | None = None
        self.events: EventLog | None = None
        if config.observability:
            self.metrics = MetricsRegistry()
            self.events = EventLog(config.event_capacity,
                                   clock=self._clock)
        # shared response store (single-fill protocol)
        self.shared_cache: SharedResponseCache | None = None
        if config.cache_size > 0:
            self.shared_cache = SharedResponseCache(
                config.cache_size,
                key_fn=cache_key_fn or content_key,
                key_batch_fn=cache_key_batch_fn)
        # cluster budget reconciler
        if cluster_config is None:
            cluster_config = ClusterBudgetConfig(
                target_remote_fraction=config.remote_fraction_budget,
                cost_budget_per_request=config.cost_budget,
                interval_s=reconcile_interval_s)
        self.cluster = ClusterBudgetController(cluster_config)
        self.cluster.events = self.events
        if self.metrics is not None:
            self.cluster.install_metrics(self.metrics)
        # one mesh for every replica (same devices; DESIGN.md §12)
        mesh = None
        if config.data_parallel:
            from repro.launch.mesh import make_serving_mesh
            mesh = make_serving_mesh()
        self.replicas: OrderedDict[str, ClusterReplica] = OrderedDict()
        for i in range(config.replicas):
            name = f"r{i}"
            controller = config.build_controller()
            view = (self.shared_cache.view(name)
                    if self.shared_cache is not None else None)
            obs = None
            if config.observability:
                obs = Observability(
                    metrics=_ReplicaMetrics(self.metrics, name),
                    events=_ReplicaEvents(self.events, name))
            engine = CascadeEngine.from_config(
                config, local_apply, transport=transport,
                controller=controller, cache=view, observability=obs,
                mesh=mesh, clock=self._clock)
            sched = MicrobatchScheduler.from_config(
                engine, config, fallback=fallback,
                admission_share=functools.partial(
                    self.cluster.admission_scale, name))
            self.cluster.register(name, controller)
            self.replicas[name] = ClusterReplica(
                name, engine, sched, controller, view, _Worker(name))
        # per-replica installs each re-pointed the shared router at
        # their labelled proxy (last one wins) — the router and its
        # transports are fleet-scope, so re-attach the raw log
        if self.events is not None and self.router is not None \
                and hasattr(self.router, "attach_events"):
            self.router.attach_events(self.events)
        self._closed = False

    # -- driving -------------------------------------------------------
    @property
    def names(self) -> list[str]:
        return list(self.replicas)

    def replica(self, name: str) -> ClusterReplica:
        return self.replicas[name]

    def submit(self, replica: str, request: Any) -> Any:
        """Enqueue one request on a replica (its worker thread runs the
        admission decision). Returns the immediate SHED response when
        admission refuses it, else None."""
        rep = self.replicas[replica]
        return rep.worker.run_sync(
            functools.partial(rep.scheduler.submit, request))

    def flush(self, *, reconcile: bool = True
              ) -> dict[str, list[Any]]:
        """Drain every replica once, in a fresh seeded-permutation merge
        order, releasing unfilled cache claims after each turn; then
        reconcile the cluster budget if the cadence is due. Returns
        ``{replica: [responses]}`` (insertion order = merge order)."""
        out: dict[str, list[Any]] = {}
        order = self._rng.sample(self.names, len(self.replicas))
        for name in order:
            rep = self.replicas[name]
            out[name] = rep.worker.run_sync(rep.scheduler.flush)
            if self.shared_cache is not None:
                self.shared_cache.release_unfilled(name)
        if reconcile:
            self.maybe_reconcile()
        return out

    def maybe_reconcile(self, now: float | None = None
                        ) -> ClusterBudgetState | None:
        """Run the budget reconcile when the cadence interval elapsed
        (the staleness bound of DESIGN.md §12); None when not due."""
        now = self._clock() if now is None else now
        if now - self._last_reconcile < self.reconcile_interval_s:
            return None
        self._last_reconcile = now
        return self.cluster.reconcile(now)

    # -- aggregation ---------------------------------------------------
    def global_billing(self) -> dict[str, Any]:
        """Fleet-level billing: the per-replica ``CascadeStats`` summed
        in sorted replica order (replica-order invariant)."""
        return cluster_billing(
            {n: r.engine.stats for n, r in self.replicas.items()})

    def close(self, wait: bool = True) -> None:
        """Drain every replica, then shut engines down (the shared
        router's shutdown is idempotent across replicas) and stop the
        worker threads."""
        if self._closed:
            return
        self._closed = True
        self.flush(reconcile=False)
        for name in self.names:
            rep = self.replicas[name]
            rep.worker.run_sync(
                functools.partial(rep.engine.close, wait))
            rep.worker.stop()


def cluster_billing(stats_by_replica: dict[str, Any]) -> dict[str, Any]:
    """Aggregate per-replica ``CascadeStats`` into fleet totals.

    Iterates replicas (and their per-backend slices) in sorted-name
    order so float accumulation is independent of dict insertion /
    merge order — the property the permutation tests pin down. Returns
    ``{"billing": {field: total}, "per_backend": {name: {...}}}`` over
    exactly the ``BILLING_FIELDS`` contract.
    """
    from repro.serving.engine import BILLING_FIELDS
    billing: dict[str, Any] = dict.fromkeys(BILLING_FIELDS, 0)
    per_backend: dict[str, dict[str, Any]] = {}
    for name in sorted(stats_by_replica):
        st = stats_by_replica[name]
        for f in BILLING_FIELDS:
            billing[f] = billing[f] + getattr(st, f)
        for bname in sorted(st.per_backend):
            u = st.per_backend[bname]
            agg = per_backend.setdefault(bname, {
                "remote_calls": 0, "cache_hits": 0,
                "transport_failures": 0, "cost": 0.0,
                "remote_latency_s": 0.0})
            agg["remote_calls"] += u.remote_calls
            agg["cache_hits"] += u.cache_hits
            agg["transport_failures"] += u.transport_failures
            agg["cost"] += u.cost
            agg["remote_latency_s"] += u.remote_latency_s
    return {"billing": billing, "per_backend": per_backend}

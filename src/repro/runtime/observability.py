"""Cascade observability layer (DESIGN.md §9).

BiSupervised's value proposition is an operational trade-off — dollars
saved vs accuracy lost *per request* — so the runtime must be
inspectable at per-request granularity, not just through aggregate
``CascadeStats`` counters after the fact. This module is the one place
that visibility lives; it is zero-dependency (stdlib + numpy) and every
hook is no-op-cheap when observability is disabled (the engine guards
each stamp behind one ``is not None`` check and allocates nothing per
row).

Three components behind one ``Observability`` facade:

* ``MetricsRegistry`` — counters, gauges and fixed-bucket histograms,
  snapshotable as JSON (``snapshot``) and Prometheus exposition text
  (``render_prometheus``). Hot-path publishers touch counters once per
  *window* (commit time); everything derivable from existing stats
  objects (escalation fraction, breaker state, controller EMA, cache
  hit ratio, per-backend inflight/cost/latency) is sampled lazily at
  snapshot time via registered collector callbacks, so steady-state
  serving pays nothing for gauges.

* ``TraceSink`` — a bounded buffer of per-request span timelines
  (enqueue → pack → dispatch → gate → route → remote-RTT or cache-hit
  → commit → hand-back) threaded through the engine's ``_InFlight``
  bookkeeping. Spans carry disposition, backend, realised $ cost and
  the gating threshold; ``write_jsonl`` emits one span per line and
  ``write_chrome_trace`` exports the Chrome ``trace_event`` format for
  perfetto / chrome://tracing.

* ``EventLog`` — a bounded, thread-safe log of state transitions that
  previously happened silently: breaker open/half-open/close, router
  failover/fail-back, replay ticket redemption, controller drift,
  deadline/policy downgrades. Every event carries a global sequence
  number (the ordering contract — emitters live on engine and pool
  threads), a monotonic timestamp, and the window id that triggered it.

Span stage glossary, metric names and the event schema are tabulated in
DESIGN.md §9; the future chaos bench asserts against the trace/event
output as ground truth.
"""

from __future__ import annotations

import json
import threading
import time
from collections import Counter as _Counter
from collections.abc import Callable
from typing import Any

__all__ = [
    "EV_ADMISSION_DEGRADE",
    "EV_ADMISSION_SHED",
    "EV_BACKEND_AGREEMENT",
    "EV_BREAKER_CLOSE",
    "EV_BREAKER_HALF_OPEN",
    "EV_BREAKER_OPEN",
    "EV_CHAOS_BEGIN",
    "EV_CHAOS_END",
    "EV_CLUSTER_RECONCILE",
    "EV_CONTROLLER_DRIFT",
    "EV_CONTROLLER_UPDATE",
    "EV_DEADLINE_DOWNGRADE",
    "EV_POLICY_DOWNGRADE",
    "EV_REPLAY_DROPPED",
    "EV_REPLAY_PARKED",
    "EV_REPLAY_SERVED",
    "EV_ROUTER_FAILBACK",
    "EV_ROUTER_FAILOVER",
    "EV_STAGE_ANSWER",
    "EV_TIER_RECONCILE",
    "LATENCY_BUCKETS_S",
    "SPAN_STAGES",
    "Counter",
    "EventLog",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "MetricsServer",
    "Observability",
    "TraceSink",
]

# -- event names (DESIGN.md §9 event schema) --------------------------------
EV_BREAKER_OPEN = "breaker_open"
EV_BREAKER_HALF_OPEN = "breaker_half_open"
EV_BREAKER_CLOSE = "breaker_close"
EV_ROUTER_FAILOVER = "router_failover"
EV_ROUTER_FAILBACK = "router_failback"
EV_REPLAY_PARKED = "replay_parked"
EV_REPLAY_SERVED = "replay_served"
EV_REPLAY_DROPPED = "replay_dropped"
EV_CONTROLLER_DRIFT = "controller_drift"
EV_CONTROLLER_UPDATE = "controller_update"
EV_DEADLINE_DOWNGRADE = "deadline_downgrade"
EV_POLICY_DOWNGRADE = "policy_downgrade"
# chaos injection (DESIGN.md §10): episode activation markers — emitted
# by the ChaosRemote wrapper on the first call that observes the episode
# active / over, so cause (chaos_episode_begin) is always sequenced
# before effect (the breaker/failover events the faults trigger)
EV_CHAOS_BEGIN = "chaos_episode_begin"
EV_CHAOS_END = "chaos_episode_end"
# admission control (DESIGN.md §10): a request shed at submit (SHED
# disposition) or degraded to local-only under overload
EV_ADMISSION_SHED = "admission_shed"
EV_ADMISSION_DEGRADE = "admission_degrade"
# cluster scale-out (DESIGN.md §12): one event per ClusterBudgetController
# reconcile — carries the pooled threshold, per-replica targets and any
# replicas excluded as stale (blackout) this round
EV_CLUSTER_RECONCILE = "cluster_reconcile"
# N-tier hierarchy (DESIGN.md §13): per-commit attribution of which
# stage of a chained backend answered how many rows at what cost, the
# per-backend agreement-with-local EMA update, and one event per
# TieredBudgetController reconcile (per-hop targets re-centred on the
# global end-to-end budget)
EV_STAGE_ANSWER = "stage_answer"
EV_BACKEND_AGREEMENT = "backend_agreement"
EV_TIER_RECONCILE = "tier_reconcile"

# canonical span stage order (a span contains the subset that applies to
# its disposition; timestamps are nondecreasing in this order).
# "pack" and "join" are alternatives: windowed rows are packed into a
# microbatch, continuous-batching rows join a slot of the persistent
# batch (DESIGN.md §11); "emit" marks a trusted-local row surfaced at
# gate time by the in-kernel early emit, ahead of its window's commit
SPAN_STAGES = ("enqueue", "pack", "join", "dispatch", "gate", "route",
               "cache_hit", "remote", "commit", "emit", "handback")

# fixed histogram buckets for latency-shaped observations (seconds);
# +inf is implicit (the _count line covers it)
LATENCY_BUCKETS_S = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                     0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def _series_key(name: str, labels: tuple[tuple[str, str], ...]) -> str:
    if not labels:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonic counter. ``inc`` is a bare ``+=`` — publishers update
    from one thread (the engine's commit half); cross-thread emitters go
    through the ``EventLog`` instead."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value: float = 0

    def inc(self, n: float = 1) -> None:
        self.value += n


class Gauge:
    """Point-in-time value; ``None`` means "no observation yet" and the
    series is omitted from snapshots (the empty-stats contract — a fresh
    runtime must not report a 0.0 latency it never measured)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value: float | None = None

    def set(self, v: float | None) -> None:
        self.value = None if v is None else float(v)


class Histogram:
    """Fixed-bucket histogram (cumulative counts at snapshot time)."""

    __slots__ = ("buckets", "counts", "total", "sum")

    def __init__(self, buckets: tuple[float, ...] = LATENCY_BUCKETS_S):
        self.buckets = tuple(sorted(buckets))
        self.counts = [0] * len(self.buckets)
        self.total = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        self.total += 1
        self.sum += v
        for i, ub in enumerate(self.buckets):
            if v <= ub:
                self.counts[i] += 1
                break

    def cumulative(self) -> list[int]:
        out, acc = [], 0
        for c in self.counts:
            acc += c
            out.append(acc)
        return out


class MetricsRegistry:
    """Name+labels keyed registry of ``Counter``/``Gauge``/``Histogram``.

    ``register_collector(fn)`` defers derived gauges to snapshot time:
    ``fn(registry)`` runs at every ``snapshot()``/``render_prometheus()``
    and samples whatever live state it closed over — the serving hot
    path never touches a gauge.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: dict[tuple[str, tuple], Counter] = {}
        self._gauges: dict[tuple[str, tuple], Gauge] = {}
        self._histograms: dict[tuple[str, tuple], Histogram] = {}
        self._collectors: list[Callable[[MetricsRegistry], None]] = []

    @staticmethod
    def _key(name: str, labels: dict[str, Any]) -> tuple[str, tuple]:
        return name, tuple(sorted((k, str(v)) for k, v in labels.items()))

    def counter(self, name: str, **labels: Any) -> Counter:
        key = self._key(name, labels)
        c = self._counters.get(key)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(key, Counter())
        return c

    def gauge(self, name: str, **labels: Any) -> Gauge:
        key = self._key(name, labels)
        g = self._gauges.get(key)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(key, Gauge())
        return g

    def histogram(self, name: str,
                  buckets: tuple[float, ...] = LATENCY_BUCKETS_S,
                  **labels: Any) -> Histogram:
        key = self._key(name, labels)
        h = self._histograms.get(key)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(key, Histogram(buckets))
        return h

    def register_collector(self, fn: Callable[["MetricsRegistry"], None]
                           ) -> None:
        self._collectors.append(fn)

    def _collect(self) -> None:
        for fn in self._collectors:
            fn(self)

    def snapshot(self) -> dict[str, Any]:
        """JSON-able snapshot: ``{counters, gauges, histograms}`` keyed by
        ``name{label="value"}``. Gauges whose value is ``None`` (never
        observed) are ABSENT, not 0.0."""
        self._collect()
        counters = {_series_key(n, lb): c.value
                    for (n, lb), c in sorted(self._counters.items())}
        gauges = {_series_key(n, lb): g.value
                  for (n, lb), g in sorted(self._gauges.items())
                  if g.value is not None}
        hists = {}
        for (n, lb), h in sorted(self._histograms.items()):
            hists[_series_key(n, lb)] = {
                "buckets": {str(ub): c for ub, c in
                            zip(h.buckets, h.cumulative())},
                "count": h.total,
                "sum": h.sum,
            }
        return {"counters": counters, "gauges": gauges,
                "histograms": hists}

    def render_prometheus(self) -> str:
        """Prometheus text exposition (``# TYPE`` headers, cumulative
        ``_bucket{le=...}`` histogram series)."""
        self._collect()
        lines: list[str] = []
        typed: set[str] = set()

        def header(name: str, kind: str) -> None:
            if name not in typed:
                typed.add(name)
                lines.append(f"# TYPE {name} {kind}")

        for (n, lb), c in sorted(self._counters.items()):
            header(n, "counter")
            lines.append(f"{_series_key(n, lb)} {c.value}")
        for (n, lb), g in sorted(self._gauges.items()):
            if g.value is None:
                continue
            header(n, "gauge")
            lines.append(f"{_series_key(n, lb)} {g.value}")
        for (n, lb), h in sorted(self._histograms.items()):
            header(n, "histogram")
            cum = h.cumulative()
            for ub, c in zip(h.buckets, cum):
                key = _series_key(f"{n}_bucket",
                                  lb + (("le", f"{ub:g}"),))
                lines.append(f"{key} {c}")
            inf_key = _series_key(f"{n}_bucket", lb + (("le", "+Inf"),))
            lines.append(f"{inf_key} {h.total}")
            lines.append(f"{_series_key(n + '_sum', lb)} {h.sum}")
            lines.append(f"{_series_key(n + '_count', lb)} {h.total}")
        return "\n".join(lines) + "\n"


class EventLog:
    """Bounded, thread-safe structured event log.

    Each event is a dict ``{seq, ts, event, window, backend, ...}``:
    ``seq`` is a global monotonic counter assigned under the log's lock
    — the cross-thread ordering contract (breaker transitions land from
    transport pool threads while routing events land from the engine
    thread) — and ``ts`` comes from the injectable clock. The deque is
    bounded; ``dropped`` counts evicted-oldest events.
    """

    def __init__(self, capacity: int = 8192,
                 clock: Callable[[], float] = time.monotonic):
        from collections import deque
        self._events: Any = deque(maxlen=max(1, capacity))
        self._lock = threading.Lock()
        self._clock = clock
        self.total = 0

    def emit(self, event: str, *, window: int | None = None,
             backend: str | None = None, **fields: Any) -> dict:
        rec = {"event": event, "window": window, "backend": backend,
               **fields}
        with self._lock:
            rec["seq"] = self.total
            rec["ts"] = self._clock()
            self.total += 1
            self._events.append(rec)
        return rec

    @property
    def dropped(self) -> int:
        return self.total - len(self._events)

    def events(self, event: str | None = None,
               backend: str | None = None) -> list[dict]:
        with self._lock:
            evs = list(self._events)
        if event is not None:
            evs = [e for e in evs if e["event"] == event]
        if backend is not None:
            evs = [e for e in evs if e.get("backend") == backend]
        return evs

    def counts(self) -> dict[str, int]:
        with self._lock:
            return dict(_Counter(e["event"] for e in self._events))

    def first_seq(self, event: str, backend: str | None = None
                  ) -> int | None:
        evs = self.events(event, backend)
        return evs[0]["seq"] if evs else None


class TraceSink:
    """Bounded buffer of per-request span timelines.

    A span is ``{uid, window, disposition, backend, cost, source,
    t_local_gate, stages: [[stage, ts], ...]}`` with stage timestamps
    nondecreasing in ``SPAN_STAGES`` order. The buffer is bounded
    (``dropped`` counts spans past capacity); ``write_jsonl`` dumps one
    span per line and ``write_chrome_trace`` exports Chrome
    ``trace_event`` JSON (one complete "X" slice per stage transition;
    ``tid`` is the engine window, so perfetto lanes show pipelining).
    """

    def __init__(self, capacity: int = 65536):
        self.capacity = max(1, capacity)
        self._spans: list[dict] = []
        self._lock = threading.Lock()
        self.dropped = 0

    def emit(self, span: dict) -> None:
        with self._lock:
            if len(self._spans) >= self.capacity:
                self.dropped += 1
                return
            self._spans.append(span)

    def __len__(self) -> int:
        with self._lock:
            return len(self._spans)

    def spans(self) -> list[dict]:
        with self._lock:
            return list(self._spans)

    def write_jsonl(self, path: str) -> int:
        spans = self.spans()
        with open(path, "w") as f:
            for s in spans:
                f.write(json.dumps(s) + "\n")
        return len(spans)

    def write_chrome_trace(self, path: str) -> int:
        """Chrome ``trace_event`` export (catapult / perfetto): each
        consecutive stage pair becomes one complete event named after
        the later stage (the segment that *ended* there)."""
        spans = self.spans()
        t0 = min((s["stages"][0][1] for s in spans if s["stages"]),
                 default=0.0)
        events = []
        for s in spans:
            stages = s["stages"]
            for (_, prev_ts), (stage, ts) in zip(stages, stages[1:]):
                events.append({
                    "name": stage,
                    "cat": s.get("disposition", ""),
                    "ph": "X",
                    "pid": 1,
                    "tid": s.get("window") or 0,
                    "ts": (prev_ts - t0) * 1e6,
                    "dur": max(ts - prev_ts, 0.0) * 1e6,
                    "args": {"uid": s.get("uid"),
                             "backend": s.get("backend"),
                             "cost": s.get("cost")},
                })
        with open(path, "w") as f:
            json.dump({"traceEvents": events,
                       "displayTimeUnit": "ms"}, f)
        return len(events)


class Observability:
    """Facade bundling the metrics registry, trace sink and event log.

    The engine, scheduler, router, transports and controller all hold a
    reference to (parts of) one ``Observability``; ``install(engine)``
    wires everything in one place so component hot paths only carry the
    ``is not None`` guard. Construct via ``ServeConfig(
    observability=True)`` / ``build_observability()`` in normal use.
    """

    def __init__(self, *, metrics: MetricsRegistry | None = None,
                 trace: TraceSink | None = None,
                 events: EventLog | None = None,
                 clock: Callable[[], float] = time.monotonic):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.trace = trace
        self.events = events if events is not None else EventLog(clock=clock)

    @classmethod
    def enabled(cls, *, trace_capacity: int = 65536,
                event_capacity: int = 8192,
                clock: Callable[[], float] = time.monotonic
                ) -> "Observability":
        """Fully-enabled instance (metrics + trace + events)."""
        return cls(metrics=MetricsRegistry(),
                   trace=TraceSink(trace_capacity),
                   events=EventLog(event_capacity, clock=clock),
                   clock=clock)

    # -- wiring ---------------------------------------------------------
    def install(self, engine: Any) -> "Observability":
        """Attach to a ``CascadeEngine`` (runtime path): the engine
        stamps window stages and publishes commit-time counters; every
        backend transport, the router and the controller emit their
        state transitions into the shared event log; derived gauges are
        registered as snapshot-time collectors over the live stats."""
        engine.observability = self
        # one clock everywhere: event timestamps become comparable with
        # span stage stamps (ordering across threads still uses seq)
        self.events._clock = engine._clock
        if engine.router is not None:
            engine.router.attach_events(self.events)
        if engine.controller is not None:
            engine.controller.events = self.events
        self.metrics.register_collector(
            lambda reg: _collect_engine(reg, engine))
        return self


def _collect_engine(reg: MetricsRegistry, engine: Any) -> None:
    """Snapshot-time collector: derived gauges sampled from the live
    engine/router/controller/cache stats (DESIGN.md §9 metric table).
    Ratios and latencies with an empty denominator are left unset —
    absent from the snapshot — instead of reporting 0.0."""
    st = engine.stats
    reg.gauge("cascade_inflight_windows").set(engine.inflight)
    if st.requests > 0:
        reg.gauge("cascade_escalation_fraction").set(st.escalation_fraction)
        reg.gauge("cascade_remote_fraction").set(st.remote_fraction)
    reg.gauge("cascade_mean_modelled_latency_seconds").set(st.mean_latency_s)
    reg.gauge("cascade_mean_wall_latency_seconds").set(st.mean_wall_latency_s)
    reg.gauge("cascade_p95_wall_latency_seconds").set(st.wall_percentile(95))
    if engine.router is not None:
        rs = engine.router.stats
        reg.gauge("router_failovers").set(rs.failovers)
        reg.gauge("router_unrouted").set(rs.unrouted)
        reg.gauge("router_replays_served").set(rs.replay_served)
        for b in engine.router.backends:
            lab = {"backend": b.name}
            state = {"closed": 0, "half_open": 1, "open": 2}.get(
                b.breaker.state, -1)
            reg.gauge("backend_breaker_state", **lab).set(state)
            reg.gauge("backend_breaker_opens", **lab).set(
                b.stats.breaker_opens)
            reg.gauge("backend_inflight_windows", **lab).set(b.inflight)
            reg.gauge("backend_remote_latency_ema_seconds", **lab).set(
                b.stats.latency_ema_s)
            reg.gauge("backend_mean_remote_latency_seconds", **lab).set(
                b.stats.mean_latency_s)
            u = st.per_backend.get(b.name)
            if u is not None:
                reg.gauge("backend_billed_dollars", **lab).set(u.cost)
                reg.gauge("backend_remote_calls", **lab).set(u.remote_calls)
    # per-backend/per-stage agreement-with-local EMA (DESIGN.md §13):
    # iterated over per_backend rather than router.backends because a
    # chained CascadeStage attributes to stage names the router never
    # sees as backends of its own
    for bname in sorted(st.per_backend, key=str):
        u = st.per_backend[bname]
        if u.agreement_ema is not None:
            reg.gauge("backend_agreement_ema", backend=str(bname)).set(
                u.agreement_ema)
    if engine.controller is not None:
        cs = engine.controller.state
        reg.gauge("controller_windows").set(cs.windows)
        reg.gauge("controller_ema_remote_fraction").set(cs.ema_fraction)
        reg.gauge("controller_rho").set(cs.rho)
        reg.gauge("controller_t_local").set(cs.t_local)
        reg.gauge("controller_t_remote").set(cs.t_remote)
        reg.gauge("controller_drift_events").set(cs.drift_events)
        reg.gauge("controller_last_psi").set(cs.last_psi)
        reg.gauge("controller_effective_target").set(cs.effective_target)
    if engine.cache is not None:
        cst = engine.cache.stats
        reg.gauge("cache_hit_ratio").set(cst.hit_rate)
        reg.gauge("cache_hits").set(cst.hits)
        reg.gauge("cache_misses").set(cst.misses)
        reg.gauge("cache_evictions").set(cst.evictions)
        reg.gauge("cache_cross_replica_hits").set(cst.cross_hits)
        reg.gauge("cache_entries").set(len(engine.cache))


class MetricsServer:
    """Stdlib HTTP scrape endpoint for a ``MetricsRegistry``.

    Serves the live registry over a daemon thread (DESIGN.md §9 follow-
    on: metrics over a real scrape endpoint instead of file dumps):

    * ``GET /metrics``      — Prometheus text exposition
      (``render_prometheus``; content type ``text/plain; version=0.0.4``)
    * ``GET /metrics.json`` — the JSON ``snapshot``
    * ``GET /healthz``      — liveness probe (``ok``)

    ``port=0`` binds an ephemeral port; the realised one is ``.port``.
    Collectors registered on the registry run at scrape time under the
    registry's own synchronisation, so scrapes ride alongside a live
    serve loop without touching its hot path. ``close()`` (or the
    context manager) shuts the listener down; request logging is
    suppressed — a scrape every few seconds must not spam the serve
    loop's stderr.
    """

    def __init__(self, metrics: MetricsRegistry, port: int = 0,
                 host: str = "127.0.0.1"):
        import http.server

        registry = metrics

        class Handler(http.server.BaseHTTPRequestHandler):
            def do_GET(self) -> None:  # noqa: N802 - stdlib casing
                path = self.path.split("?", 1)[0]
                if path == "/metrics":
                    body = registry.render_prometheus().encode()
                    ctype = "text/plain; version=0.0.4; charset=utf-8"
                elif path == "/metrics.json":
                    body = json.dumps(registry.snapshot()).encode()
                    ctype = "application/json"
                elif path == "/healthz":
                    body, ctype = b"ok\n", "text/plain; charset=utf-8"
                else:
                    self.send_error(404)
                    return
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, fmt: str, *args: Any) -> None:
                pass

        self.metrics = metrics
        self._server = http.server.ThreadingHTTPServer((host, port), Handler)
        self._server.daemon_threads = True
        self.host, self.port = self._server.server_address[:2]
        self._thread = threading.Thread(target=self._server.serve_forever,
                                        name="metrics-server", daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def close(self) -> None:
        self._server.shutdown()
        self._server.server_close()
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, exc_type: Any, exc: Any, tb: Any) -> None:
        self.close()

"""Seeded, deterministic fault injection for the remote tier (DESIGN.md §10).

The paper's second supervisor exists because the remote tier cannot be
trusted blindly; the transport/router stack (DESIGN.md §3, §6) exists
because it cannot be *reached* reliably either. This module makes the
unreliable world reproducible: a ``ChaosSchedule`` scripts episodes of
misbehaviour — correlated multi-backend outages, partial brownouts,
error bursts, latency-inflation ramps, timeout storms, flapping links —
and wraps any ``RemoteTransport.remote_apply`` so the faults fire inside
the real retry/breaker/router machinery, not around it.

Determinism contract:

* **Count-indexed decisions.** Probabilistic faults (``brownout``) draw
  from a ``random.Random`` stream seeded per ``(schedule seed, episode,
  backend)`` and indexed by that wrapper's *call count*, never by time
  or thread interleaving. Windows are submitted in request order in
  every completion mode (DESIGN.md §7), so FIFO and streaming drains of
  the same request stream see the *same* faults — the billing-identity
  invariant survives chaos.
* **Virtual time.** Episodes activate on the transport's injectable
  clock; ``VirtualClock`` provides a thread-safe manual clock + sleep so
  a whole multi-episode schedule replays bit-identically with zero
  wall-clock waits (latency inflation advances the clock, the post-hoc
  deadline check in ``_call_window`` turns it into real timeouts).
* **Tagged faults.** Every injected exception message carries
  ``chaos[<episode>]`` and per-episode injection counts live in
  ``ChaosStats``, so event-log assertions can match cause to effect;
  ``chaos_episode_begin`` is emitted before the episode's first fault
  is raised, guaranteeing ``begin.seq < breaker_open.seq``.

Wrap a router in one line::

    schedule = ChaosSchedule([ChaosEpisode("outage", 8.0, 4.0,
                                           backends=("primary",))],
                             seed=7)
    schedule.wrap_router(router)
"""

from __future__ import annotations

import random
import threading
import zlib
from collections.abc import Callable
from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.runtime.observability import EV_CHAOS_BEGIN, EV_CHAOS_END
from repro.runtime.transport import (RemoteBackend, RemoteCallError,
                                     RemoteRouter, RemoteTimeout,
                                     RemoteTransport)

__all__ = [
    "CHAOS_KINDS",
    "ChaosEpisode",
    "ChaosFault",
    "ChaosRemote",
    "ChaosSchedule",
    "ChaosStats",
    "ChaosTimeout",
    "VirtualClock",
]

# episode kinds (DESIGN.md §10):
#   outage        — every call fails (hard down)
#   brownout      — each call fails with probability ``rate`` (partial)
#   error_burst   — alias shape for a short rate-1.0 brownout; kept as
#                   its own kind so event logs name the failure mode
#   latency       — each call sleeps ``extra_latency_s`` first
#   latency_ramp  — like latency, scaled 0 -> extra_latency_s across the
#                   episode (a degradation, not a step)
#   timeout_storm — sleeps ``extra_latency_s`` then raises a timeout
#   flap          — down for the first half of every ``period_s``, up
#                   for the second (breaker-flapping link)
CHAOS_KINDS = ("outage", "brownout", "error_burst", "latency",
               "latency_ramp", "timeout_storm", "flap")
_FAULT_KINDS = ("outage", "brownout", "error_burst", "flap")


class ChaosFault(RemoteCallError):
    """Injected transient remote error (tagged with its episode)."""


class ChaosTimeout(RemoteTimeout):
    """Injected timeout (tagged with its episode)."""


class VirtualClock:
    """Thread-safe manual clock: ``clock()``/``sleep(dt)`` drop-ins for
    the transport's injectable hooks. ``sleep`` advances time instead of
    waiting, so latency inflation and breaker resets replay instantly;
    ``advance_to`` never moves backwards (drivers race pool threads)."""

    def __init__(self, start: float = 0.0):
        self._now = float(start)
        self._lock = threading.Lock()

    def __call__(self) -> float:
        with self._lock:
            return self._now

    def sleep(self, dt: float) -> None:
        with self._lock:
            self._now += max(0.0, float(dt))

    def advance_to(self, t: float) -> None:
        with self._lock:
            self._now = max(self._now, float(t))


@dataclass(frozen=True)
class ChaosEpisode:
    """One scripted episode of remote-tier misbehaviour.

    ``backends=()`` hits every wrapped backend — that's how correlated
    multi-backend brownouts are scripted (one episode, many victims).
    ``rate`` applies to ``brownout``/``error_burst``; ``extra_latency_s``
    to ``latency``/``latency_ramp``/``timeout_storm``; ``period_s`` to
    ``flap``. ``name`` defaults to ``kind@start`` and is the tag carried
    by every fault message and episode event."""
    kind: str
    start_s: float
    duration_s: float
    backends: tuple[str, ...] = ()
    rate: float = 1.0
    extra_latency_s: float = 0.0
    period_s: float = 0.2
    name: str = ""

    def __post_init__(self):
        if self.kind not in CHAOS_KINDS:
            raise ValueError(f"unknown chaos kind {self.kind!r}; "
                             f"choose from {CHAOS_KINDS}")
        if self.duration_s <= 0:
            raise ValueError("duration_s must be > 0")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError("rate must be in [0, 1]")
        if self.period_s <= 0:
            raise ValueError("period_s must be > 0")
        if not self.name:
            object.__setattr__(self, "name",
                               f"{self.kind}@{self.start_s:g}")

    @property
    def end_s(self) -> float:
        return self.start_s + self.duration_s

    def covers(self, backend: str, now: float) -> bool:
        """Is this episode active for ``backend`` at time ``now``?"""
        if not self.start_s <= now < self.end_s:
            return False
        return not self.backends or backend in self.backends

    def progress(self, now: float) -> float:
        """Fraction of the episode elapsed at ``now`` (clipped [0, 1])."""
        return min(1.0, max(0.0, (now - self.start_s) / self.duration_s))


@dataclass
class ChaosStats:
    calls: int = 0              # wrapped remote_apply invocations seen
    injected: int = 0           # faults raised (timeouts + errors)
    delayed: int = 0            # calls slowed by latency episodes
    extra_latency_s: float = 0.0  # total injected latency
    by_episode: dict = field(default_factory=dict)  # name -> faults
    by_kind: dict = field(default_factory=dict)     # kind -> faults


class ChaosSchedule:
    """A seeded set of ``ChaosEpisode``s plus the shared injection state.

    ``wrap(backend)`` / ``wrap_router(router)`` splice a ``ChaosRemote``
    in front of each transport's ``remote_apply``; the wrapper reads the
    transport's injectable ``_clock``/``_sleep`` so virtual-clock runs
    replay without waits, and its (lazily installed) ``events`` log so
    episode begin/end markers land in the same sequence as the breaker
    events the faults cause."""

    def __init__(self, episodes, seed: int = 0):
        self.episodes = tuple(episodes)
        names = [ep.name for ep in self.episodes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate episode names: {names}")
        self.seed = int(seed)
        self.stats = ChaosStats()
        self._lock = threading.Lock()
        self._begun: set[str] = set()
        self._ended: set[str] = set()

    def active(self, backend: str, now: float) -> list[ChaosEpisode]:
        return [ep for ep in self.episodes if ep.covers(backend, now)]

    def stream_seed(self, episode: ChaosEpisode, backend: str) -> int:
        """Seed for one (episode, backend) Bernoulli decision stream."""
        key = f"{self.seed}:{episode.name}:{backend}".encode()
        return zlib.crc32(key)

    # -- wiring ---------------------------------------------------------
    def wrap_transport(self, transport: RemoteTransport,
                       backend_name: str | None = None) -> ChaosRemote:
        """Splice a ``ChaosRemote`` in front of ``transport.remote_apply``
        (idempotent per transport: wrapping twice raises)."""
        if isinstance(transport.remote_apply, ChaosRemote):
            raise ValueError("transport is already chaos-wrapped")
        wrapper = ChaosRemote(transport.remote_apply,
                              backend_name or transport.event_source,
                              self, transport=transport)
        transport.remote_apply = wrapper
        return wrapper

    def wrap(self, backend: RemoteBackend) -> RemoteBackend:
        self.wrap_transport(backend.transport, backend.name)
        return backend

    def wrap_router(self, router: RemoteRouter) -> RemoteRouter:
        for b in router.backends:
            self.wrap(b)
        return router

    # -- episode begin/end markers --------------------------------------
    def mark(self, now: float, events: Any) -> None:
        """Emit begin/end events for episodes whose activation state is
        newly visible at ``now``. Called by wrappers *before* they raise
        the episode's fault, so cause precedes effect in seq order."""
        with self._lock:
            pending: list[tuple[str, ChaosEpisode]] = []
            for ep in self.episodes:
                if ep.start_s <= now and ep.name not in self._begun:
                    self._begun.add(ep.name)
                    pending.append((EV_CHAOS_BEGIN, ep))
                if now >= ep.end_s and ep.name not in self._ended:
                    self._ended.add(ep.name)
                    pending.append((EV_CHAOS_END, ep))
        if events is not None:
            for kind, ep in pending:
                events.emit(kind, episode=ep.name, chaos_kind=ep.kind,
                            start_s=ep.start_s, end_s=ep.end_s,
                            targets=list(ep.backends) or None)

    def finalize(self, events: Any, now: float | None = None) -> None:
        """Emit end markers for episodes still open when traffic stopped
        (an episode ends silently if no call observes the time after it;
        benches call this once after the drive loop)."""
        self.mark(float("inf") if now is None else now, events)


class ChaosRemote:
    """Callable wrapper around one transport's ``remote_apply``.

    Applies the schedule's active episodes on every call: latency first
    (``_sleep`` — virtual or real), then at most one fault. Decision
    order is schedule order; per-episode call counts and rng streams
    live here (per backend), so two wrappers never share state and a
    replay with the same per-backend call order is bit-identical."""

    def __init__(self, inner: Callable, backend: str,
                 schedule: ChaosSchedule, *,
                 transport: RemoteTransport | None = None,
                 clock: Callable[[], float] | None = None,
                 sleep: Callable[[float], None] | None = None):
        self.inner = inner
        self.backend = backend
        self.schedule = schedule
        self._transport = transport
        self._clock = clock if clock is not None else transport._clock
        self._sleep = sleep if sleep is not None else transport._sleep
        self._calls: dict[str, int] = {}          # episode -> calls seen
        self._streams: dict[str, random.Random] = {}

    def _events(self) -> Any:
        # resolved lazily: Observability.install() wires transport.events
        # after construction, possibly after wrapping
        return self._transport.events if self._transport is not None else None

    def _decide(self, ep: ChaosEpisode, now: float) -> bool:
        """Should this call fail under ``ep``? (count-indexed for the
        probabilistic kinds, time-based for deterministic ones)"""
        if ep.kind == "outage":
            return True
        if ep.kind == "flap":
            # down for the first half of each period — deterministic in
            # (virtual) time, so replays flap identically
            return (now - ep.start_s) % ep.period_s < ep.period_s / 2
        # brownout / error_burst: one Bernoulli draw per call, from the
        # per-(episode, backend) stream — the call index IS the stream
        # position, immune to completion-order differences
        rng = self._streams.get(ep.name)
        if rng is None:
            rng = self._streams[ep.name] = random.Random(
                self.schedule.stream_seed(ep, self.backend))
        return rng.random() < ep.rate

    def __call__(self, batch: Any) -> Any:
        sched = self.schedule
        now = self._clock()
        extra = 0.0
        fault: tuple[ChaosEpisode, str] | None = None
        with sched._lock:
            sched.stats.calls += 1
            active = sched.active(self.backend, now)
            for ep in active:
                self._calls[ep.name] = self._calls.get(ep.name, 0) + 1
            for ep in active:
                if ep.kind in ("latency", "latency_ramp", "timeout_storm"):
                    scale = (ep.progress(now) if ep.kind == "latency_ramp"
                             else 1.0)
                    extra += ep.extra_latency_s * scale
                if fault is None and ep.kind == "timeout_storm":
                    fault = (ep, "timeout")
                if (fault is None and ep.kind in _FAULT_KINDS
                        and self._decide(ep, now)):
                    fault = (ep, "error")
            if fault is not None:
                ep = fault[0]
                sched.stats.injected += 1
                sched.stats.by_episode[ep.name] = (
                    sched.stats.by_episode.get(ep.name, 0) + 1)
                sched.stats.by_kind[ep.kind] = (
                    sched.stats.by_kind.get(ep.kind, 0) + 1)
            if extra > 0.0:
                sched.stats.delayed += 1
                sched.stats.extra_latency_s += extra
        # cause-before-effect: episode markers enter the log before the
        # fault below can trip a breaker
        sched.mark(now, self._events())
        if extra > 0.0:
            self._sleep(extra)
        if fault is not None:
            ep, mode = fault
            if mode == "timeout":
                raise ChaosTimeout(f"chaos[{ep.name}] injected timeout "
                                   f"({ep.kind})")
            raise ChaosFault(f"chaos[{ep.name}] injected fault ({ep.kind})")
        return np.asarray(self.inner(batch))

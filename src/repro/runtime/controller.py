"""Online budget controller for the cascade (runtime control plane,
DESIGN.md §2).

The paper (§4.5) calls thresholds *runtime-tunable configuration* but the
seed engine froze ``t_remote`` and the escalation capacity at construction.
This controller closes the loop: it tracks the realised remote fraction
against a budget and retunes, once per control window,

  * ``t_local``   — quantile tracking on a rolling buffer of 1st-level
    supervisor scores, feed-forward corrected by a PI term on the EMA of
    the budget error (classic EMA/PID hybrid: the quantile adapts to the
    score distribution, the PI term absorbs cap saturation and mix shift);
  * ``capacity``  — the per-batch escalation cap k, kept at
    ``ceil(min(1, slack * rho) * B)`` so bursts cannot blow the budget;
  * ``t_remote``  — quantile of recently observed 2nd-level scores at the
    target rejection (false-alarm) rate, mirroring the nominal-quantile
    calibration of ``core.thresholds`` but online.

Drift detection: the controller keeps a reference histogram of 1st-level
scores and compares each window's histogram via the Population Stability
Index. On PSI > ``drift_threshold`` it declares a drift event, drops the
PI integral (stale under the new distribution), rebases the reference and
recalibrates ``t_local`` directly from the drifted window.

Until the first window completes the controller reports ``t_local = None``
and the engine falls back to budget-exact capacity-k selection (the seed
behaviour) — a safe warm start.

Dollar budgets (DESIGN.md §6): with a multi-remote registry the price of
an escalation depends on which backend served it, so a remote-*fraction*
budget no longer pins spend. When ``cost_budget_per_request`` is set the
controller learns the realised blended $ per escalation (EMA over windows
of the per-window billed cost the engine reports via ``observe(cost=...)``)
and re-derives the effective target fraction each window as
``cost_budget / ema_cost_per_escalation`` — the existing fraction loop then
holds a **dollar** budget across failovers and price mixes.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, replace

import numpy as np

from repro.core.cascade import escalation_capacity


@dataclass(frozen=True)
class ControllerConfig:
    target_remote_fraction: float = 0.2
    window: int = 256             # requests per control update
    ema_alpha: float = 0.4        # EMA weight of the newest window
    kp: float = 0.8               # proportional gain on budget error
    ki: float = 0.3               # integral gain
    integral_clip: float = 0.25
    history: int = 4096           # rolling score-buffer length
    drift_bins: int = 16
    drift_threshold: float = 0.25  # PSI above this = drift event
    capacity_slack: float = 2.0   # per-batch cap = slack * rho * B
    target_rejection_rate: float = 0.05  # 2nd-level nominal false-alarm
    # dollar budget: target realised $ per request; None = fraction mode.
    # The effective target fraction becomes cost_budget / learned blended
    # $-per-escalation, clipped to [0, target_remote_fraction ceiling 1].
    cost_budget_per_request: float | None = None
    cost_ema_alpha: float = 0.3   # EMA weight for $-per-escalation


@dataclass
class ControllerState:
    t_local: float | None = None
    t_remote: float | None = None
    rho: float = 0.0              # current feed-forward escalation rate
    ema_fraction: float = 0.0
    integral: float = 0.0
    windows: int = 0
    drift_events: int = 0
    last_psi: float = 0.0
    # dollar-budget telemetry (None until the first costed window)
    ema_cost_per_escalation: float | None = None
    effective_target: float | None = None


def population_stability_index(p_counts: np.ndarray,
                               q_counts: np.ndarray) -> float:
    """PSI between two histograms (same binning); symmetric-ish drift score."""
    p = p_counts / max(p_counts.sum(), 1)
    q = q_counts / max(q_counts.sum(), 1)
    eps = 1e-4
    p, q = np.clip(p, eps, None), np.clip(q, eps, None)
    p, q = p / p.sum(), q / q.sum()
    return float(np.sum((p - q) * np.log(p / q)))


class AdaptiveController:
    """EMA/PI budget controller with histogram drift detection."""

    def __init__(self, config: ControllerConfig = ControllerConfig()):
        self.config = config
        self.state = ControllerState(rho=config.target_remote_fraction)
        self._scores: deque[float] = deque(maxlen=config.history)
        self._remote_scores: deque[float] = deque(maxlen=config.history)
        self._win_scores: list[float] = []
        self._win_escalated = 0
        self._win_requests = 0
        self._win_cost = 0.0
        self._ref_hist: np.ndarray | None = None
        self._bin_edges: np.ndarray | None = None
        # lifetime count of budget-eligible requests observed (policy-
        # blocked rows excluded, matching the window denominator). The
        # cluster reconciler uses deltas of this as per-replica traffic
        # weights (DESIGN.md §12).
        self.lifetime_requests = 0
        # observability (DESIGN.md §9): shared EventLog installed by the
        # Observability facade (None = disabled). ``event_window`` is the
        # engine window being committed when ``observe`` runs, so control
        # updates and drift flags carry the window that triggered them.
        self.events = None
        self.event_window: int | None = None

    # -- knobs the engine reads each batch ---------------------------------
    @property
    def t_local(self) -> float | None:
        return self.state.t_local

    @property
    def t_remote(self) -> float | None:
        return self.state.t_remote

    def capacity(self, batch_size: int) -> int:
        # before the first update t_local is None and the engine selects
        # exactly `capacity` rows, so slack must not apply (it would bake
        # a slack-times overshoot into the warm start)
        slack = self.config.capacity_slack if self.state.t_local is not None \
            else 1.0
        rho_cap = min(1.0, slack * self.state.rho)
        return escalation_capacity(batch_size, max(rho_cap, 1e-6))

    # -- cluster hooks (DESIGN.md §12) -------------------------------------
    def recent_scores(self) -> np.ndarray:
        """Rolling 1st-level score buffer as an array (newest last). The
        cluster reconciler pools these across replicas to place one
        global escalation threshold."""
        return np.asarray(self._scores, np.float64)

    def retarget(self, target_remote_fraction: float) -> None:
        """Push a new budget target (cluster reconcile). The PI loop
        keeps its integral — the clip bounds any stale correction — and
        converges on the new target from the next window on."""
        t = float(np.clip(target_remote_fraction, 0.0, 1.0))
        self.config = replace(self.config, target_remote_fraction=t)

    # -- observations the engine feeds back --------------------------------
    def observe(self, local_conf: np.ndarray, escalated: int,
                requests: int, remote_conf: np.ndarray | None = None,
                cost: float = 0.0, policy_blocked: int = 0) -> None:
        """Record one served batch (real rows only) and update per window.
        ``cost`` is the batch's realised billed $ (per-backend pricing), so
        the controller can hold a dollar budget (DESIGN.md §6).
        ``policy_blocked`` counts rows the per-request policy layer
        withheld from escalation (deadline/cost downgrades,
        ``escalation="never"`` — DESIGN.md §8): they are excluded from
        the realised-fraction denominator so the budget loop tracks the
        *eligible* population instead of chasing rows it can never
        escalate (which would drag ``t_local`` up and overspend on the
        rest)."""
        conf = np.asarray(local_conf, np.float64).ravel()
        self._scores.extend(conf.tolist())
        self._win_scores.extend(conf.tolist())
        self._win_escalated += int(escalated)
        eligible = max(int(requests) - int(policy_blocked), 0)
        self._win_requests += eligible
        self.lifetime_requests += eligible
        self._win_cost += float(cost)
        if remote_conf is not None:
            rc = np.asarray(remote_conf, np.float64).ravel()
            self._remote_scores.extend(rc[np.isfinite(rc)].tolist())
        # one update over everything accumulated — a window is "at least
        # cfg.window requests", never split (splitting would manufacture
        # empty phantom windows that drag the EMA toward zero)
        if self._win_requests >= self.config.window:
            self._update()

    # -- one control update ------------------------------------------------
    def _update(self) -> None:
        cfg, st = self.config, self.state
        frac = self._win_escalated / max(self._win_requests, 1)
        if st.windows == 0:
            st.ema_fraction = frac
        else:
            st.ema_fraction = (cfg.ema_alpha * frac
                               + (1 - cfg.ema_alpha) * st.ema_fraction)

        # learn the blended $ per escalation; a dollar budget re-derives
        # the target fraction each window (DESIGN.md §6)
        if self._win_escalated > 0:
            c = self._win_cost / self._win_escalated
            st.ema_cost_per_escalation = (
                c if st.ema_cost_per_escalation is None else
                cfg.cost_ema_alpha * c
                + (1 - cfg.cost_ema_alpha) * st.ema_cost_per_escalation)
        target = cfg.target_remote_fraction
        if (cfg.cost_budget_per_request is not None
                and st.ema_cost_per_escalation is not None):
            if st.ema_cost_per_escalation <= 0.0:
                target = 1.0    # free escalations: the $ budget never binds
            else:
                target = float(np.clip(
                    cfg.cost_budget_per_request
                    / st.ema_cost_per_escalation, 0.0, 1.0))
        st.effective_target = target

        err = st.ema_fraction - target
        st.integral = float(np.clip(st.integral + err,
                                    -cfg.integral_clip, cfg.integral_clip))

        drifted = self._detect_drift(np.asarray(self._win_scores))
        if drifted:
            st.drift_events += 1
            if self.events is not None:
                self.events.emit("controller_drift",
                                 window=self.event_window,
                                 psi=st.last_psi,
                                 threshold=self.config.drift_threshold,
                                 drift_events=st.drift_events)
            st.integral = 0.0
            st.ema_fraction = target
            err = 0.0

        # feed-forward escalation rate, PI-corrected, then realised as a
        # quantile of the recent score distribution
        st.rho = float(np.clip(
            target - cfg.kp * err - cfg.ki * st.integral, 0.0, 1.0))
        scores = (np.asarray(self._win_scores) if drifted
                  else np.asarray(self._scores))
        if scores.size:
            st.t_local = float(np.quantile(scores, st.rho))
        if len(self._remote_scores) >= 8:
            st.t_remote = float(np.quantile(
                np.asarray(self._remote_scores), cfg.target_rejection_rate))

        st.windows += 1
        if self.events is not None:
            # one bounded event per control window (not per batch): the
            # knob values the next windows will be served under
            self.events.emit("controller_update",
                             window=self.event_window,
                             rho=st.rho, t_local=st.t_local,
                             t_remote=st.t_remote,
                             ema_fraction=st.ema_fraction,
                             effective_target=st.effective_target)
        self._win_scores = []
        self._win_escalated = 0
        self._win_requests = 0
        self._win_cost = 0.0

    def _detect_drift(self, win_scores: np.ndarray) -> bool:
        cfg, st = self.config, self.state
        if win_scores.size == 0:
            return False
        if self._bin_edges is None:
            lo, hi = float(win_scores.min()), float(win_scores.max())
            span = max(hi - lo, 1e-6)
            self._bin_edges = np.linspace(lo - 0.1 * span, hi + 0.1 * span,
                                          cfg.drift_bins + 1)
            self._ref_hist = np.histogram(win_scores, self._bin_edges)[0]
            return False
        hist = np.histogram(win_scores, self._bin_edges)[0]
        st.last_psi = population_stability_index(self._ref_hist, hist)
        if st.last_psi > cfg.drift_threshold:
            # rebase the reference on the drifted distribution
            lo, hi = float(win_scores.min()), float(win_scores.max())
            span = max(hi - lo, 1e-6)
            self._bin_edges = np.linspace(lo - 0.1 * span, hi + 0.1 * span,
                                          cfg.drift_bins + 1)
            self._ref_hist = np.histogram(win_scores, self._bin_edges)[0]
            self._scores = deque(win_scores.tolist(),
                                 maxlen=self.config.history)
            return True
        # slow reference update so benign wander doesn't accumulate into
        # a spurious drift flag
        self._ref_hist = 0.9 * self._ref_hist + 0.1 * hist
        return False


# ---------------------------------------------------------------------------
# Per-tier budgets for N-tier hierarchies (DESIGN.md §13)
# ---------------------------------------------------------------------------

class TieredBudgetController:
    """One EMA/PI budget loop per cascade hop, reconciled to a global
    end-to-end budget.

    Each hop of an N-tier cascade (DESIGN.md §13) gets its own
    ``AdaptiveController`` tracking that hop's observed escalation
    fraction against a per-hop target. Because the fraction of traffic
    reaching the deepest tier is the *product* of the per-hop fractions,
    holding each hop loosely at its own target can still drift the
    end-to-end remote fraction off the global budget — so every
    ``reconcile_every`` hop-windows the controller re-centres: it takes
    the observed end-to-end fraction (product of per-hop EMAs), compares
    it to ``global_target``, and scales every hop's target by the n-th
    root of the ratio (clipped to ``[floor, 1]``). The same
    ``retarget`` hook the cluster reconciler uses (DESIGN.md §12)
    carries the correction, so each hop's PI loop converges on its new
    target from the next window on.

    ``loop(name)`` hands a hop's controller to its ``CascadeStage`` (or
    to the engine for hop 1); ``observe`` delegates by hop name and
    counts control windows to trigger the reconcile.
    """

    def __init__(self, hop_targets, *, global_target: float | None = None,
                 base: ControllerConfig = ControllerConfig(),
                 reconcile_every: int = 4, floor: float = 0.01):
        items = (list(hop_targets.items())
                 if isinstance(hop_targets, dict) else list(hop_targets))
        if not items:
            raise ValueError("need at least one hop")
        self.hops = [name for name, _ in items]
        self.loops = {
            name: AdaptiveController(
                replace(base, target_remote_fraction=float(t)))
            for name, t in items}
        prod = 1.0
        for _, t in items:
            prod *= float(t)
        self.global_target = float(global_target if global_target is not None
                                   else prod)
        self.reconcile_every = max(1, int(reconcile_every))
        self.floor = float(floor)
        self.reconciles = 0
        self._last_windows = 0
        # observability (installed like AdaptiveController.events)
        self.events = None
        self.event_window: int | None = None

    def loop(self, name: str) -> AdaptiveController:
        return self.loops[name]

    def _total_windows(self) -> int:
        return sum(self.loops[h].state.windows for h in self.hops)

    def observe(self, name: str, conf, escalated: int, requests: int,
                remote_conf=None, cost: float = 0.0) -> None:
        """Feed one hop's served batch to its loop; reconcile when
        enough control windows have elapsed across the hops."""
        self.loops[name].observe(conf, escalated, requests,
                                 remote_conf, cost=cost)
        self.tick()

    def tick(self) -> bool:
        """Reconcile iff enough control windows elapsed across the hops
        since the last one. The drive loop's hook when hops observe
        through their own ``AdaptiveController`` references (e.g. a
        ``CascadeStage`` holding ``loop(name)``) rather than through
        ``observe``."""
        if self._total_windows() - self._last_windows \
                >= self.reconcile_every:
            self.reconcile()
            return True
        return False

    def hop_fractions(self) -> dict[str, float]:
        """Per-hop observed escalation fraction (EMA; the hop's target
        until its first control window)."""
        out = {}
        for h in self.hops:
            lp = self.loops[h]
            out[h] = (lp.state.ema_fraction if lp.state.windows
                      else lp.config.target_remote_fraction)
        return out

    def end_to_end_fraction(self) -> float:
        """Observed fraction of traffic reaching past the last hop —
        the product of per-hop escalation fractions."""
        prod = 1.0
        for f in self.hop_fractions().values():
            prod *= f
        return prod

    def reconcile(self) -> dict:
        """Re-centre the per-hop targets on the global budget: scale each
        by the n-th root of target/observed (hops iterate in registration
        order, so the outcome is deterministic)."""
        self._last_windows = self._total_windows()
        observed = self.end_to_end_fraction()
        targets = {}
        if observed > 0.0:
            scale = (self.global_target / observed) ** (1.0 / len(self.hops))
            for h in self.hops:
                lp = self.loops[h]
                t = float(np.clip(lp.config.target_remote_fraction * scale,
                                  self.floor, 1.0))
                lp.retarget(t)
                targets[h] = t
        else:
            # nothing escalates anywhere: reopen every hop at the global
            # target's n-th root rather than steering on a zero product
            t0 = self.global_target ** (1.0 / len(self.hops))
            for h in self.hops:
                t = float(np.clip(t0, self.floor, 1.0))
                self.loops[h].retarget(t)
                targets[h] = t
        self.reconciles += 1
        if self.events is not None:
            self.events.emit("tier_reconcile",
                             window=self.event_window,
                             observed=observed,
                             global_target=self.global_target,
                             targets=targets,
                             reconciles=self.reconciles)
        return {"observed": observed, "targets": targets}

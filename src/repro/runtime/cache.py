"""Content-keyed remote-response cache (runtime control plane, DESIGN.md §4).

Escalating the same input twice must not be billed twice: remote tiers are
metered per request (CheapET-3 frames the remote model as a billed service),
so the runtime keys every escalated request by the *content* of its
remote-tier input and serves duplicates from an LRU cache. Hit/miss counts
are folded into the engine's `CascadeStats` so the cost model only bills
genuine remote invocations.

Keys are content hashes over the request pytree (arrays hashed with their
dtype/shape so `[1, 2]` int32 and `[1, 2]` float32 never collide).

With a multi-remote registry (DESIGN.md §6) every entry also remembers the
*source* — the name of the backend that filled it — so a cache hit
attributes to the right backend in the engine's per-backend accounting
(hits stay $0-billed regardless of source).
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any

import numpy as np


def content_key(row: Any) -> bytes:
    """Stable content hash of one request's (pytree) remote input."""
    h = hashlib.blake2b(digest_size=16)
    _update(h, row)
    return h.digest()


def content_keys(batch: Any, rows: int) -> list[bytes]:
    """Batched ``content_key`` over the leading axis of a stacked pytree.

    Produces byte-identical digests to ``content_key(row_i)`` where
    ``row_i`` is the i-th row of every leaf, but walks the tree ONCE:
    per-leaf header bytes (dtype + row shape) are computed a single time
    and each row is hashed from a contiguous slice — no per-row
    ``tree.map`` materialisation, no per-row re-layout. This is the hot
    path of the vectorised escalation gather (DESIGN.md §5).
    """
    hs = [hashlib.blake2b(digest_size=16) for _ in range(rows)]
    _update_batched(hs, batch)
    return [h.digest() for h in hs]


def _update_batched(hs: list, node: Any) -> None:
    if isinstance(node, dict):
        for k in sorted(node):
            enc = repr(k).encode()
            for h in hs:
                h.update(enc)
            _update_batched(hs, node[k])
    elif isinstance(node, (list, tuple)):
        for h in hs:
            h.update(b"[")
        for item in node:
            _update_batched(hs, item)
        for h in hs:
            h.update(b"]")
    else:
        a = np.ascontiguousarray(np.asarray(node))
        if a.shape[0] < len(hs):
            raise ValueError(f"leaf has {a.shape[0]} rows; need {len(hs)}")
        head = str(a.dtype).encode() + repr(a.shape[1:]).encode()
        for i, h in enumerate(hs):
            h.update(head)
            h.update(a[i].tobytes())


def _update(h, node: Any) -> None:
    if isinstance(node, dict):
        for k in sorted(node):
            h.update(repr(k).encode())
            _update(h, node[k])
    elif isinstance(node, (list, tuple)):
        h.update(b"[")
        for item in node:
            _update(h, item)
        h.update(b"]")
    else:
        a = np.asarray(node)
        h.update(str(a.dtype).encode())
        h.update(repr(a.shape).encode())
        h.update(np.ascontiguousarray(a).tobytes())


def _row(node: Any, i: int) -> Any:
    """Slice row i out of a stacked pytree (custom-key_fn fallback)."""
    if isinstance(node, dict):
        return {k: _row(v, i) for k, v in node.items()}
    if isinstance(node, (list, tuple)):
        return type(node)(_row(v, i) for v in node)
    return np.asarray(node)[i]


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    # hits served from an entry that a *different* replica filled
    # (cluster shared cache, DESIGN.md §12); always 0 for a
    # single-process cache
    cross_hits: int = 0

    @property
    def hit_rate(self) -> float | None:
        """Hit ratio; None before any lookup — an untouched cache must
        not report a 0.0 hit rate (DESIGN.md §9 empty-stats contract)."""
        looked = self.hits + self.misses
        if looked == 0:
            return None
        return self.hits / looked


class RemoteResponseCache:
    """Bounded LRU of remote responses keyed by request content.

    ``key_fn`` maps one request's remote-input pytree to the hashable
    content that identifies it (default: the whole pytree). Override it
    when the pytree carries non-semantic fields — e.g. a per-request uid
    — that would make every key unique and the cache structurally cold.

    ``key_batch_fn(batch, rows) -> list[bytes]`` is the vectorised
    counterpart over a stacked sub-batch; supply it alongside a custom
    ``key_fn`` to keep the serving hot path free of per-row pytree
    slicing (the default pairing ``content_key``/``content_keys`` is
    wired automatically).
    """

    def __init__(self, capacity: int = 4096, key_fn=content_key,
                 key_batch_fn=None):
        if capacity < 1:
            raise ValueError("cache capacity must be >= 1")
        self.capacity = capacity
        self.key_fn = key_fn
        if key_batch_fn is None and key_fn is content_key:
            key_batch_fn = content_keys
        self.key_batch_fn = key_batch_fn
        self.stats = CacheStats()
        # key -> (response, source backend name | None)
        self._store: OrderedDict[bytes,
                                 tuple[np.ndarray, str | None]] = OrderedDict()

    def keys_for(self, batch: Any, rows: int) -> list[bytes]:
        """Keys for the leading ``rows`` of a stacked request pytree —
        batched when a ``key_batch_fn`` is available, else a per-row
        fallback through ``key_fn``."""
        if self.key_batch_fn is not None:
            return self.key_batch_fn(batch, rows)
        return [self.key_fn(_row(batch, i)) for i in range(rows)]

    def __len__(self) -> int:
        return len(self._store)

    def lookup(self, key: bytes) -> tuple[np.ndarray, str | None] | None:
        """Like ``get`` but returns ``(value, source)`` where ``source``
        is the backend name recorded at ``put`` time (None for entries
        stored without attribution)."""
        hit = self._store.get(key)
        if hit is None:
            self.stats.misses += 1
            return None
        self._store.move_to_end(key)
        self.stats.hits += 1
        return hit

    def get(self, key: bytes) -> np.ndarray | None:
        hit = self.lookup(key)
        return None if hit is None else hit[0]

    def put(self, key: bytes, value: np.ndarray,
            source: str | None = None) -> None:
        if key in self._store:
            self._store.move_to_end(key)
        self._store[key] = (np.asarray(value), source)
        if len(self._store) > self.capacity:
            self._store.popitem(last=False)
            self.stats.evictions += 1

    def clear(self) -> None:
        self._store.clear()

"""Content-keyed remote-response cache (runtime control plane, DESIGN.md §4).

Escalating the same input twice must not be billed twice: remote tiers are
metered per request (CheapET-3 frames the remote model as a billed service),
so the runtime keys every escalated request by the *content* of its
remote-tier input and serves duplicates from an LRU cache. Hit/miss counts
are folded into the engine's `CascadeStats` so the cost model only bills
genuine remote invocations.

Keys are content hashes over the request pytree (arrays hashed with their
dtype/shape so `[1, 2]` int32 and `[1, 2]` float32 never collide).
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any

import numpy as np


def content_key(row: Any) -> bytes:
    """Stable content hash of one request's (pytree) remote input."""
    h = hashlib.blake2b(digest_size=16)
    _update(h, row)
    return h.digest()


def _update(h, node: Any) -> None:
    if isinstance(node, dict):
        for k in sorted(node):
            h.update(repr(k).encode())
            _update(h, node[k])
    elif isinstance(node, (list, tuple)):
        h.update(b"[")
        for item in node:
            _update(h, item)
        h.update(b"]")
    else:
        a = np.asarray(node)
        h.update(str(a.dtype).encode())
        h.update(repr(a.shape).encode())
        h.update(np.ascontiguousarray(a).tobytes())


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.hits + self.misses, 1)


class RemoteResponseCache:
    """Bounded LRU of remote responses keyed by request content.

    ``key_fn`` maps one request's remote-input pytree to the hashable
    content that identifies it (default: the whole pytree). Override it
    when the pytree carries non-semantic fields — e.g. a per-request uid
    — that would make every key unique and the cache structurally cold.
    """

    def __init__(self, capacity: int = 4096, key_fn=content_key):
        if capacity < 1:
            raise ValueError("cache capacity must be >= 1")
        self.capacity = capacity
        self.key_fn = key_fn
        self.stats = CacheStats()
        self._store: OrderedDict[bytes, np.ndarray] = OrderedDict()

    def __len__(self) -> int:
        return len(self._store)

    def get(self, key: bytes) -> np.ndarray | None:
        hit = self._store.get(key)
        if hit is None:
            self.stats.misses += 1
            return None
        self._store.move_to_end(key)
        self.stats.hits += 1
        return hit

    def put(self, key: bytes, value: np.ndarray) -> None:
        if key in self._store:
            self._store.move_to_end(key)
        self._store[key] = np.asarray(value)
        if len(self._store) > self.capacity:
            self._store.popitem(last=False)
            self.stats.evictions += 1

    def clear(self) -> None:
        self._store.clear()

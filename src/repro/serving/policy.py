"""Per-request policy API + the serving configuration facade (DESIGN.md §8).

BiSupervised's value proposition is per-input triage — trust the local
model when the first supervisor clears it, pay for the remote only when
needed (paper §1) — yet until this layer existed every serving knob was
*process-wide*: budget, routing, completion mode and timeouts lived in
~20 ``serve.py`` flags and four constructors. Weiss & Tonella's
uncertainty-quantification guidelines stress that the right supervision
trade-off is workload-dependent; this module makes it **request**-
dependent:

* ``RequestPolicy`` — the per-request contract attached to a
  ``Request``: a latency SLA (``deadline_s``), a spend ceiling
  (``cost_cap``), a backend preference (``routing_hint``), an escalation
  override (``auto`` / ``never`` / ``always``) and the miss behaviour
  (``fallback`` serves the local prediction, ``reject`` takes the
  REJECTED path).
* ``ServeConfig`` — one immutable facade subsuming the flag/constructor
  sprawl: ``serve.py`` builds exactly one and every runtime component
  (``CascadeEngine``, ``MicrobatchScheduler``, ``RemoteRouter``, the
  budget controller, the response cache, the observability layer) is
  constructed *from* it. The keyword constructors remain as the
  low-level composition-root API for tests and bespoke wiring.

Dispositions (``Response.disposition``) surface how each request was
actually served — the billing attribution at the API boundary:

=================  ========================================================
``LOCAL``          1st-level supervisor trusted the local prediction
``REMOTE``         escalated, served by a remote backend, trusted ($ billed)
``CACHED``         escalated, served from the response cache ($0)
``REJECTED``       escalated but untrusted/failed/policy-rejected → fallback
``DEADLINE_LOCAL`` downgraded to the local prediction: no backend could
                   make the round trip inside ``deadline_s`` (DESIGN.md §8)
``POLICY_LOCAL``   escalation suppressed by policy (``escalation="never"``
                   or ``cost_cap`` below every available backend's price)
``SHED``           refused at admission (DESIGN.md §10): the bounded queue
                   was full, or overload/deadline-infeasibility plus
                   ``on_miss="reject"``; answered immediately from the
                   fallback, never enqueued, $0 billed
=================  ========================================================
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.runtime.cache import RemoteResponseCache
from repro.runtime.controller import AdaptiveController, ControllerConfig
from repro.runtime.transport import (ROUTE_POLICIES, RemoteBackend,
                                     RemoteRouter, TransportConfig)

ESCALATION_MODES = ("auto", "never", "always")
ON_MISS_MODES = ("fallback", "reject")

# Response.disposition values (billing attribution at the API boundary)
LOCAL = "LOCAL"
REMOTE = "REMOTE"
CACHED = "CACHED"
REJECTED = "REJECTED"
DEADLINE_LOCAL = "DEADLINE_LOCAL"
POLICY_LOCAL = "POLICY_LOCAL"
SHED = "SHED"
DISPOSITIONS = (LOCAL, REMOTE, CACHED, REJECTED, DEADLINE_LOCAL,
                POLICY_LOCAL, SHED)

PACKING_MODES = ("none", "policy")

# microbatch formation (DESIGN.md §11): "window" accumulates fixed
# `_next_chunk()` windows (the PR-3..7 behaviour); "continuous" admits
# rows into free slots of a persistent padded batch (slot-map) and hands
# locally-trusted rows back at gate time via in-kernel early emit
BATCHING_MODES = ("window", "continuous")


@dataclass(frozen=True)
class RequestPolicy:
    """Per-request serving contract (DESIGN.md §8).

    ``deadline_s``   — latency SLA measured from enqueue: the engine only
                       escalates when some backend's round-trip estimate
                       (measured EMA/p95, modelled prior until
                       observations arrive) fits in the remaining budget;
                       otherwise the request downgrades to the local
                       prediction (``DEADLINE_LOCAL``) or, with
                       ``on_miss="reject"``, takes the REJECTED path.
    ``cost_cap``     — max $ this request may be billed; backends pricier
                       than the cap are unroutable for it (``cost_cap=0``
                       forces local-only).
    ``routing_hint`` — preferred backend name; advisory — honored when
                       that backend is available and satisfies the
                       window's merged constraints.
    ``escalation``   — ``auto`` (gate decides), ``never`` (stay local even
                       when the gate is untrusted), ``always`` (escalate
                       even when the gate trusts the local answer;
                       deadline/cost feasibility still applies).
    ``on_miss``      — what an infeasible deadline/cost does: ``fallback``
                       serves the local prediction with a ``*_LOCAL``
                       disposition; ``reject`` forces the REJECTED →
                       scheduler-fallback path.

    The all-default policy is semantically identical to *no* policy; the
    engine and scheduler fast-path it so unpolicied traffic stays
    bitwise-identical to the pre-policy runtime.
    """
    deadline_s: float | None = None
    cost_cap: float | None = None
    routing_hint: str | None = None
    escalation: str = "auto"
    on_miss: str = "fallback"

    def __post_init__(self):
        if self.escalation not in ESCALATION_MODES:
            raise ValueError(f"unknown escalation {self.escalation!r}; "
                             f"choose from {ESCALATION_MODES}")
        if self.on_miss not in ON_MISS_MODES:
            raise ValueError(f"unknown on_miss {self.on_miss!r}; "
                             f"choose from {ON_MISS_MODES}")
        if self.deadline_s is not None and self.deadline_s < 0:
            raise ValueError("deadline_s must be >= 0")
        if self.cost_cap is not None and self.cost_cap < 0:
            raise ValueError("cost_cap must be >= 0")

    @property
    def is_default(self) -> bool:
        """True iff this policy constrains nothing (== no policy)."""
        return (self.deadline_s is None and self.cost_cap is None
                and self.routing_hint is None and self.escalation == "auto")


@dataclass(frozen=True)
class RemoteSpec:
    """Declarative spec for one named remote backend (``ServeConfig``
    builds the actual ``RemoteBackend`` around the deployment's remote
    callable). ``cost_per_request``/``latency_s`` = None fall back to the
    engine's ``CostModel`` constants."""
    name: str
    cost_per_request: float | None = None
    latency_s: float | None = None

    @classmethod
    def parse(cls, spec: str) -> "RemoteSpec":
        """``name[:cost[:latency]]`` — empty fields keep the defaults."""
        parts = spec.split(":")
        if len(parts) > 3 or not parts[0]:
            raise ValueError(f"bad remote spec {spec!r}; "
                             f"expected name[:cost[:latency]]")
        cost = float(parts[1]) if len(parts) > 1 and parts[1] else None
        latency = float(parts[2]) if len(parts) > 2 and parts[2] else None
        return cls(parts[0], cost, latency)


def _parse_remotes(text: str) -> tuple[RemoteSpec, ...]:
    """``name:cost:lat[;name:cost:lat...]`` → tuple of specs."""
    return tuple(RemoteSpec.parse(s) for s in text.split(";") if s)


@dataclass(frozen=True)
class TierSpec:
    """Declarative spec for one hop of an N-tier cascade ladder
    (DESIGN.md §13). The ladder replaces the flat ``remotes`` registry:
    ``ServeConfig.build_router`` chains the tiers into one
    ``CascadeStage`` head routed as a single logical backend — each hop
    answers the rows its supervisor scores above ``threshold`` and
    escalates the residual; the last tier is terminal (its trust gate is
    the engine's ``t_remote``)."""
    name: str
    cost_per_request: float | None = None
    latency_s: float | None = None
    threshold: float = 0.0
    supervisor: str = "max_softmax"

    @classmethod
    def parse(cls, spec: str) -> "TierSpec":
        """``name[:cost[:lat[:threshold[:supervisor]]]]`` — empty fields
        keep the defaults."""
        parts = spec.split(":")
        if len(parts) > 5 or not parts[0]:
            raise ValueError(
                f"bad tier spec {spec!r}; expected "
                f"name[:cost[:latency[:threshold[:supervisor]]]]")
        cost = float(parts[1]) if len(parts) > 1 and parts[1] else None
        latency = float(parts[2]) if len(parts) > 2 and parts[2] else None
        threshold = float(parts[3]) if len(parts) > 3 and parts[3] else 0.0
        supervisor = (parts[4] if len(parts) > 4 and parts[4]
                      else "max_softmax")
        return cls(parts[0], cost, latency, threshold, supervisor)


def _parse_tiers(text: str) -> tuple[TierSpec, ...]:
    """``name:cost:lat:thr[;...]`` (outermost hop first) → tier specs."""
    return tuple(TierSpec.parse(s) for s in text.split(";") if s)


@dataclass(frozen=True)
class ServeConfig:
    """The one serving-surface configuration object (DESIGN.md §8).

    ``serve.py`` builds a single ``ServeConfig``; ``CascadeEngine``,
    ``MicrobatchScheduler``, ``RemoteRouter``, the budget controller and
    the response cache are all constructed *from* it (``build_*`` /
    ``from_config``). Field-level overrides parse from ``key=value``
    strings (``with_overrides``), including nested ``transport.*``,
    ``cost.*`` and ``default_policy.*`` fields — the migration target for
    the retired per-knob CLI flags (migration table in DESIGN.md §8).
    """
    # -- cascade --------------------------------------------------------
    batch_size: int = 32
    remote_fraction_budget: float = 0.25
    t_remote: float = 0.9
    t_local: float | None = None
    supervisor: str = "max_softmax"
    cost: Any = None                    # CostModel | None = engine default
    fused: bool = False                 # seed-style fully-jitted cascade
    # -- pipeline / completion (DESIGN.md §5, §7, §11) ------------------
    pipeline_depth: int = 1
    completion_mode: str = "fifo"
    batching: str = "window"            # window | continuous (slot-map)
    # -- remote tier(s) (DESIGN.md §3, §6) ------------------------------
    transport: TransportConfig = field(default_factory=TransportConfig)
    remotes: tuple[RemoteSpec, ...] = ()
    # N-tier cascade ladder (DESIGN.md §13): tiers chain into one routed
    # CascadeStage head (outermost hop first); exclusive with `remotes`
    tiers: tuple[TierSpec, ...] = ()
    route_policy: str = "primary-failover"
    replay_max: int = 8
    # -- response cache (DESIGN.md §4; 0 disables) ----------------------
    cache_size: int = 4096
    # -- budget controller (DESIGN.md §2, §6) ---------------------------
    adaptive: bool = False
    control_window: int = 128
    target_rejection_rate: float = 0.05
    cost_budget: float | None = None    # $/request; None = fraction mode
    # -- per-request policy layer (DESIGN.md §8) ------------------------
    default_policy: RequestPolicy = field(default_factory=RequestPolicy)
    packing: str = "none"               # window packing: none | policy
    # -- overload admission control (DESIGN.md §10; 0 disables) ---------
    # hard queue bound: a request arriving at a full queue is SHED
    # (answered from the fallback, $0, never enqueued). Above
    # ``admission_soft_ratio * admission_limit`` the scheduler applies
    # the request's ``on_miss`` vocabulary instead: ``fallback`` pins
    # the request local (degrade), ``reject`` sheds it.
    admission_limit: int = 0
    admission_soft_ratio: float = 0.5
    # -- observability (DESIGN.md §9) -----------------------------------
    observability: bool = False         # metrics + traces + event log
    trace_capacity: int = 65536         # bounded TraceSink (spans kept)
    event_capacity: int = 8192          # bounded EventLog (events kept)
    # -- cluster scale-out (DESIGN.md §12) ------------------------------
    # replicas > 1 runs N engines behind one logical cascade (shared
    # response cache, shared router, cluster budget reconcile) via
    # ``repro.runtime.cluster.ClusterHarness``; data_parallel shards the
    # forward's batch dim over all local devices (launch/mesh.py).
    replicas: int = 1
    data_parallel: bool = False

    def __post_init__(self):
        if self.completion_mode not in ("fifo", "streaming"):
            raise ValueError(f"unknown completion_mode "
                             f"{self.completion_mode!r}")
        if self.route_policy not in ROUTE_POLICIES:
            raise ValueError(f"unknown route_policy {self.route_policy!r}; "
                             f"choose from {ROUTE_POLICIES}")
        if self.packing not in PACKING_MODES:
            raise ValueError(f"unknown packing {self.packing!r}; "
                             f"choose from {PACKING_MODES}")
        if self.batching not in BATCHING_MODES:
            raise ValueError(f"unknown batching {self.batching!r}; "
                             f"choose from {BATCHING_MODES}")
        if self.batching == "continuous" and self.completion_mode != \
                "streaming":
            raise ValueError("batching='continuous' requires "
                             "completion_mode='streaming' (rows hand back "
                             "as they clear; a FIFO drain would re-impose "
                             "window quantization)")
        if self.admission_limit < 0:
            raise ValueError("admission_limit must be >= 0")
        if not 0.0 <= self.admission_soft_ratio <= 1.0:
            raise ValueError("admission_soft_ratio must be in [0, 1]")
        if self.replicas < 1:
            raise ValueError("replicas must be >= 1")
        if self.tiers and self.remotes:
            raise ValueError("tiers and remotes are exclusive: a tier "
                             "ladder chains into ONE routed backend; mix "
                             "by wrapping backends in CascadeStage "
                             "directly (DESIGN.md §13)")
        if self.replicas > 1 and not self.adaptive:
            raise ValueError("replicas > 1 needs adaptive=True: the "
                             "cluster budget reconcile re-targets each "
                             "replica's controller (DESIGN.md §12)")
        if self.fused and (self.replicas > 1 or self.data_parallel):
            raise ValueError("fused bypasses the runtime path: drop "
                             "replicas/data_parallel")
        if self.fused and (self.adaptive or self.pipeline_depth > 1
                           or self.completion_mode == "streaming"
                           or self.cost_budget is not None
                           or not self.default_policy.is_default
                           or self.packing != "none"
                           or self.remotes
                           or self.tiers
                           or self.observability
                           or self.admission_limit
                           or self.batching != "window"):
            raise ValueError("fused bypasses the transport path: drop "
                             "adaptive/pipeline_depth/streaming/"
                             "cost_budget/default_policy/packing/remotes/"
                             "tiers/observability/admission_limit/"
                             "batching")

    # -- component builders --------------------------------------------
    def build_router(self, remote_apply: Callable, **kw) -> RemoteRouter:
        """Registry of named backends around the deployment's remote
        callable (one ``"remote"`` backend when no specs are given).
        With ``tiers`` set, the specs chain into one ``CascadeStage``
        head routed as a single logical backend (DESIGN.md §13);
        ``remote_apply`` may be a single callable shared by every hop or
        a mapping ``{tier_name: callable}``."""
        if self.tiers:
            from repro.runtime.hierarchy import build_stage_chain
            applies = (remote_apply if isinstance(remote_apply, dict)
                       else {t.name: remote_apply for t in self.tiers})
            head = build_stage_chain(
                [dict(name=t.name, apply=applies[t.name],
                      supervisor=t.supervisor, threshold=t.threshold,
                      cost_per_request=t.cost_per_request,
                      latency_s=t.latency_s) for t in self.tiers],
                config=self.transport, **kw)
            return RemoteRouter([head], policy=self.route_policy,
                                replay_max=self.replay_max)
        specs = self.remotes or (RemoteSpec("remote"),)
        return RemoteRouter(
            [RemoteBackend(s.name, remote_apply, self.transport,
                           cost_per_request=s.cost_per_request,
                           latency_s=s.latency_s, **kw) for s in specs],
            policy=self.route_policy, replay_max=self.replay_max)

    def build_controller(self) -> AdaptiveController | None:
        if not self.adaptive:
            return None
        return AdaptiveController(ControllerConfig(
            target_remote_fraction=self.remote_fraction_budget,
            window=self.control_window,
            target_rejection_rate=self.target_rejection_rate,
            cost_budget_per_request=self.cost_budget))

    def build_observability(self):
        """Fully-enabled ``Observability`` facade (metrics + trace sink +
        event log) sized from the config; None when disabled. The engine
        installs it at construction (``from_config``), which wires the
        router, every backend transport and the controller into the
        shared event log (DESIGN.md §9)."""
        if not self.observability:
            return None
        from repro.runtime.observability import Observability
        return Observability.enabled(trace_capacity=self.trace_capacity,
                                     event_capacity=self.event_capacity)

    def build_cache(self, **kw) -> RemoteResponseCache | None:
        """Response cache sized from the config (``key_fn`` /
        ``key_batch_fn`` pass through); None when disabled."""
        if self.cache_size <= 0:
            return None
        return RemoteResponseCache(self.cache_size, **kw)

    def build_engine(self, local_apply: Callable,
                     remote_apply: Callable | None = None, **kw):
        """``CascadeEngine.from_config`` convenience: on the runtime path
        a ``transport=`` (router) may be passed explicitly, otherwise one
        is built from ``remote_apply`` per the ``remotes`` specs."""
        from repro.serving.engine import CascadeEngine
        return CascadeEngine.from_config(self, local_apply,
                                         remote_apply=remote_apply, **kw)

    def build_scheduler(self, engine, **kw):
        from repro.serving.scheduler import MicrobatchScheduler
        return MicrobatchScheduler.from_config(engine, self, **kw)

    def build(self, local_apply: Callable,
              remote_apply: Callable | None = None, *,
              fallback: Callable | None = None,
              prior: Callable | None = None, **engine_kw):
        """One-call construction of the whole serving stack: returns
        ``(engine, scheduler)`` wired per this config."""
        engine = self.build_engine(local_apply, remote_apply, **engine_kw)
        sched = self.build_scheduler(engine, fallback=fallback, prior=prior)
        return engine, sched

    # -- key=value overrides (the retired flags' migration target) ------
    def with_overrides(self, overrides) -> "ServeConfig":
        """Return a copy with ``key=value`` strings applied. Nested
        ``transport.*`` / ``cost.*`` / ``default_policy.*`` keys reach
        into the sub-configs; ``remotes`` parses a ``name:cost:lat[;...]``
        spec list; ``none`` clears an optional field."""
        updates: dict[str, Any] = {}
        nested: dict[str, dict[str, Any]] = {}
        for item in overrides:
            key, sep, raw = item.partition("=")
            if not sep:
                raise ValueError(f"bad override {item!r}; expected "
                                 f"key=value")
            key = key.strip()
            raw = raw.strip()
            if "." in key:
                outer, inner = key.split(".", 1)
                sub = getattr(self, outer, None)
                if outer not in ("transport", "cost", "default_policy"):
                    raise ValueError(f"unknown nested override {key!r}")
                if outer == "cost" and sub is None:
                    from repro.serving.engine import CostModel
                    sub = CostModel()
                tgt = nested.setdefault(outer, {"_obj": sub})
                tgt[inner] = _coerce_field(type(sub), inner, raw)
            elif key == "remotes":
                # "none" clears the registry (back to the single default
                # "remote" backend), like any other optional field
                updates[key] = (() if raw.lower() in ("none", "null")
                                else _parse_remotes(raw))
            elif key == "tiers":
                updates[key] = (() if raw.lower() in ("none", "null")
                                else _parse_tiers(raw))
            else:
                updates[key] = _coerce_field(ServeConfig, key, raw)
        for outer, kv in nested.items():
            obj = kv.pop("_obj")
            updates[outer] = dataclasses.replace(obj, **kv)
        return dataclasses.replace(self, **updates)


def _coerce_field(cls, name: str, raw: str) -> Any:
    """Parse ``raw`` per the declared type of dataclass field ``name``."""
    flds = {f.name: f for f in dataclasses.fields(cls)}
    if name not in flds:
        raise ValueError(f"unknown {cls.__name__} field {name!r}; "
                         f"known: {sorted(flds)}")
    if raw.lower() in ("none", "null"):
        return None
    ann = str(flds[name].type)
    if "bool" in ann:
        if raw.lower() in ("true", "1", "yes", "on"):
            return True
        if raw.lower() in ("false", "0", "no", "off"):
            return False
        raise ValueError(f"bad bool for {name}: {raw!r}")
    if "int" in ann:
        return int(raw)
    if "float" in ann:
        return float(raw)
    if "str" in ann:
        return raw
    # non-scalar field (cost/transport/default_policy): storing the raw
    # string would blow up far from the CLI — demand nested overrides
    raise ValueError(f"{cls.__name__}.{name} is not settable as a bare "
                     f"value; use nested '{name}.<field>=...' overrides")

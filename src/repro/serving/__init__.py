"""Two-tier cascade serving runtime."""

from repro.serving.engine import (CascadeEngine, CascadeStats, CostModel,
                                  make_cascade_step, make_gated_local_step,
                                  make_local_step)
from repro.serving.generate import greedy_generate
from repro.serving.policy import (DISPOSITIONS, ESCALATION_MODES,
                                  ON_MISS_MODES, PACKING_MODES,
                                  RemoteSpec, RequestPolicy, ServeConfig,
                                  TierSpec)
from repro.serving.scheduler import (COMPLETION_MODES, MicrobatchScheduler,
                                     Request, Response)

__all__ = ["CascadeEngine", "CascadeStats", "CostModel", "COMPLETION_MODES",
           "DISPOSITIONS", "ESCALATION_MODES", "ON_MISS_MODES",
           "PACKING_MODES", "RemoteSpec", "RequestPolicy", "ServeConfig",
           "TierSpec", "make_cascade_step", "make_gated_local_step",
           "make_local_step", "greedy_generate", "MicrobatchScheduler",
           "Request", "Response"]

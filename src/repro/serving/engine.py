"""Cascade serving engine — BiSupervised as a two-tier production runtime.

The engine composes:
  * a LOCAL tier: cheap classifier (surrogate) evaluated for every request,
  * a 1st-level supervisor on the local logits,
  * escalation to a REMOTE tier — either a fused in-jit callable (offline /
    trusted deployments) or a fault-aware ``repro.runtime`` transport /
    multi-backend router with caching and an online budget controller
    (DESIGN.md §2-§4, §6),
  * a 2nd-level supervisor on the remote metadata,
  * per-request cost/latency accounting mirroring the paper's billing
    model (Table 7 / §5.6) — padded scheduler rows are never billed.

Three serve paths (DESIGN.md §2, §5):
  * fused     — ``make_cascade_step``: local + remote in one jitted step
    with a static escalation capacity k (the seed behaviour; remote tier
    is an infallible callable).
  * runtime   — local tier jitted behind the fused ``confidence_gate``
    kernel (only the compact (conf, pred, idx) triple crosses the host
    boundary), escalated sub-batch routed host-side through
    ``RemoteResponseCache`` -> ``RemoteTransport``; failed windows degrade
    to the REJECTED/fallback path; an ``AdaptiveController`` retunes
    ``t_local``/``t_remote``/capacity per control window.
  * pipelined — the runtime path split at the transport boundary:
    ``begin_serve`` dispatches local compute + non-blocking remote
    submission, ``complete_next`` drains in-flight windows strictly in
    submission order, so batch i+1's local tier overlaps batch i's remote
    round trip while accounting and controller observations stay
    deterministic.
  * streaming — the pipelined path with per-request completion
    (DESIGN.md §7): ``complete_ready``/``stream`` finalize windows the
    moment their remote futures resolve (out of submission order when
    thresholds are static), while accounting still COMMITS strictly in
    submission order — responses, billing, per-backend attribution and
    controller updates are bitwise-identical to the FIFO drain.

Device-overlap double buffering (DESIGN.md §7): ``begin_serve`` only
DISPATCHES batch i's local forward; the host half (``device_get`` of the
gate triple, cache lookups, routing, remote submission) runs when batch
i+1 begins — so the accelerator computes batch i+1 while batch i's
escalations cross the host boundary. ``flush_dispatch`` unparks the final
window once no more begins are coming.

Per-request policy (DESIGN.md §8): every serve path accepts one
``RequestPolicy`` per genuine row (deadline SLA, cost cap, routing hint,
escalation override). The host half enforces them before any cache or
transport work — deadline-infeasible escalations downgrade to the local
prediction with the ``DEADLINE_LOCAL`` disposition instead of blowing
the SLA — and every result row carries ``disposition``/``backend``/
``cost`` so billing attribution surfaces at the API boundary. The
engine (like the scheduler and router) is constructed from a single
``ServeConfig`` facade via ``from_config``; the keyword constructor
remains as the low-level composition-root API (tests, bespoke wiring).

Observability (DESIGN.md §9): construct with ``observability=`` (or
``ServeConfig(observability=True)``) and the engine stamps a per-window
stage timeline into ``_InFlight.tr`` (dispatch → gate → route → remote →
commit), publishes commit-time counters into the metrics registry, and
emits downgrade events; the scheduler turns window stamps into one span
per request at hand-back. Every hook is guarded by a single
``is not None`` test, so the disabled mode adds zero per-row work.

Multi-remote routing (DESIGN.md §6): the runtime/pipelined paths accept a
``RemoteRouter`` of named ``RemoteBackend``s in place of a bare transport
(a bare ``RemoteTransport`` is auto-wrapped as a single-backend registry,
preserving the PR-2 behaviour bit for bit). Each escalation window is
routed to one backend picked at submit time — an open breaker fails over
within the same window — and billing/latency attribute per backend in
``CascadeStats.per_backend`` using the backend's own price and modelled
latency (falling back to the ``CostModel`` constants).
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, ClassVar, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cascade import (combine_escalated, escalation_capacity,
                                gather_requests, select_escalations)
from repro.core.supervisors import SOFTMAX_SUPERVISORS
from repro.kernels.confidence_gate.ops import _on_tpu, confidence_gate
from repro.kernels.fused_head_gate.ops import FusedLocalHead, fused_head_gate
from repro.runtime.observability import (EV_BACKEND_AGREEMENT,
                                         EV_DEADLINE_DOWNGRADE,
                                         EV_POLICY_DOWNGRADE,
                                         EV_STAGE_ANSWER)
from repro.runtime.transport import (RemoteBackend, RemoteRouter,
                                     RouteConstraint)
from repro.serving.policy import (CACHED, DEADLINE_LOCAL, LOCAL,
                                  POLICY_LOCAL, REJECTED, REMOTE,
                                  RequestPolicy, ServeConfig)

def _any_policy(policies) -> bool:
    """True iff some entry actually constrains serving."""
    return policies is not None and any(
        p is not None and not p.is_default for p in policies)

# per-backend accounting key for escalations no backend would accept
# (every breaker open): they fail without touching any transport
UNROUTED = "(unrouted)"
# the CascadeStats fields that constitute the billing contract: every
# "pipelined/streaming accounting is identical to serial/FIFO" check
# (benchmarks, tests) compares exactly these — extend HERE when stats
# grow a new billable field so the equivalence checks can't silently
# weaken
BILLING_FIELDS = ("requests", "escalations", "remote_calls", "cache_hits",
                  "transport_failures", "rejected", "total_cost")
# attribution for cache entries stored without a source backend
UNATTRIBUTED = "(cache)"
# EMA weight for the per-backend agreement-with-local signal
# (DESIGN.md §13): one observation per committed window per backend
AGREEMENT_ALPHA = 0.2


@dataclass(frozen=True)
class CostModel:
    """Latency/cost constants (paper Table 7 / GPT-3 style billing).

    Cache hits are re-served, not re-billed: they cost ``cache_hit_
    latency_s`` and $0 (DESIGN.md §4). With a multi-remote registry the
    remote constants are *defaults*: a ``RemoteBackend`` carrying its own
    ``cost_per_request`` / ``latency_s`` overrides them per window
    (DESIGN.md §6)."""
    local_latency_s: float = 0.05
    remote_latency_s: float = 0.32       # incl. network round trip
    remote_cost_per_request: float = 0.0048
    cache_hit_latency_s: float = 0.001

    def backend_cost(self, backend) -> float:
        """Per-call price for a backend (None backend/price -> default)."""
        if backend is not None and backend.cost_per_request is not None:
            return backend.cost_per_request
        return self.remote_cost_per_request

    def backend_latency(self, backend) -> float:
        """Modelled round trip for a backend (None -> default)."""
        if backend is not None and backend.latency_s is not None:
            return backend.latency_s
        return self.remote_latency_s


@dataclass
class BackendUsage:
    """Per-backend slice of the cascade accounting (DESIGN.md §6). The
    invariant ``escalations = remote_calls + cache_hits +
    transport_failures`` holds summed over all per-backend entries
    (including the ``UNROUTED`` pseudo-backend)."""
    remote_calls: int = 0            # billed invocations of this backend
    cache_hits: int = 0              # hits on entries this backend filled
    transport_failures: int = 0      # escalations this backend lost
    cost: float = 0.0                # realised $ billed to this backend
    remote_latency_s: float = 0.0    # modelled remote seconds accrued
    # running agreement-with-local EMA over the escalated rows this
    # backend served (DESIGN.md §13): the label-free accuracy signal the
    # 2nd-level threshold can consult — None until the first served row
    agreement_ema: float | None = None
    agreement_rows: int = 0


@dataclass
class CascadeStats:
    requests: int = 0                # genuine (non-padding) requests
    escalations: int = 0             # requests routed past the local tier
    remote_calls: int = 0            # billed remote invocations
    cache_hits: int = 0              # escalations served from cache ($0)
    transport_failures: int = 0      # escalations lost to transport faults
    rejected: int = 0
    total_cost: float = 0.0
    total_latency_s: float = 0.0     # modelled (CostModel constants)
    wall_latency_s: float = 0.0      # measured request-seconds (timers)
    # per-backend billing/latency attribution (runtime path; DESIGN.md §6)
    per_backend: dict = field(default_factory=dict)
    # ring buffer of recent per-window wall times: percentiles stay
    # representative of CURRENT behaviour on long-running servers
    wall_samples: deque = field(
        default_factory=lambda: deque(maxlen=65536), repr=False)
    # EMA of per-window wall service time — the admission controller's
    # queue-wait estimator (DESIGN.md §10): expected_wait ≈ windows_ahead
    # * window_service_ema_s. None until the first window commits.
    window_service_ema_s: float | None = None

    SERVICE_EMA_ALPHA: ClassVar[float] = 0.2

    def backend_usage(self, name: str) -> BackendUsage:
        return self.per_backend.setdefault(name, BackendUsage())

    @property
    def remote_fraction(self) -> float:
        return self.remote_calls / max(self.requests, 1)

    @property
    def escalation_fraction(self) -> float:
        return self.escalations / max(self.requests, 1)

    @property
    def mean_latency_s(self) -> float | None:
        """Modelled mean per-request latency; None before any request —
        empty stats must render as absent, not as a flattering 0.0
        (DESIGN.md §9 empty-stats contract)."""
        if self.requests == 0:
            return None
        return self.total_latency_s / self.requests

    # -- measured wall-clock latency (vs the modelled numbers above) ----
    def record_wall(self, window_wall_s: float, real: int) -> None:
        """Fold one served window's measured wall time into the stats.
        In pipelined mode this spans submit -> drain, so per-request wall
        latency includes pipeline residency, not just compute."""
        self.wall_latency_s += window_wall_s * real
        self.wall_samples.append(float(window_wall_s))
        a = self.SERVICE_EMA_ALPHA
        self.window_service_ema_s = (
            window_wall_s if self.window_service_ema_s is None
            else a * window_wall_s + (1 - a) * self.window_service_ema_s)

    @property
    def mean_wall_latency_s(self) -> float | None:
        """Measured mean per-request wall latency; None before any
        request (empty-stats contract, see ``mean_latency_s``)."""
        if self.requests == 0:
            return None
        return self.wall_latency_s / self.requests

    def wall_percentile(self, q: float) -> float | None:
        """q-th percentile (0-100) of recent per-window wall latency;
        None before any window has been timed."""
        if not self.wall_samples:
            return None
        return float(np.percentile(np.fromiter(self.wall_samples,
                                               np.float64), q))


def make_cascade_step(local_apply: Callable, remote_apply: Callable,
                      capacity: int, supervisor: str = "max_softmax"):
    """Build the jit-able fused cascade step.

    local_apply(local_batch) -> logits [B, C]
    remote_apply(remote_batch_gathered) -> logits [k, C]
    Requests carry BOTH input views (paper §4.1 input-domain reduction):
    batch = {"local": <reduced inputs>, "remote": <full inputs>}.

    `supervisor` is a SOFTMAX_SUPERVISORS name, or any callable
    logits -> confidence (e.g. a bound MDSA on hidden states — the paper's
    recommendation for non-softmax local models, §4.2).

    Returns step(batch) -> dict(pred, local_conf, remote_conf, escalated).
    """
    sup = (supervisor if callable(supervisor)
           else SOFTMAX_SUPERVISORS[supervisor])

    def step(batch):
        local_logits = local_apply(batch["local"])
        local_conf = sup(local_logits)
        local_pred = jnp.argmax(local_logits, -1)

        idx, esc_mask = select_escalations(local_conf, capacity)
        remote_in = gather_requests(batch["remote"], idx)
        remote_logits = remote_apply(remote_in)
        remote_pred = jnp.argmax(remote_logits, -1)
        remote_conf_sub = sup(remote_logits)

        pred = combine_escalated(local_pred, idx, remote_pred)
        # non-escalated requests never consult the 2nd supervisor; fill +inf
        remote_conf = jnp.full_like(local_conf, jnp.inf).at[idx].set(
            remote_conf_sub)
        return {"prediction": pred, "local_conf": local_conf,
                "remote_conf": remote_conf, "escalated": esc_mask,
                "local_pred": local_pred}

    return step


def make_local_step(local_apply: Callable, supervisor="max_softmax"):
    """Jit-able local-tier-only step (legacy runtime path; returns the
    full logits — prefer make_gated_local_step on the hot path)."""
    sup = (supervisor if callable(supervisor)
           else SOFTMAX_SUPERVISORS[supervisor])

    def step(local_batch):
        logits = local_apply(local_batch)
        return {"local_conf": sup(logits),
                "local_pred": jnp.argmax(logits, -1),
                "local_logits": logits}

    return step


def make_gated_local_step(local_apply: Callable, supervisor="max_softmax",
                          emit=None):
    """Jit-able local tier fused with the confidence gate: supervisor
    scoring + thresholded ascending escalation ranking happen on device,
    and only the compact ``(conf [B], pred [B], idx [B])`` triple crosses
    the host boundary — never the ``[B, C]`` logits (DESIGN.md §5).

    step(local_batch, t_local [f32 scalar, +inf = no threshold],
         n_valid [i32 scalar]) -> {conf, pred, idx}; the scalars are
    traced, so runtime retuning never recompiles.

    When ``local_apply`` is a ``FusedLocalHead`` the final projection is
    folded into the gate's scoring pass (kernels/fused_head_gate) so
    full-vocab logits never round-trip through HBM.

    ``emit`` opts into in-kernel early emit (DESIGN.md §11): the step
    gains a trailing ``seq`` arg and the gate surfaces its triple to
    ``emit(seq, conf, pred, idx)`` on the host the moment it lands.
    """
    fused = isinstance(local_apply, FusedLocalHead)

    if emit is None:
        def step(local_batch, t_local, n_valid):
            if fused:
                h = local_apply.trunk(local_batch)
                return fused_head_gate(h, local_apply.w, local_apply.bias,
                                       t_local, n_valid,
                                       supervisor=supervisor)
            logits = local_apply(local_batch)
            return confidence_gate(logits, t_local, n_valid,
                                   supervisor=supervisor)

        return step

    def step(local_batch, t_local, n_valid, seq):
        if fused:
            h = local_apply.trunk(local_batch)
            return fused_head_gate(h, local_apply.w, local_apply.bias,
                                   t_local, n_valid, supervisor=supervisor,
                                   emit=emit, emit_tag=seq)
        logits = local_apply(local_batch)
        return confidence_gate(logits, t_local, n_valid,
                               supervisor=supervisor, emit=emit,
                               emit_tag=seq)

    return step


def _leading_rows(tree: Any) -> int:
    if isinstance(tree, dict):
        return _leading_rows(next(iter(tree.values())))
    return int(tree.shape[0]) if hasattr(tree, "shape") else \
        int(np.asarray(tree).shape[0])


class _Resolved:
    """Adapter giving a synchronous transport result the future API."""

    def __init__(self, result):
        self._result = result

    def done(self) -> bool:
        return True

    def result(self, timeout=None):
        return self._result


@dataclass
class _InFlight:
    """One microbatch's per-request completion bookkeeping, from dispatch
    to its accounting commit. Lifecycle (DESIGN.md §7)::

        dispatch      device local forward launched; control state
                      (capacity, t_local) snapshotted at submit time
        host half     gate triple fetched, cache lookups, routing,
                      remote submission  (deferred one begin by the
                      double buffer; ``host_done`` flips here)
        finalize      remote responses folded in, acceptance decided
                      with the CURRENT t_remote (``finalized`` flips;
                      ``result`` holds the per-request outputs)
        commit        stats / per-backend billing / controller observe
                      — strictly in submission (seq) order
    """
    seq: int                    # submission order (1-based, monotonic)
    t0: float
    b: int                      # padded batch rows
    real: int                   # genuine leading rows
    asynchronous: bool          # futures (pipelined) vs sync transport
    capacity: int               # escalation cap snapshotted at dispatch
    # -- per-request policy layer (DESIGN.md §8) -------------------------
    policies: Any = None        # [real] RequestPolicy | None per row
    t_enq: Any = None           # [real] enqueue stamps (deadline anchor)
    policed: bool = False       # any row carries a non-trivial policy
    downgraded: dict = field(default_factory=dict)  # row -> disposition
    forced: set = field(default_factory=set)   # idx POSITIONS policy-REJECTED
    blocked: int = 0            # rows policy withheld from escalation
    constraint: Any = None      # merged RouteConstraint (cap/hint part)
    # earliest absolute deadline among escalating rows (engine clock);
    # the latency ceiling is recomputed from it at every routing
    # decision — submit-time pick AND drain-time replay — so a window
    # that rode the pipeline can't be served against a stale budget
    abs_deadline: float | None = None
    early: list = field(default_factory=list)  # rows decidable at host half
    # -- dispatch half (device) ----------------------------------------
    gate_dev: Any = None        # un-fetched device gate output
    remote_batch: Any = None    # batch["remote"], held until the host half
    gate_done: bool = False     # gate half ran (conf/pred/idx pinned)
    host_done: bool = False
    # -- host half ------------------------------------------------------
    conf: np.ndarray | None = None   # [b] 1st-level confidences
    local_pred: np.ndarray | None = None  # [b] local preds (never mutated)
    pred: np.ndarray | None = None   # [b] served preds (remote scattered)
    idx: np.ndarray | None = None    # [k] escalated row indices (asc conf)
    k: int = 0
    keys: list | None = None    # cache keys per escalated row
    cached: list | None = None  # cache hits / filled-in remote responses
    hit_src: list | None = None # backend name per cache hit (attribution)
    miss: list = field(default_factory=list)  # idx positions gone remote
    pending: Any = None         # TransportFuture | _Resolved | None
    backend: Any = None         # RemoteBackend routed to (None = unrouted)
    replay_ticket: bool = False # parked for a bounded (unrouted) replay
    sub_miss: Any = None        # miss sub-batch, held only for a replay
    # -- finalize half --------------------------------------------------
    finalized: bool = False
    result: dict | None = None
    remote_conf: np.ndarray | None = None
    n_sent: int = 0
    n_failed: int = 0
    n_hits: int = 0
    bname: str = UNROUTED
    # per-row stage attribution from a chained CascadeStage backend
    # (DESIGN.md §13); None for plain backends and terminal stages, which
    # keeps the degenerate 2-stage config on the existing path
    stage_detail: dict | None = None
    stage_split: dict | None = None  # stage -> [calls, failures, cost, lat]
    agreement: list | None = None    # (backend, rows, window frac, ema)
    # -- observability (DESIGN.md §9) -----------------------------------
    # per-window stage timestamps (dispatch/gate/route/remote/commit) +
    # the gating threshold; None when observability is disabled, so the
    # hot path allocates nothing per window, let alone per row
    tr: dict | None = None


class CascadeEngine:
    """Host-side engine: batching, runtime thresholds, accounting.

    Legacy fused construction (remote tier = bare infallible callable,
    static capacity)::

        CascadeEngine(local_apply, remote_apply, batch_size=32,
                      remote_fraction_budget=0.25, t_remote=0.9)

    Runtime construction (fault-aware transport, optional controller and
    response cache — DESIGN.md §2)::

        CascadeEngine(local_apply, batch_size=32,
                      remote_fraction_budget=0.25, t_remote=0.9,
                      transport=RemoteTransport(remote_apply),
                      controller=AdaptiveController(),
                      cache=RemoteResponseCache())

    Multi-remote construction (DESIGN.md §6) — pass a router instead::

        CascadeEngine(local_apply, batch_size=32, ...,
                      transport=RemoteRouter([
                          RemoteBackend("cheap", apply_a,
                                        cost_per_request=0.002),
                          RemoteBackend("fast", apply_b,
                                        cost_per_request=0.008),
                      ], policy="cheapest-available"))

    A bare transport is wrapped as a single-backend registry; predictions
    and billing stay bitwise-identical to the pre-registry path.

    The runtime path can serve synchronously (``serve``), pipelined
    (``begin_serve`` / ``complete_next`` — DESIGN.md §5, completions
    drain strictly in submission order), or streaming (``begin_serve`` /
    ``complete_ready`` / ``stream`` — DESIGN.md §7, windows hand back the
    moment their remote futures resolve while accounting still commits in
    submission order). In all three, results, stats and controller state
    do not depend on remote completion order. ``close()`` (or using the
    engine as a context manager) drains in-flight windows and shuts down
    every backend's thread pool.
    """

    def __init__(self, local_apply, remote_apply=None, *, batch_size: int,
                 remote_fraction_budget: float,
                 t_remote: float, cost: CostModel = CostModel(),
                 supervisor="max_softmax", transport=None, controller=None,
                 cache=None, clock: Callable[[], float] = time.perf_counter,
                 default_policy: RequestPolicy | None = None,
                 observability=None, early_emit: bool | str = False,
                 mesh=None):
        if remote_apply is None and transport is None:
            raise ValueError("need a remote tier: remote_apply or transport")
        self.batch_size = batch_size
        self.capacity = escalation_capacity(batch_size,
                                            remote_fraction_budget)
        self.t_remote = t_remote            # runtime-tunable (paper §4.5)
        self.t_local: float | None = None   # runtime-tunable escalation gate
        self.cost = cost
        self.stats = CascadeStats()
        # `transport` may be a RemoteTransport OR a RemoteRouter; keep the
        # raw object (schedulers/tests check `engine.transport`) and route
        # internally through a registry either way
        self.transport = transport
        self.router: RemoteRouter | None = None
        if transport is not None:
            self.router = (transport if isinstance(transport, RemoteRouter)
                           else RemoteRouter(
                               [RemoteBackend("remote", transport=transport)]))
        self.controller = controller
        self.cache = cache
        # default RequestPolicy applied to rows without their own; a
        # trivial default collapses to None so unpolicied traffic keeps
        # the zero-overhead fast path (DESIGN.md §8)
        self.default_policy = (default_policy
                               if default_policy is not None
                               and not default_policy.is_default else None)
        self._clock = clock
        # opt-in for _early_decide (DESIGN.md §8): only a streaming
        # consumer reads fl.early, so the streaming scheduler flips this
        # and the FIFO paths skip the extra host-half supervisor pass
        self.early_handback = False
        self._inflight: deque[_InFlight] = deque()
        self._seq = 0
        # set by any window's remote future resolving (any backend): the
        # streaming drain parks here instead of polling head-of-line
        self._ready = threading.Event()
        self._supervisor = (supervisor if callable(supervisor)
                            else SOFTMAX_SUPERVISORS[supervisor])
        # observability facade (DESIGN.md §9): None = disabled; install()
        # wires the router/transports/controller into the shared event
        # log and registers the snapshot-time metrics collector
        self.observability = None
        if observability is not None:
            observability.install(self)
        # in-kernel early emit (DESIGN.md §11): the gate surfaces its
        # triple through an io_callback keyed by window seq the moment
        # the scoring pass lands, so the continuous batcher can hand
        # locally-trusted rows back at *gate* time instead of waiting
        # for the window's host half to fetch the device buffer.
        # "auto" arms it only where dispatch is asynchronous enough for
        # the callback to overlap device work (TPU): on CPU the host
        # rendezvous costs ~350us per dispatch — more than the whole
        # local step — and the host half reads the device buffer just
        # as fast. The callback is an accelerator, never a correctness
        # dependency: unarmed (or late), the host half falls back to
        # the ordinary device fetch.
        if early_emit == "auto":
            early_emit = _on_tpu()
        self.early_emit = bool(early_emit) and transport is not None
        self._gate_emits = 0            # telemetry: callbacks landed
        self._gate_lock = threading.Lock()
        self._gate_results: dict[int, tuple] = {}
        # data-parallel local forward (DESIGN.md §12): when a mesh is
        # supplied the gated local step constrains its input batch to
        # batch-dim sharding before jit — parameters stay replicated.
        # On a 1-device mesh the constraint is a no-op, so enabling it
        # never changes predictions.
        self.mesh = mesh
        if transport is None:
            self._step = jax.jit(make_cascade_step(
                local_apply, remote_apply, self.capacity, supervisor))
        else:
            step = make_gated_local_step(
                local_apply, supervisor,
                emit=self._on_gate if self.early_emit else None)
            if mesh is not None:
                from repro.launch.sharding import shard_local_step
                step = shard_local_step(step, mesh)
            self._local_step = jax.jit(step)

    # -- ServeConfig construction (DESIGN.md §8) -----------------------
    _UNSET = object()

    @classmethod
    def from_config(cls, config: ServeConfig, local_apply,
                    remote_apply=None, *, transport=None,
                    controller=_UNSET, cache=_UNSET,
                    observability=_UNSET, mesh=_UNSET,
                    clock: Callable[[], float] = time.perf_counter
                    ) -> "CascadeEngine":
        """Build the engine from one ``ServeConfig`` (the supported
        construction path). On the runtime path the remote registry is
        built from ``remote_apply`` per ``config.remotes`` unless a
        ``transport``/router is passed explicitly; the controller,
        response cache, observability facade and data-parallel mesh come
        from the config unless overridden (pass ``controller=None``/
        ``cache=None``/``observability=None``/``mesh=None`` to force
        them off — the cluster harness overrides all four per replica,
        DESIGN.md §12)."""
        if config.fused:
            eng = cls(local_apply, remote_apply,
                      batch_size=config.batch_size,
                      remote_fraction_budget=config.remote_fraction_budget,
                      t_remote=config.t_remote,
                      cost=config.cost or CostModel(),
                      supervisor=config.supervisor, clock=clock)
        else:
            if transport is None:
                if remote_apply is None:
                    raise ValueError("runtime path needs remote_apply or "
                                     "an explicit transport/router")
                transport = config.build_router(remote_apply)
            if mesh is cls._UNSET:
                if config.data_parallel:
                    from repro.launch.mesh import make_serving_mesh
                    mesh = make_serving_mesh()
                else:
                    mesh = None
            eng = cls(local_apply, batch_size=config.batch_size,
                      remote_fraction_budget=config.remote_fraction_budget,
                      t_remote=config.t_remote,
                      cost=config.cost or CostModel(),
                      supervisor=config.supervisor, transport=transport,
                      controller=(config.build_controller()
                                  if controller is cls._UNSET
                                  else controller),
                      cache=(config.build_cache() if cache is cls._UNSET
                             else cache),
                      clock=clock, default_policy=config.default_policy,
                      observability=(config.build_observability()
                                     if observability is cls._UNSET
                                     else observability),
                      early_emit=("auto"
                                  if config.batching == "continuous"
                                  else False),
                      mesh=mesh)
        if config.t_local is not None:
            eng.set_local_threshold(config.t_local)
        return eng

    def set_remote_threshold(self, t: float) -> None:
        """Runtime reconfiguration (paper §4.5)."""
        self.t_remote = t

    def set_local_threshold(self, t: float | None) -> None:
        """Runtime escalation gate (runtime path; None = capacity-k)."""
        self.t_local = t

    # -- in-kernel early emit (DESIGN.md §11) ---------------------------
    def _on_gate(self, seq, conf, pred, idx) -> None:
        """io_callback target: the gate's (conf, pred, idx) triple for
        window ``seq`` just landed on the host. Runs whenever the device
        forces the computation — possibly on a transport thread — so it
        only stores and signals; consumers poll ``gate_result``."""
        with self._gate_lock:
            self._gate_results[int(seq)] = (np.asarray(conf).copy(),
                                            np.asarray(pred).copy(),
                                            np.asarray(idx).copy())
            self._gate_emits += 1
        self._ready.set()

    def gate_result(self, seq: int):
        """The early-emitted gate triple for window ``seq`` (``(conf,
        pred, idx)`` numpy arrays), or None if the gate hasn't cleared
        yet. Entries are consumed by the window's host half and swept at
        commit; callers must treat the arrays as read-only."""
        with self._gate_lock:
            return self._gate_results.get(seq)

    # ------------------------------------------------------------------
    def serve(self, batch: dict[str, Any], real_rows: int | None = None,
              policies=None, t_enq=None) -> dict[str, np.ndarray]:
        """Serve one batch; ``real_rows`` marks how many leading rows are
        genuine — padded replicas beyond it are served (static jit shapes)
        but never counted or billed. ``policies`` carries one
        ``RequestPolicy | None`` per genuine row and ``t_enq`` the rows'
        enqueue stamps (the deadline anchor) — DESIGN.md §8."""
        if self.transport is None:
            if _any_policy(policies) or self.default_policy is not None:
                raise RuntimeError("per-request policies need the runtime "
                                   "path (construct the engine with "
                                   "transport=...)")
            return self._serve_fused(batch, real_rows)
        if self._inflight:
            raise RuntimeError("pipelined windows in flight; drain them "
                               "with complete_next() before serve()")
        fl = self._dispatch(batch, real_rows, asynchronous=False,
                            policies=policies, t_enq=t_enq)
        self._host_begin(fl)
        self._finalize(fl)
        return self._commit(fl)

    # -- pipelined runtime path (DESIGN.md §5, §7) ---------------------
    def begin_serve(self, batch: dict[str, Any],
                    real_rows: int | None = None,
                    policies=None, t_enq=None) -> _InFlight:
        """Dispatch one microbatch's local forward on the device, then
        run the host half of the PREVIOUS window (double buffering,
        DESIGN.md §7): the gate triple fetch, cache lookups, routing and
        the non-blocking remote submission of batch i happen while batch
        i+1 computes on the accelerator. Returns the window handle; its
        ``conf``/``local_pred``/``idx`` fields populate once its own host
        half runs (at the next begin, ``flush_dispatch``, or its drain)."""
        if self.transport is None:
            raise RuntimeError("pipelined serving needs the runtime path "
                               "(construct the engine with transport=...)")
        prev = self._inflight[-1] if self._inflight else None
        fl = self._dispatch(batch, real_rows, asynchronous=True,
                            policies=policies, t_enq=t_enq)
        self._inflight.append(fl)
        if prev is not None and not prev.host_done:
            self._host_begin(prev)
        return fl

    def flush_gate(self) -> None:
        """Run only the GATE half of the NEWEST window's deferred host
        work: triple fetch + escalation-set pinning + policy pass, no
        cache/routing/transport. The continuous scheduler calls this
        right after ``begin_serve`` so trusted-local rows hand back
        before the escalations are even routed; ``flush_dispatch`` (or
        the drain) later completes the submit half (DESIGN.md §11)."""
        if self._inflight and not self._inflight[-1].gate_done:
            self._host_gate(self._inflight[-1])

    def flush_dispatch(self) -> None:
        """Run the deferred host half of the NEWEST window (the double
        buffer parks it until the next begin). Call when no further
        ``begin_serve`` is coming, so the last window's remote submission
        overlaps the earlier drains instead of serialising behind them."""
        if self._inflight and not self._inflight[-1].host_done:
            self._host_begin(self._inflight[-1])

    def complete_next(self) -> dict[str, np.ndarray] | None:
        """Drain the OLDEST in-flight window (blocks until its remote
        responses land). FIFO draining keeps accounting and controller
        observations independent of remote completion order."""
        if not self._inflight:
            return None
        fl = self._inflight[0]
        self._finalize(fl)              # forces a parked host half too
        self._inflight.popleft()
        return self._commit(fl)

    # -- streaming completion (DESIGN.md §7) ---------------------------
    def complete_ready(self, block: bool = False
                       ) -> list[tuple[int, dict[str, np.ndarray]]]:
        """Per-request streaming drain: finalize every in-flight window
        whose remote responses have landed and hand back their results —
        OUT of submission order — while accounting (stats, per-backend
        billing, controller observations) still commits strictly in
        submission order, so totals are bitwise-identical to the FIFO
        drain.

        With a live controller the ready set is restricted to the FIFO
        prefix: acceptance thresholds evolve with every committed window,
        so finalizing out of order would change which remote answers are
        trusted. Static thresholds have no such coupling and windows
        finalize the moment their future resolves. Windows parked with an
        (unrouted) replay ticket wait until they reach the head, giving a
        breaker the full pipeline residency to half-open before the
        replay pick.

        With a response cache, out-of-order finalize makes cache FILL
        timing depend on remote latency, so the cache_hits/remote_calls
        split (and hence total_cost) may differ from the FIFO drain when
        escalated content repeats across in-flight windows — bounded and
        benign: hits can only be gained, cost can only drop, and served
        predictions are unchanged (an entry holds the very logits the
        remote call would return). The bitwise-billing guarantee is
        exact for cacheless runs and for repeats across already-drained
        windows (DESIGN.md §7).

        Returns ``(seq, result)`` pairs for windows finalized by THIS
        call, ``seq`` being the value on the ``begin_serve`` handle. With
        ``block=True`` waits until at least one window finalizes
        (returns ``[]`` immediately when nothing is in flight)."""
        while True:
            events = self._scan_ready()
            if events or not block or not self._inflight:
                return events
            self._ready.clear()
            events = self._scan_ready()  # racing resolve before clear()
            if events:
                return events
            # event wakeup from any backend's pool; the timeout is a
            # safety net, not a poll interval
            self._ready.wait(0.05)

    def stream(self) -> Iterator[tuple[int, dict[str, np.ndarray]]]:
        """Generator draining every in-flight window in completion order
        (``complete_ready`` semantics): yields ``(seq, result)`` as each
        window's remote responses land."""
        while self._inflight:
            yield from self.complete_ready(block=True)

    def _scan_ready(self) -> list[tuple[int, dict[str, np.ndarray]]]:
        """One non-blocking pass of the streaming drain: finalize every
        ready window, then commit the contiguous finalized prefix.

        With a controller, finalize NEVER runs ahead of commit: window
        i+1's acceptance must see the t_remote that window i's
        observation produced, so the pass walks head-first, committing
        each window before looking at the next."""
        events: list[tuple[int, dict[str, np.ndarray]]] = []
        if self.controller is not None:        # FIFO prefix only
            while self._inflight:
                fl = self._inflight[0]
                if not fl.host_done:
                    # only the newest window can be parked; head+parked
                    # means it is alone — nothing else can unblock it
                    self._host_begin(fl)
                if fl.pending is not None and not fl.pending.done():
                    break
                self._finalize(fl)
                events.append((fl.seq, fl.result))
                self._commit(self._inflight.popleft())
            return events
        progressed = True
        while progressed and self._inflight:
            progressed = False
            # a lone parked window cannot be unblocked by anything else:
            # run its host half so its remote round trip starts
            if len(self._inflight) == 1 and not self._inflight[0].host_done:
                self._host_begin(self._inflight[0])
                progressed = True
            head = self._inflight[0]
            for fl in self._inflight:
                if fl.finalized or not fl.host_done:
                    continue
                if fl.pending is not None:
                    ready = fl.pending.done()
                else:
                    # no remote in flight: ready now — except a replay
                    # ticket, which waits for the head (max residency
                    # for a breaker to half-open before the replay pick)
                    ready = not fl.replay_ticket or fl is head
                if ready:
                    self._finalize(fl)
                    events.append((fl.seq, fl.result))
                    progressed = True
            while self._inflight and self._inflight[0].finalized:
                self._commit(self._inflight.popleft())
                progressed = True
        return events

    @property
    def inflight(self) -> int:
        """Windows begun but not yet COMMITTED (the backpressure bound)."""
        return len(self._inflight)

    # -- fused path (seed semantics + padding-aware accounting) --------
    def _serve_fused(self, batch, real_rows):
        t0 = self._clock()
        out = jax.device_get(self._step(batch))
        b = out["prediction"].shape[0]
        real = b if real_rows is None else min(real_rows, b)
        escalated = out["escalated"]
        accepted = (~escalated) | (out["remote_conf"] > self.t_remote)
        n_remote = int(escalated[:real].sum())
        self._account(real, n_remote, n_remote, 0, 0,
                      int((~accepted[:real]).sum()))
        self.stats.record_wall(self._clock() - t0, real)
        if self.controller is not None:
            self.controller.observe(
                out["local_conf"][:real], n_remote, real,
                out["remote_conf"][:real],
                cost=n_remote * self.cost.remote_cost_per_request)
        out["accepted"] = accepted
        return out

    # -- runtime path: dispatch half (device) --------------------------
    def _dispatch(self, batch, real_rows, *, asynchronous: bool,
                  policies=None, t_enq=None) -> _InFlight:
        """Launch the local forward + confidence gate on the device and
        snapshot the submit-time control state. Returns WITHOUT fetching
        the gate output — the host half (``_host_begin``) runs one begin
        later, so the device computes the next batch meanwhile."""
        t0 = self._clock()
        b = _leading_rows(batch["local"])
        real = b if real_rows is None else min(real_rows, b)

        # --- escalation set: controller threshold, capped by capacity ---
        capacity = (self.controller.capacity(self.batch_size)
                    if self.controller is not None else self.capacity)
        # calibrated warm start: engine t_local applies until the
        # controller has produced its own (mirrors t_remote at complete)
        t_local = self.t_local
        if self.controller is not None and self.controller.t_local is not None:
            t_local = self.controller.t_local
        t = np.float32(np.inf) if t_local is None else np.float32(t_local)

        seq = self._seq + 1
        if self.early_emit:
            gate_dev = self._local_step(batch["local"], t, np.int32(real),
                                        np.int32(seq))
        else:
            gate_dev = self._local_step(batch["local"], t, np.int32(real))
        self._seq = seq
        fl = _InFlight(seq=self._seq, t0=t0, b=b, real=real,
                       asynchronous=asynchronous, capacity=capacity,
                       gate_dev=gate_dev, remote_batch=batch["remote"],
                       policies=policies, t_enq=t_enq,
                       policed=(_any_policy(policies)
                                or self.default_policy is not None))
        if self.observability is not None:
            # per-window stage timeline (DESIGN.md §9): one dict per
            # WINDOW, so disabled mode allocates nothing
            fl.tr = {"dispatch": t0,
                     "t_local": None if t_local is None else float(t_local)}
        return fl

    # -- runtime path: host half ---------------------------------------
    def _host_gate(self, fl: _InFlight) -> None:
        """The CHEAP half of the host work: land the gate triple on the
        host (early-emit reuse or device fetch), pin the escalation set
        and run the per-request policy pass. After this every locally-
        trusted row is fully decidable — the continuous scheduler calls
        it via ``flush_gate`` so those rows hand back BEFORE the
        escalations' cache/routing/transport submission (DESIGN.md
        §11)."""
        emitted = self.gate_result(fl.seq) if self.early_emit else None
        if emitted is not None:
            # the in-kernel emit already landed this window's triple on
            # the host — reuse it instead of a second device fetch
            conf, pred, cand = emitted
            fl.conf = np.asarray(conf)
            fl.local_pred = np.asarray(pred)
        else:
            gate = jax.device_get(fl.gate_dev)
            fl.conf = np.asarray(gate["conf"])
            fl.local_pred = np.asarray(gate["pred"])
            cand = gate["idx"]
        fl.gate_dev = None
        fl.pred = fl.local_pred.copy()
        cand = np.asarray(cand)
        cand = cand[cand >= 0]          # eligible rows, ascending by conf
        fl.k = int(min(cand.size, fl.capacity, fl.real))
        fl.idx = cand[:fl.k]
        if fl.tr is not None:
            fl.tr["gate"] = self._clock()

        if fl.policed:
            # per-request policy pass (DESIGN.md §8): escalation
            # overrides, cost-cap and deadline-vs-EMA feasibility — may
            # shrink/extend fl.idx and record downgrades/forced rejects
            self._apply_policies(fl)
        fl.gate_done = True

    def _host_begin(self, fl: _InFlight) -> None:
        """Run the host escalation path: the gate half (if it hasn't run
        yet), then batched gather, cache lookups, submit-time routing and
        the remote submission for the misses."""
        if not fl.gate_done:
            self._host_gate(fl)
        if fl.k > 0:
            host = jax.tree.map(np.asarray, fl.remote_batch)
            sub = jax.tree.map(lambda a: a[fl.idx], host)  # batched gather
            if self.cache is not None:
                fl.keys = self.cache.keys_for(sub, fl.k)
                # policy-REJECTED rows never consult cache or transport
                found = [None if j in fl.forced else self.cache.lookup(key)
                         for j, key in enumerate(fl.keys)]
                fl.cached = [f[0] if f is not None else None for f in found]
                fl.hit_src = [f[1] if f is not None else None for f in found]
            else:
                fl.keys = [None] * fl.k
                fl.cached = [None] * fl.k
                fl.hit_src = [None] * fl.k
            fl.miss = [j for j, c in enumerate(fl.cached)
                       if c is None and j not in fl.forced]
            if fl.miss:
                # route the window at submit time; an open breaker fails
                # over to the next policy candidate immediately. The
                # merged RouteConstraint (cost cap / remaining deadline /
                # hint) narrows the candidate set (DESIGN.md §8)
                fl.backend = self.router.pick(self._window_constraint(fl),
                                              window=fl.seq)
                marr = np.asarray(fl.miss)
                sub_miss = jax.tree.map(lambda a: a[marr], sub)
                if fl.backend is not None:
                    fl.pending = (fl.backend.submit(sub_miss, fl.seq)
                                  if fl.asynchronous
                                  else _Resolved(
                                      fl.backend.call(sub_miss, fl.seq)))
                    if fl.asynchronous:
                        # ready-set wakeup for the streaming drain
                        fl.pending.add_done_callback(
                            lambda _f: self._ready.set())
                elif (fl.asynchronous
                      and self.router.acquire_replay_slot(window=fl.seq)):
                    # every breaker refused: park the window with a
                    # bounded replay ticket — redeemed at its drain, when
                    # a breaker may have half-opened (DESIGN.md §7). The
                    # sync path finalizes immediately, so a ticket there
                    # could never be served — don't burn a slot on it
                    fl.replay_ticket = True
                    fl.sub_miss = sub_miss
            if (fl.asynchronous and self.early_handback
                    and self.controller is None):
                # cache hits are fully decidable now (static t_remote):
                # expose them so the streaming scheduler hands them back
                # with the trusted locals instead of after the window's
                # remote drain (DESIGN.md §8; the finalize half still
                # recomputes, keeping FIFO results untouched)
                self._early_decide(fl)
        if fl.tr is not None and fl.k > 0:
            fl.tr["route"] = self._clock()
        fl.remote_batch = None
        fl.host_done = True

    # -- per-request policy layer (DESIGN.md §8) -----------------------
    def _policy_for(self, fl: _InFlight, i: int) -> RequestPolicy | None:
        p = fl.policies[i] if fl.policies is not None else None
        return p if p is not None else self.default_policy

    def _apply_policies(self, fl: _InFlight) -> None:
        """Apply each genuine row's ``RequestPolicy`` to the gate's
        escalation set (host half, before any cache/transport work):

        * ``escalation="never"``    — row leaves the set (POLICY_LOCAL);
        * ``escalation="always"``   — row joins the set even when the
          gate trusted it (explicit per-request demand; bypasses the
          batch capacity cap, feasibility still applies);
        * ``cost_cap`` infeasible (cheapest available backend above the
          cap, or no backend) — POLICY_LOCAL downgrade, or the REJECTED
          path with ``on_miss="reject"``;
        * ``deadline_s`` infeasible — the remaining budget
          ``deadline_s - (now - t_enq)`` is checked against the fastest
          available backend's round-trip estimate (measured EMA,
          modelled prior until observations land): DEADLINE_LOCAL
          downgrade or REJECTED per ``on_miss``.

        Surviving constrained rows merge into one ``RouteConstraint``
        (tightest cap/deadline, first hint) since one window is served
        by exactly one backend."""
        now = self._clock()
        default_cost = self.cost.remote_cost_per_request
        # loop-invariant router scans, hoisted: one availability snapshot
        # per WINDOW (also more consistent than per-row reads racing
        # concurrent breaker flips)
        min_cost = self.router.min_available_cost(default_cost)
        lat_by_cap: dict[float | None, float | None] = {}

        def min_latency(cap):
            if cap not in lat_by_cap:
                lat_by_cap[cap] = self.router.min_latency_estimate(
                    max_cost=cap, default_cost=default_cost)
            return lat_by_cap[cap]

        gate_rows = {int(i) for i in fl.idx}
        drop: set[int] = set()          # downgraded rows (leave the set)
        forced: set[int] = set()        # policy-REJECTED rows (stay)
        adds: list[int] = []            # escalation="always" additions
        caps: list[float] = []
        abs_deadlines: list[float] = []  # anchor + deadline_s (absolute)
        hints: list[str] = []
        for i in range(fl.real):
            p = self._policy_for(fl, i)
            if p is None or p.is_default:
                continue
            in_gate = i in gate_rows
            if p.escalation == "never":
                if in_gate:
                    drop.add(i)
                    fl.downgraded[i] = POLICY_LOCAL
                continue
            if not in_gate and p.escalation != "always":
                continue
            # feasibility: cost cap first, then deadline-vs-EMA
            infeasible = None
            if p.cost_cap is not None:
                if min_cost is None or min_cost > p.cost_cap + 1e-12:
                    infeasible = POLICY_LOCAL
            if infeasible is None and p.deadline_s is not None:
                anchor = (fl.t_enq[i] if fl.t_enq is not None else fl.t0)
                remaining = p.deadline_s - (now - anchor)
                est = min_latency(p.cost_cap)
                if est is None or est > remaining:
                    infeasible = DEADLINE_LOCAL
                else:
                    abs_deadlines.append(anchor + p.deadline_s)
            if infeasible is not None:
                if p.on_miss == "reject":
                    forced.add(i)
                    if not in_gate:
                        adds.append(i)
                else:
                    if in_gate:
                        drop.add(i)
                    fl.downgraded[i] = infeasible
                continue
            if not in_gate:
                adds.append(i)
            if p.cost_cap is not None:
                caps.append(p.cost_cap)
            if p.routing_hint is not None:
                hints.append(p.routing_hint)
        new_idx = [i for i in map(int, fl.idx) if i not in drop]
        # appended demands keep the ascending-confidence convention
        new_idx.extend(sorted(adds, key=lambda i: float(fl.conf[i])))
        fl.idx = np.asarray(new_idx, np.int64)
        fl.k = len(new_idx)
        fl.forced = {j for j, i in enumerate(new_idx) if i in forced}
        fl.blocked = len(drop) + len(forced)
        fl.abs_deadline = min(abs_deadlines) if abs_deadlines else None
        if caps or abs_deadlines or hints:
            fl.constraint = RouteConstraint(
                max_cost=min(caps) if caps else None,
                hint=hints[0] if hints else None,
                default_cost=default_cost)

    def _window_constraint(self, fl: _InFlight) -> RouteConstraint | None:
        """The window's routing constraint AT THIS INSTANT: the latency
        ceiling is the tightest row's remaining deadline budget
        recomputed against the current clock, so a replay pick after
        pipeline residency sees the burnt-down budget (an expired one
        admits no backend and the window keeps the REJECTED path)."""
        if fl.constraint is None:
            return None
        if fl.abs_deadline is None:
            return fl.constraint
        return RouteConstraint(
            max_cost=fl.constraint.max_cost,
            max_latency_s=fl.abs_deadline - self._clock(),
            hint=fl.constraint.hint,
            default_cost=fl.constraint.default_cost)

    def _early_decide(self, fl: _InFlight) -> None:
        """Pre-decide rows that need no remote round trip — cache hits —
        with the CURRENT (static) ``t_remote``, so the streaming
        scheduler hands them back at gate-clear time instead of after
        the window's drain (the satellite latency fix; DESIGN.md §8).
        Only runs without a controller: a live controller couples
        acceptance to commit order."""
        hit = [j for j in range(fl.k)
               if j not in fl.forced and fl.cached[j] is not None]
        if not hit:
            return
        rlogits = jnp.asarray(np.stack([fl.cached[j] for j in hit]))
        rconf = np.asarray(self._supervisor(rlogits))
        rpred = np.asarray(jnp.argmax(rlogits, -1))
        for w, j in enumerate(hit):
            i = int(fl.idx[j])
            accepted = bool(rconf[w] > self.t_remote)
            fl.early.append({
                "row": i, "accepted": accepted,
                "prediction": int(rpred[w]),
                "remote_conf": float(rconf[w]),
                "disposition": CACHED if accepted else REJECTED,
                "backend": (fl.hit_src[j] if fl.hit_src[j] is not None
                            else UNATTRIBUTED),
                "cost": 0.0,
            })

    # -- runtime path: finalize half -----------------------------------
    def _finalize(self, fl: _InFlight) -> None:
        """Fold the window's remote responses in and decide acceptance
        with the CURRENT t_remote. Blocks on the window's future (forcing
        a parked host half first). Idempotent; does NOT touch stats — the
        commit half does, strictly in submission order."""
        if fl.finalized:
            return
        if not fl.host_done:
            self._host_begin(fl)
        remote_conf = np.full((fl.b,), np.inf, np.float32)
        n_hits = n_sent = n_failed = 0
        if fl.k > 0:
            cached = fl.cached
            if fl.miss:
                if fl.pending is None and fl.replay_ticket:
                    # (unrouted) replay (DESIGN.md §7): one more pick at
                    # drain time — a breaker that half-opened while the
                    # window rode the pipeline serves it (the call IS the
                    # half-open probe), billed to the replaying backend
                    fl.replay_ticket = False
                    fl.backend = self.router.redeem_replay(
                        self._window_constraint(fl), window=fl.seq)
                    if fl.backend is not None:
                        fl.pending = _Resolved(
                            fl.backend.call(fl.sub_miss, fl.seq))
                    fl.sub_miss = None
                if fl.pending is not None:
                    logits, ok = fl.pending.result()
                    n_sent = int(ok.sum())
                    n_failed = len(fl.miss) - n_sent
                    bname = fl.backend.name
                    # a chained CascadeStage hands back which hop answered
                    # each row, at what confidence and price (DESIGN.md
                    # §13); plain backends and terminal stages return
                    # None, keeping the existing path byte-for-byte
                    take = getattr(fl.backend, "take_detail", None)
                    fl.stage_detail = (take(fl.seq) if take is not None
                                       else None)
                    det = fl.stage_detail
                    for w, j in enumerate(fl.miss):
                        if ok[w]:
                            cached[j] = logits[w]
                            if self.cache is not None:
                                src = (str(det["stage"][w])
                                       if det is not None else bname)
                                self.cache.put(fl.keys[j], logits[w],
                                               source=src)
                else:                 # no backend available at submit time
                    n_failed = len(fl.miss)
            n_hits = fl.k - len(fl.miss) - len(fl.forced)
            got = [j for j, c in enumerate(cached) if c is not None]
            if got:
                rlogits = jnp.asarray(np.stack([cached[j] for j in got]))
                rconf = np.asarray(self._supervisor(rlogits))
                rpred = np.asarray(jnp.argmax(rlogits, -1))
                remote_conf[fl.idx[got]] = rconf
                fl.pred[fl.idx[got]] = rpred
            failed = [j for j, c in enumerate(cached) if c is None]
            # transport-lost escalations: 2nd supervisor can never trust
            # them -> REJECTED -> scheduler fallback (Algorithm 1 line 12)
            remote_conf[fl.idx[failed]] = -np.inf
            if fl.stage_detail is not None:
                # fresh rows answered mid-chain carry the answering
                # stage's OWN supervisor score — that is the confidence
                # the accept gate below must judge, not the engine
                # supervisor re-scored on the spliced logits
                sdet = fl.stage_detail
                for w, j in enumerate(fl.miss):
                    if cached[j] is not None:
                        remote_conf[fl.idx[j]] = sdet["conf"][w]

        escalated = np.zeros((fl.b,), bool)
        escalated[fl.idx] = True
        t_remote = self.t_remote
        if self.controller is not None and self.controller.t_remote is not None:
            t_remote = self.controller.t_remote
        accepted = (~escalated) | (remote_conf > t_remote)
        if fl.tr is not None:
            if fl.k > 0:
                fl.tr["remote"] = self._clock()
            fl.tr["t_remote"] = float(t_remote)

        fl.remote_conf = remote_conf
        fl.n_sent, fl.n_failed, fl.n_hits = n_sent, n_failed, n_hits
        fl.bname = fl.backend.name if fl.backend is not None else UNROUTED

        # per-row billing attribution for the API boundary (DESIGN.md §8):
        # how each row was served, by which backend, at what billed $
        disposition = np.full((fl.b,), LOCAL, object)
        row_backend = np.full((fl.b,), None, object)
        row_cost = np.zeros((fl.b,), np.float64)
        for i, d in fl.downgraded.items():
            disposition[i] = d
        cost_per = self.cost.backend_cost(fl.backend)
        miss_set = set(fl.miss)
        # with stage detail, rows attribute to the hop that actually
        # answered (or lost) them, at that hop's price (DESIGN.md §13)
        w_of = ({j: w for w, j in enumerate(fl.miss)}
                if fl.stage_detail is not None else None)
        for j, i in enumerate(map(int, fl.idx)):
            if j in fl.forced:
                disposition[i] = REJECTED       # policy-rejected, $0
            elif j in miss_set:
                if fl.cached[j] is not None:    # billed remote answer
                    disposition[i] = (REMOTE if accepted[i] else REJECTED)
                    if w_of is None:
                        row_backend[i] = fl.bname
                        row_cost[i] = cost_per
                    else:
                        w = w_of[j]
                        sc = fl.stage_detail["cost"][w]
                        row_backend[i] = str(fl.stage_detail["stage"][w])
                        row_cost[i] = (self.cost.remote_cost_per_request
                                       if np.isnan(sc) else float(sc))
                else:                           # transport-lost, $0
                    disposition[i] = REJECTED
                    if w_of is not None:
                        row_backend[i] = str(
                            fl.stage_detail["stage"][w_of[j]])
                    elif fl.backend is not None:
                        row_backend[i] = fl.bname
            else:                               # cache hit, $0
                disposition[i] = (CACHED if accepted[i] else REJECTED)
                row_backend[i] = (fl.hit_src[j]
                                  if fl.hit_src[j] is not None
                                  else UNATTRIBUTED)

        fl.result = {"prediction": fl.pred, "local_pred": fl.local_pred,
                     "local_conf": fl.conf, "remote_conf": remote_conf,
                     "escalated": escalated, "accepted": accepted,
                     "disposition": disposition, "backend": row_backend,
                     "cost": row_cost}
        if fl.tr is not None:
            # window trace handed to the scheduler, which turns it into
            # one span per request at hand-back (DESIGN.md §9). Row sets
            # tell the span builder which stage a row went through:
            # remote_rows attempted a billed remote call, hit_rows were
            # served from cache.
            fl.result["trace"] = {
                "window": fl.seq,
                "stages": fl.tr,
                "remote_rows": {int(fl.idx[j]) for j in miss_set},
                "hit_rows": {int(fl.idx[j]) for j in range(fl.k)
                             if j not in miss_set and j not in fl.forced},
            }
        fl.finalized = True

    # -- runtime path: commit half -------------------------------------
    def _commit(self, fl: _InFlight) -> dict[str, np.ndarray]:
        """Fold the finalized window into stats / per-backend billing /
        controller state. Callers MUST commit in submission order — that
        is what keeps streaming accounting bitwise-identical to FIFO."""
        # per-backend billing/latency attribution (DESIGN.md §6): billed
        # calls and failures charge the routed backend; cache hits charge
        # $0 to whichever backend originally filled the entry
        cost_per = self.cost.backend_cost(fl.backend)
        lat_per = self.cost.backend_latency(fl.backend)
        if fl.stage_detail is not None and fl.miss:
            # per-stage billing split (DESIGN.md §13): each fresh row
            # charges the hop that answered it at that hop's price; lost
            # rows charge their failure to the hop whose transport dropped
            # them. The lump-sum path below stays byte-for-byte for plain
            # backends and terminal (degenerate 2-tier) stages.
            sdet = fl.stage_detail
            split: dict[str, list] = {}
            for w, j in enumerate(fl.miss):
                row = split.setdefault(str(sdet["stage"][w]),
                                       [0, 0, 0.0, 0.0])
                if fl.cached[j] is not None:
                    sc, sl = sdet["cost"][w], sdet["latency"][w]
                    row[0] += 1
                    row[2] += (self.cost.remote_cost_per_request
                               if np.isnan(sc) else float(sc))
                    row[3] += (self.cost.remote_latency_s
                               if np.isnan(sl) else float(sl))
                else:
                    row[1] += 1
            fl.stage_split = split
            window_cost = 0.0
            window_lat = 0.0
            for name in sorted(split):
                calls, fails, c, lt = split[name]
                u = self.stats.backend_usage(name)
                u.remote_calls += calls
                u.transport_failures += fails
                u.cost += c
                u.remote_latency_s += lt
                window_cost += c
                window_lat += lt
        else:
            window_cost = fl.n_sent * cost_per
            window_lat = fl.n_sent * lat_per
            if fl.n_sent or fl.n_failed:
                u = self.stats.backend_usage(fl.bname)
                u.remote_calls += fl.n_sent
                u.transport_failures += fl.n_failed
                u.cost += window_cost
                u.remote_latency_s += window_lat
        if fl.n_hits and fl.hit_src is not None:
            miss_set = set(fl.miss)
            for j in range(fl.k):
                # policy-forced REJECTED rows are neither misses nor hits
                if j not in miss_set and j not in fl.forced:
                    src = fl.hit_src[j]
                    self.stats.backend_usage(
                        src if src is not None else UNATTRIBUTED
                    ).cache_hits += 1

        # per-backend agreement-with-local EMA (DESIGN.md §13): on served
        # escalated rows, how often the answering backend's argmax agreed
        # with the local model's — a label-free cross-tier accuracy proxy
        if fl.k > 0:
            rb = fl.result["backend"]
            groups: dict[str, list] = {}
            for j, i in enumerate(map(int, fl.idx)):
                if (j not in fl.forced and i < fl.real
                        and np.isfinite(fl.remote_conf[i])
                        and rb[i] is not None):
                    groups.setdefault(str(rb[i]), []).append(
                        int(fl.pred[i] == fl.local_pred[i]))
            if groups:
                fl.agreement = []
                for name in sorted(groups):
                    rows = groups[name]
                    frac = float(np.mean(rows))
                    u = self.stats.backend_usage(name)
                    u.agreement_rows += len(rows)
                    u.agreement_ema = (
                        frac if u.agreement_ema is None
                        else (1.0 - AGREEMENT_ALPHA) * u.agreement_ema
                        + AGREEMENT_ALPHA * frac)
                    fl.agreement.append((name, len(rows), frac,
                                         u.agreement_ema))

        accepted = fl.result["accepted"]
        # policy-rejected rows never touched a tier past the local model:
        # they are `rejected`, not `escalations` (the billing invariant
        # escalations = remote_calls + cache_hits + transport_failures
        # stays exact — DESIGN.md §8)
        escalations = fl.k - len(fl.forced)
        rejected = int((~accepted[:fl.real]).sum())
        self._account(fl.real, escalations, fl.n_sent, fl.n_hits,
                      fl.n_failed, rejected,
                      cost=window_cost,
                      remote_latency_s=window_lat)
        wall_s = self._clock() - fl.t0
        self.stats.record_wall(wall_s, fl.real)
        if fl.tr is not None:
            fl.tr["commit"] = self._clock()
        if self.observability is not None:
            self._publish_commit(fl, window_cost, escalations, rejected,
                                 wall_s)
            if self.controller is not None:
                self.controller.event_window = fl.seq
        if self.controller is not None:
            self.controller.observe(fl.conf[:fl.real], escalations, fl.real,
                                    fl.remote_conf[:fl.real],
                                    cost=window_cost,
                                    policy_blocked=fl.blocked)
        if self.early_emit:
            # sweep the early-emit triple (the host half may have left it
            # behind when it raced the device fetch)
            with self._gate_lock:
                self._gate_results.pop(fl.seq, None)
        return fl.result

    def _publish_commit(self, fl: _InFlight, window_cost: float,
                        escalations: int, rejected: int,
                        wall_s: float) -> None:
        """Commit-half metrics/events (observability enabled only).
        Counters update strictly in commit (= submission) order with the
        SAME per-window increments as ``_account``, so the running
        ``cascade_cost_dollars_total`` float is bitwise-identical to
        ``CascadeStats.total_cost`` at every commit boundary."""
        m = self.observability.metrics
        m.counter("cascade_windows_total").inc()
        m.counter("cascade_requests_total").inc(fl.real)
        m.counter("cascade_escalations_total").inc(escalations)
        m.counter("cascade_remote_calls_total").inc(fl.n_sent)
        m.counter("cascade_cache_hits_total").inc(fl.n_hits)
        m.counter("cascade_transport_failures_total").inc(fl.n_failed)
        m.counter("cascade_rejected_total").inc(rejected)
        m.counter("cascade_cost_dollars_total").inc(window_cost)
        names, counts = np.unique(
            fl.result["disposition"][:fl.real].astype(str),
            return_counts=True)
        for d, c in zip(names, counts):
            m.counter("cascade_disposition_total",
                      disposition=str(d)).inc(int(c))
        m.histogram("cascade_window_wall_seconds").observe(wall_s)
        if fl.stage_split is not None:
            for name in sorted(fl.stage_split):
                calls, fails, _c, _lt = fl.stage_split[name]
                if calls:
                    m.counter("cascade_stage_answered_total",
                              stage=name).inc(calls)
                if fails:
                    m.counter("cascade_stage_failures_total",
                              stage=name).inc(fails)
        ev = self.observability.events
        if ev is not None and fl.downgraded:
            for i, d in sorted(fl.downgraded.items()):
                ev.emit(EV_DEADLINE_DOWNGRADE if d == DEADLINE_LOCAL
                        else EV_POLICY_DOWNGRADE,
                        window=fl.seq, row=int(i), disposition=d)
        if ev is not None and fl.stage_split is not None:
            for name in sorted(fl.stage_split):
                calls, fails, c, _lt = fl.stage_split[name]
                ev.emit(EV_STAGE_ANSWER, window=fl.seq, stage=name,
                        answered=calls, failures=fails, cost=c)
        if ev is not None and fl.agreement is not None:
            for name, rows, frac, ema in fl.agreement:
                ev.emit(EV_BACKEND_AGREEMENT, window=fl.seq,
                        backend=name, rows=rows,
                        window_fraction=frac, ema=ema)

    # ------------------------------------------------------------------
    def close(self, wait: bool = True) -> None:
        """Drain any in-flight pipelined/streaming windows (their results
        are accounted but discarded) and shut down every backend's thread
        pool. Half-finalized streaming runs drain too: already-finalized
        windows just commit, the rest finalize first. Idempotent; a no-op
        on the fused path."""
        while self._inflight:
            self.complete_next()
        if self.router is not None:
            self.router.shutdown(wait=wait)

    def __enter__(self) -> "CascadeEngine":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    # ------------------------------------------------------------------
    def _account(self, real, escalations, remote_calls, cache_hits,
                 transport_failures, rejected, *, cost=None,
                 remote_latency_s=None):
        """Fold one window into the aggregate stats. ``cost`` and
        ``remote_latency_s`` carry per-backend pricing from the runtime
        path; when omitted (fused path) the CostModel defaults apply."""
        if cost is None:
            cost = remote_calls * self.cost.remote_cost_per_request
        if remote_latency_s is None:
            remote_latency_s = remote_calls * self.cost.remote_latency_s
        st = self.stats
        st.requests += real
        st.escalations += escalations
        st.remote_calls += remote_calls
        st.cache_hits += cache_hits
        st.transport_failures += transport_failures
        st.rejected += rejected
        st.total_cost += cost
        st.total_latency_s += (real * self.cost.local_latency_s
                               + remote_latency_s
                               + cache_hits * self.cost.cache_hit_latency_s)

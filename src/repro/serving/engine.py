"""Cascade serving engine — BiSupervised as a two-tier production runtime.

The engine composes:
  * a LOCAL tier: cheap classifier (surrogate) evaluated for every request,
  * a 1st-level supervisor on the local logits,
  * capacity-based escalation (core.cascade) to a REMOTE tier — a sharded
    in-framework model (or any callable),
  * a 2nd-level supervisor on the remote metadata,
  * per-request cost/latency accounting mirroring the paper's billing
    model (Table 7 / §5.6).

The jitted fast path is `make_cascade_step`; the Python-level
`CascadeEngine` adds queueing, runtime-tunable thresholds and accounting.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cascade import (combine_escalated, escalation_capacity,
                                gather_requests, select_escalations)
from repro.core.supervisors import SOFTMAX_SUPERVISORS


@dataclass(frozen=True)
class CostModel:
    """Latency/cost constants (paper Table 7 / GPT-3 style billing)."""
    local_latency_s: float = 0.05
    remote_latency_s: float = 0.32       # incl. network round trip
    remote_cost_per_request: float = 0.0048


@dataclass
class CascadeStats:
    requests: int = 0
    remote_calls: int = 0
    rejected: int = 0
    total_cost: float = 0.0
    total_latency_s: float = 0.0

    @property
    def remote_fraction(self) -> float:
        return self.remote_calls / max(self.requests, 1)

    @property
    def mean_latency_s(self) -> float:
        return self.total_latency_s / max(self.requests, 1)


def make_cascade_step(local_apply: Callable, remote_apply: Callable,
                      capacity: int, supervisor: str = "max_softmax"):
    """Build the jit-able fused cascade step.

    local_apply(local_batch) -> logits [B, C]
    remote_apply(remote_batch_gathered) -> logits [k, C]
    Requests carry BOTH input views (paper §4.1 input-domain reduction):
    batch = {"local": <reduced inputs>, "remote": <full inputs>}.

    `supervisor` is a SOFTMAX_SUPERVISORS name, or any callable
    logits -> confidence (e.g. a bound MDSA on hidden states — the paper's
    recommendation for non-softmax local models, §4.2).

    Returns step(batch) -> dict(pred, local_conf, remote_conf, escalated).
    """
    sup = (supervisor if callable(supervisor)
           else SOFTMAX_SUPERVISORS[supervisor])

    def step(batch):
        local_logits = local_apply(batch["local"])
        local_conf = sup(local_logits)
        local_pred = jnp.argmax(local_logits, -1)

        idx, esc_mask = select_escalations(local_conf, capacity)
        remote_in = gather_requests(batch["remote"], idx)
        remote_logits = remote_apply(remote_in)
        remote_pred = jnp.argmax(remote_logits, -1)
        remote_conf_sub = sup(remote_logits)

        pred = combine_escalated(local_pred, idx, remote_pred)
        # non-escalated requests never consult the 2nd supervisor; fill +inf
        remote_conf = jnp.full_like(local_conf, jnp.inf).at[idx].set(
            remote_conf_sub)
        return {"prediction": pred, "local_conf": local_conf,
                "remote_conf": remote_conf, "escalated": esc_mask,
                "local_pred": local_pred}

    return step


class CascadeEngine:
    """Host-side engine: batching, runtime thresholds, accounting."""

    def __init__(self, local_apply, remote_apply, *, batch_size: int,
                 remote_fraction_budget: float,
                 t_remote: float, cost: CostModel = CostModel(),
                 supervisor="max_softmax"):
        self.batch_size = batch_size
        self.capacity = escalation_capacity(batch_size,
                                            remote_fraction_budget)
        self.t_remote = t_remote            # runtime-tunable (paper §4.5)
        self.cost = cost
        self.stats = CascadeStats()
        self._step = jax.jit(make_cascade_step(
            local_apply, remote_apply, self.capacity, supervisor))

    def set_remote_threshold(self, t: float) -> None:
        """Runtime reconfiguration (paper §4.5)."""
        self.t_remote = t

    def serve(self, batch: dict[str, Any]) -> dict[str, np.ndarray]:
        out = jax.device_get(self._step(batch))
        b = out["prediction"].shape[0]
        escalated = out["escalated"]
        accepted = (~escalated) | (out["remote_conf"] > self.t_remote)
        n_remote = int(escalated.sum())
        self.stats.requests += b
        self.stats.remote_calls += n_remote
        self.stats.rejected += int((~accepted).sum())
        self.stats.total_cost += n_remote * self.cost.remote_cost_per_request
        self.stats.total_latency_s += (
            b * self.cost.local_latency_s
            + n_remote * self.cost.remote_latency_s)
        out["accepted"] = accepted
        return out

"""Cascade serving engine — BiSupervised as a two-tier production runtime.

The engine composes:
  * a LOCAL tier: cheap classifier (surrogate) evaluated for every request,
  * a 1st-level supervisor on the local logits,
  * escalation to a REMOTE tier — either a fused in-jit callable (offline /
    trusted deployments) or a fault-aware ``repro.runtime`` transport with
    caching and an online budget controller (DESIGN.md §2-§4),
  * a 2nd-level supervisor on the remote metadata,
  * per-request cost/latency accounting mirroring the paper's billing
    model (Table 7 / §5.6) — padded scheduler rows are never billed.

Two serve paths (DESIGN.md §2):
  * fused   — ``make_cascade_step``: local + remote in one jitted step with
    a static escalation capacity k (the seed behaviour; remote tier is an
    infallible callable).
  * runtime — local tier jitted, escalated sub-batch routed host-side
    through ``RemoteResponseCache`` -> ``RemoteTransport``; failed windows
    degrade to the REJECTED/fallback path; an ``AdaptiveController``
    retunes ``t_local``/``t_remote``/capacity per control window.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cascade import (combine_escalated, escalation_capacity,
                                gather_requests, select_escalations)
from repro.core.supervisors import SOFTMAX_SUPERVISORS


@dataclass(frozen=True)
class CostModel:
    """Latency/cost constants (paper Table 7 / GPT-3 style billing).

    Cache hits are re-served, not re-billed: they cost ``cache_hit_
    latency_s`` and $0 (DESIGN.md §4)."""
    local_latency_s: float = 0.05
    remote_latency_s: float = 0.32       # incl. network round trip
    remote_cost_per_request: float = 0.0048
    cache_hit_latency_s: float = 0.001


@dataclass
class CascadeStats:
    requests: int = 0                # genuine (non-padding) requests
    escalations: int = 0             # requests routed past the local tier
    remote_calls: int = 0            # billed remote invocations
    cache_hits: int = 0              # escalations served from cache ($0)
    transport_failures: int = 0      # escalations lost to transport faults
    rejected: int = 0
    total_cost: float = 0.0
    total_latency_s: float = 0.0

    @property
    def remote_fraction(self) -> float:
        return self.remote_calls / max(self.requests, 1)

    @property
    def escalation_fraction(self) -> float:
        return self.escalations / max(self.requests, 1)

    @property
    def mean_latency_s(self) -> float:
        return self.total_latency_s / max(self.requests, 1)


def make_cascade_step(local_apply: Callable, remote_apply: Callable,
                      capacity: int, supervisor: str = "max_softmax"):
    """Build the jit-able fused cascade step.

    local_apply(local_batch) -> logits [B, C]
    remote_apply(remote_batch_gathered) -> logits [k, C]
    Requests carry BOTH input views (paper §4.1 input-domain reduction):
    batch = {"local": <reduced inputs>, "remote": <full inputs>}.

    `supervisor` is a SOFTMAX_SUPERVISORS name, or any callable
    logits -> confidence (e.g. a bound MDSA on hidden states — the paper's
    recommendation for non-softmax local models, §4.2).

    Returns step(batch) -> dict(pred, local_conf, remote_conf, escalated).
    """
    sup = (supervisor if callable(supervisor)
           else SOFTMAX_SUPERVISORS[supervisor])

    def step(batch):
        local_logits = local_apply(batch["local"])
        local_conf = sup(local_logits)
        local_pred = jnp.argmax(local_logits, -1)

        idx, esc_mask = select_escalations(local_conf, capacity)
        remote_in = gather_requests(batch["remote"], idx)
        remote_logits = remote_apply(remote_in)
        remote_pred = jnp.argmax(remote_logits, -1)
        remote_conf_sub = sup(remote_logits)

        pred = combine_escalated(local_pred, idx, remote_pred)
        # non-escalated requests never consult the 2nd supervisor; fill +inf
        remote_conf = jnp.full_like(local_conf, jnp.inf).at[idx].set(
            remote_conf_sub)
        return {"prediction": pred, "local_conf": local_conf,
                "remote_conf": remote_conf, "escalated": esc_mask,
                "local_pred": local_pred}

    return step


def make_local_step(local_apply: Callable, supervisor="max_softmax"):
    """Jit-able local-tier-only step for the runtime serve path."""
    sup = (supervisor if callable(supervisor)
           else SOFTMAX_SUPERVISORS[supervisor])

    def step(local_batch):
        logits = local_apply(local_batch)
        return {"local_conf": sup(logits),
                "local_pred": jnp.argmax(logits, -1),
                "local_logits": logits}

    return step


class CascadeEngine:
    """Host-side engine: batching, runtime thresholds, accounting.

    Legacy fused construction (remote tier = bare infallible callable,
    static capacity)::

        CascadeEngine(local_apply, remote_apply, batch_size=32,
                      remote_fraction_budget=0.25, t_remote=0.9)

    Runtime construction (fault-aware transport, optional controller and
    response cache — DESIGN.md §2)::

        CascadeEngine(local_apply, batch_size=32,
                      remote_fraction_budget=0.25, t_remote=0.9,
                      transport=RemoteTransport(remote_apply),
                      controller=AdaptiveController(),
                      cache=RemoteResponseCache())
    """

    def __init__(self, local_apply, remote_apply=None, *, batch_size: int,
                 remote_fraction_budget: float,
                 t_remote: float, cost: CostModel = CostModel(),
                 supervisor="max_softmax", transport=None, controller=None,
                 cache=None):
        if remote_apply is None and transport is None:
            raise ValueError("need a remote tier: remote_apply or transport")
        self.batch_size = batch_size
        self.capacity = escalation_capacity(batch_size,
                                            remote_fraction_budget)
        self.t_remote = t_remote            # runtime-tunable (paper §4.5)
        self.t_local: float | None = None   # runtime-tunable escalation gate
        self.cost = cost
        self.stats = CascadeStats()
        self.transport = transport
        self.controller = controller
        self.cache = cache
        if transport is None:
            self._step = jax.jit(make_cascade_step(
                local_apply, remote_apply, self.capacity, supervisor))
            self._supervisor = (supervisor if callable(supervisor)
                                else SOFTMAX_SUPERVISORS[supervisor])
        else:
            self._local_step = jax.jit(make_local_step(local_apply,
                                                       supervisor))
            self._supervisor = (supervisor if callable(supervisor)
                                else SOFTMAX_SUPERVISORS[supervisor])

    def set_remote_threshold(self, t: float) -> None:
        """Runtime reconfiguration (paper §4.5)."""
        self.t_remote = t

    def set_local_threshold(self, t: float | None) -> None:
        """Runtime escalation gate (runtime path; None = capacity-k)."""
        self.t_local = t

    # ------------------------------------------------------------------
    def serve(self, batch: dict[str, Any],
              real_rows: int | None = None) -> dict[str, np.ndarray]:
        """Serve one batch; ``real_rows`` marks how many leading rows are
        genuine — padded replicas beyond it are served (static jit shapes)
        but never counted or billed."""
        if self.transport is None:
            return self._serve_fused(batch, real_rows)
        return self._serve_runtime(batch, real_rows)

    # -- fused path (seed semantics + padding-aware accounting) --------
    def _serve_fused(self, batch, real_rows):
        out = jax.device_get(self._step(batch))
        b = out["prediction"].shape[0]
        real = b if real_rows is None else min(real_rows, b)
        escalated = out["escalated"]
        accepted = (~escalated) | (out["remote_conf"] > self.t_remote)
        n_remote = int(escalated[:real].sum())
        self._account(real, n_remote, n_remote, 0, 0,
                      int((~accepted[:real]).sum()))
        if self.controller is not None:
            self.controller.observe(out["local_conf"][:real], n_remote,
                                    real, out["remote_conf"][:real])
        out["accepted"] = accepted
        return out

    # -- runtime path (transport + cache + controller) -----------------
    def _serve_runtime(self, batch, real_rows):
        local = jax.device_get(self._local_step(batch["local"]))
        conf = np.asarray(local["local_conf"])
        pred = np.asarray(local["local_pred"]).copy()
        b = conf.shape[0]
        real = b if real_rows is None else min(real_rows, b)

        # --- escalation set: controller threshold, capped by capacity ---
        capacity = (self.controller.capacity(self.batch_size)
                    if self.controller is not None else self.capacity)
        # calibrated warm start: engine t_local applies until the
        # controller has produced its own (mirrors t_remote below)
        t_local = self.t_local
        if self.controller is not None and self.controller.t_local is not None:
            t_local = self.controller.t_local
        order = np.argsort(conf[:real], kind="stable")
        if t_local is None:
            k = min(capacity, real)
        else:
            k = min(int((conf[:real] < t_local).sum()), capacity, real)
        idx = order[:k]                      # k lowest-confidence real rows

        remote_conf = np.full((b,), np.inf, np.float32)
        n_hits = n_sent = n_failed = 0
        if k > 0:
            host = jax.tree.map(np.asarray, batch["remote"])
            rows = [jax.tree.map(lambda a: a[i], host) for i in idx]
            keys = ([self.cache.key_fn(r) for r in rows]
                    if self.cache is not None else [None] * k)
            cached = [None if key is None else self.cache.get(key)
                      for key in keys]
            miss = [j for j, c in enumerate(cached) if c is None]
            if miss:
                sub = jax.tree.map(
                    lambda *leaves: np.stack(leaves), *[rows[j] for j in miss])
                logits, ok = self.transport.call(sub)
                n_sent = int(ok.sum())
                n_failed = len(miss) - n_sent
                for w, j in enumerate(miss):
                    if ok[w]:
                        cached[j] = logits[w]
                        if self.cache is not None:
                            self.cache.put(keys[j], logits[w])
            n_hits = k - len(miss)
            got = [j for j, c in enumerate(cached) if c is not None]
            if got:
                rlogits = jnp.asarray(np.stack([cached[j] for j in got]))
                rconf = np.asarray(self._supervisor(rlogits))
                rpred = np.asarray(jnp.argmax(rlogits, -1))
                remote_conf[idx[got]] = rconf
                pred[idx[got]] = rpred
            failed = [j for j, c in enumerate(cached) if c is None]
            # transport-lost escalations: 2nd supervisor can never trust
            # them -> REJECTED -> scheduler fallback (Algorithm 1 line 12)
            remote_conf[idx[failed]] = -np.inf

        escalated = np.zeros((b,), bool)
        escalated[idx] = True
        t_remote = self.t_remote
        if self.controller is not None and self.controller.t_remote is not None:
            t_remote = self.controller.t_remote
        accepted = (~escalated) | (remote_conf > t_remote)

        self._account(real, k, n_sent, n_hits, n_failed,
                      int((~accepted[:real]).sum()))
        if self.controller is not None:
            self.controller.observe(conf[:real], k, real, remote_conf[:real])
        return {"prediction": pred, "local_pred": local["local_pred"],
                "local_conf": conf, "remote_conf": remote_conf,
                "escalated": escalated, "accepted": accepted}

    # ------------------------------------------------------------------
    def _account(self, real, escalations, remote_calls, cache_hits,
                 transport_failures, rejected):
        st = self.stats
        st.requests += real
        st.escalations += escalations
        st.remote_calls += remote_calls
        st.cache_hits += cache_hits
        st.transport_failures += transport_failures
        st.rejected += rejected
        st.total_cost += remote_calls * self.cost.remote_cost_per_request
        st.total_latency_s += (real * self.cost.local_latency_s
                               + remote_calls * self.cost.remote_latency_s
                               + cache_hits * self.cost.cache_hit_latency_s)

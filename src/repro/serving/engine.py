"""Cascade serving engine — BiSupervised as a two-tier production runtime.

The engine composes:
  * a LOCAL tier: cheap classifier (surrogate) evaluated for every request,
  * a 1st-level supervisor on the local logits,
  * escalation to a REMOTE tier — either a fused in-jit callable (offline /
    trusted deployments) or a fault-aware ``repro.runtime`` transport /
    multi-backend router with caching and an online budget controller
    (DESIGN.md §2-§4, §6),
  * a 2nd-level supervisor on the remote metadata,
  * per-request cost/latency accounting mirroring the paper's billing
    model (Table 7 / §5.6) — padded scheduler rows are never billed.

Three serve paths (DESIGN.md §2, §5):
  * fused     — ``make_cascade_step``: local + remote in one jitted step
    with a static escalation capacity k (the seed behaviour; remote tier
    is an infallible callable).
  * runtime   — local tier jitted behind the fused ``confidence_gate``
    kernel (only the compact (conf, pred, idx) triple crosses the host
    boundary), escalated sub-batch routed host-side through
    ``RemoteResponseCache`` -> ``RemoteTransport``; failed windows degrade
    to the REJECTED/fallback path; an ``AdaptiveController`` retunes
    ``t_local``/``t_remote``/capacity per control window.
  * pipelined — the runtime path split at the transport boundary:
    ``begin_serve`` dispatches local compute + non-blocking remote
    submission, ``complete_next`` drains in-flight windows strictly in
    submission order, so batch i+1's local tier overlaps batch i's remote
    round trip while accounting and controller observations stay
    deterministic.

Multi-remote routing (DESIGN.md §6): the runtime/pipelined paths accept a
``RemoteRouter`` of named ``RemoteBackend``s in place of a bare transport
(a bare ``RemoteTransport`` is auto-wrapped as a single-backend registry,
preserving the PR-2 behaviour bit for bit). Each escalation window is
routed to one backend picked at submit time — an open breaker fails over
within the same window — and billing/latency attribute per backend in
``CascadeStats.per_backend`` using the backend's own price and modelled
latency (falling back to the ``CostModel`` constants).
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cascade import (combine_escalated, escalation_capacity,
                                gather_requests, select_escalations)
from repro.core.supervisors import SOFTMAX_SUPERVISORS
from repro.kernels.confidence_gate.ops import confidence_gate
from repro.runtime.transport import RemoteBackend, RemoteRouter

# per-backend accounting key for escalations no backend would accept
# (every breaker open): they fail without touching any transport
UNROUTED = "(unrouted)"
# attribution for cache entries stored without a source backend
UNATTRIBUTED = "(cache)"


@dataclass(frozen=True)
class CostModel:
    """Latency/cost constants (paper Table 7 / GPT-3 style billing).

    Cache hits are re-served, not re-billed: they cost ``cache_hit_
    latency_s`` and $0 (DESIGN.md §4). With a multi-remote registry the
    remote constants are *defaults*: a ``RemoteBackend`` carrying its own
    ``cost_per_request`` / ``latency_s`` overrides them per window
    (DESIGN.md §6)."""
    local_latency_s: float = 0.05
    remote_latency_s: float = 0.32       # incl. network round trip
    remote_cost_per_request: float = 0.0048
    cache_hit_latency_s: float = 0.001

    def backend_cost(self, backend) -> float:
        """Per-call price for a backend (None backend/price -> default)."""
        if backend is not None and backend.cost_per_request is not None:
            return backend.cost_per_request
        return self.remote_cost_per_request

    def backend_latency(self, backend) -> float:
        """Modelled round trip for a backend (None -> default)."""
        if backend is not None and backend.latency_s is not None:
            return backend.latency_s
        return self.remote_latency_s


@dataclass
class BackendUsage:
    """Per-backend slice of the cascade accounting (DESIGN.md §6). The
    invariant ``escalations = remote_calls + cache_hits +
    transport_failures`` holds summed over all per-backend entries
    (including the ``UNROUTED`` pseudo-backend)."""
    remote_calls: int = 0            # billed invocations of this backend
    cache_hits: int = 0              # hits on entries this backend filled
    transport_failures: int = 0      # escalations this backend lost
    cost: float = 0.0                # realised $ billed to this backend
    remote_latency_s: float = 0.0    # modelled remote seconds accrued


@dataclass
class CascadeStats:
    requests: int = 0                # genuine (non-padding) requests
    escalations: int = 0             # requests routed past the local tier
    remote_calls: int = 0            # billed remote invocations
    cache_hits: int = 0              # escalations served from cache ($0)
    transport_failures: int = 0      # escalations lost to transport faults
    rejected: int = 0
    total_cost: float = 0.0
    total_latency_s: float = 0.0     # modelled (CostModel constants)
    wall_latency_s: float = 0.0      # measured request-seconds (timers)
    # per-backend billing/latency attribution (runtime path; DESIGN.md §6)
    per_backend: dict = field(default_factory=dict)
    # ring buffer of recent per-window wall times: percentiles stay
    # representative of CURRENT behaviour on long-running servers
    wall_samples: deque = field(
        default_factory=lambda: deque(maxlen=65536), repr=False)

    def backend_usage(self, name: str) -> BackendUsage:
        return self.per_backend.setdefault(name, BackendUsage())

    @property
    def remote_fraction(self) -> float:
        return self.remote_calls / max(self.requests, 1)

    @property
    def escalation_fraction(self) -> float:
        return self.escalations / max(self.requests, 1)

    @property
    def mean_latency_s(self) -> float:
        return self.total_latency_s / max(self.requests, 1)

    # -- measured wall-clock latency (vs the modelled numbers above) ----
    def record_wall(self, window_wall_s: float, real: int) -> None:
        """Fold one served window's measured wall time into the stats.
        In pipelined mode this spans submit -> drain, so per-request wall
        latency includes pipeline residency, not just compute."""
        self.wall_latency_s += window_wall_s * real
        self.wall_samples.append(float(window_wall_s))

    @property
    def mean_wall_latency_s(self) -> float:
        return self.wall_latency_s / max(self.requests, 1)

    def wall_percentile(self, q: float) -> float:
        """q-th percentile (0-100) of recent per-window wall latency."""
        if not self.wall_samples:
            return 0.0
        return float(np.percentile(np.fromiter(self.wall_samples,
                                               np.float64), q))


def make_cascade_step(local_apply: Callable, remote_apply: Callable,
                      capacity: int, supervisor: str = "max_softmax"):
    """Build the jit-able fused cascade step.

    local_apply(local_batch) -> logits [B, C]
    remote_apply(remote_batch_gathered) -> logits [k, C]
    Requests carry BOTH input views (paper §4.1 input-domain reduction):
    batch = {"local": <reduced inputs>, "remote": <full inputs>}.

    `supervisor` is a SOFTMAX_SUPERVISORS name, or any callable
    logits -> confidence (e.g. a bound MDSA on hidden states — the paper's
    recommendation for non-softmax local models, §4.2).

    Returns step(batch) -> dict(pred, local_conf, remote_conf, escalated).
    """
    sup = (supervisor if callable(supervisor)
           else SOFTMAX_SUPERVISORS[supervisor])

    def step(batch):
        local_logits = local_apply(batch["local"])
        local_conf = sup(local_logits)
        local_pred = jnp.argmax(local_logits, -1)

        idx, esc_mask = select_escalations(local_conf, capacity)
        remote_in = gather_requests(batch["remote"], idx)
        remote_logits = remote_apply(remote_in)
        remote_pred = jnp.argmax(remote_logits, -1)
        remote_conf_sub = sup(remote_logits)

        pred = combine_escalated(local_pred, idx, remote_pred)
        # non-escalated requests never consult the 2nd supervisor; fill +inf
        remote_conf = jnp.full_like(local_conf, jnp.inf).at[idx].set(
            remote_conf_sub)
        return {"prediction": pred, "local_conf": local_conf,
                "remote_conf": remote_conf, "escalated": esc_mask,
                "local_pred": local_pred}

    return step


def make_local_step(local_apply: Callable, supervisor="max_softmax"):
    """Jit-able local-tier-only step (legacy runtime path; returns the
    full logits — prefer make_gated_local_step on the hot path)."""
    sup = (supervisor if callable(supervisor)
           else SOFTMAX_SUPERVISORS[supervisor])

    def step(local_batch):
        logits = local_apply(local_batch)
        return {"local_conf": sup(logits),
                "local_pred": jnp.argmax(logits, -1),
                "local_logits": logits}

    return step


def make_gated_local_step(local_apply: Callable, supervisor="max_softmax"):
    """Jit-able local tier fused with the confidence gate: supervisor
    scoring + thresholded ascending escalation ranking happen on device,
    and only the compact ``(conf [B], pred [B], idx [B])`` triple crosses
    the host boundary — never the ``[B, C]`` logits (DESIGN.md §5).

    step(local_batch, t_local [f32 scalar, +inf = no threshold],
         n_valid [i32 scalar]) -> {conf, pred, idx}; the scalars are
    traced, so runtime retuning never recompiles.
    """

    def step(local_batch, t_local, n_valid):
        logits = local_apply(local_batch)
        return confidence_gate(logits, t_local, n_valid,
                               supervisor=supervisor)

    return step


def _leading_rows(tree: Any) -> int:
    if isinstance(tree, dict):
        return _leading_rows(next(iter(tree.values())))
    return int(tree.shape[0]) if hasattr(tree, "shape") else \
        int(np.asarray(tree).shape[0])


class _Resolved:
    """Adapter giving a synchronous transport result the future API."""

    def __init__(self, result):
        self._result = result

    def done(self) -> bool:
        return True

    def result(self, timeout=None):
        return self._result


@dataclass
class _InFlight:
    """One microbatch between begin_serve and its FIFO completion."""
    t0: float
    b: int                      # padded batch rows
    real: int                   # genuine leading rows
    conf: np.ndarray            # [b] 1st-level confidences
    local_pred: np.ndarray      # [b] local predictions (never mutated)
    pred: np.ndarray            # [b] served predictions (remote scattered)
    idx: np.ndarray             # [k] escalated row indices (asc. conf)
    k: int
    keys: list | None           # cache keys per escalated row
    cached: list | None         # cache hits / filled-in remote responses
    hit_src: list | None        # backend name per cache hit (attribution)
    miss: list                  # positions within idx that went remote
    pending: Any                # TransportFuture | _Resolved | None
    backend: Any = None         # RemoteBackend routed to (None = unrouted)


class CascadeEngine:
    """Host-side engine: batching, runtime thresholds, accounting.

    Legacy fused construction (remote tier = bare infallible callable,
    static capacity)::

        CascadeEngine(local_apply, remote_apply, batch_size=32,
                      remote_fraction_budget=0.25, t_remote=0.9)

    Runtime construction (fault-aware transport, optional controller and
    response cache — DESIGN.md §2)::

        CascadeEngine(local_apply, batch_size=32,
                      remote_fraction_budget=0.25, t_remote=0.9,
                      transport=RemoteTransport(remote_apply),
                      controller=AdaptiveController(),
                      cache=RemoteResponseCache())

    Multi-remote construction (DESIGN.md §6) — pass a router instead::

        CascadeEngine(local_apply, batch_size=32, ...,
                      transport=RemoteRouter([
                          RemoteBackend("cheap", apply_a,
                                        cost_per_request=0.002),
                          RemoteBackend("fast", apply_b,
                                        cost_per_request=0.008),
                      ], policy="cheapest-available"))

    A bare transport is wrapped as a single-backend registry; predictions
    and billing stay bitwise-identical to the pre-registry path.

    The runtime path can serve synchronously (``serve``) or pipelined
    (``begin_serve`` / ``complete_next`` — DESIGN.md §5): completions
    drain strictly in submission order, so results, stats and controller
    state do not depend on remote completion order. ``close()`` (or using
    the engine as a context manager) drains in-flight windows and shuts
    down every backend's thread pool.
    """

    def __init__(self, local_apply, remote_apply=None, *, batch_size: int,
                 remote_fraction_budget: float,
                 t_remote: float, cost: CostModel = CostModel(),
                 supervisor="max_softmax", transport=None, controller=None,
                 cache=None, clock: Callable[[], float] = time.perf_counter):
        if remote_apply is None and transport is None:
            raise ValueError("need a remote tier: remote_apply or transport")
        self.batch_size = batch_size
        self.capacity = escalation_capacity(batch_size,
                                            remote_fraction_budget)
        self.t_remote = t_remote            # runtime-tunable (paper §4.5)
        self.t_local: float | None = None   # runtime-tunable escalation gate
        self.cost = cost
        self.stats = CascadeStats()
        # `transport` may be a RemoteTransport OR a RemoteRouter; keep the
        # raw object (schedulers/tests check `engine.transport`) and route
        # internally through a registry either way
        self.transport = transport
        self.router: RemoteRouter | None = None
        if transport is not None:
            self.router = (transport if isinstance(transport, RemoteRouter)
                           else RemoteRouter(
                               [RemoteBackend("remote", transport=transport)]))
        self.controller = controller
        self.cache = cache
        self._clock = clock
        self._inflight: deque[_InFlight] = deque()
        self._supervisor = (supervisor if callable(supervisor)
                            else SOFTMAX_SUPERVISORS[supervisor])
        if transport is None:
            self._step = jax.jit(make_cascade_step(
                local_apply, remote_apply, self.capacity, supervisor))
        else:
            self._local_step = jax.jit(make_gated_local_step(local_apply,
                                                             supervisor))

    def set_remote_threshold(self, t: float) -> None:
        """Runtime reconfiguration (paper §4.5)."""
        self.t_remote = t

    def set_local_threshold(self, t: float | None) -> None:
        """Runtime escalation gate (runtime path; None = capacity-k)."""
        self.t_local = t

    # ------------------------------------------------------------------
    def serve(self, batch: dict[str, Any],
              real_rows: int | None = None) -> dict[str, np.ndarray]:
        """Serve one batch; ``real_rows`` marks how many leading rows are
        genuine — padded replicas beyond it are served (static jit shapes)
        but never counted or billed."""
        if self.transport is None:
            return self._serve_fused(batch, real_rows)
        if self._inflight:
            raise RuntimeError("pipelined windows in flight; drain them "
                               "with complete_next() before serve()")
        return self._complete(self._begin(batch, real_rows,
                                          asynchronous=False))

    # -- pipelined runtime path (DESIGN.md §5) -------------------------
    def begin_serve(self, batch: dict[str, Any],
                    real_rows: int | None = None) -> _InFlight:
        """Dispatch one microbatch: local tier + confidence gate, cache
        lookups, and a NON-blocking remote submission for the misses.
        Returns after local compute; the remote round trip stays on the
        wire while subsequent batches begin."""
        if self.transport is None:
            raise RuntimeError("pipelined serving needs the runtime path "
                               "(construct the engine with transport=...)")
        fl = self._begin(batch, real_rows, asynchronous=True)
        self._inflight.append(fl)
        return fl

    def complete_next(self) -> dict[str, np.ndarray] | None:
        """Drain the OLDEST in-flight window (blocks until its remote
        responses land). FIFO draining keeps accounting and controller
        observations independent of remote completion order."""
        if not self._inflight:
            return None
        return self._complete(self._inflight.popleft())

    @property
    def inflight(self) -> int:
        return len(self._inflight)

    # -- fused path (seed semantics + padding-aware accounting) --------
    def _serve_fused(self, batch, real_rows):
        t0 = self._clock()
        out = jax.device_get(self._step(batch))
        b = out["prediction"].shape[0]
        real = b if real_rows is None else min(real_rows, b)
        escalated = out["escalated"]
        accepted = (~escalated) | (out["remote_conf"] > self.t_remote)
        n_remote = int(escalated[:real].sum())
        self._account(real, n_remote, n_remote, 0, 0,
                      int((~accepted[:real]).sum()))
        self.stats.record_wall(self._clock() - t0, real)
        if self.controller is not None:
            self.controller.observe(
                out["local_conf"][:real], n_remote, real,
                out["remote_conf"][:real],
                cost=n_remote * self.cost.remote_cost_per_request)
        out["accepted"] = accepted
        return out

    # -- runtime path: dispatch half -----------------------------------
    def _begin(self, batch, real_rows, *, asynchronous: bool) -> _InFlight:
        t0 = self._clock()
        b = _leading_rows(batch["local"])
        real = b if real_rows is None else min(real_rows, b)

        # --- escalation set: controller threshold, capped by capacity ---
        capacity = (self.controller.capacity(self.batch_size)
                    if self.controller is not None else self.capacity)
        # calibrated warm start: engine t_local applies until the
        # controller has produced its own (mirrors t_remote at complete)
        t_local = self.t_local
        if self.controller is not None and self.controller.t_local is not None:
            t_local = self.controller.t_local
        t = np.float32(np.inf) if t_local is None else np.float32(t_local)

        gate = jax.device_get(self._local_step(batch["local"], t,
                                               np.int32(real)))
        conf = np.asarray(gate["conf"])
        local_pred = np.asarray(gate["pred"])
        pred = local_pred.copy()
        cand = np.asarray(gate["idx"])
        cand = cand[cand >= 0]          # eligible rows, ascending by conf
        k = int(min(cand.size, capacity, real))
        idx = cand[:k]

        keys = cached = hit_src = None
        miss: list[int] = []
        pending = backend = None
        if k > 0:
            host = jax.tree.map(np.asarray, batch["remote"])
            sub = jax.tree.map(lambda a: a[idx], host)   # batched gather
            if self.cache is not None:
                keys = self.cache.keys_for(sub, k)
                found = [self.cache.lookup(key) for key in keys]
                cached = [f[0] if f is not None else None for f in found]
                hit_src = [f[1] if f is not None else None for f in found]
            else:
                keys = [None] * k
                cached = [None] * k
                hit_src = [None] * k
            miss = [j for j, c in enumerate(cached) if c is None]
            if miss:
                # route the window at submit time; an open breaker fails
                # over to the next policy candidate immediately, and a
                # fully-open registry (backend None) degrades the window
                # to REJECTED/fallback without touching any transport
                backend = self.router.pick()
                if backend is not None:
                    marr = np.asarray(miss)
                    sub_miss = jax.tree.map(lambda a: a[marr], sub)
                    pending = (backend.submit(sub_miss) if asynchronous
                               else _Resolved(backend.call(sub_miss)))
        return _InFlight(t0=t0, b=b, real=real, conf=conf,
                         local_pred=local_pred, pred=pred, idx=idx, k=k,
                         keys=keys, cached=cached, hit_src=hit_src,
                         miss=miss, pending=pending, backend=backend)

    # -- runtime path: completion half ---------------------------------
    def _complete(self, fl: _InFlight) -> dict[str, np.ndarray]:
        remote_conf = np.full((fl.b,), np.inf, np.float32)
        n_hits = n_sent = n_failed = 0
        bname = fl.backend.name if fl.backend is not None else UNROUTED
        if fl.k > 0:
            cached = fl.cached
            if fl.miss:
                if fl.pending is not None:
                    logits, ok = fl.pending.result()
                    n_sent = int(ok.sum())
                    n_failed = len(fl.miss) - n_sent
                    for w, j in enumerate(fl.miss):
                        if ok[w]:
                            cached[j] = logits[w]
                            if self.cache is not None:
                                self.cache.put(fl.keys[j], logits[w],
                                               source=bname)
                else:                 # no backend available at submit time
                    n_failed = len(fl.miss)
            n_hits = fl.k - len(fl.miss)
            got = [j for j, c in enumerate(cached) if c is not None]
            if got:
                rlogits = jnp.asarray(np.stack([cached[j] for j in got]))
                rconf = np.asarray(self._supervisor(rlogits))
                rpred = np.asarray(jnp.argmax(rlogits, -1))
                remote_conf[fl.idx[got]] = rconf
                fl.pred[fl.idx[got]] = rpred
            failed = [j for j, c in enumerate(cached) if c is None]
            # transport-lost escalations: 2nd supervisor can never trust
            # them -> REJECTED -> scheduler fallback (Algorithm 1 line 12)
            remote_conf[fl.idx[failed]] = -np.inf

        escalated = np.zeros((fl.b,), bool)
        escalated[fl.idx] = True
        t_remote = self.t_remote
        if self.controller is not None and self.controller.t_remote is not None:
            t_remote = self.controller.t_remote
        accepted = (~escalated) | (remote_conf > t_remote)

        # per-backend billing/latency attribution (DESIGN.md §6): billed
        # calls and failures charge the routed backend; cache hits charge
        # $0 to whichever backend originally filled the entry
        cost_per = self.cost.backend_cost(fl.backend)
        lat_per = self.cost.backend_latency(fl.backend)
        window_cost = n_sent * cost_per
        if n_sent or n_failed:
            u = self.stats.backend_usage(bname)
            u.remote_calls += n_sent
            u.transport_failures += n_failed
            u.cost += window_cost
            u.remote_latency_s += n_sent * lat_per
        if n_hits and fl.hit_src is not None:
            miss_set = set(fl.miss)
            for j in range(fl.k):
                if j not in miss_set:
                    src = fl.hit_src[j]
                    self.stats.backend_usage(
                        src if src is not None else UNATTRIBUTED
                    ).cache_hits += 1

        self._account(fl.real, fl.k, n_sent, n_hits, n_failed,
                      int((~accepted[:fl.real]).sum()),
                      cost=window_cost,
                      remote_latency_s=n_sent * lat_per)
        self.stats.record_wall(self._clock() - fl.t0, fl.real)
        if self.controller is not None:
            self.controller.observe(fl.conf[:fl.real], fl.k, fl.real,
                                    remote_conf[:fl.real],
                                    cost=window_cost)
        return {"prediction": fl.pred, "local_pred": fl.local_pred,
                "local_conf": fl.conf, "remote_conf": remote_conf,
                "escalated": escalated, "accepted": accepted}

    # ------------------------------------------------------------------
    def close(self, wait: bool = True) -> None:
        """Drain any in-flight pipelined windows (their results are
        accounted but discarded) and shut down every backend's thread
        pool. Idempotent; a no-op on the fused path."""
        while self._inflight:
            self._complete(self._inflight.popleft())
        if self.router is not None:
            self.router.shutdown(wait=wait)

    def __enter__(self) -> "CascadeEngine":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    # ------------------------------------------------------------------
    def _account(self, real, escalations, remote_calls, cache_hits,
                 transport_failures, rejected, *, cost=None,
                 remote_latency_s=None):
        """Fold one window into the aggregate stats. ``cost`` and
        ``remote_latency_s`` carry per-backend pricing from the runtime
        path; when omitted (fused path) the CostModel defaults apply."""
        if cost is None:
            cost = remote_calls * self.cost.remote_cost_per_request
        if remote_latency_s is None:
            remote_latency_s = remote_calls * self.cost.remote_latency_s
        st = self.stats
        st.requests += real
        st.escalations += escalations
        st.remote_calls += remote_calls
        st.cache_hits += cache_hits
        st.transport_failures += transport_failures
        st.rejected += rejected
        st.total_cost += cost
        st.total_latency_s += (real * self.cost.local_latency_s
                               + remote_latency_s
                               + cache_hits * self.cost.cache_hit_latency_s)

"""Request scheduler: microbatching queue in front of the cascade engine.

Requests arrive one by one (each carrying both input views); the scheduler
packs fixed-size microbatches (padding the tail with replicas so jitted
shapes never change), runs the engine and routes per-request results,
including the REJECTED -> fallback path (paper Algorithm 1 line 12).
Transport failures surface as REJECTED too (DESIGN.md §3), so an outage
degrades to fallback answers instead of dropped requests.

The engine is told how many rows are genuine (``real_rows``) so padded
replicas are never counted in the stats or billed against the remote tier.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np


def _stack(items):
    """Stack a list of (possibly pytree) request inputs into a batch."""
    first = items[0]
    if isinstance(first, dict):
        return {k: _stack([it[k] for it in items]) for k in first}
    return np.stack(items)


@dataclass
class Request:
    uid: int
    local_input: np.ndarray
    remote_input: np.ndarray


@dataclass
class Response:
    uid: int
    prediction: int
    source: str               # "local" | "remote" | "fallback"
    local_conf: float
    remote_conf: float


class MicrobatchScheduler:
    def __init__(self, engine, fallback: Callable[[Request], int] | None = None):
        self.engine = engine
        self.fallback = fallback
        self.queue: list[Request] = []
        self.responses: dict[int, Response] = {}
        self.fallbacks = 0

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _pad(self, reqs: list[Request]) -> list[Request]:
        b = self.engine.batch_size
        return reqs + [reqs[-1]] * (b - len(reqs))

    def flush(self) -> list[Response]:
        out: list[Response] = []
        while self.queue:
            chunk = self.queue[: self.engine.batch_size]
            self.queue = self.queue[self.engine.batch_size:]
            real = len(chunk)
            padded = self._pad(chunk)
            batch = {
                "local": _stack([r.local_input for r in padded]),
                "remote": _stack([r.remote_input for r in padded]),
            }
            res = self.engine.serve(batch, real_rows=real)
            for i, req in enumerate(chunk):
                escalated = bool(res["escalated"][i])
                accepted = bool(res["accepted"][i])
                if not escalated:
                    src = "local"
                    pred = int(res["local_pred"][i])
                elif accepted:
                    src = "remote"
                    pred = int(res["prediction"][i])
                else:
                    src = "fallback"
                    self.fallbacks += 1
                    pred = (self.fallback(req) if self.fallback
                            else -1)  # "raise Exception" analogue
                resp = Response(req.uid, pred, src,
                                float(res["local_conf"][i]),
                                float(res["remote_conf"][i]))
                self.responses[req.uid] = resp
                out.append(resp)
        return out

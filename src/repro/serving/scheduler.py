"""Request scheduler: microbatching queue in front of the cascade engine.

Requests arrive one by one (each carrying both input views); the scheduler
packs fixed-size microbatches (padding the tail with replicas so jitted
shapes never change), runs the engine and routes per-request results,
including the REJECTED -> fallback path (paper Algorithm 1 line 12).
Transport failures surface as REJECTED too (DESIGN.md §3), so an outage
degrades to fallback answers instead of dropped requests.

The queue is a deque (an O(n^2) list-slice drain lived here once); the
engine is told how many rows are genuine (``real_rows``) so padded
replicas are never counted in the stats or billed against the remote tier.

``flush(pipeline_depth=N)`` drives the engine's pipelined runtime path
(DESIGN.md §5): up to N microbatches stay in flight — batch i+1's local
tier runs while batch i's escalations are on the wire — and windows are
drained strictly in submission order, so responses, stats and controller
observations are identical regardless of remote completion order.
``pipeline_depth`` doubles as the backpressure bound: submission stalls
on the oldest window once N are outstanding.

``completion_mode="streaming"`` (DESIGN.md §7) keeps the same pipeline
but hands results back per REQUEST instead of per FIFO window: locally
trusted rows return the moment their window's confidence gate clears;
escalated rows return as their remote futures resolve (out of submission
order when thresholds are static). ``self.responses`` is the reorder-free
response map — responses are keyed by uid at emission, so no reordering
buffer ever exists — and every ``Response`` carries its measured
``latency_s`` (window dispatch -> hand-back, i.e. pipeline residency).
Billing and controller state stay bitwise-identical to FIFO because the
engine commits accounting in submission order either way (with a
response cache, repeats across concurrently in-flight windows may gain
extra $0 cache hits vs FIFO — see ``CascadeEngine.complete_ready``).
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable

import numpy as np

COMPLETION_MODES = ("fifo", "streaming")


def _stack(items):
    """Stack a list of (possibly pytree) request inputs into a batch."""
    first = items[0]
    if isinstance(first, dict):
        return {k: _stack([it[k] for it in items]) for k in first}
    return np.stack(items)


@dataclass
class Request:
    uid: int
    local_input: np.ndarray
    remote_input: np.ndarray


@dataclass
class Response:
    uid: int
    prediction: int
    source: str               # "local" | "remote" | "fallback"
    local_conf: float
    remote_conf: float
    latency_s: float = 0.0    # measured: window dispatch -> hand-back


class _Window:
    """Scheduler-side bookkeeping for one in-flight microbatch."""

    __slots__ = ("chunk", "fl", "t0", "local_emitted")

    def __init__(self, chunk, fl, t0):
        self.chunk = chunk
        self.fl = fl
        self.t0 = t0
        self.local_emitted = False


class MicrobatchScheduler:
    def __init__(self, engine, fallback: Callable[[Request], int] | None = None,
                 pipeline_depth: int = 1, completion_mode: str = "fifo"):
        if completion_mode not in COMPLETION_MODES:
            raise ValueError(f"unknown completion_mode {completion_mode!r};"
                             f" choose from {COMPLETION_MODES}")
        self.engine = engine
        self.fallback = fallback
        self.pipeline_depth = max(1, pipeline_depth)
        self.completion_mode = completion_mode
        self.queue: deque[Request] = deque()
        self.responses: dict[int, Response] = {}
        self.fallbacks = 0
        # time from flush start to the first response handed back (the
        # streaming mode's headline telemetry; tracked for FIFO too)
        self.first_response_s: float | None = None
        self._flush_t0: float = 0.0

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _pad(self, reqs: list[Request]) -> list[Request]:
        b = self.engine.batch_size
        return reqs + [reqs[-1]] * (b - len(reqs))

    def _next_chunk(self) -> tuple[list[Request], dict[str, Any]]:
        b = self.engine.batch_size
        chunk = [self.queue.popleft()
                 for _ in range(min(b, len(self.queue)))]
        padded = self._pad(chunk)
        batch = {
            "local": _stack([r.local_input for r in padded]),
            "remote": _stack([r.remote_input for r in padded]),
        }
        return chunk, batch

    def _record(self, resp: Response, out: list[Response]) -> None:
        """Reorder-free hand-back: key by uid, never buffer for order."""
        if self.first_response_s is None:
            self.first_response_s = time.perf_counter() - self._flush_t0
        self.responses[resp.uid] = resp
        out.append(resp)

    def _route(self, chunk: list[Request], res: dict,
               t0: float) -> list[Response]:
        out: list[Response] = []
        lat = time.perf_counter() - t0
        for i, req in enumerate(chunk):
            escalated = bool(res["escalated"][i])
            accepted = bool(res["accepted"][i])
            if not escalated:
                src = "local"
                pred = int(res["local_pred"][i])
            elif accepted:
                src = "remote"
                pred = int(res["prediction"][i])
            else:
                src = "fallback"
                self.fallbacks += 1
                pred = (self.fallback(req) if self.fallback
                        else -1)  # "raise Exception" analogue
            resp = Response(req.uid, pred, src,
                            float(res["local_conf"][i]),
                            float(res["remote_conf"][i]), latency_s=lat)
            self._record(resp, out)
        return out

    def flush(self, pipeline_depth: int | None = None) -> list[Response]:
        depth = (self.pipeline_depth if pipeline_depth is None
                 else max(1, pipeline_depth))
        self.first_response_s = None
        self._flush_t0 = time.perf_counter()
        if self.engine.transport is not None:
            if self.completion_mode == "streaming":
                return self._flush_streaming(depth)
            if depth > 1:
                return self._flush_pipelined(depth)
        out: list[Response] = []
        while self.queue:
            chunk, batch = self._next_chunk()
            t0 = time.perf_counter()
            res = self.engine.serve(batch, real_rows=len(chunk))
            out.extend(self._route(chunk, res, t0))
        return out

    def _check_exclusive_engine(self) -> None:
        if self.engine.inflight:
            # windows begun outside this flush (or left over from an
            # aborted one) would silently pair with the wrong requests
            raise RuntimeError(f"engine has {self.engine.inflight} "
                               "in-flight windows not owned by this "
                               "scheduler; drain complete_next() first")

    def _flush_pipelined(self, depth: int) -> list[Response]:
        """Overlapped drain: keep up to ``depth`` microbatches in flight,
        completing the oldest (FIFO) whenever the window is full or the
        queue is empty. Responses come back in submission order."""
        self._check_exclusive_engine()
        out: list[Response] = []
        pending: deque[tuple[list[Request], float]] = deque()
        while self.queue or pending:
            while self.queue and len(pending) < depth:
                chunk, batch = self._next_chunk()
                t0 = time.perf_counter()
                self.engine.begin_serve(batch, real_rows=len(chunk))
                pending.append((chunk, t0))
            # about to block on the oldest window: unpark the double-
            # buffered newest one first, so its remote submission (and in
            # streaming mode its trusted-local rows) never waits out a
            # full drain
            self.engine.flush_dispatch()
            res = self.engine.complete_next()
            chunk, t0 = pending.popleft()
            out.extend(self._route(chunk, res, t0))
        return out

    # -- streaming completion mode (DESIGN.md §7) ----------------------
    def _flush_streaming(self, depth: int) -> list[Response]:
        """Per-request drain: locally-trusted rows hand back as soon as
        their window's host half runs (confidence gate cleared); escalated
        rows hand back when their window finalizes. With static thresholds
        windows finalize out of submission order via ``complete_ready``;
        with a live controller the drain uses ``complete_next`` so the
        begin/commit interleaving — hence every threshold each window
        sees — reproduces the FIFO drain exactly. Either way the engine
        commits accounting in submission order, so billing, per-backend
        attribution and controller state are bitwise-identical to FIFO."""
        self._check_exclusive_engine()
        out: list[Response] = []
        windows: dict[int, _Window] = {}        # seq -> bookkeeping
        fifo_drain = self.engine.controller is not None

        def emit_ready_locals():
            for w in windows.values():
                if not w.local_emitted and w.fl.host_done:
                    self._emit_locals(w, out)

        def emit_window(seq, res):
            w = windows.pop(seq)
            if not w.local_emitted:     # host half ran at the finalize
                self._emit_locals(w, out)
            self._emit_escalated(w, res, out)

        while self.queue or windows:
            while self.queue and self.engine.inflight < depth:
                chunk, batch = self._next_chunk()
                t0 = time.perf_counter()
                fl = self.engine.begin_serve(batch, real_rows=len(chunk))
                windows[fl.seq] = _Window(chunk, fl, t0)
                emit_ready_locals()     # previous window's host half ran
                if not fifo_drain:
                    for seq, res in self.engine.complete_ready():
                        emit_window(seq, res)
            # about to block: unpark the newest window so its remote
            # round trip starts and its trusted-local rows emit NOW
            # instead of after the next drain wave
            self.engine.flush_dispatch()
            emit_ready_locals()
            if not windows:
                break
            if fifo_drain:
                res = self.engine.complete_next()
                emit_window(min(windows), res)      # FIFO = lowest seq
            else:
                for seq, res in self.engine.complete_ready(block=True):
                    emit_window(seq, res)
        return out

    def _emit_locals(self, w: _Window, out: list[Response]) -> None:
        """Hand back the window's locally-trusted rows (gate cleared, no
        remote involved): available as soon as the host half has run."""
        fl = w.fl
        lat = time.perf_counter() - w.t0
        esc = {int(j) for j in fl.idx} if fl.k else set()
        for i, req in enumerate(w.chunk):
            if i in esc:
                continue
            self._record(Response(req.uid, int(fl.local_pred[i]), "local",
                                  float(fl.conf[i]), float("inf"),
                                  latency_s=lat), out)
        w.local_emitted = True

    def _emit_escalated(self, w: _Window, res: dict,
                        out: list[Response]) -> None:
        """Hand back the window's escalated rows once finalized."""
        fl = w.fl
        lat = time.perf_counter() - w.t0
        for j in fl.idx:
            i = int(j)
            req = w.chunk[i]            # idx only covers genuine rows
            if bool(res["accepted"][i]):
                resp = Response(req.uid, int(res["prediction"][i]),
                                "remote", float(res["local_conf"][i]),
                                float(res["remote_conf"][i]), latency_s=lat)
            else:
                self.fallbacks += 1
                pred = self.fallback(req) if self.fallback else -1
                resp = Response(req.uid, pred, "fallback",
                                float(res["local_conf"][i]),
                                float(res["remote_conf"][i]), latency_s=lat)
            self._record(resp, out)

"""Request scheduler: microbatching queue in front of the cascade engine.

Requests arrive one by one (each carrying both input views); the scheduler
packs fixed-size microbatches (padding the tail with replicas so jitted
shapes never change), runs the engine and routes per-request results,
including the REJECTED -> fallback path (paper Algorithm 1 line 12).
Transport failures surface as REJECTED too (DESIGN.md §3), so an outage
degrades to fallback answers instead of dropped requests.

The queue is a deque (an O(n^2) list-slice drain lived here once); the
engine is told how many rows are genuine (``real_rows``) so padded
replicas are never counted in the stats or billed against the remote tier.

``flush(pipeline_depth=N)`` drives the engine's pipelined runtime path
(DESIGN.md §5): up to N microbatches stay in flight — batch i+1's local
tier runs while batch i's escalations are on the wire — and windows are
drained strictly in submission order, so responses, stats and controller
observations are identical regardless of remote completion order.
``pipeline_depth`` doubles as the backpressure bound: submission stalls
on the oldest window once N are outstanding.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Any, Callable

import numpy as np


def _stack(items):
    """Stack a list of (possibly pytree) request inputs into a batch."""
    first = items[0]
    if isinstance(first, dict):
        return {k: _stack([it[k] for it in items]) for k in first}
    return np.stack(items)


@dataclass
class Request:
    uid: int
    local_input: np.ndarray
    remote_input: np.ndarray


@dataclass
class Response:
    uid: int
    prediction: int
    source: str               # "local" | "remote" | "fallback"
    local_conf: float
    remote_conf: float


class MicrobatchScheduler:
    def __init__(self, engine, fallback: Callable[[Request], int] | None = None,
                 pipeline_depth: int = 1):
        self.engine = engine
        self.fallback = fallback
        self.pipeline_depth = max(1, pipeline_depth)
        self.queue: deque[Request] = deque()
        self.responses: dict[int, Response] = {}
        self.fallbacks = 0

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _pad(self, reqs: list[Request]) -> list[Request]:
        b = self.engine.batch_size
        return reqs + [reqs[-1]] * (b - len(reqs))

    def _next_chunk(self) -> tuple[list[Request], dict[str, Any]]:
        b = self.engine.batch_size
        chunk = [self.queue.popleft()
                 for _ in range(min(b, len(self.queue)))]
        padded = self._pad(chunk)
        batch = {
            "local": _stack([r.local_input for r in padded]),
            "remote": _stack([r.remote_input for r in padded]),
        }
        return chunk, batch

    def _route(self, chunk: list[Request], res: dict) -> list[Response]:
        out: list[Response] = []
        for i, req in enumerate(chunk):
            escalated = bool(res["escalated"][i])
            accepted = bool(res["accepted"][i])
            if not escalated:
                src = "local"
                pred = int(res["local_pred"][i])
            elif accepted:
                src = "remote"
                pred = int(res["prediction"][i])
            else:
                src = "fallback"
                self.fallbacks += 1
                pred = (self.fallback(req) if self.fallback
                        else -1)  # "raise Exception" analogue
            resp = Response(req.uid, pred, src,
                            float(res["local_conf"][i]),
                            float(res["remote_conf"][i]))
            self.responses[req.uid] = resp
            out.append(resp)
        return out

    def flush(self, pipeline_depth: int | None = None) -> list[Response]:
        depth = (self.pipeline_depth if pipeline_depth is None
                 else max(1, pipeline_depth))
        if depth > 1 and self.engine.transport is not None:
            return self._flush_pipelined(depth)
        out: list[Response] = []
        while self.queue:
            chunk, batch = self._next_chunk()
            res = self.engine.serve(batch, real_rows=len(chunk))
            out.extend(self._route(chunk, res))
        return out

    def _flush_pipelined(self, depth: int) -> list[Response]:
        """Overlapped drain: keep up to ``depth`` microbatches in flight,
        completing the oldest (FIFO) whenever the window is full or the
        queue is empty. Responses come back in submission order."""
        if self.engine.inflight:
            # windows begun outside this flush (or left over from an
            # aborted one) would silently pair with the wrong requests
            raise RuntimeError(f"engine has {self.engine.inflight} "
                               "in-flight windows not owned by this "
                               "scheduler; drain complete_next() first")
        out: list[Response] = []
        pending: deque[list[Request]] = deque()
        while self.queue or pending:
            while self.queue and len(pending) < depth:
                chunk, batch = self._next_chunk()
                self.engine.begin_serve(batch, real_rows=len(chunk))
                pending.append(chunk)
            res = self.engine.complete_next()
            out.extend(self._route(pending.popleft(), res))
        return out

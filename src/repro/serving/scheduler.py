"""Request scheduler: microbatching queue in front of the cascade engine.

Requests arrive one by one (each carrying both input views); the scheduler
packs fixed-size microbatches (padding the tail with replicas so jitted
shapes never change), runs the engine and routes per-request results,
including the REJECTED -> fallback path (paper Algorithm 1 line 12).
Transport failures surface as REJECTED too (DESIGN.md §3), so an outage
degrades to fallback answers instead of dropped requests.

The queue is a deque (an O(n^2) list-slice drain lived here once); the
engine is told how many rows are genuine (``real_rows``) so padded
replicas are never counted in the stats or billed against the remote tier.

``flush(pipeline_depth=N)`` drives the engine's pipelined runtime path
(DESIGN.md §5): up to N microbatches stay in flight — batch i+1's local
tier runs while batch i's escalations are on the wire — and windows are
drained strictly in submission order, so responses, stats and controller
observations are identical regardless of remote completion order.
``pipeline_depth`` doubles as the backpressure bound: submission stalls
on the oldest window once N are outstanding.

``completion_mode="streaming"`` (DESIGN.md §7) keeps the same pipeline
but hands results back per REQUEST instead of per FIFO window: locally
trusted rows return the moment their window's confidence gate clears;
escalated rows return as their remote futures resolve (out of submission
order when thresholds are static). ``self.responses`` is the reorder-free
response map — responses are keyed by uid at emission, so no reordering
buffer ever exists — and every ``Response`` carries its measured
``latency_s`` (enqueue -> hand-back, consistently for every path).
Billing and controller state stay bitwise-identical to FIFO because the
engine commits accounting in submission order either way (with a
response cache, repeats across concurrently in-flight windows may gain
extra $0 cache hits vs FIFO — see ``CascadeEngine.complete_ready``).

Per-request policy + window packing (DESIGN.md §8): every ``Request``
may carry a ``RequestPolicy`` (deadline SLA, cost cap, routing hint,
escalation override); the scheduler forwards policies and enqueue stamps
to the engine, and each ``Response`` reports ``disposition`` /
``backend`` / ``cost`` — how the request was actually served and what it
was billed. With ``packing="policy"`` the scheduler classifies each
request at submit time — can it possibly go remote (policy feasibility
against the router's price/latency estimates), and is it *likely* to
(the calibration-table escalation ``prior``)? — and packs HOT
(likely-escalating) and COLD (trusted-local / policy-pinned) rows into
separate windows, draining cold windows first: trusted-local rows never
share a window with a remote round trip, and deadline-pinned rows don't
queue behind one. Windows are never mixed (the tail of each class is
padded instead); ``packing_stats`` reports the realised purity.

Overload admission control (DESIGN.md §10): with ``admission_limit > 0``
the queue is bounded. ``submit`` evaluates three rules before enqueueing
— hard bound (queue full → SHED), soft watermark (queue past
``admission_soft_ratio``·limit → apply the request's ``on_miss``:
``fallback`` degrades it to local-only, ``reject`` sheds), and deadline
feasibility (expected queue wait from the engine's window-service EMA
plus the fastest backend RTT exceeds the remaining deadline → same
``on_miss`` split). A shed request is answered *immediately* from the
fallback with the ``SHED`` disposition, $0 cost and ``source="shed"`` —
never enqueued, never billed, never silently dropped: shed responses are
recorded in ``self.responses`` at submit and included in the next
``flush`` output, so ``submitted == len(responses)`` still holds and
``AdmissionStats.submitted == engine.stats.requests + shed`` reconciles
with billing exactly.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from repro.runtime.observability import (EV_ADMISSION_DEGRADE,
                                         EV_ADMISSION_SHED)
from repro.serving.policy import (BATCHING_MODES, CACHED, LOCAL, REJECTED,
                                  REMOTE, SHED, RequestPolicy, ServeConfig)

COMPLETION_MODES = ("fifo", "streaming")


def _stack(items):
    """Stack a list of (possibly pytree) request inputs into a batch."""
    first = items[0]
    if isinstance(first, dict):
        return {k: _stack([it[k] for it in items]) for k in first}
    return np.stack(items)


@dataclass
class Request:
    uid: int
    local_input: np.ndarray
    remote_input: np.ndarray
    policy: RequestPolicy | None = None   # per-request contract (§8)
    t_enq: float = 0.0                    # stamped at submit()


@dataclass
class Response:
    uid: int
    prediction: int
    source: str               # "local" | "remote" | "fallback"
    local_conf: float
    remote_conf: float
    latency_s: float = 0.0    # measured: enqueue -> hand-back
    disposition: str = LOCAL  # how the row was served (DESIGN.md §8)
    backend: str | None = None  # backend billed/attributed (None = local)
    cost: float = 0.0         # realised $ billed for this request
    # enqueue -> window dispatch: the load-dependent share of latency_s.
    # latency_s - queue_s is the SERVICE latency (dispatch -> hand-back),
    # the basis of the streaming trusted-local-vs-FIFO comparison
    queue_s: float = 0.0


@dataclass
class AdmissionStats:
    """Overload admission accounting (DESIGN.md §10). Reconciliation:
    ``submitted == admitted + shed`` and, once every admitted request is
    flushed, ``admitted == engine.stats.requests`` — so shed + served +
    rejected counts tie out bitwise against ``CascadeStats`` billing."""
    submitted: int = 0          # submit() calls seen
    admitted: int = 0           # enqueued (includes degraded)
    degraded: int = 0           # admitted pinned local by overload rules
    shed: int = 0               # refused, answered via fallback (SHED)
    shed_reasons: dict = field(default_factory=dict)     # reason -> n
    degrade_reasons: dict = field(default_factory=dict)  # reason -> n


class _Window:
    """Scheduler-side bookkeeping for one in-flight microbatch."""

    __slots__ = ("chunk", "fl", "t_disp", "emitted", "host_emitted",
                 "early_emitted", "left")

    def __init__(self, chunk, fl, t_disp):
        self.chunk = chunk
        self.fl = fl
        self.t_disp = t_disp            # window dispatch stamp (queue_s)
        self.emitted: set[int] = set()  # rows already handed back
        self.host_emitted = False       # host-half emission pass done
        self.early_emitted = False      # pre-decided cache hits handed back
        self.left = 0                   # rows already freed in the slot map


class _SlotMap:
    """Slot-occupancy ledger for the continuous batcher (DESIGN.md §11).

    The continuous serve loop admits dispatch cohorts against FREE SLOTS
    of a persistent padded batch (``batch_size × pipeline_depth`` rows)
    instead of counting whole in-flight windows: a row occupies its slot
    from dispatch until its response is handed back, so a cohort of
    trusted-local rows returns its slots at *gate* time and admission
    reopens while the window's escalations are still on the wire. The
    occupancy-fraction EMA is the admission/deadline-feasibility signal
    (`_queue_wait_estimate`) — the continuous analogue of queue depth in
    windows."""

    __slots__ = ("capacity", "occupied", "peak", "joins", "leaves",
                 "occupancy_ema", "_alpha")

    def __init__(self, capacity: int, alpha: float = 0.2):
        self.capacity = max(1, capacity)
        self.occupied = 0
        self.peak = 0
        self.joins = 0
        self.leaves = 0
        self.occupancy_ema = 0.0
        self._alpha = alpha

    @property
    def free(self) -> int:
        return self.capacity - self.occupied

    def join(self, n: int) -> None:
        self.occupied += n
        self.joins += n
        if self.occupied > self.peak:
            self.peak = self.occupied
        self._observe()

    def leave(self, n: int) -> None:
        self.occupied -= n
        self.leaves += n
        self._observe()

    def _observe(self) -> None:
        frac = self.occupied / self.capacity
        self.occupancy_ema += self._alpha * (frac - self.occupancy_ema)


class MicrobatchScheduler:
    def __init__(self, engine, fallback: Callable[[Request], int] | None = None,
                 pipeline_depth: int = 1, completion_mode: str = "fifo",
                 packing: str = "none",
                 prior: Callable[[Request], float] | None = None,
                 admission_limit: int = 0,
                 admission_soft_ratio: float = 0.5,
                 batching: str = "window",
                 admission_share: Callable[[], float] | None = None):
        if completion_mode not in COMPLETION_MODES:
            raise ValueError(f"unknown completion_mode {completion_mode!r};"
                             f" choose from {COMPLETION_MODES}")
        if packing not in ("none", "policy"):
            raise ValueError(f"unknown packing {packing!r}")
        if packing != "none" and engine.transport is None:
            raise ValueError("window packing needs the runtime path")
        if admission_limit and engine.transport is None:
            raise ValueError("admission control needs the runtime path")
        if batching not in BATCHING_MODES:
            raise ValueError(f"unknown batching {batching!r}; "
                             f"choose from {BATCHING_MODES}")
        if batching == "continuous":
            if engine.transport is None:
                raise ValueError("continuous batching needs the runtime "
                                 "path")
            if completion_mode != "streaming":
                raise ValueError("batching='continuous' requires "
                                 "completion_mode='streaming'")
        self.engine = engine
        self.fallback = fallback
        self.pipeline_depth = max(1, pipeline_depth)
        self.completion_mode = completion_mode
        self.batching = batching
        # slot-occupancy ledger (continuous only; DESIGN.md §11) — also
        # the admission/deadline-feasibility signal between flushes
        self._slots = (_SlotMap(engine.batch_size * self.pipeline_depth)
                       if batching == "continuous" else None)
        # span-stage vocabulary: continuous rows JOIN the slot map (and
        # may carry an early EMIT stage); window rows are packed
        self._pack_stage = "join" if batching == "continuous" else "pack"
        if completion_mode == "streaming":
            # we consume fl.early (cache hits handed back at gate-clear);
            # FIFO consumers leave it off and skip the extra host pass
            engine.early_handback = True
        self.packing = packing
        # P(escalate | request): the calibration-table prior driving the
        # HOT/COLD split (repro.runtime.fit_escalation_prior). None =
        # classify by policy feasibility alone (DESIGN.md §8)
        self.prior = prior
        self.prior_threshold = 0.5
        self.queue: deque[Request] = deque()      # HOT / default queue
        self.cold: deque[Request] = deque()       # trusted-local-bound
        self.responses: dict[int, Response] = {}
        self.fallbacks = 0
        # overload admission control (DESIGN.md §10; 0 = unbounded)
        self.admission_limit = max(0, admission_limit)
        self.admission_soft = (max(1, int(self.admission_limit
                                          * admission_soft_ratio))
                               if self.admission_limit else 0)
        self.admission = AdmissionStats()
        # cluster-aware admission (DESIGN.md §12): a callable returning
        # this replica's current budget share (1.0 = fair share). The
        # soft watermark scales with it, so a replica the cluster
        # reconciler has squeezed sheds/degrades earlier while one
        # granted headroom rides closer to its hard bound. None (the
        # single-replica default) leaves the watermark fixed.
        self.admission_share = admission_share
        self._shed_out: list[Response] = []       # shed since last flush
        # window purity telemetry (packing="policy" only): windows are
        # pure by construction; `mixed` staying 0 is the invariant the
        # serving bench gates (DESIGN.md §8)
        self.packing_stats = {"windows": 0, "cold": 0, "hot": 0, "mixed": 0}
        # time from flush start to the first response handed back (the
        # streaming mode's headline telemetry; tracked for FIFO too)
        self.first_response_s: float | None = None
        self._flush_t0: float = 0.0
        self._clock = engine._clock
        # observability (DESIGN.md §9): memoized per-response latency
        # histogram handle; resolved lazily so installing the facade
        # after scheduler construction still works. None while disabled.
        self._lat_hist = None

    @classmethod
    def from_config(cls, engine, config: ServeConfig, *,
                    fallback: Callable[[Request], int] | None = None,
                    prior: Callable[[Request], float] | None = None,
                    admission_share: Callable[[], float] | None = None
                    ) -> "MicrobatchScheduler":
        """Build the scheduler from the one ``ServeConfig`` facade
        (DESIGN.md §8) — the supported construction path."""
        return cls(engine, fallback=fallback,
                   pipeline_depth=config.pipeline_depth,
                   completion_mode=config.completion_mode,
                   packing=config.packing, prior=prior,
                   admission_limit=config.admission_limit,
                   admission_soft_ratio=config.admission_soft_ratio,
                   batching=config.batching,
                   admission_share=admission_share)

    # -- admission ------------------------------------------------------
    def submit(self, req: Request) -> Response | None:
        """Enqueue one request. With admission control enabled the
        overload rules run first; a shed request is answered *here* —
        the SHED ``Response`` is returned (and re-delivered in the next
        ``flush`` output, so callers that only collect flush results
        still see every submission exactly once)."""
        if req.t_enq == 0.0:
            req.t_enq = self._clock()   # the deadline/latency anchor
        self.admission.submitted += 1
        if self.admission_limit:
            action, reason = self._admit(req)
            if action == "shed":
                return self._shed(req, reason)
            if action == "degrade":
                self._degrade(req, reason)
        self.admission.admitted += 1
        if self.packing == "policy":
            # the label sticks to the REQUEST so window purity is
            # measured from the rows actually dispatched together, not
            # from which queue a chunk was drawn from (a cross-queue
            # mixing bug must show up as `mixed`, not be defined away)
            req._pack_class = self._classify(req)
            (self.cold if req._pack_class == "cold"
             else self.queue).append(req)
        else:
            self.queue.append(req)
        return None

    # -- overload admission control (DESIGN.md §10) ---------------------
    def _admit(self, req: Request) -> tuple[str, str | None]:
        """Admission decision: ``("admit"|"degrade"|"shed", reason)``.
        Hard bound first (queue full always sheds — a degrade cannot
        bound memory), then the soft watermark and deadline-feasibility
        rules, both of which resolve through the request's ``on_miss``
        vocabulary: ``fallback`` degrades to local-only, ``reject``
        sheds."""
        depth = self._qsize()
        if depth >= self.admission_limit:
            return "shed", "queue_full"
        pol = (req.policy if req.policy is not None
               else self.engine.default_policy)
        on_miss = pol.on_miss if pol is not None else "fallback"
        miss = "shed" if on_miss == "reject" else "degrade"
        if depth >= self._soft_watermark():
            return miss, "overload"
        if pol is not None and pol.deadline_s is not None:
            wait = self._queue_wait_estimate(depth)
            if wait is not None:
                remaining = pol.deadline_s - (self._clock() - req.t_enq)
                est = (self.engine.router.min_latency_estimate(
                           max_cost=pol.cost_cap,
                           default_cost=self.engine.cost
                           .remote_cost_per_request)
                       if pol.escalation != "never" else None)
                if wait + (est or 0.0) > remaining:
                    # a local-only row that already can't make it only
                    # sheds (degrading is a no-op for it)
                    if est is None and miss == "degrade":
                        return "admit", None
                    return miss, "deadline"
        return "admit", None

    def _soft_watermark(self) -> int:
        """Soft admission watermark, scaled by the replica's cluster
        budget share when one is wired (DESIGN.md §12). The scale is
        clamped to [0.25, 4.0] so a pathological share can neither
        disable soft admission nor override the hard bound, and the
        result stays >= 1 and <= admission_limit - 1 (the hard bound
        must remain reachable only through genuine queue growth)."""
        soft = self.admission_soft
        if self.admission_share is None or not soft:
            return soft
        scale = min(max(float(self.admission_share()), 0.25), 4.0)
        soft = max(1, int(round(soft * scale)))
        return min(soft, max(self.admission_limit - 1, 1))

    def _queue_wait_estimate(self, depth: int) -> float | None:
        """Expected time for a request joining behind ``depth`` queued
        rows to clear its own window: full windows ahead of it plus its
        own, priced at the engine's measured window-service EMA. None
        until a window has committed (no estimate beats a fabricated
        one).

        Continuous batching (DESIGN.md §11) prices against SLOT occupancy
        instead: rows already holding slots are ahead of the queue, but
        up to ``pipeline_depth`` cohorts drain concurrently, so the
        window count amortizes over the pipeline width — an idle slot map
        collapses the estimate to one window's EMA, a saturated one
        degrades toward the windowed bound."""
        ema = self.engine.stats.window_service_ema_s
        if ema is None:
            return None
        b = self.engine.batch_size
        if self._slots is not None:
            rows_ahead = depth + self._slots.occupied
            return ema * (1.0 + (rows_ahead // b) / self.pipeline_depth)
        return (depth // b + 1) * ema

    def _shed(self, req: Request, reason: str) -> Response:
        """Refuse ``req`` at admission: answer immediately from the
        fallback with the SHED disposition ($0, never enqueued). The
        response is recorded now and re-delivered by the next flush
        (zero-silent-drop: flush output covers every submission)."""
        self.admission.shed += 1
        self.admission.shed_reasons[reason] = (
            self.admission.shed_reasons.get(reason, 0) + 1)
        pred = self.fallback(req) if self.fallback else -1
        now = self._clock()
        resp = Response(req.uid, pred, "shed", 0.0, 0.0,
                        latency_s=now - req.t_enq, disposition=SHED,
                        backend=None, cost=0.0, queue_s=0.0)
        self.responses[resp.uid] = resp
        self._shed_out.append(resp)
        obs = self.engine.observability
        if obs is not None:
            obs.metrics.counter("cascade_admission_shed_total",
                                reason=reason).inc()
            if obs.events is not None:
                obs.events.emit(EV_ADMISSION_SHED, uid=req.uid,
                                reason=reason, depth=self._qsize(),
                                limit=self.admission_limit)
        return resp

    def _degrade(self, req: Request, reason: str) -> None:
        """Admit ``req`` pinned to the local tier: its policy is replaced
        with an ``escalation="never"`` copy, so the engine serves it as
        POLICY_LOCAL — load is shed from the *remote* tier while the
        request still gets its local answer (the ``on_miss="fallback"``
        arm of the overload rules)."""
        self.admission.degraded += 1
        self.admission.degrade_reasons[reason] = (
            self.admission.degrade_reasons.get(reason, 0) + 1)
        base = (req.policy if req.policy is not None
                else self.engine.default_policy) or RequestPolicy()
        req.policy = dataclasses.replace(base, escalation="never")
        obs = self.engine.observability
        if obs is not None:
            obs.metrics.counter("cascade_admission_degraded_total",
                                reason=reason).inc()
            if obs.events is not None:
                obs.events.emit(EV_ADMISSION_DEGRADE, uid=req.uid,
                                reason=reason, depth=self._qsize(),
                                limit=self.admission_limit)

    def _drain_shed(self) -> list[Response]:
        out, self._shed_out = self._shed_out, []
        return out

    def _can_escalate(self, pol: RequestPolicy, t_enq: float) -> bool:
        """Submit-time feasibility mirror of the engine's policy pass:
        could this request possibly be served remotely? (The engine
        re-checks authoritatively at the window's host half.)"""
        if pol.escalation == "never":
            return False
        router = self.engine.router
        default_cost = self.engine.cost.remote_cost_per_request
        if pol.cost_cap is not None:
            mc = router.min_available_cost(default_cost)
            if mc is None or mc > pol.cost_cap + 1e-12:
                return False
        if pol.deadline_s is not None:
            est = router.min_latency_estimate(max_cost=pol.cost_cap,
                                              default_cost=default_cost)
            remaining = pol.deadline_s - (self._clock() - t_enq)
            if est is None or est > remaining:
                return False
        return True

    def _classify(self, req: Request) -> str:
        """HOT (may ride a remote round trip) vs COLD (stays local):
        policy feasibility first, then the escalation-likelihood prior."""
        pol = (req.policy if req.policy is not None
               else self.engine.default_policy)
        if pol is not None and not pol.is_default:
            if not self._can_escalate(pol, req.t_enq):
                return "cold"
            if pol.escalation == "always":
                return "hot"
        if self.prior is not None:
            return ("hot" if self.prior(req) >= self.prior_threshold
                    else "cold")
        return "hot"

    # -- chunking -------------------------------------------------------
    def _qsize(self) -> int:
        return len(self.queue) + len(self.cold)

    def _pad(self, reqs: list[Request]) -> list[Request]:
        b = self.engine.batch_size
        return reqs + [reqs[-1]] * (b - len(reqs))

    def _next_chunk(self) -> tuple[list[Request], dict[str, Any]]:
        b = self.engine.batch_size
        # cold windows drain first (deadline-pinned / trusted-local rows
        # must not queue behind remote round trips) and classes never
        # share a window — short tails are padded, not mixed (§8)
        src = self.cold if self.cold else self.queue
        chunk = [src.popleft() for _ in range(min(b, len(src)))]
        if self.packing == "policy":
            classes = {getattr(r, "_pack_class", "hot") for r in chunk}
            self.packing_stats["windows"] += 1
            self.packing_stats[classes.pop() if len(classes) == 1
                               else "mixed"] += 1
        padded = self._pad(chunk)
        batch = {
            "local": _stack([r.local_input for r in padded]),
            "remote": _stack([r.remote_input for r in padded]),
        }
        return chunk, batch

    @staticmethod
    def _serve_args(chunk: list[Request]) -> dict[str, Any]:
        """policies/t_enq kwargs for the engine (omitted when no row in
        the chunk carries a policy — the unpolicied fast path)."""
        if all(r.policy is None for r in chunk):
            return {"t_enq": [r.t_enq for r in chunk]}
        return {"policies": [r.policy for r in chunk],
                "t_enq": [r.t_enq for r in chunk]}

    # -- hand-back ------------------------------------------------------
    def _record(self, resp: Response, out: list[Response]) -> None:
        """Reorder-free hand-back: key by uid, never buffer for order."""
        if self.first_response_s is None:
            self.first_response_s = self._clock() - self._flush_t0
        self.responses[resp.uid] = resp
        out.append(resp)
        obs = self.engine.observability
        if obs is not None:
            h = self._lat_hist
            if h is None:
                h = self._lat_hist = obs.metrics.histogram(
                    "cascade_request_latency_seconds")
            h.observe(resp.latency_s)

    # -- per-request trace spans (DESIGN.md §9) ------------------------
    def _emit_span(self, resp: Response, req: Request, t_disp: float,
                   tr: dict, window: int, handback: float, *,
                   remote: bool, hit: bool,
                   emit_ts: float | None = None) -> None:
        """Assemble one request's span timeline from its window's stage
        stamps. Stages are appended in canonical ``SPAN_STAGES`` order —
        enqueue → pack/join → dispatch → gate → route → cache_hit/remote
        → commit → emit → hand-back — and each stamp was taken later than
        the one before it, so timestamps are nondecreasing by
        construction. ``commit`` is present whenever the window committed
        before the row was handed back (always true for sync/FIFO drains;
        absent for streaming rows emitted ahead of their window's
        commit). Continuous-batching rows join a slot instead of packing
        a window (``join`` stage) and trusted-local rows surfaced at gate
        time carry an ``emit`` stage (DESIGN.md §11)."""
        stages = [["enqueue", req.t_enq], [self._pack_stage, t_disp],
                  ["dispatch", tr["dispatch"]]]
        if "gate" in tr:
            stages.append(["gate", tr["gate"]])
        if (remote or hit) and "route" in tr:
            stages.append(["route", tr["route"]])
            if hit:
                # the lookup happened inside the gate→route interval;
                # the route stamp is its completion time
                stages.append(["cache_hit", tr["route"]])
        if remote and "remote" in tr:
            stages.append(["remote", tr["remote"]])
        if "commit" in tr:
            stages.append(["commit", tr["commit"]])
        if emit_ts is not None:
            stages.append(["emit", emit_ts])
        stages.append(["handback", handback])
        self.engine.observability.trace.emit({
            "uid": resp.uid, "window": window,
            "disposition": resp.disposition, "backend": resp.backend,
            "cost": resp.cost, "source": resp.source,
            "t_local_gate": tr.get("t_local"),
            "t_remote_gate": tr.get("t_remote"),
            "stages": stages,
        })

    def _tracing(self) -> bool:
        obs = self.engine.observability
        return obs is not None and obs.trace is not None

    def _route(self, chunk: list[Request], res: dict,
               t_disp: float) -> list[Response]:
        out: list[Response] = []
        now = self._clock()
        dispo = res.get("disposition")
        backend = res.get("backend")
        cost = res.get("cost")
        trace = res.get("trace") if self._tracing() else None
        for i, req in enumerate(chunk):
            escalated = bool(res["escalated"][i])
            accepted = bool(res["accepted"][i])
            if not escalated:
                src = "local"
                pred = int(res["local_pred"][i])
            elif accepted:
                src = "remote"
                pred = int(res["prediction"][i])
            else:
                src = "fallback"
                self.fallbacks += 1
                pred = (self.fallback(req) if self.fallback
                        else -1)  # "raise Exception" analogue
            if dispo is not None:
                d, b, c = dispo[i], backend[i], float(cost[i])
            else:
                # fused path: derive attribution from the routing masks
                d = LOCAL if not escalated else (REMOTE if accepted
                                                 else REJECTED)
                b = None
                c = (self.engine.cost.remote_cost_per_request
                     if escalated else 0.0)
            resp = Response(req.uid, pred, src,
                            float(res["local_conf"][i]),
                            float(res["remote_conf"][i]),
                            latency_s=now - req.t_enq,
                            disposition=d, backend=b, cost=c,
                            queue_s=t_disp - req.t_enq)
            self._record(resp, out)
            if trace is not None:
                self._emit_span(resp, req, t_disp, trace["stages"],
                                trace["window"], now,
                                remote=i in trace["remote_rows"],
                                hit=i in trace["hit_rows"])
        return out

    def flush(self, pipeline_depth: int | None = None) -> list[Response]:
        depth = (self.pipeline_depth if pipeline_depth is None
                 else max(1, pipeline_depth))
        self.first_response_s = None
        self._flush_t0 = self._clock()
        # requests shed at admission since the last flush lead the output
        # (they were answered at submit; re-delivering here keeps "flush
        # returns every submission exactly once" true for every caller)
        shed = self._drain_shed()
        if self.engine.transport is not None:
            if self.batching == "continuous":
                return shed + self._flush_continuous(depth)
            if self.completion_mode == "streaming":
                return shed + self._flush_streaming(depth)
            if depth > 1:
                return shed + self._flush_pipelined(depth)
        out: list[Response] = shed
        while self._qsize():
            chunk, batch = self._next_chunk()
            t_disp = self._clock()
            res = self.engine.serve(batch, real_rows=len(chunk),
                                    **self._serve_args(chunk))
            out.extend(self._route(chunk, res, t_disp))
        return out

    def _check_exclusive_engine(self) -> None:
        if self.engine.inflight:
            # windows begun outside this flush (or left over from an
            # aborted one) would silently pair with the wrong requests
            raise RuntimeError(f"engine has {self.engine.inflight} "
                               "in-flight windows not owned by this "
                               "scheduler; drain complete_next() first")

    def _flush_pipelined(self, depth: int) -> list[Response]:
        """Overlapped drain: keep up to ``depth`` microbatches in flight,
        completing the oldest (FIFO) whenever the window is full or the
        queue is empty. Responses come back in submission order."""
        self._check_exclusive_engine()
        out: list[Response] = []
        pending: deque[tuple[list[Request], float]] = deque()
        while self._qsize() or pending:
            while self._qsize() and len(pending) < depth:
                chunk, batch = self._next_chunk()
                t_disp = self._clock()
                self.engine.begin_serve(batch, real_rows=len(chunk),
                                        **self._serve_args(chunk))
                pending.append((chunk, t_disp))
            # about to block on the oldest window: unpark the double-
            # buffered newest one first, so its remote submission (and in
            # streaming mode its trusted-local rows) never waits out a
            # full drain
            self.engine.flush_dispatch()
            res = self.engine.complete_next()
            chunk, t_disp = pending.popleft()
            out.extend(self._route(chunk, res, t_disp))
        return out

    # -- streaming completion mode (DESIGN.md §7) ----------------------
    def _flush_streaming(self, depth: int) -> list[Response]:
        """Per-request drain: locally-trusted rows hand back as soon as
        their window's host half runs (confidence gate cleared); escalated
        rows hand back when their window finalizes. With static thresholds
        windows finalize out of submission order via ``complete_ready``;
        with a live controller the drain uses ``complete_next`` so the
        begin/commit interleaving — hence every threshold each window
        sees — reproduces the FIFO drain exactly. Either way the engine
        commits accounting in submission order, so billing, per-backend
        attribution and controller state are bitwise-identical to FIFO."""
        self._check_exclusive_engine()
        out: list[Response] = []
        windows: dict[int, _Window] = {}        # seq -> bookkeeping
        fifo_drain = self.engine.controller is not None

        def emit_ready_locals():
            for w in windows.values():
                if not w.host_emitted and w.fl.host_done:
                    self._emit_locals(w, out)

        def emit_window(seq, res):
            w = windows.pop(seq)
            if not w.host_emitted:      # host half ran at the finalize
                self._emit_locals(w, out)
            self._emit_escalated(w, res, out)

        while self._qsize() or windows:
            while self._qsize() and self.engine.inflight < depth:
                chunk, batch = self._next_chunk()
                t_disp = self._clock()
                fl = self.engine.begin_serve(batch, real_rows=len(chunk),
                                             **self._serve_args(chunk))
                windows[fl.seq] = _Window(chunk, fl, t_disp)
                emit_ready_locals()     # previous window's host half ran
                if not fifo_drain:
                    for seq, res in self.engine.complete_ready():
                        emit_window(seq, res)
            # about to block: unpark the newest window so its remote
            # round trip starts and its trusted-local rows emit NOW
            # instead of after the next drain wave
            self.engine.flush_dispatch()
            emit_ready_locals()
            if not windows:
                break
            if fifo_drain:
                res = self.engine.complete_next()
                emit_window(min(windows), res)      # FIFO = lowest seq
            else:
                for seq, res in self.engine.complete_ready(block=True):
                    emit_window(seq, res)
        return out

    # -- continuous batching (DESIGN.md §11) ---------------------------
    def _flush_continuous(self, depth: int) -> list[Response]:
        """Slot-map serve loop: dispatch cohorts join free slots of a
        persistent ``batch_size × depth`` padded batch and every row
        leaves its slot the moment its response is handed back. Two
        deltas against the streaming window drain, neither of which
        touches what is served:

        * each cohort's host half runs IMMEDIATELY after its dispatch
          (``flush_dispatch`` after every ``begin_serve`` instead of only
          before blocking), so a trusted-local row's service time is the
          gate time — the in-kernel early emit lands the gate triple on
          the host as the scoring pass clears, and the hand-back happens
          before the next cohort is even formed;
        * without a live controller, admission is keyed on FREE SLOTS
          rather than in-flight window count: a cohort of trusted locals
          returns its slots at gate time and the loop admits the next
          cohort while earlier escalations are still on the wire (the
          row-level backpressure bound is the slot capacity, not
          ``depth`` windows).

        Cohorts are still drawn cold-first exactly like ``_next_chunk``
        (hot/cold are slot-priority classes; the never-mixed invariant is
        per dispatch cohort), and the engine still commits accounting in
        submission order — so predictions, billing and controller
        observations are bitwise-identical to ``batching="window"``. With
        a live controller the admission bound stays ``depth`` in-flight
        windows so the begin/commit interleaving (hence every threshold
        snapshot) reproduces the windowed streaming drain exactly. One
        caveat matches the documented streaming-vs-FIFO one: because host
        halves run one begin EARLIER than the windowed drain, a response
        cache can resolve lookups against a younger cache state — billing
        identity is exact for cacheless runs (DESIGN.md §11)."""
        self._check_exclusive_engine()
        out: list[Response] = []
        windows: dict[int, _Window] = {}        # seq -> bookkeeping
        fifo_drain = self.engine.controller is not None
        slots = self._slots
        slots.capacity = max(1, self.engine.batch_size * depth)

        def sync_slots(w: _Window) -> None:
            freed = len(w.emitted) - w.left
            if freed > 0:
                slots.leave(freed)
                w.left = len(w.emitted)

        def emit_ready_locals():
            for w in windows.values():
                if not w.host_emitted and w.fl.gate_done:
                    self._emit_locals(w, out)
                    sync_slots(w)
                elif (w.host_emitted and not w.early_emitted
                        and w.fl.host_done and w.fl.early):
                    # pre-decided cache hits surface at the submit half,
                    # AFTER the gate-time local emission pass
                    self._emit_early_hits(w, out)
                    sync_slots(w)

        def emit_window(seq, res):
            w = windows.pop(seq)
            if not w.host_emitted:      # host half ran at the finalize
                self._emit_locals(w, out)
            self._emit_escalated(w, res, out)
            sync_slots(w)

        def admissible() -> bool:
            if fifo_drain:
                return self.engine.inflight < depth
            return slots.free >= self.engine.batch_size

        while self._qsize() or windows:
            while self._qsize() and admissible():
                chunk, batch = self._next_chunk()
                t_disp = self._clock()
                fl = self.engine.begin_serve(batch, real_rows=len(chunk),
                                             **self._serve_args(chunk))
                windows[fl.seq] = _Window(chunk, fl, t_disp)
                slots.join(len(chunk))
                # run this cohort's GATE half NOW (triple fetch + policy
                # pass only — the early-emitted triple is already on the
                # host) and hand its trusted locals back before the
                # escalations' cache/routing/remote submission even runs;
                # flush_dispatch then completes the submit half
                self.engine.flush_gate()
                emit_ready_locals()
                self.engine.flush_dispatch()
                emit_ready_locals()
                if not fifo_drain:
                    for seq, res in self.engine.complete_ready():
                        emit_window(seq, res)
            self.engine.flush_dispatch()
            emit_ready_locals()
            if not windows:
                break
            if fifo_drain:
                res = self.engine.complete_next()
                emit_window(min(windows), res)      # FIFO = lowest seq
            else:
                for seq, res in self.engine.complete_ready(block=True):
                    emit_window(seq, res)
        return out

    def _emit_locals(self, w: _Window, out: list[Response]) -> None:
        """Hand back every row decidable at the window's host half: the
        locally-trusted rows (gate cleared), policy/deadline downgrades
        (served locally by construction — DESIGN.md §8) and pre-decided
        cache hits (``fl.early``; no remote round trip to wait for — the
        §8 latency fix: their hand-back no longer includes the window
        drain)."""
        fl = w.fl
        now = self._clock()
        tr = fl.tr if self._tracing() else None
        esc = {int(j) for j in fl.idx} if fl.k else set()
        for i, req in enumerate(w.chunk):
            if i in esc or i in w.emitted:
                continue
            resp = Response(req.uid, int(fl.local_pred[i]), "local",
                            float(fl.conf[i]), float("inf"),
                            latency_s=now - req.t_enq,
                            disposition=fl.downgraded.get(i, LOCAL),
                            queue_s=w.t_disp - req.t_enq)
            self._record(resp, out)
            if tr is not None:
                self._emit_span(resp, req, w.t_disp, tr, fl.seq, now,
                                remote=False, hit=False,
                                emit_ts=(now if self._slots is not None
                                         else None))
            w.emitted.add(i)
        w.host_emitted = True
        if fl.host_done:
            # window/streaming drains run the whole host half at once, so
            # pre-decided cache hits are known here; the continuous loop
            # emits at GATE time (before the submit half) and offers the
            # hits in a later ``emit_ready_locals`` pass instead
            self._emit_early_hits(w, out)

    def _emit_early_hits(self, w: _Window, out: list[Response]) -> None:
        """Hand back the window's pre-decided cache hits (``fl.early`` —
        no remote round trip to wait for; the §8 latency fix)."""
        fl = w.fl
        now = self._clock()
        tr = fl.tr if self._tracing() else None
        for e in fl.early:
            i = e["row"]
            if i in w.emitted or i >= len(w.chunk):
                continue
            req = w.chunk[i]
            if e["accepted"]:
                resp = Response(req.uid, e["prediction"], "remote",
                                float(fl.conf[i]), e["remote_conf"],
                                latency_s=now - req.t_enq,
                                disposition=CACHED, backend=e["backend"],
                                cost=e["cost"],
                                queue_s=w.t_disp - req.t_enq)
            else:
                self.fallbacks += 1
                pred = self.fallback(req) if self.fallback else -1
                resp = Response(req.uid, pred, "fallback",
                                float(fl.conf[i]), e["remote_conf"],
                                latency_s=now - req.t_enq,
                                disposition=REJECTED, backend=e["backend"],
                                cost=e["cost"],
                                queue_s=w.t_disp - req.t_enq)
            self._record(resp, out)
            if tr is not None:
                self._emit_span(resp, req, w.t_disp, tr, fl.seq, now,
                                remote=False, hit=True)
            w.emitted.add(i)
        w.early_emitted = True

    def _emit_escalated(self, w: _Window, res: dict,
                        out: list[Response]) -> None:
        """Hand back the window's escalated rows once finalized."""
        fl = w.fl
        now = self._clock()
        trace = res.get("trace") if self._tracing() else None
        for j in fl.idx:
            i = int(j)
            if i in w.emitted:
                continue                # handed back at the host half
            req = w.chunk[i]            # idx only covers genuine rows
            d, b, c = (res["disposition"][i], res["backend"][i],
                       float(res["cost"][i]))
            if bool(res["accepted"][i]):
                resp = Response(req.uid, int(res["prediction"][i]),
                                "remote", float(res["local_conf"][i]),
                                float(res["remote_conf"][i]),
                                latency_s=now - req.t_enq,
                                disposition=d, backend=b, cost=c,
                                queue_s=w.t_disp - req.t_enq)
            else:
                self.fallbacks += 1
                pred = self.fallback(req) if self.fallback else -1
                resp = Response(req.uid, pred, "fallback",
                                float(res["local_conf"][i]),
                                float(res["remote_conf"][i]),
                                latency_s=now - req.t_enq,
                                disposition=d, backend=b, cost=c,
                                queue_s=w.t_disp - req.t_enq)
            self._record(resp, out)
            if trace is not None:
                self._emit_span(resp, req, w.t_disp, trace["stages"],
                                trace["window"], now,
                                remote=i in trace["remote_rows"],
                                hit=i in trace["hit_rows"])
            w.emitted.add(i)

"""Greedy generation over the unified model API (prefill + decode loop).

Returns per-token likelihoods of the chosen tokens so the sequence
supervisors (seq_min_likelihood — the paper's QA reducer) apply directly:
this is the generative analogue of the classification cascade.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import decode_step, make_cache, prefill


def greedy_generate(cfg: ModelConfig, params, prompt_batch: dict,
                    max_new_tokens: int, max_len: int | None = None):
    """prompt_batch: {"tokens": [B, T]} (or {"embeds": ...} for VLM/audio).
    Returns (tokens [B, max_new_tokens], likelihood [B, max_new_tokens])."""
    if "tokens" in prompt_batch:
        b, t = prompt_batch["tokens"].shape
    else:
        b, t = prompt_batch["embeds"].shape[:2]
    max_len = max_len or (t + max_new_tokens)

    logits, cache = prefill(cfg, params, prompt_batch)
    full = make_cache(cfg, b, max_len)

    def graft(dst, src):
        # prefill caches cover [0, t); copy into the serving cache
        def cp(d, s):
            if d.shape == s.shape:
                return s
            idx = (slice(None), slice(None), slice(0, s.shape[2]))
            return d.at[idx].set(s) if d.ndim >= 3 else s
        return jax.tree.map(cp, dst, src)

    cache = graft(full, cache)

    @jax.jit
    def step(carry, _):
        cache, tok, pos = carry
        logits, cache = decode_step(cfg, params, tok, cache, pos)
        probs = jax.nn.softmax(logits, -1)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        lik = jnp.max(probs, -1)
        return (cache, nxt, pos + 1), (nxt, lik)

    tok0 = jnp.argmax(logits, -1).astype(jnp.int32)
    lik0 = jnp.max(jax.nn.softmax(logits, -1), -1)
    toks, liks = [tok0], [lik0]
    carry = (cache, tok0, jnp.int32(t))
    for _ in range(max_new_tokens - 1):
        carry, (nxt, lik) = step(carry, None)
        toks.append(nxt)
        liks.append(lik)
    return jnp.stack(toks, 1), jnp.stack(liks, 1)

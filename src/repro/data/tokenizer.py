"""Hash tokenizer with input-domain reduction (paper §4.1).

The paper's local models use a reduced input domain: a small dictionary
(2000 most frequent words) and clipped sequence length (IMDB: 100 words).
`HashTokenizer` is a deterministic, dependency-free stand-in: words hash
into a full-size id space for the remote model, and `reduce()` maps ids
into the local model's reduced dictionary (out-of-dict -> UNK), mirroring
the local/remote asymmetry.
"""

from __future__ import annotations

import numpy as np

PAD, UNK = 0, 1


class HashTokenizer:
    def __init__(self, vocab_size: int):
        assert vocab_size > 2
        self.vocab_size = vocab_size

    def encode(self, text: str, max_len: int) -> np.ndarray:
        ids = [(hash(w) % (self.vocab_size - 2)) + 2
               for w in text.lower().split()][:max_len]
        out = np.full((max_len,), PAD, np.int32)
        out[: len(ids)] = ids
        return out

    def encode_batch(self, texts: list[str], max_len: int) -> np.ndarray:
        return np.stack([self.encode(t, max_len) for t in texts])


def reduce_domain(tokens: np.ndarray, local_vocab: int,
                  local_len: int) -> np.ndarray:
    """Input-domain reduction: clip length, map out-of-dict ids to UNK.
    Deterministic (id-order) frequency proxy: ids < local_vocab survive."""
    clipped = tokens[..., :local_len]
    return np.where((clipped >= local_vocab) & (clipped != PAD), UNK,
                    clipped).astype(np.int32)

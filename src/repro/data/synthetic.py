"""Synthetic analogues of the paper's four case studies (DESIGN.md §6).

No internet access in this environment, so IMDB / GitHub-issues / ImageNet /
SQuADv2 are reproduced as *calibrated generative processes* that preserve
the statistical structure the paper's claims rest on:

  * a per-example latent difficulty z ~ N(0, 1);
  * local tier:  correct ~ Bernoulli(sigmoid(a_l - b_l * z));
  * remote tier: correct ~ Bernoulli(sigmoid(a_r - b_r * z + c * w)),
    where w ~ N(0,1) is a *complementarity* component independent of z —
    inputs hard for the local model but easy for the remote one and vice
    versa (the paper's source of superaccurate performance);
  * supervisor confidences are noisy monotone functions of the same
    latents, so MaxSoftmax-style supervision is informative but imperfect;
  * a_l, a_r are calibrated so the marginal accuracies match Table 1.

An `invalid_rate` adds SQuADv2-style unanswerable inputs: neither tier can
be correct and both tiers' confidence distributions shift down (RQ2).
"""

from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np


def _sigmoid(x):
    return 1.0 / (1.0 + np.exp(-x))


def calibrate_intercept(target_acc: float, slope: float, comp: float,
                        n: int = 200_000, seed: int = 0) -> float:
    """Find a s.t. E_z,w[sigmoid(a - slope*z + comp*w)] == target_acc."""
    rng = np.random.default_rng(seed)
    z = rng.standard_normal(n)
    w = rng.standard_normal(n)
    lo, hi = -10.0, 10.0
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        acc = float(np.mean(_sigmoid(mid - slope * z + comp * w)))
        if acc < target_acc:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


@dataclass(frozen=True)
class CaseStudy:
    name: str
    metric: str                   # accuracy | micro_f1 | exact_match
    local_acc: float              # Table 1 values
    remote_acc: float
    num_classes: int
    difficulty_slope_local: float = 2.0
    difficulty_slope_remote: float = 1.2
    complementarity: float = 0.0  # >0 -> superaccuracy possible
    conf_noise: float = 0.6       # supervisor imperfection
    invalid_rate: float = 0.0     # unanswerable fraction (RQ2)
    seed: int = 0


# Table 1 calibration. IMDB and SQuAD get complementarity (the paper found
# superaccuracy exactly there); Issues and ImageNet get ~none.
IMDB = CaseStudy("imdb", "accuracy", 0.794, 0.895, 2,
                 complementarity=0.9, seed=1)
ISSUES = CaseStudy("issues", "micro_f1", 0.711, 0.823, 3,
                   complementarity=0.12, seed=2)
IMAGENET = CaseStudy("imagenet", "accuracy", 0.678, 0.852, 1000,
                     complementarity=0.10, seed=3)
SQUADV2 = CaseStudy("squadv2", "exact_match", 0.280, 0.308, 0,  # free text
                    difficulty_slope_local=1.6,
                    complementarity=0.55, conf_noise=0.8, seed=4)
SQUADV2_ALL = replace(SQUADV2, name="squadv2_all", invalid_rate=0.33, seed=5)

CASE_STUDIES = {c.name: c for c in (IMDB, ISSUES, IMAGENET, SQUADV2,
                                    SQUADV2_ALL)}


@dataclass
class CascadeSample:
    """Per-input simulation outputs consumed by RQ1/RQ2 evaluation."""
    local_correct: np.ndarray    # [n] 0/1
    remote_correct: np.ndarray   # [n] 0/1
    local_conf: np.ndarray       # [n] 1st-level supervisor confidence
    remote_conf: np.ndarray      # [n] 2nd-level supervisor confidence
    invalid: np.ndarray          # [n] bool


def sample_case_study(cs: CaseStudy, n: int, seed: int | None = None
                      ) -> CascadeSample:
    rng = np.random.default_rng(cs.seed if seed is None else seed)
    z = rng.standard_normal(n)                  # shared difficulty
    w = rng.standard_normal(n)                  # complementarity direction
    invalid = rng.random(n) < cs.invalid_rate

    a_l = calibrate_intercept(cs.local_acc, cs.difficulty_slope_local,
                              cs.complementarity)
    a_r = calibrate_intercept(cs.remote_acc, cs.difficulty_slope_remote,
                              cs.complementarity)

    p_loc = _sigmoid(a_l - cs.difficulty_slope_local * z
                     - cs.complementarity * w)
    p_rem = _sigmoid(a_r - cs.difficulty_slope_remote * z
                     + cs.complementarity * w)
    local_correct = (rng.random(n) < p_loc) & ~invalid
    remote_correct = (rng.random(n) < p_rem) & ~invalid

    # supervisor confidences: noisy monotone views of the same likelihoods,
    # shifted down for invalid inputs (both models are "confused").
    def conf(p, noise_scale, invalid_shift):
        raw = (np.log(p / (1 - p + 1e-9))
               + noise_scale * rng.standard_normal(n)
               - invalid_shift * invalid)
        return _sigmoid(raw)

    local_conf = conf(p_loc, cs.conf_noise, 1.5)
    remote_conf = conf(p_rem, cs.conf_noise, 1.5)
    return CascadeSample(local_correct.astype(np.float64),
                         remote_correct.astype(np.float64),
                         local_conf, remote_conf, invalid)


# --------------------------------------------------------------------------
# real-model task: teacher-labelled token classification, learnable by the
# in-framework surrogate + remote models (examples / integration tests)
# --------------------------------------------------------------------------

def make_classification_task(seed: int, *, n: int, vocab: int, seq_len: int,
                             num_classes: int, label_noise: float = 0.05):
    """Token sequences whose label is a (noisy) linear-teacher readout of
    bag-of-token features — small models learn it partially, bigger models
    better; mirrors the local/remote accuracy gap structurally."""
    rng = np.random.default_rng(seed)
    tokens = rng.integers(1, vocab, size=(n, seq_len), dtype=np.int32)
    teacher = rng.standard_normal((vocab, num_classes)) / np.sqrt(seq_len)
    feats = np.zeros((n, num_classes))
    for c in range(0, seq_len, 64):
        chunk = tokens[:, c:c + 64]
        feats += teacher[chunk].sum(axis=1)
    # second-order term makes the task non-trivial for linear/small models
    pair = teacher[tokens[:, ::2]].sum(1) * teacher[tokens[:, 1::2]].sum(1)
    logits = feats + 0.5 * pair
    labels = np.argmax(logits, axis=-1).astype(np.int32)
    flip = rng.random(n) < label_noise
    labels[flip] = rng.integers(0, num_classes, size=int(flip.sum()))
    margin = np.sort(logits, axis=-1)
    difficulty = -(margin[:, -1] - margin[:, -2])
    return tokens, labels, difficulty

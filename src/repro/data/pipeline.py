"""Data pipeline: deterministic, shardable batching for training/serving.

Host-side numpy pipeline feeding jit'd steps; `shard_batch` places a global
batch onto the mesh's batch axes (("pod",) "data") so pjit consumes it
without resharding.
"""

from __future__ import annotations

from typing import Iterator

import jax
import numpy as np


class BatchIterator:
    """Infinite shuffled epochs over an array dict, fixed batch size."""

    def __init__(self, data: dict[str, np.ndarray], batch_size: int,
                 seed: int = 0, drop_remainder: bool = True):
        n = len(next(iter(data.values())))
        assert all(len(v) == n for v in data.values())
        assert drop_remainder
        self.data, self.n, self.bs = data, n, batch_size
        self.rng = np.random.default_rng(seed)

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        while True:
            order = self.rng.permutation(self.n)
            for i in range(0, self.n - self.bs + 1, self.bs):
                idx = order[i:i + self.bs]
                yield {k: v[idx] for k, v in self.data.items()}


def shard_batch(batch: dict[str, np.ndarray], mesh,
                batch_axes: tuple[str, ...]) -> dict[str, jax.Array]:
    """Place a host batch on the mesh, batch dim sharded over batch_axes."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    out = {}
    for k, v in batch.items():
        spec = P(batch_axes, *([None] * (v.ndim - 1)))
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out

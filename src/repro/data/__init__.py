"""Data substrate: synthetic case studies, tokenizer, pipeline."""

from repro.data.pipeline import BatchIterator, shard_batch
from repro.data.synthetic import (CASE_STUDIES, CascadeSample, CaseStudy,
                                  make_classification_task,
                                  sample_case_study)
from repro.data.tokenizer import HashTokenizer, reduce_domain

__all__ = ["CASE_STUDIES", "CaseStudy", "CascadeSample", "sample_case_study",
           "make_classification_task", "HashTokenizer", "reduce_domain",
           "BatchIterator", "shard_batch"]

"""Modality frontend STUBS (per assignment carve-out).

[audio] and [vlm] architectures specify the transformer BACKBONE only; the
mel-spectrogram + conv feature extractor (HuBERT) and the ViT encoder +
projector (Pixtral) are not implemented. These helpers produce the
embedding tensors such frontends would emit — with the right shape, dtype
and deterministic content for tests — so the backbone, cascade, sharding
and dry-run all operate on genuine inputs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def frontend_embeddings(cfg: ModelConfig, batch: int, seq_len: int,
                        seed: int = 0) -> jnp.ndarray:
    """Deterministic stand-in for frame (audio) / patch (vision) embeddings."""
    assert cfg.takes_embeddings, cfg.name
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (batch, seq_len, cfg.d_model))
    return x.astype(jnp.dtype(cfg.dtype))


def frontend_spec(cfg: ModelConfig, batch: int, seq_len: int):
    """ShapeDtypeStruct version for the dry-run (no allocation)."""
    return jax.ShapeDtypeStruct((batch, seq_len, cfg.d_model),
                                jnp.dtype(cfg.dtype))

"""Local surrogate models — the paper's "small, local" tier.

The paper's local models are tiny custom transformers (IMDB: 79k params,
one transformer block + pooling + two dense layers, dropout before the
dense layers). This module reproduces that recipe as a classifier factory
with *inference-time dropout support* so MC-Dropout and Ensemble
supervisors work (dropout layers can be kept live at prediction time).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.layers import (Params, dense, dense_params, gelu_mlp,
                                 gelu_mlp_params, layer_norm)


@dataclass(frozen=True)
class SurrogateConfig:
    name: str
    vocab_size: int           # input-domain-reduced dictionary
    max_len: int              # input-domain-reduced sequence clip
    d_model: int
    num_heads: int
    d_ff: int
    num_classes: int
    num_blocks: int = 1
    dropout: float = 0.1
    pool: str = "mean"        # mean | first
    norm_eps: float = 1e-5


def init_params(cfg: SurrogateConfig, key) -> Params:
    ks = jax.random.split(key, 4 + 2 * cfg.num_blocks)
    p: Params = {
        "embed": jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model))
                 * 0.02,
        "pos": jax.random.normal(ks[1], (cfg.max_len, cfg.d_model)) * 0.02,
        "blocks": [],
        "hidden": dense_params(ks[2], cfg.d_model, cfg.d_ff, jnp.float32,
                               bias=True),
        "out": dense_params(ks[3], cfg.d_ff, cfg.num_classes, jnp.float32,
                            bias=True),
    }
    blocks = []
    for i in range(cfg.num_blocks):
        k1, k2 = ks[4 + 2 * i], ks[5 + 2 * i]
        blocks.append({
            "ln1_w": jnp.ones((cfg.d_model,)), "ln1_b": jnp.zeros((cfg.d_model,)),
            "ln2_w": jnp.ones((cfg.d_model,)), "ln2_b": jnp.zeros((cfg.d_model,)),
            "wq": dense_params(k1, cfg.d_model, cfg.d_model, jnp.float32),
            "wk": dense_params(jax.random.fold_in(k1, 1), cfg.d_model,
                               cfg.d_model, jnp.float32),
            "wv": dense_params(jax.random.fold_in(k1, 2), cfg.d_model,
                               cfg.d_model, jnp.float32),
            "wo": dense_params(jax.random.fold_in(k1, 3), cfg.d_model,
                               cfg.d_model, jnp.float32),
            "mlp": gelu_mlp_params(k2, cfg.d_model, cfg.d_ff, jnp.float32),
        })
    p["blocks"] = blocks
    return p


def _mha(cfg: SurrogateConfig, bp: Params, x, mask):
    b, t, d = x.shape
    h = cfg.num_heads
    hd = d // h
    q = dense(bp["wq"], x).reshape(b, t, h, hd)
    k = dense(bp["wk"], x).reshape(b, t, h, hd)
    v = dense(bp["wv"], x).reshape(b, t, h, hd)
    lg = jnp.einsum("bthd,bshd->bhts", q, k) / jnp.sqrt(hd)
    lg = jnp.where(mask[:, None, None, :], lg, -1e30)
    w = jax.nn.softmax(lg, axis=-1)
    o = jnp.einsum("bhts,bshd->bthd", w, v).reshape(b, t, d)
    return dense(bp["wo"], o)


def _dropout(x, rate, key, enabled):
    if not enabled or rate == 0.0:
        return x
    keep = jax.random.bernoulli(key, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), 0.0)


def apply(cfg: SurrogateConfig, params: Params, tokens, *, dropout_rng=None,
          train: bool = False, mc_dropout: bool = False,
          return_hidden: bool = False):
    """tokens: [B, T<=max_len] int32 (0 = pad). Returns logits [B, C].

    mc_dropout=True keeps dropout live at inference (MC-Dropout sampling);
    dropout_rng is then required. return_hidden additionally returns the
    penultimate activation (MDSA / autoencoder supervisors hook here).
    """
    use_do = (train or mc_dropout) and cfg.dropout > 0
    if use_do:
        assert dropout_rng is not None
        rngs = jax.random.split(dropout_rng, 2 + len(params["blocks"]))
    mask = tokens > 0
    t = tokens.shape[1]
    x = params["embed"][tokens] + params["pos"][:t]
    for i, bp in enumerate(params["blocks"]):
        h = layer_norm(x, bp["ln1_w"], bp["ln1_b"], cfg.norm_eps)
        x = x + _mha(cfg, bp, h, mask)
        h = layer_norm(x, bp["ln2_w"], bp["ln2_b"], cfg.norm_eps)
        x = x + gelu_mlp(bp["mlp"], h)
        if use_do:
            x = _dropout(x, cfg.dropout, rngs[2 + i], True)
    if cfg.pool == "mean":
        denom = jnp.maximum(jnp.sum(mask, -1, keepdims=True), 1)
        pooled = jnp.sum(x * mask[..., None], axis=1) / denom
    else:
        pooled = x[:, 0]
    if use_do:
        pooled = _dropout(pooled, cfg.dropout, rngs[0], True)
    hidden = jax.nn.relu(dense(params["hidden"], pooled))
    if use_do:
        hidden = _dropout(hidden, cfg.dropout, rngs[1], True)
    logits = dense(params["out"], hidden)
    if return_hidden:
        return logits, hidden
    return logits


def loss_fn(cfg: SurrogateConfig, params: Params, tokens, labels, rng):
    logits = apply(cfg, params, tokens, dropout_rng=rng, train=True)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], -1)[:, 0]
    loss = jnp.mean(lse - gold)
    acc = jnp.mean(jnp.argmax(logits, -1) == labels)
    return loss, {"acc": acc}

"""Mamba2 (SSD) mixer — the backbone block of Zamba2.

Scalar-decay state-space duality form: per head (head_dim P, state N):
    h_t = exp(dt_t * a) * h_{t-1} + dt_t * x_t  B_t^T     (h in R^{P x N})
    y_t = h_t C_t + D * x_t
with a < 0 learned per head, dt_t = softplus(dt_proj(u_t) + dt_bias) per
head, B_t, C_t in R^N shared across the head's channels, plus a depthwise
causal conv (width 4) on (x, B, C) and a SiLU gate z — matching the Mamba2
reference topology. State is O(1) in sequence length.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, dense, dense_params, rms_norm
from repro.models.shard_hints import constrain

CONV_W = 4
HEAD_P = 64  # mamba2 head dim


def _dims(cfg: ModelConfig):
    d_inner = 2 * cfg.d_model
    n_heads = d_inner // HEAD_P
    return d_inner, n_heads, cfg.ssm_state_dim


def mamba2_params(key, cfg: ModelConfig, dtype) -> Params:
    """In-projections are UNFUSED by sharding role (SPerf iteration B1):
    the reference fused [z,x,B,C,dt] projection has out-dim
    2*d_inner+2n+h (zamba2: 14520), indivisible by the 16-way `model`
    axis, which forced XLA SPMD into involuntary full rematerialization
    (replicate + repartition) on every layer. Split by role — w_zx
    (14336, 16-aligned, column-parallel), w_bc (2n, column-parallel),
    w_dt (h, replicated) — the math is identical (the depthwise conv
    splits exactly across the channel groups)."""
    d = cfg.d_model
    d_inner, h, n = _dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "w_zx": dense_params(ks[0], d, 2 * d_inner, dtype),   # [z, x]
        "w_bc": dense_params(ks[1], d, 2 * n, dtype),         # [B, C]
        "w_dt": dense_params(ks[2], d, h, dtype),
        "conv_x_w": (jax.random.normal(ks[3], (CONV_W, d_inner)) * 0.1
                     ).astype(dtype),
        "conv_x_b": jnp.zeros((d_inner,), dtype),
        "conv_bc_w": (jax.random.normal(ks[4], (CONV_W, 2 * n)) * 0.1
                      ).astype(dtype),
        "conv_bc_b": jnp.zeros((2 * n,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "out_norm": jnp.ones((d_inner,), dtype),
        "w_out": dense_params(ks[5], d_inner, d, dtype),
    }


def mamba2_state(cfg: ModelConfig, batch: int, layers: int | None = None):
    n_l = cfg.num_layers if layers is None else layers
    d_inner, h, n = _dims(cfg)
    return {
        "ssm": jnp.zeros((n_l, batch, h, HEAD_P, n), jnp.float32),
        "conv_x": jnp.zeros((n_l, batch, CONV_W - 1, d_inner), jnp.float32),
        "conv_bc": jnp.zeros((n_l, batch, CONV_W - 1, 2 * n), jnp.float32),
    }


def _conv(w, b, xbc, conv_state):
    """Depthwise causal conv width-4. xbc: [B,T,C]; conv_state: [B,3,C]."""
    x_pad = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
    out = sum(x_pad[:, i:i + xbc.shape[1]] * w[i]
              for i in range(CONV_W))
    new_state = x_pad[:, -(CONV_W - 1):].astype(jnp.float32)
    return jax.nn.silu(out + b), new_state


def _scan_core(a_decay, dt, x_h, bb, cc):
    """a_decay [B,T,H] fp32, dt [B,T,H], x_h [B,T,H,P], bb/cc [B,T,N]."""
    def step(s, inp):
        dec, dt_t, x_t, b_t, c_t = inp
        upd = (dt_t[..., None, None] * x_t[..., :, None]
               * b_t[:, None, None, :])                    # [B,H,P,N]
        s = dec[..., None, None] * s + upd
        y = jnp.einsum("bhpn,bn->bhp", s, c_t)
        return s, y

    seq = (jnp.moveaxis(a_decay, 1, 0), jnp.moveaxis(dt, 1, 0),
           jnp.moveaxis(x_h, 1, 0), jnp.moveaxis(bb, 1, 0),
           jnp.moveaxis(cc, 1, 0))
    s0 = jnp.zeros(x_h.shape[0:1] + x_h.shape[2:] + (bb.shape[-1],),
                   jnp.float32)
    return seq, s0, step


def mamba2_forward(cfg: ModelConfig, p: Params, x, state=None, layer=None):
    """Full-sequence SSD mixer. x: [B,T,D] -> (y [B,T,D], final_state dict).

    state: optional initial {"ssm": [B,H,P,N], "conv": [B,3,C]}; zeros if
    None (fresh sequence).
    """
    b, t, d = x.shape
    d_inner, h, n = _dims(cfg)
    z, xi = jnp.split(dense(p["w_zx"], x), [d_inner], axis=-1)
    bc = dense(p["w_bc"], x)
    dt = dense(p["w_dt"], x)
    cx0 = (state["conv_x"] if state is not None else
           jnp.zeros((b, CONV_W - 1, d_inner), jnp.float32))
    cbc0 = (state["conv_bc"] if state is not None else
            jnp.zeros((b, CONV_W - 1, 2 * n), jnp.float32))
    xi, conv_x_t = _conv(p["conv_x_w"], p["conv_x_b"], xi, cx0)
    bc, conv_bc_t = _conv(p["conv_bc_w"], p["conv_bc_b"], bc, cbc0)
    bb, cc = jnp.split(bc, [n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])    # [B,T,H]
    a = -jnp.exp(p["a_log"])                                       # [H]
    decay = jnp.exp(dt * a)                                        # [B,T,H]
    # The d_inner channel axis is flattened P-MAJOR (index = p*h + head):
    # HEAD_P=128 divides the 16-way `model` axis while the head count
    # (d_inner/128 = 56 for zamba2) does not, so P-major blocks make the
    # column-parallel w_zx/conv shards line up EXACTLY with the
    # P-sharding of the SSD recurrence — no gather between the
    # projections and the scan, and w_out consumes the P-major layout
    # directly (its learned rows are order-free). SPerf iterations B2+B3.
    xi = constrain(xi, "data", None, "model")
    x_h = (xi.astype(jnp.float32).reshape(b, t, HEAD_P, h)
           .transpose(0, 1, 3, 2))                            # [B,T,h,P]
    x_h = constrain(x_h, "data", None, None, "model")
    bb32, cc32 = bb.astype(jnp.float32), cc.astype(jnp.float32)

    seq, s0, step = _scan_core(decay, dt, x_h, bb32, cc32)
    if state is not None:
        s0 = state["ssm"].astype(jnp.float32)
    s0 = constrain(s0, "data", None, "model", None)
    s_t, ys = jax.lax.scan(step, s0, seq)
    y = jnp.moveaxis(ys, 0, 1) + p["d_skip"][None, None, :, None] * x_h
    y = constrain(y, "data", None, None, "model")
    # back to the P-major d_inner flatten (local transpose: P stays
    # sharded) so the row-parallel w_out contraction shards line up
    y = y.transpose(0, 1, 3, 2).reshape(b, t, d_inner).astype(x.dtype)
    y = constrain(y, "data", None, "model")
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    out = dense(p["w_out"], y)
    return out, {"ssm": s_t, "conv_x": conv_x_t, "conv_bc": conv_bc_t}


def mamba2_decode(cfg: ModelConfig, p: Params, x, state):
    """One-token step. x: [B,1,D]; state {"ssm":[B,H,P,N],"conv":[B,3,C]}."""
    return mamba2_forward(cfg, p, x, state=state)

"""Global switch for structural-scan unrolling (roofline analysis mode).

``compiled.cost_analysis()`` visits a ``while`` body ONCE, so layer-stacked
``lax.scan`` (the thing that keeps 95-layer HLO compact) makes FLOPs/bytes
under-report by ~num_layers x. For roofline extraction we therefore lower a
REDUCED-depth variant with all *structural* scans (layer stacks, CE chunks,
q-chunks) fully unrolled, and extrapolate cost linearly in depth
(see analysis.roofline.roofline_extrapolated). Time-recurrence scans
(RWKV6 / Mamba2 token loops) are never unrolled — their per-step cost is
negligible next to the projections outside the loop, and unrolling a
32k-step recurrence would be intractable.

Default (training / serving / dry-run-compile path): no unrolling.
"""

from __future__ import annotations

import contextlib

_UNROLL: bool = False


def set_unroll(value: bool) -> None:
    global _UNROLL
    _UNROLL = value


def scan_unroll() -> bool | int:
    """Value for lax.scan(unroll=...): True (full) in analysis mode."""
    return True if _UNROLL else 1


@contextlib.contextmanager
def unrolled():
    """Context manager: structural scans fully unrolled within."""
    global _UNROLL
    prev = _UNROLL
    _UNROLL = True
    try:
        yield
    finally:
        _UNROLL = prev

"""Multi-head Latent Attention (DeepSeek-V2) — compressed-KV attention.

Training / prefill use the naive ("up-projected") formulation; decode uses
the *absorbed* formulation (W_uk folded into the query, W_uv folded into the
output projection) so the cache is only the kv_lora latent + the shared rope
key: cache bytes per token = kv_lora_rank + qk_rope_head_dim, a ~14x
reduction vs. vanilla GQA for deepseek-v2-lite. This mirrors DeepSeek-V2's
serving optimisation and is the arch where the paper's "expensive remote
model" tier benefits most from cache compression.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.scan_config import scan_unroll
from repro.models.layers import Params, apply_rope, dense, dense_params, rms_norm


def mla_params(key, cfg: ModelConfig, dtype) -> Params:
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    ks = jax.random.split(key, 6)
    return {
        # queries are full-rank in V2-Lite (q_lora_rank = None)
        "wq": dense_params(ks[0], cfg.d_model, h * (dn + dr), dtype),
        # compressed kv path
        "w_dkv": dense_params(ks[1], cfg.d_model, r, dtype),
        "kv_norm": jnp.ones((r,), dtype),
        "w_uk": dense_params(ks[2], r, h * dn, dtype),
        "w_uv": dense_params(ks[3], r, h * dv, dtype),
        "w_kr": dense_params(ks[4], cfg.d_model, dr, dtype),
        "wo": dense_params(ks[5], h * dv, cfg.d_model, dtype),
    }


def _split_q(cfg: ModelConfig, q):
    b, t, _ = q.shape
    h, dn, dr = cfg.num_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q = q.reshape(b, t, h, dn + dr)
    return q[..., :dn], q[..., dn:]


def _latents(cfg: ModelConfig, p: Params, x, positions):
    """Returns (c_kv [B,T,r], k_rope [B,T,1,dr]) — exactly what is cached."""
    c_kv = rms_norm(dense(p["w_dkv"], x), p["kv_norm"], cfg.norm_eps)
    k_r = dense(p["w_kr"], x)[:, :, None, :]  # single shared rope head
    k_r = apply_rope(k_r, positions, cfg.rope_theta)
    return c_kv, k_r


def mla_forward(cfg: ModelConfig, p: Params, x, positions, *,
                causal: bool = True, q_chunk: int = 1024):
    """Naive full-sequence MLA (train / prefill compute path)."""
    b, t, _ = x.shape
    h, dn, dr, dv = (cfg.num_heads, cfg.qk_nope_head_dim,
                     cfg.qk_rope_head_dim, cfg.v_head_dim)
    q_n, q_r = _split_q(cfg, dense(p["wq"], x))
    q_r = apply_rope(q_r, positions, cfg.rope_theta)
    c_kv, k_r = _latents(cfg, p, x, positions)
    k_n = dense(p["w_uk"], c_kv).reshape(b, t, h, dn)
    v = dense(p["w_uv"], c_kv).reshape(b, t, h, dv)

    scale = 1.0 / np.sqrt(dn + dr)
    kv_pos = jnp.arange(t)

    def chunk(qn_i, qr_i, q_pos):
        from repro.models.layers import _SCORES_FP32
        if _SCORES_FP32:        # ablation baseline
            lg = (jnp.einsum("btnd,bsnd->bnts", qn_i.astype(jnp.float32),
                             k_n.astype(jnp.float32))
                  + jnp.einsum("btnd,bsod->bnts", qr_i.astype(jnp.float32),
                               k_r.astype(jnp.float32))) * scale
        else:
            # bf16 dots + fp32 accumulation (SPerf iteration C1)
            lg = (jnp.einsum("btnd,bsnd->bnts", qn_i, k_n,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("btnd,bsod->bnts", qr_i, k_r,
                               preferred_element_type=jnp.float32)) * scale
        if causal:
            m = kv_pos[None, :] <= q_pos[:, None]
            lg = jnp.where(m[None, None], lg, -1e30)
        w = jax.nn.softmax(lg, axis=-1)
        if _SCORES_FP32:
            return jnp.einsum("bnts,bsnd->btnd", w,
                              v.astype(jnp.float32)).astype(x.dtype)
        return jnp.einsum("bnts,bsnd->btnd", w.astype(v.dtype), v,
                          preferred_element_type=jnp.float32).astype(x.dtype)

    if t <= q_chunk:
        out = chunk(q_n, q_r, jnp.arange(t))
    else:
        assert t % q_chunk == 0
        n = t // q_chunk
        qn_c = jnp.moveaxis(q_n.reshape(b, n, q_chunk, h, dn), 1, 0)
        qr_c = jnp.moveaxis(q_r.reshape(b, n, q_chunk, h, dr), 1, 0)

        def body(_, args):
            i, qn_i, qr_i = args
            return None, chunk(qn_i, qr_i, i * q_chunk + jnp.arange(q_chunk))

        _, out = jax.lax.scan(body, None, (jnp.arange(n), qn_c, qr_c),
                              unroll=scan_unroll())
        out = jnp.moveaxis(out, 0, 1).reshape(b, t, h, dv)

    out = dense(p["wo"], out.reshape(b, t, h * dv))
    return out, (c_kv, k_r[:, :, 0, :])


def make_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype,
                   layers: int | None = None) -> Params:
    n_l = cfg.num_layers if layers is None else layers
    return {
        "c_kv": jnp.zeros((n_l, batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((n_l, batch, max_len, cfg.qk_rope_head_dim), dtype),
    }


def mla_decode(cfg: ModelConfig, p: Params, x, c_kv_cache, kr_cache, pos):
    """Absorbed one-token decode.

    x: [B,1,D]; c_kv_cache: [B,S,r]; kr_cache: [B,S,dr]; pos: [] int32.
    score_nope = (q_n W_uk^T) . c_kv  — attention runs in latent space.
    out = (attn-weighted c_kv) W_uv  — value up-projection after weighting.
    """
    b = x.shape[0]
    h, dn, dr, dv = (cfg.num_heads, cfg.qk_nope_head_dim,
                     cfg.qk_rope_head_dim, cfg.v_head_dim)
    r = cfg.kv_lora_rank
    q_n, q_r = _split_q(cfg, dense(p["wq"], x))          # [B,1,h,dn/dr]
    posv = jnp.full((1,), pos)
    q_r = apply_rope(q_r, posv, cfg.rope_theta)
    c_kv, k_r = _latents(cfg, p, x, posv)                # [B,1,r], [B,1,1,dr]
    c_kv_cache = jax.lax.dynamic_update_slice_in_dim(c_kv_cache, c_kv, pos, 1)
    kr_cache = jax.lax.dynamic_update_slice_in_dim(
        kr_cache, k_r[:, :, 0, :], pos, 1)

    w_uk = p["w_uk"]["w"].reshape(r, h, dn)
    # absorb: q_lat [B,1,h,r] = q_n @ W_uk^T (per head); dots stay in the
    # cache dtype (bf16 MXU) with fp32 accumulation (SPerf iteration A2)
    q_lat = jnp.einsum("bthd,rhd->bthr", q_n, w_uk,
                       preferred_element_type=jnp.float32)
    scale = 1.0 / np.sqrt(dn + dr)
    lg = (jnp.einsum("bthr,bsr->bhts", q_lat.astype(c_kv_cache.dtype),
                     c_kv_cache, preferred_element_type=jnp.float32)
          + jnp.einsum("bthd,bsd->bhts", q_r, kr_cache,
                       preferred_element_type=jnp.float32)) * scale
    s = c_kv_cache.shape[1]
    valid = jnp.arange(s)[None, None, None, :] < pos + 1
    lg = jnp.where(valid, lg, -1e30)
    w = jax.nn.softmax(lg, axis=-1)
    ctx = jnp.einsum("bhts,bsr->bthr", w.astype(c_kv_cache.dtype),
                     c_kv_cache,
                     preferred_element_type=jnp.float32)    # [B,1,h,r]
    w_uv = p["w_uv"]["w"].reshape(r, h, dv)
    out = jnp.einsum("bthr,rhd->bthd", ctx.astype(w_uv.dtype), w_uv,
                     preferred_element_type=jnp.float32)
    out = dense(p["wo"], out.reshape(b, 1, h * dv).astype(x.dtype))
    return out, c_kv_cache, kr_cache

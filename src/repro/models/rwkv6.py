"""RWKV-6 "Finch" block: linear attention with data-dependent decay.

Per head (head size M): state S in R^{M x M},
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
with w_t = exp(-exp(ddlerp_w(x_t, x_{t-1}))) data-dependent per channel
(the defining Finch feature vs RWKV-5's static decay), and token-shift
low-rank ("ddlerp") mixing for r/k/v/w/g. Channel-mix is the standard
squared-ReLU token-shift MLP.

State is O(1) in sequence length -> this arch serves long_500k natively.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (Params, dense, dense_params, group_norm)

LORA_R = 32


def _lora(key, d, out, dtype):
    k1, k2 = jax.random.split(key)
    return {"a": dense_params(k1, d, LORA_R, dtype),
            "b": dense_params(k2, LORA_R, out, dtype, scale=1e-2)}


def _lora_apply(p, x):
    return dense(p["b"], jnp.tanh(dense(p["a"], x)))


def rwkv6_params(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 16)
    names = ("w", "k", "v", "r", "g")
    p: Params = {
        "maa_x": jnp.zeros((d,), dtype),
        "maa": {n: jnp.zeros((d,), dtype) for n in names},
        "maa_lora": {n: _lora(ks[i], d, d, dtype)
                     for i, n in enumerate(names)},
        "decay_base": jnp.full((d,), -6.0, dtype),
        "decay_lora": _lora(ks[5], d, d, dtype),
        "bonus_u": jnp.full((d,), 0.5, dtype),
        "wr": dense_params(ks[6], d, d, dtype),
        "wk": dense_params(ks[7], d, d, dtype),
        "wv": dense_params(ks[8], d, d, dtype),
        "wg": dense_params(ks[9], d, d, dtype),
        "wo": dense_params(ks[10], d, d, dtype),
        "ln_w": jnp.ones((d,), dtype),
        "ln_b": jnp.zeros((d,), dtype),
        # channel mix
        "cm_maa_k": jnp.zeros((d,), dtype),
        "cm_maa_r": jnp.zeros((d,), dtype),
        "cm_wk": dense_params(ks[11], d, cfg.d_ff, dtype),
        "cm_wv": dense_params(ks[12], cfg.d_ff, d, dtype),
        "cm_wr": dense_params(ks[13], d, d, dtype),
    }
    return p


def _ddlerp(p: Params, x, x_prev):
    """Data-dependent token-shift mixing -> dict of mixed inputs."""
    xx = x_prev - x
    base = x + xx * p["maa_x"]
    return {n: x + xx * (p["maa"][n] + _lora_apply(p["maa_lora"][n], base))
            for n in p["maa"]}


def _heads(cfg: ModelConfig, t: jnp.ndarray):
    b, tt, d = t.shape
    m = cfg.rwkv_head_dim
    return t.reshape(b, tt, d // m, m)


def rwkv6_state(cfg: ModelConfig, batch: int, layers: int | None = None):
    n_l = cfg.num_layers if layers is None else layers
    d, m = cfg.d_model, cfg.rwkv_head_dim
    h = d // m
    return {
        "wkv": jnp.zeros((n_l, batch, h, m, m), jnp.float32),
        "tm_prev": jnp.zeros((n_l, batch, d), jnp.float32),
        "cm_prev": jnp.zeros((n_l, batch, d), jnp.float32),
    }


def _time_mix_core(cfg, p, r, k, v, w, u, s0):
    """Scan the linear-attention recurrence.

    r,k,v,w: [B,T,H,M] (w already in (0,1)); u: [H,M]; s0: [B,H,M,M].
    Returns y [B,T,H,M], s_T.
    """
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                       # [B,H,M]
        kv = k_t[..., :, None] * v_t[..., None, :]     # [B,H,M,M]
        att = s + u[None, :, :, None] * kv
        y = jnp.einsum("bhm,bhmn->bhn", r_t, att)
        s = w_t[..., :, None] * s + kv
        return s, y

    seq = tuple(jnp.moveaxis(z.astype(jnp.float32), 1, 0) for z in (r, k, v, w))
    s_t, ys = jax.lax.scan(step, s0.astype(jnp.float32), seq)
    return jnp.moveaxis(ys, 0, 1), s_t


def time_mix(cfg: ModelConfig, p: Params, x, s0, x_prev0):
    """x: [B,T,D] normed. s0: [B,H,M,M] fp32. x_prev0: [B,D] last token of
    previous chunk (zeros at t=0). Returns (out [B,T,D], s_T, x_last)."""
    b, t, d = x.shape
    m = cfg.rwkv_head_dim
    h = d // m
    x_prev = jnp.concatenate([x_prev0[:, None].astype(x.dtype), x[:, :-1]], 1)
    mixed = _ddlerp(p, x, x_prev)
    r = _heads(cfg, dense(p["wr"], mixed["r"]))
    k = _heads(cfg, dense(p["wk"], mixed["k"]))
    v = _heads(cfg, dense(p["wv"], mixed["v"]))
    g = jax.nn.silu(dense(p["wg"], mixed["g"]))
    decay = (p["decay_base"].astype(jnp.float32)
             + _lora_apply(p["decay_lora"], mixed["w"]).astype(jnp.float32))
    w = jnp.exp(-jnp.exp(decay)).reshape(b, t, h, m)
    u = p["bonus_u"].astype(jnp.float32).reshape(h, m)
    y, s_t = _time_mix_core(cfg, p, r, k, v, w, u, s0)
    y = group_norm(y.reshape(b, t, d).astype(x.dtype),
                   p["ln_w"], p["ln_b"], h, cfg.norm_eps)
    out = dense(p["wo"], y * g)
    return out, s_t, x[:, -1].astype(jnp.float32)


def channel_mix(cfg: ModelConfig, p: Params, x, x_prev0):
    """Squared-relu channel mix with token shift. Returns (out, x_last)."""
    x_prev = jnp.concatenate([x_prev0[:, None].astype(x.dtype), x[:, :-1]], 1)
    xx = x_prev - x
    xk = x + xx * p["cm_maa_k"]
    xr = x + xx * p["cm_maa_r"]
    kk = jnp.square(jax.nn.relu(dense(p["cm_wk"], xk)))
    return (jax.nn.sigmoid(dense(p["cm_wr"], xr)) * dense(p["cm_wv"], kk),
            x[:, -1].astype(jnp.float32))

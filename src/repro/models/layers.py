"""Shared transformer building blocks (pure-jnp path).

All functions are pure; parameters are plain dict pytrees. The jnp path is
the portable reference used for training, the multi-pod dry-run and CPU
tests; Pallas kernels (repro.kernels) are drop-in accelerations of the same
math, validated against these implementations.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.scan_config import scan_unroll

Params = dict


# --------------------------------------------------------------------------
# initialisation helpers
# --------------------------------------------------------------------------

def _dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def dense_params(key, d_in: int, d_out: int, dtype, bias: bool = False,
                 scale: float | None = None) -> Params:
    kw, kb = jax.random.split(key)
    p = {"w": _dense_init(kw, (d_in, d_out), dtype, scale)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layer_norm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
               eps: float) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def group_norm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, num_groups: int,
               eps: float) -> jnp.ndarray:
    """GroupNorm over the last dim (used by RWKV6 output norm)."""
    dt = x.dtype
    *lead, d = x.shape
    x = x.astype(jnp.float32).reshape(*lead, num_groups, d // num_groups)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = ((x - mu) * jax.lax.rsqrt(var + eps)).reshape(*lead, d)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: [..., T, H, hd]; positions: broadcastable to [..., T]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., T, hd/2]
    angles = angles[..., None, :]                       # [..., T, 1, hd/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention (GQA, optional sliding window / bias), chunked for long seqs
# --------------------------------------------------------------------------

def attention_params(key, cfg: ModelConfig, dtype) -> Params:
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_params(ks[0], cfg.d_model, cfg.num_heads * hd, dtype,
                           bias=cfg.attn_bias),
        "wk": dense_params(ks[1], cfg.d_model, cfg.num_kv_heads * hd, dtype,
                           bias=cfg.attn_bias),
        "wv": dense_params(ks[2], cfg.d_model, cfg.num_kv_heads * hd, dtype,
                           bias=cfg.attn_bias),
        "wo": dense_params(ks[3], cfg.num_heads * hd, cfg.d_model, dtype),
    }


_SCORES_FP32 = False    # ablation: paper-era fp32 attention math


def set_scores_fp32(value: bool) -> None:
    """Toggle the pre-optimization fp32 attention-score path (used by the
    perf harness to measure the SPerf A2/C1 baseline)."""
    global _SCORES_FP32
    _SCORES_FP32 = value


def _sdpa(q, k, v, mask, scale):
    """q:[B,Tq,K,G,hd] k,v:[B,S,K,hd] mask:[Tq,S] bool -> [B,Tq,K,G,hd]."""
    # Dots run at the INPUT dtype (bf16 MXU for bf16 models) with fp32
    # accumulation; softmax stays fp32. The former fp32 upcast of K/V
    # materialised an fp32 copy of the whole KV cache per decode step AND
    # pushed every attention dot onto the ~4x slower fp32 MXU path
    # (EXPERIMENTS.md SPerf iteration A2/C1).
    if _SCORES_FP32:            # ablation baseline
        logits = jnp.einsum("btkgh,bskh->bkgts", q.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bkgts,bskh->btkgh", w, v.astype(jnp.float32))
        return out.astype(q.dtype)
    logits = jnp.einsum("btkgh,bskh->bkgts", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def gqa_attention(q, k, v, *, causal: bool, q_offset, window: int = 0,
                  kv_len_valid=None, q_chunk: int = 1024):
    """Grouped-query attention, scanned over query chunks so [Tq,S] score
    tensors never exceed q_chunk rows (keeps 32k prefill in memory budget).

    q: [B, Tq, H, hd]; k, v: [B, S, K, hd]. q_offset: absolute position of
    q[0] (array or int). kv_len_valid: number of valid cache slots (decode).
    """
    b, tq, h, hd = q.shape
    s = k.shape[1]
    kh = k.shape[2]
    g = h // kh
    q = q.reshape(b, tq, kh, g, hd)
    scale = 1.0 / np.sqrt(hd)
    kv_pos = jnp.arange(s)

    def mask_for(q_pos):
        # q_pos: [tc] absolute positions
        m = jnp.ones((q_pos.shape[0], s), bool)
        if causal:
            m &= kv_pos[None, :] <= q_pos[:, None]
        if window:
            m &= kv_pos[None, :] > q_pos[:, None] - window
        if kv_len_valid is not None:
            m &= kv_pos[None, :] < kv_len_valid
        return m

    if tq <= q_chunk:
        q_pos = q_offset + jnp.arange(tq)
        out = _sdpa(q, k, v, mask_for(q_pos), scale)
        return out.reshape(b, tq, h, hd)

    assert tq % q_chunk == 0, (tq, q_chunk)
    nchunk = tq // q_chunk
    qc = q.reshape(b, nchunk, q_chunk, kh, g, hd)

    def body(_, args):
        i, qi = args
        q_pos = q_offset + i * q_chunk + jnp.arange(q_chunk)
        return None, _sdpa(qi, k, v, mask_for(q_pos), scale)

    _, out = jax.lax.scan(
        body, None, (jnp.arange(nchunk), jnp.moveaxis(qc, 1, 0)),
        unroll=scan_unroll())
    out = jnp.moveaxis(out, 0, 1).reshape(b, tq, h, hd)
    return out


def attn_forward(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                 positions: jnp.ndarray, *, causal: bool) -> jnp.ndarray:
    """Full-sequence attention (train / prefill / encoder)."""
    b, t, _ = x.shape
    hd = cfg.resolved_head_dim
    q = dense(p["wq"], x).reshape(b, t, cfg.num_heads, hd)
    k = dense(p["wk"], x).reshape(b, t, cfg.num_kv_heads, hd)
    v = dense(p["wv"], x).reshape(b, t, cfg.num_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = gqa_attention(q, k, v, causal=causal, q_offset=0,
                        window=cfg.sliding_window)
    return dense(p["wo"], out.reshape(b, t, cfg.num_heads * hd))


def make_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype,
                  layers: int | None = None) -> Params:
    """Contiguous KV cache. SWA caches only the window (ring buffer)."""
    slots = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    n_l = cfg.num_layers if layers is None else layers
    hd = cfg.resolved_head_dim
    shape = (n_l, batch, slots, cfg.num_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attn_prefill(cfg: ModelConfig, p: Params, x, positions):
    """Returns (out, (k, v)) — caller stores k/v into the layer cache."""
    b, t, _ = x.shape
    hd = cfg.resolved_head_dim
    q = dense(p["wq"], x).reshape(b, t, cfg.num_heads, hd)
    k = dense(p["wk"], x).reshape(b, t, cfg.num_kv_heads, hd)
    v = dense(p["wv"], x).reshape(b, t, cfg.num_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = gqa_attention(q, k, v, causal=True, q_offset=0,
                        window=cfg.sliding_window)
    out = dense(p["wo"], out.reshape(b, t, cfg.num_heads * hd))
    if cfg.sliding_window and t > cfg.sliding_window:
        # Keep only the window, ROLLED so position p lands at ring slot
        # p % window — the convention attn_decode writes with
        # (slot = pos % slots); without the roll, decode would evict the
        # wrong key whenever t % window != 0.
        w = cfg.sliding_window
        k = jnp.roll(k[:, -w:], shift=t % w, axis=1)
        v = jnp.roll(v[:, -w:], shift=t % w, axis=1)
    return out, (k, v)


def attn_decode(cfg: ModelConfig, p: Params, x, k_cache, v_cache, pos):
    """One-token decode. x: [B,1,D]; caches [B,slots,K,hd]; pos: [] int32
    absolute position of the new token. Returns (out, new_k, new_v, slot)."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    slots = k_cache.shape[1]
    q = dense(p["wq"], x).reshape(b, 1, cfg.num_heads, hd)
    k = dense(p["wk"], x).reshape(b, 1, cfg.num_kv_heads, hd)
    v = dense(p["wv"], x).reshape(b, 1, cfg.num_kv_heads, hd)
    posv = jnp.full((1,), pos)
    q = apply_rope(q, posv, cfg.rope_theta)
    k = apply_rope(k, posv, cfg.rope_theta)
    slot = pos % slots if cfg.sliding_window else pos
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, slot, axis=1)
    if cfg.sliding_window:
        # ring buffer: every stored slot is within the window -> all valid
        kv_valid = jnp.minimum(pos + 1, slots)
        out = gqa_attention(q, k_cache, v_cache, causal=False, q_offset=pos,
                            kv_len_valid=kv_valid)
    else:
        out = gqa_attention(q, k_cache, v_cache, causal=False, q_offset=pos,
                            kv_len_valid=pos + 1)
    out = dense(p["wo"], out.reshape(b, 1, cfg.num_heads * hd))
    return out, k_cache, v_cache


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def swiglu_params(key, d_model: int, d_ff: int, dtype) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_params(ks[0], d_model, d_ff, dtype),
        "w_up": dense_params(ks[1], d_model, d_ff, dtype),
        "w_down": dense_params(ks[2], d_ff, d_model, dtype),
    }


def swiglu(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return dense(p["w_down"],
                 jax.nn.silu(dense(p["w_gate"], x)) * dense(p["w_up"], x))


def gelu_mlp_params(key, d_model: int, d_ff: int, dtype) -> Params:
    ks = jax.random.split(key, 2)
    return {"w_in": dense_params(ks[0], d_model, d_ff, dtype, bias=True),
            "w_out": dense_params(ks[1], d_ff, d_model, dtype, bias=True)}


def gelu_mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return dense(p["w_out"], jax.nn.gelu(dense(p["w_in"], x)))

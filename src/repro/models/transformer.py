"""Unified model API over all assigned architecture families.

Pure functions over params pytrees:

    init_params(cfg, key)                       -> params
    forward(cfg, params, batch)                 -> full-seq hidden/logits
    loss_fn(cfg, params, batch)                 -> (loss, metrics)
    prefill(cfg, params, batch)                 -> (last_logits, cache)
    decode_step(cfg, params, token, cache, pos) -> (logits, cache)

Layer stacks are scanned (`lax.scan` over stacked params) so 95-layer
models lower to compact HLO; the scan body is `jax.checkpoint`-wrapped for
training. Cross-entropy is computed in sequence chunks so [B,T,V] logits
are never materialised (V up to 152k).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.scan_config import scan_unroll
from repro.models import mla as mla_mod
from repro.models import mamba2 as m2
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rwkv
from repro.models.layers import (Params, attention_params, attn_decode,
                                 attn_forward, attn_prefill, dense,
                                 dense_params, make_kv_cache, rms_norm,
                                 swiglu, swiglu_params)

Batch = dict[str, Any]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _stack_init(fn, key, n: int) -> Params:
    return jax.vmap(fn)(jax.random.split(key, n))


def _attn_block_params(key, cfg: ModelConfig, dtype, moe: bool) -> Params:
    k1, k2 = jax.random.split(key)
    p = {"norm1": jnp.ones((cfg.d_model,), dtype),
         "norm2": jnp.ones((cfg.d_model,), dtype)}
    if cfg.use_mla:
        p["attn"] = mla_mod.mla_params(k1, cfg, dtype)
    else:
        p["attn"] = attention_params(k1, cfg, dtype)
    if moe:
        p["moe"] = moe_mod.moe_params(k2, cfg, dtype)
    else:
        p["mlp"] = swiglu_params(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def _rwkv_block_params(key, cfg: ModelConfig, dtype) -> Params:
    p = {"norm1": jnp.ones((cfg.d_model,), dtype),
         "norm2": jnp.ones((cfg.d_model,), dtype)}
    p.update(rwkv.rwkv6_params(key, cfg, dtype))
    return p


def _mamba_block_params(key, cfg: ModelConfig, dtype) -> Params:
    return {"norm": jnp.ones((cfg.d_model,), dtype),
            "mixer": m2.mamba2_params(key, cfg, dtype)}


def _zamba_groups(cfg: ModelConfig) -> tuple[int, int]:
    """(num_groups, mamba layers per group). Requires divisibility."""
    period = cfg.shared_attn_period
    assert cfg.num_layers % period == 0, (cfg.num_layers, period)
    return cfg.num_layers // period, period


def init_params(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    p: Params = {}
    if not cfg.takes_embeddings or cfg.name.startswith("pixtral"):
        p["embed"] = (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model))
                      * 0.02).astype(dtype)
    p["final_norm"] = jnp.ones((cfg.d_model,), dtype)
    out_dim = cfg.num_classes or cfg.vocab_size
    p["head"] = dense_params(ks[1], cfg.d_model, out_dim, dtype)

    if cfg.block_type == "attn":
        n_dense = cfg.first_dense_layers
        n_main = cfg.num_layers - n_dense
        if n_dense:
            p["dense_blocks"] = _stack_init(
                lambda k: _attn_block_params(k, cfg, dtype, moe=False),
                ks[2], n_dense)
        p["blocks"] = _stack_init(
            lambda k: _attn_block_params(k, cfg, dtype, moe=cfg.is_moe),
            ks[3], n_main)
    elif cfg.block_type == "rwkv6":
        p["blocks"] = _stack_init(
            lambda k: _rwkv_block_params(k, cfg, dtype), ks[3],
            cfg.num_layers)
    elif cfg.block_type == "mamba2":
        p["blocks"] = _stack_init(
            lambda k: _mamba_block_params(k, cfg, dtype), ks[3],
            cfg.num_layers)
        if cfg.shared_attn_period:
            p["shared_attn"] = _attn_block_params(ks[4], cfg, dtype,
                                                  moe=False)
    else:
        raise ValueError(cfg.block_type)
    return p


# --------------------------------------------------------------------------
# full-sequence forward (train / encoder / prefill compute)
# --------------------------------------------------------------------------

def _embed_in(cfg: ModelConfig, params: Params, batch: Batch) -> jnp.ndarray:
    """Token / frontend-embedding input. VLMs (pixtral) interleave: the
    patch-embedding prefix (frontend stub) is concatenated before the text
    tokens' embeddings."""
    parts = []
    if "embeds" in batch:
        parts.append(batch["embeds"].astype(jnp.dtype(cfg.dtype)))
    if "tokens" in batch and "embed" in params:
        parts.append(params["embed"][batch["tokens"]])
    assert parts, "batch needs 'tokens' and/or 'embeds'"
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)


def _attn_body(cfg: ModelConfig, lp: Params, x, positions, *, causal, moe):
    h = rms_norm(x, lp["norm1"], cfg.norm_eps)
    if cfg.use_mla:
        a, _ = mla_mod.mla_forward(cfg, lp["attn"], h, positions,
                                   causal=causal)
    else:
        a = attn_forward(cfg, lp["attn"], h, positions, causal=causal)
    x = x + a
    h = rms_norm(x, lp["norm2"], cfg.norm_eps)
    if moe:
        y, aux = moe_mod.moe_forward(cfg, lp["moe"], h)
    else:
        y, aux = swiglu(lp["mlp"], h), jnp.float32(0.0)
    return x + y, aux


def _rwkv_body(cfg: ModelConfig, lp: Params, x, st):
    """st: per-layer {"wkv","tm_prev","cm_prev"}; returns (x, new st)."""
    h = rms_norm(x, lp["norm1"], cfg.norm_eps)
    out, wkv, tm_last = rwkv.time_mix(cfg, lp, h, st["wkv"], st["tm_prev"])
    x = x + out
    h = rms_norm(x, lp["norm2"], cfg.norm_eps)
    out, cm_last = rwkv.channel_mix(cfg, lp, h, st["cm_prev"])
    return x + out, {"wkv": wkv, "tm_prev": tm_last, "cm_prev": cm_last}


def _run_attn_stack(cfg, params, x, positions, *, causal, remat: bool):
    aux_total = jnp.float32(0.0)

    def mk_body(moe):
        def body(carry, lp):
            x, aux = carry
            x, a = _attn_body(cfg, lp, x, positions, causal=causal, moe=moe)
            return (x, aux + a), None
        return jax.checkpoint(body) if remat else body

    if "dense_blocks" in params:
        (x, aux_total), _ = jax.lax.scan(mk_body(False), (x, aux_total),
                                         params["dense_blocks"],
                                         unroll=scan_unroll())
    (x, aux_total), _ = jax.lax.scan(mk_body(cfg.is_moe), (x, aux_total),
                                     params["blocks"],
                                     unroll=scan_unroll())
    return x, aux_total


def _run_rwkv_stack(cfg, params, x, state, *, remat: bool):
    """state: stacked [L,...] rwkv6_state. Returns (x, new_state)."""
    def body(x, inp):
        lp, st = inp
        x, st = _rwkv_body(cfg, lp, x, st)
        return x, st
    body = jax.checkpoint(body) if remat else body
    x, new_state = jax.lax.scan(body, x, (params["blocks"], state),
                                unroll=scan_unroll())
    return x, new_state


def _run_zamba_stack(cfg, params, x, positions, mamba_state, attn_fn,
                     attn_xs, *, remat: bool):
    """Scan groups: [shared attn] + per-group inner scan of mamba layers.

    attn_fn(x, group_attn_xs) -> (x, group_attn_ys) abstracts full-seq vs
    decode attention; attn_xs has leading dim G (e.g. per-group KV caches,
    or None placeholders for training).
    """
    g, per = _zamba_groups(cfg)

    def leaves_regroup(t):
        return jax.tree.map(lambda a: a.reshape((g, per) + a.shape[1:]), t)

    blocks = leaves_regroup(params["blocks"])
    mamba_state = leaves_regroup(mamba_state) if mamba_state is not None \
        else None

    def inner(x, inp):
        lp, st = inp
        h = rms_norm(x, lp["norm"], cfg.norm_eps)
        out, new_st = m2.mamba2_forward(cfg, lp["mixer"], h, state=st)
        return x + out, new_st

    inner = jax.checkpoint(inner) if remat else inner

    def group(x, inp):
        gblocks, gstate, gattn = inp
        x, attn_ys = attn_fn(x, gattn)
        x, new_state = jax.lax.scan(inner, x, (gblocks, gstate),
                                    unroll=scan_unroll())
        return x, (new_state, attn_ys)

    x, (new_mamba, attn_ys) = jax.lax.scan(
        group, x, (blocks, mamba_state, attn_xs), unroll=scan_unroll())
    flatten = lambda t: jax.tree.map(
        lambda a: a.reshape((g * per,) + a.shape[2:]), t)
    return x, flatten(new_mamba), attn_ys


def forward(cfg: ModelConfig, params: Params, batch: Batch, *,
            remat: bool = False):
    """Full-sequence hidden states [B,T,D] (+ aux dict)."""
    x = _embed_in(cfg, params, batch)
    b, t, _ = x.shape
    positions = jnp.arange(t)
    causal = not cfg.is_encoder

    if cfg.block_type == "attn":
        x, aux = _run_attn_stack(cfg, params, x, positions, causal=causal,
                                 remat=remat)
        extras = {"moe_aux": aux}
    elif cfg.block_type == "rwkv6":
        state = rwkv.rwkv6_state(cfg, b)
        x, _ = _run_rwkv_stack(cfg, params, x, state, remat=remat)
        extras = {"moe_aux": jnp.float32(0.0)}
    else:  # mamba2 / zamba hybrid
        g, _ = _zamba_groups(cfg)
        state = m2.mamba2_state(cfg, b)
        sp = params.get("shared_attn")

        def attn_fn(x, _):
            if sp is None:
                return x, 0.0
            h = rms_norm(x, sp["norm1"], cfg.norm_eps)
            a = attn_forward(cfg, sp["attn"], h, positions, causal=causal)
            x = x + a
            h = rms_norm(x, sp["norm2"], cfg.norm_eps)
            return x + swiglu(sp["mlp"], h), 0.0

        x, _, _ = _run_zamba_stack(cfg, params, x, positions, state, attn_fn,
                                   jnp.zeros((g,)), remat=remat)
        extras = {"moe_aux": jnp.float32(0.0)}

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, extras


# --------------------------------------------------------------------------
# loss (chunked cross-entropy — never materialises [B,T,V])
# --------------------------------------------------------------------------

def _chunked_ce(head: Params, x, labels, mask, chunk: int = 512):
    """x: [B,T,D] final hidden; labels/mask: [B,T]. Mean CE over mask."""
    b, t, d = x.shape
    chunk = min(chunk, t)
    assert t % chunk == 0
    n = t // chunk
    xs = (jnp.moveaxis(x.reshape(b, n, chunk, d), 1, 0),
          jnp.moveaxis(labels.reshape(b, n, chunk), 1, 0),
          jnp.moveaxis(mask.reshape(b, n, chunk), 1, 0))

    @jax.checkpoint
    def body(carry, inp):
        xc, yc, mc = inp
        logits = dense(head, xc).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        ce = (lse - gold) * mc
        ncorrect = jnp.sum((jnp.argmax(logits, -1) == yc) * mc)
        return (carry[0] + jnp.sum(ce), carry[1] + jnp.sum(mc),
                carry[2] + ncorrect), None

    (tot, cnt, ncorr), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0), jnp.float32(0.0)), xs,
        unroll=scan_unroll())
    return tot / jnp.maximum(cnt, 1.0), ncorr / jnp.maximum(cnt, 1.0)


def loss_fn(cfg: ModelConfig, params: Params, batch: Batch, *,
            remat: bool = True):
    """Next-token LM loss (decoders) or per-frame classification (encoders)."""
    x, extras = forward(cfg, params, batch, remat=remat)
    if cfg.is_encoder:
        labels = batch["labels"]
        mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
        loss, acc = _chunked_ce(params["head"], x, labels, mask)
    else:
        if "labels" in batch:
            labels = batch["labels"]
            mask = batch.get("mask", jnp.ones(labels.shape, jnp.float32))
        elif "embeds" in batch and "tokens" in batch:
            # VLM: image-patch prefix emits no labels; next-token loss over
            # the text region only (last text position zero-masked).
            toks = batch["tokens"]
            b, t_img = batch["embeds"].shape[:2]
            labels = jnp.concatenate(
                [jnp.zeros((b, t_img), toks.dtype),
                 toks[:, 1:], jnp.zeros_like(toks[:, :1])], axis=1)
            mask = jnp.concatenate(
                [jnp.zeros((b, t_img), jnp.float32),
                 jnp.ones(toks[:, 1:].shape, jnp.float32),
                 jnp.zeros(toks[:, :1].shape, jnp.float32)], axis=1)
        else:
            # next-token: shift left, zero-mask the final position so the
            # time axis stays chunk-divisible.
            toks = batch["tokens"]
            labels = jnp.concatenate(
                [toks[:, 1:], jnp.zeros_like(toks[:, :1])], axis=1)
            mask = jnp.concatenate(
                [jnp.ones(toks[:, 1:].shape, jnp.float32),
                 jnp.zeros(toks[:, :1].shape, jnp.float32)], axis=1)
        loss, acc = _chunked_ce(params["head"], x, labels, mask)
    total = loss + cfg.router_aux_loss_coef * extras["moe_aux"]
    return total, {"ce": loss, "acc": acc, "moe_aux": extras["moe_aux"]}


# --------------------------------------------------------------------------
# prefill / decode (serving path)
# --------------------------------------------------------------------------

def make_cache(cfg: ModelConfig, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.dtype)
    if cfg.block_type == "attn":
        n_dense = cfg.first_dense_layers
        n_main = cfg.num_layers - n_dense
        if cfg.use_mla:
            cache = {"main": mla_mod.make_mla_cache(cfg, batch, max_len,
                                                    dtype, layers=n_main)}
            if n_dense:
                cache["dense"] = mla_mod.make_mla_cache(cfg, batch, max_len,
                                                        dtype, layers=n_dense)
        else:
            cache = {"main": make_kv_cache(cfg, batch, max_len, dtype,
                                           layers=n_main)}
            if n_dense:
                cache["dense"] = make_kv_cache(cfg, batch, max_len, dtype,
                                               layers=n_dense)
        return cache
    if cfg.block_type == "rwkv6":
        return {"rwkv": rwkv.rwkv6_state(cfg, batch)}
    # zamba hybrid: mamba state + per-group shared-attn KV cache
    g, _ = _zamba_groups(cfg)
    hd = cfg.resolved_head_dim
    return {
        "mamba": m2.mamba2_state(cfg, batch),
        "attn_k": jnp.zeros((g, batch, max_len, cfg.num_kv_heads, hd), dtype),
        "attn_v": jnp.zeros((g, batch, max_len, cfg.num_kv_heads, hd), dtype),
    }


def _head_logits(cfg: ModelConfig, params: Params, x_last):
    """x_last: [B, D] -> logits [B, V or C] fp32."""
    return dense(params["head"], x_last).astype(jnp.float32)


def prefill(cfg: ModelConfig, params: Params, batch: Batch):
    """Run the full prompt; return (last-position logits, cache)."""
    x = _embed_in(cfg, params, batch)
    b, t, _ = x.shape
    positions = jnp.arange(t)

    if cfg.block_type == "attn":
        def body(x, lp):
            h = rms_norm(x, lp["norm1"], cfg.norm_eps)
            if cfg.use_mla:
                a, (c_kv, k_r) = mla_mod.mla_forward(cfg, lp["attn"], h,
                                                     positions, causal=True)
                kv = {"c_kv": c_kv, "k_rope": k_r}
            else:
                a, (k, v) = attn_prefill(cfg, lp["attn"], h, positions)
                kv = {"k": k, "v": v}
            x = x + a
            h = rms_norm(x, lp["norm2"], cfg.norm_eps)
            if "moe" in lp:
                y, _ = moe_mod.moe_forward(cfg, lp["moe"], h, dropless=True)
            else:
                y = swiglu(lp["mlp"], h)
            return x + y, kv

        cache = {}
        if "dense_blocks" in params:
            x, cache["dense"] = jax.lax.scan(body, x, params["dense_blocks"],
                                             unroll=scan_unroll())
        x, cache["main"] = jax.lax.scan(body, x, params["blocks"],
                                        unroll=scan_unroll())
    elif cfg.block_type == "rwkv6":
        state = rwkv.rwkv6_state(cfg, b)
        x, state = _run_rwkv_stack(cfg, params, x, state, remat=False)
        cache = {"rwkv": state}
    else:
        g, _ = _zamba_groups(cfg)
        state = m2.mamba2_state(cfg, b)
        sp = params.get("shared_attn")

        def attn_fn(x, _):
            h = rms_norm(x, sp["norm1"], cfg.norm_eps)
            a, (k, v) = attn_prefill(cfg, sp["attn"], h, positions)
            x = x + a
            h = rms_norm(x, sp["norm2"], cfg.norm_eps)
            return x + swiglu(sp["mlp"], h), (k, v)

        x, new_mamba, (ks, vs) = _run_zamba_stack(
            cfg, params, x, positions, state, attn_fn, jnp.zeros((g,)),
            remat=False)
        cache = {"mamba": new_mamba, "attn_k": ks, "attn_v": vs}

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _head_logits(cfg, params, x[:, -1]), cache


def decode_step(cfg: ModelConfig, params: Params, token, cache, pos):
    """One new token. token: [B] int32 (or [B,D] embeds); pos: [] int32.
    Returns (logits [B,V], new cache)."""
    assert cfg.supports_decode, f"{cfg.name} is encoder-only"
    if token.ndim == 1:
        x = params["embed"][token][:, None, :]
    else:
        x = token.astype(jnp.dtype(cfg.dtype))[:, None, :]
    if cfg.block_type == "attn":
        def body(x, inp):
            lp, kv = inp
            h = rms_norm(x, lp["norm1"], cfg.norm_eps)
            if cfg.use_mla:
                a, ck, kr = mla_mod.mla_decode(cfg, lp["attn"], h,
                                               kv["c_kv"], kv["k_rope"], pos)
                new_kv = {"c_kv": ck, "k_rope": kr}
            else:
                a, kc, vc = attn_decode(cfg, lp["attn"], h, kv["k"], kv["v"],
                                        pos)
                new_kv = {"k": kc, "v": vc}
            x = x + a
            h = rms_norm(x, lp["norm2"], cfg.norm_eps)
            if "moe" in lp:
                y, _ = moe_mod.moe_forward(cfg, lp["moe"], h, dropless=True)
            else:
                y = swiglu(lp["mlp"], h)
            return x + y, new_kv

        new_cache = {}
        if "dense" in cache:
            x, new_cache["dense"] = jax.lax.scan(
                body, x, (params["dense_blocks"], cache["dense"]),
                unroll=scan_unroll())
        x, new_cache["main"] = jax.lax.scan(
            body, x, (params["blocks"], cache["main"]),
            unroll=scan_unroll())
    elif cfg.block_type == "rwkv6":
        x, state = _run_rwkv_stack(cfg, params, x, cache["rwkv"],
                                   remat=False)
        new_cache = {"rwkv": state}
    else:
        sp = params.get("shared_attn")

        def attn_fn(x, gattn):
            kc, vc = gattn
            h = rms_norm(x, sp["norm1"], cfg.norm_eps)
            a, kc, vc = attn_decode(cfg, sp["attn"], h, kc, vc, pos)
            x = x + a
            h = rms_norm(x, sp["norm2"], cfg.norm_eps)
            return x + swiglu(sp["mlp"], h), (kc, vc)

        x, new_mamba, (ks, vs) = _run_zamba_stack(
            cfg, params, x, None, cache["mamba"], attn_fn,
            (cache["attn_k"], cache["attn_v"]), remat=False)
        new_cache = {"mamba": new_mamba, "attn_k": ks, "attn_v": vs}

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _head_logits(cfg, params, x[:, 0]), new_cache

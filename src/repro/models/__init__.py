"""Model substrate: unified API over all assigned architecture families."""

from repro.models.transformer import (decode_step, forward, init_params,
                                      loss_fn, make_cache, prefill)

__all__ = ["init_params", "forward", "loss_fn", "prefill", "decode_step",
           "make_cache"]

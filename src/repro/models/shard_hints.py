"""Best-effort activation-sharding hints.

`constrain(x, *axes)` applies jax.lax.with_sharding_constraint using only
the mesh axes that (a) exist in the ambient abstract mesh and (b) divide
the corresponding dim — so model code can pin the sharding the SPMD
partitioner should pick on the production mesh while remaining a no-op in
CPU tests and single-device runs.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def get_abstract_mesh():
    """`jax.sharding.get_abstract_mesh`, reaching into `jax._src.mesh` on
    older releases (e.g. 0.4.x) where it is not yet public. Returns None
    when unavailable so callers degrade to the unsharded no-op path."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is None:
        try:
            from jax._src.mesh import get_abstract_mesh as fn
        except ImportError:
            return None
    try:
        mesh = fn()
    except Exception:
        return None
    # older jax returns internal context objects from the _src fallback;
    # only a real (Abstract)Mesh with axis names is usable
    return mesh if hasattr(mesh, "axis_names") else None


def constrain(x, *axes):
    mesh = get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    spec = []
    for want, dim in zip(axes, x.shape):
        ok = (want is not None and want in mesh.axis_names
              and dim % mesh.shape[want] == 0)
        spec.append(want if ok else None)
    if not any(spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))

"""Best-effort activation-sharding hints.

`constrain(x, *axes)` applies jax.lax.with_sharding_constraint using only
the mesh axes that (a) exist in the ambient abstract mesh and (b) divide
the corresponding dim — so model code can pin the sharding the SPMD
partitioner should pick on the production mesh while remaining a no-op in
CPU tests and single-device runs.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def constrain(x, *axes):
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    spec = []
    for want, dim in zip(axes, x.shape):
        ok = (want is not None and want in mesh.axis_names
              and dim % mesh.shape[want] == 0)
        spec.append(want if ok else None)
    if not any(spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))

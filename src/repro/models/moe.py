"""Mixture-of-Experts layer: top-k router + capacity-based dispatch.

TPU-native formulation, GShard/Switch-style: the token stream is split
into G dispatch GROUPS (G = the ambient mesh's `data` size, 1 on a single
device), each group gets its own capacity and a group-LOCAL cumsum for
slot assignment, so dispatch never needs cross-shard prefix sums and the
expert-major buffer [G, E, C_g, D] shards cleanly as
P("data", "model", None, None) — experts over `model` (EP), groups over
`data` (DP). Expert compute is one batched einsum over stacked expert
weights (MXU friendly). Tokens beyond an expert's per-group capacity are
dropped (classic GShard semantics); capacity_factor controls the rate.

§Perf history: the original single-group global-cumsum dispatch forced
XLA SPMD to REPLICATE the expert einsum on every chip (the scatter with
global indices could not be partitioned) — 256x redundant expert compute
on the production mesh. The grouped formulation is iteration C3 in
EXPERIMENTS.md.

A load-balance auxiliary loss (Switch-style, computed over ALL tokens) is
returned alongside.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, dense_params, swiglu, swiglu_params


from repro.models.shard_hints import constrain as _constrain
from repro.models.shard_hints import get_abstract_mesh


def _dispatch_groups(n: int) -> int:
    """Number of dispatch groups = ambient `data` axis size (1 if absent
    or indivisible)."""
    mesh = get_abstract_mesh()
    if mesh is None or "data" not in mesh.axis_names:
        return 1
    g = mesh.shape["data"]
    return g if n % g == 0 else 1


def moe_params(key, cfg: ModelConfig, dtype) -> Params:
    e, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    scale = 1.0 / jnp.sqrt(d)
    p = {
        "router": dense_params(ks[0], d, e, jnp.float32),
        "w_gate": jax.random.normal(ks[1], (e, d, f)).astype(dtype) * scale,
        "w_up": jax.random.normal(ks[2], (e, d, f)).astype(dtype) * scale,
        "w_down": jax.random.normal(ks[3], (e, f, d)).astype(dtype)
                  / jnp.sqrt(f),
    }
    if cfg.num_shared_experts:
        p["shared"] = swiglu_params(
            ks[4], d, cfg.num_shared_experts * cfg.moe_d_ff, dtype)
    return p


def _group_dispatch(xg, top_e, top_p, e: int, k: int, cap: int):
    """Per-group dispatch. xg: [M, D]; top_e/top_p: [M, k].
    Returns (xe [E, cap, D], flat_idx [M*k], weight [M*k])."""
    m, d = xg.shape
    flat_e = top_e.reshape(m * k)                           # slot-major
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)     # [M*k, E]
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot          # group-LOCAL
    pos = jnp.sum(pos_in_e * onehot, axis=-1)               # [M*k]
    keep = pos < cap
    flat_idx = jnp.where(keep, flat_e * cap + pos, e * cap)  # drop slot
    tok_idx = jnp.tile(jnp.arange(m)[:, None], (1, k)).reshape(m * k)
    buf = jnp.zeros((e * cap + 1, d), xg.dtype)
    buf = buf.at[flat_idx].set(xg[tok_idx], mode="drop",
                               unique_indices=False)
    xe = buf[: e * cap].reshape(e, cap, d)
    weight = (top_p.reshape(m * k) * keep)
    return xe, flat_idx, weight


def _group_combine(ye, flat_idx, weight, m: int, k: int):
    """ye: [E, cap, D] -> y [M, D] (router-prob weighted)."""
    e, cap, d = ye.shape
    ye_flat = jnp.concatenate(
        [ye.reshape(e * cap, d), jnp.zeros((1, d), ye.dtype)], axis=0)
    gathered = ye_flat[flat_idx]                            # [M*k, D]
    w = weight.astype(gathered.dtype)
    return jnp.sum((gathered * w[:, None]).reshape(m, k, d), axis=1)


def moe_forward(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                capacity_factor: float | None = None,
                dropless: bool = False):
    """x: [B, T, D] -> (y [B, T, D], aux_loss scalar).

    ``dropless=True`` sizes the per-expert capacity so no token can be
    dropped (each token occupies at most one slot per expert, so cap = m
    suffices). Serving paths use it: capacity dropping is a training
    throughput tradeoff, and it breaks prefill/decode equivalence — the
    same token drops in a crowded prefill but not in a 1-token decode."""
    b, t, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    n = b * t
    g = _dispatch_groups(n)
    m = n // g                                              # tokens/group
    if dropless:
        cap = m
    else:
        cf = (cfg.capacity_factor if capacity_factor is None
              else capacity_factor)
        cap = max(int(m * k * cf / e), 1)
    # round capacity to a lane-friendly multiple of 8
    cap = (cap + 7) // 8 * 8

    xf = x.reshape(n, d)
    router_logits = (xf.astype(jnp.float32)
                     @ p["router"]["w"].astype(jnp.float32))      # [N, E]
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                        # [N, k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)        # renormalise

    # ---- load-balance aux loss (Switch): E * sum_e f_e * P_e ----
    me = jnp.mean(probs, axis=0)                                   # [E]
    onehot_any = jnp.sum(jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=1)
    ce = jnp.mean(onehot_any, axis=0) / k                          # [E]
    aux = e * jnp.sum(me * ce)

    # ---- grouped dispatch: G groups, group-local capacity + cumsum ----
    xg = _constrain(xf.reshape(g, m, d), "data", None, None)
    te = top_e.reshape(g, m, k)
    tp = top_p.reshape(g, m, k)
    xe, flat_idx, weight = jax.vmap(
        lambda xi, ei, pi: _group_dispatch(xi, ei, pi, e, k, cap))(
        xg, te, tp)                                 # xe: [G, E, cap, D]
    xe = _constrain(xe, "data", "model", None, None)

    # ---- expert compute: stacked swiglu, batched over groups ----
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])) \
        * jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])       # [G, E, cap, D]
    ye = _constrain(ye, "data", "model", None, None)

    # ---- combine: per-group gather, router-prob weighted ----
    y = jax.vmap(lambda yi, fi, wi: _group_combine(yi, fi, wi, m, k))(
        ye, flat_idx, weight)                               # [G, M, D]
    y = _constrain(y, "data", None, None).reshape(n, d)

    if cfg.num_shared_experts:
        y = y + swiglu(p["shared"], xf)
    return y.reshape(b, t, d), aux.astype(jnp.float32)

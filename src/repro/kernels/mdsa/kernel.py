"""MDSA Mahalanobis-distance Pallas TPU kernel.

Surprise adequacy is the paper's recommended 1st-level supervisor for
non-softmax local models; its hot spot is d(x) = sqrt((x-mu)^T P (x-mu))
over a batch of activation traces. The quadratic form is evaluated as two
MXU matmuls per (batch-block, feature-block) tile:

    z_j  += y_i @ P[i, j]        (accumulated over feature blocks i)
    d2   += rowsum(z_j * y_j)    (accumulated over feature blocks j)

Grid: (batch blocks, D blocks j, D blocks i) with i innermost; z lives in
VMEM scratch [BB, DB]; d2 in scratch [BB]. Block sizes are multiples of
128 to align the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _kernel(y_i_ref, p_ref, y_j_ref, out_ref, z, d2, *, nd: int):
    j, i = pl.program_id(1), pl.program_id(2)

    @pl.when(jnp.logical_and(j == 0, i == 0))
    def _init_row():
        d2[...] = jnp.zeros_like(d2)

    @pl.when(i == 0)
    def _init_z():
        z[...] = jnp.zeros_like(z)

    y_i = y_i_ref[...].astype(jnp.float32)          # [BB, DB] (block i)
    z[...] += jax.lax.dot(y_i, p_ref[...].astype(jnp.float32),
                          precision=jax.lax.Precision.HIGHEST)

    @pl.when(i == nd - 1)
    def _accumulate():
        y_j = y_j_ref[...].astype(jnp.float32)      # [BB, DB] (block j)
        d2[...] += jnp.sum(z[...] * y_j, axis=1)

    @pl.when(jnp.logical_and(j == nd - 1, i == nd - 1))
    def _finish():
        out_ref[...] = jnp.sqrt(jnp.maximum(d2[...], 0.0))


@functools.partial(jax.jit, static_argnames=("bb", "db", "interpret"))
def mdsa_pallas(x: jnp.ndarray, mean: jnp.ndarray, prec: jnp.ndarray, *,
                bb: int = 128, db: int = 128,
                interpret: bool = False) -> jnp.ndarray:
    b, d = x.shape
    assert b % bb == 0 and d % db == 0, (b, d, bb, db)
    y = x.astype(jnp.float32) - mean.astype(jnp.float32)
    nb, nd = b // bb, d // db
    return pl.pallas_call(
        functools.partial(_kernel, nd=nd),
        grid=(nb, nd, nd),
        in_specs=[
            pl.BlockSpec((bb, db), lambda b_, j, i: (b_, i)),   # y block i
            pl.BlockSpec((db, db), lambda b_, j, i: (i, j)),    # P[i, j]
            pl.BlockSpec((bb, db), lambda b_, j, i: (b_, j)),   # y block j
        ],
        out_specs=pl.BlockSpec((bb,), lambda b_, j, i: (b_,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bb, db), jnp.float32),
                        pltpu.VMEM((bb,), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
    )(y, prec, y)

"""Jit'd wrapper for the MDSA kernel (TPU Pallas / CPU jnp fallback)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.mdsa.kernel import mdsa_pallas
from repro.kernels.mdsa.ref import mdsa_ref


def mdsa_distance(x: jnp.ndarray, mean: jnp.ndarray, prec: jnp.ndarray, *,
                  bb: int = 128, db: int = 128, force_pallas: bool = False,
                  interpret: bool = False) -> jnp.ndarray:
    """Mahalanobis distance per row; pads batch/features as needed."""
    on_tpu = jax.default_backend() == "tpu"
    if not (force_pallas or on_tpu):
        return mdsa_ref(x, mean, prec)
    b, d = x.shape
    pad_b, pad_d = (-b) % bb, (-d) % db
    if pad_d:
        x = jnp.pad(x, ((0, 0), (0, pad_d)))
        mean = jnp.pad(mean, (0, pad_d))
        prec = jnp.pad(prec, ((0, pad_d), (0, pad_d)))
    if pad_b:
        x = jnp.pad(x, ((0, pad_b), (0, 0)))
    out = mdsa_pallas(x, mean, prec, bb=bb, db=db,
                      interpret=interpret or not on_tpu)
    return out[:b]

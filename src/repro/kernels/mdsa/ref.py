"""Pure-jnp oracle for the MDSA Mahalanobis-distance kernel."""

from __future__ import annotations

import jax.numpy as jnp


def mdsa_ref(x: jnp.ndarray, mean: jnp.ndarray,
             prec: jnp.ndarray) -> jnp.ndarray:
    """x: [B, D], mean: [D], prec: [D, D] -> sqrt((x-mu)^T P (x-mu)) [B]."""
    y = x.astype(jnp.float32) - mean.astype(jnp.float32)
    d2 = jnp.einsum("bd,de,be->b", y, prec.astype(jnp.float32), y)
    return jnp.sqrt(jnp.maximum(d2, 0.0))

"""Fused supervisor-confidence Pallas TPU kernel.

The 1st/2nd-level supervisors need (argmax, max-softmax, PCS, entropy) of
an LM-head output whose vocab runs to 152k. Done naively that is four
passes over the logits in HBM (softmax + top-k + entropy). This kernel
streams vocab blocks HBM->VMEM once, maintaining online-softmax style
running statistics per row:

    m1, a1 : running max logit + its index      -> prediction, max-softmax
    m2     : running second-max logit           -> PCS
    s      : running sum exp(x - m1)            -> normaliser
    t      : running sum exp(x - m1) * x        -> entropy via
             H = (m1 + log s) - t / s  ... with exact rescaling on every
             new m1 (identical algebra to flash-attention's online update).

Grid: (batch blocks, vocab blocks); vocab is the innermost ("arbitrary")
dimension so the per-row scratch carries across vocab steps. Block shapes
are (BB, VB) = (8, 2048) by default — 64 KiB of VMEM per logits tile,
MXU-independent (pure VPU reductions).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG = -1e30


def _kernel(x_ref, pred_ref, ms_ref, pcs_ref, ent_ref,
            m1, m2, s, t, a1, *, nv: int, vb: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m1[...] = jnp.full_like(m1, NEG)
        m2[...] = jnp.full_like(m2, NEG)
        s[...] = jnp.zeros_like(s)
        t[...] = jnp.zeros_like(t)
        a1[...] = jnp.zeros_like(a1)

    x = x_ref[...].astype(jnp.float32)                     # [BB, VB]
    col = j * vb + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)

    bm1 = jnp.max(x, axis=1)                               # block max
    ba1 = jnp.argmax(x, axis=1).astype(jnp.int32) + j * vb
    x2 = jnp.where(col == ba1[:, None] , NEG, x)
    bm2 = jnp.max(x2, axis=1)                              # block 2nd max
    bs = jnp.sum(jnp.exp(x - bm1[:, None]), axis=1)
    bt = jnp.sum(jnp.exp(x - bm1[:, None]) * x, axis=1)

    om1, om2, os, ot, oa1 = m1[...], m2[...], s[...], t[...], a1[...]
    nm1 = jnp.maximum(om1, bm1)
    # merged second max: best of (loser of the two maxes, both second maxes)
    nm2 = jnp.maximum(jnp.minimum(om1, bm1), jnp.maximum(om2, bm2))
    c_old = jnp.exp(om1 - nm1)
    c_new = jnp.exp(bm1 - nm1)
    m1[...] = nm1
    m2[...] = nm2
    s[...] = os * c_old + bs * c_new
    t[...] = ot * c_old + bt * c_new
    a1[...] = jnp.where(bm1 > om1, ba1, oa1)

    @pl.when(j == nv - 1)
    def _finish():
        zf = s[...]
        pred_ref[...] = a1[...]
        ms_ref[...] = 1.0 / zf                               # exp(m1-m1)/s
        pcs_ref[...] = (1.0 - jnp.exp(m2[...] - m1[...])) / zf
        ent_ref[...] = (m1[...] + jnp.log(zf)) - t[...] / zf


@functools.partial(jax.jit, static_argnames=("bb", "vb", "interpret"))
def maxconf_pallas(logits: jnp.ndarray, *, bb: int = 8, vb: int = 2048,
                   interpret: bool = False) -> dict[str, jnp.ndarray]:
    b, v = logits.shape
    assert b % bb == 0 and v % vb == 0, (b, v, bb, vb)
    nb, nv = b // bb, v // vb
    grid = (nb, nv)
    out_shapes = (
        jax.ShapeDtypeStruct((b,), jnp.int32),    # prediction
        jax.ShapeDtypeStruct((b,), jnp.float32),  # max_softmax
        jax.ShapeDtypeStruct((b,), jnp.float32),  # pcs
        jax.ShapeDtypeStruct((b,), jnp.float32),  # entropy
    )
    row_spec = pl.BlockSpec((bb,), lambda i, j: (i,))
    pred, ms, pcs, ent = pl.pallas_call(
        functools.partial(_kernel, nv=nv, vb=vb),
        grid=grid,
        in_specs=[pl.BlockSpec((bb, vb), lambda i, j: (i, j))],
        out_specs=(row_spec, row_spec, row_spec, row_spec),
        out_shape=out_shapes,
        scratch_shapes=[pltpu.VMEM((bb,), jnp.float32)] * 4
                       + [pltpu.VMEM((bb,), jnp.int32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(logits)
    return {"prediction": pred, "max_softmax": ms, "pcs": pcs,
            "entropy": ent}

"""Jit'd public wrapper for the fused supervisor-confidence kernel.

On TPU dispatches to the Pallas kernel; elsewhere (this CPU container)
falls back to the jnp oracle, so callers use one API everywhere. Pads the
batch to the block multiple when needed.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.maxconf.kernel import maxconf_pallas
from repro.kernels.maxconf.ref import maxconf_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def maxconf(logits: jnp.ndarray, *, bb: int = 8, vb: int = 2048,
            force_pallas: bool = False, interpret: bool = False):
    """logits [B, V] -> {prediction, max_softmax, pcs, entropy} per row."""
    b, v = logits.shape
    if not (force_pallas or _on_tpu()):
        return maxconf_ref(logits)
    pad_b = (-b) % bb
    pad_v = (-v) % vb
    if pad_v:
        logits = jnp.pad(logits, ((0, 0), (0, pad_v)),
                         constant_values=-1e30)
    if pad_b:
        logits = jnp.pad(logits, ((0, pad_b), (0, 0)))
    out = maxconf_pallas(logits, bb=bb, vb=vb,
                         interpret=interpret or not _on_tpu())
    if pad_b:
        out = {k: a[:b] for k, a in out.items()}
    return out

"""Pure-jnp oracle for the fused supervisor-confidence kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def maxconf_ref(logits: jnp.ndarray) -> dict[str, jnp.ndarray]:
    """logits: [B, V] -> per-row supervisor metadata:
    prediction (argmax), max_softmax, pcs (top1 - top2 softmax), entropy."""
    lg = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(lg, axis=-1)
    p = jnp.exp(logp)
    top2 = jax.lax.top_k(p, 2)[0]
    return {
        "prediction": jnp.argmax(lg, axis=-1).astype(jnp.int32),
        "max_softmax": top2[:, 0],
        "pcs": top2[:, 0] - top2[:, 1],
        "entropy": -jnp.sum(p * logp, axis=-1),
    }

"""Jit'd wrapper for flash attention (TPU Pallas / CPU jnp fallback)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, window: int = 0, qb: int = 256,
              kb: int = 256, force_pallas: bool = False,
              interpret: bool = False) -> jnp.ndarray:
    on_tpu = jax.default_backend() == "tpu"
    if not (force_pallas or on_tpu):
        return attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention(q, k, v, causal=causal, window=window, qb=qb,
                           kb=kb, interpret=interpret or not on_tpu)

"""Flash attention (prefill) Pallas TPU kernel — GQA + causal + SWA.

The remote tier's 32k prefill is the cascade's single most expensive
compute step. This kernel streams KV blocks through VMEM with the online-
softmax recurrence so the [T, S] score matrix never exists in HBM:

  grid = (batch*kv-head, q blocks, kv blocks), kv innermost;
  per (q-block) scratch: acc [G*QB, hd], m and l [G*QB] rows;
  causal + sliding-window handled by masking inside the block (blocks
  fully outside the mask are skipped via `pl.when` on block indices).

Q blocks carry the G query heads of the kv group fused into rows
(GQA-native layout: [G*QB, hd] tiles keep the MXU fed at kv-head
granularity with no head broadcast in HBM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc, m, l, *,
            scale: float, causal: bool, window: int,
            qb: int, kb: int, nk: int, g: int):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m[...] = jnp.full_like(m, NEG)
        l[...] = jnp.zeros_like(l)

    q_start = iq * qb
    k_start = ik * kb
    # skip blocks fully masked out (causal: kv entirely after q;
    # SWA: kv entirely before the window)
    run = True
    if causal:
        run = k_start <= q_start + qb - 1
    if window:
        run = jnp.logical_and(run, k_start + kb - 1 > q_start - window)

    @pl.when(run)
    def _block():
        hd = q_ref.shape[-1]
        q = q_ref[...].astype(jnp.float32).reshape(g * qb, hd)
        k = k_ref[...].astype(jnp.float32).reshape(kb, hd)
        v = v_ref[...].astype(jnp.float32).reshape(kb, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                precision=jax.lax.Precision.HIGHEST)
        s = s * scale                                # [G*QB, KB]
        rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % qb
        cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        qpos = q_start + rows
        kpos = k_start + cols
        mask = jnp.ones(s.shape, bool)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG)

        m_prev, l_prev = m[...], l[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l[...] = l_prev * alpha + jnp.sum(p, axis=1)
        acc[...] = acc[...] * alpha[:, None] + jax.lax.dot(
            p, v, precision=jax.lax.Precision.HIGHEST)
        m[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        out = acc[...] / jnp.maximum(l[...], 1e-30)[:, None]
        o_ref[...] = out.reshape(o_ref.shape).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "qb", "kb",
                                    "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0, qb: int = 256,
                    kb: int = 256, interpret: bool = False) -> jnp.ndarray:
    """q: [B, T, H, hd]; k, v: [B, S, K, hd]. Returns [B, T, H, hd]."""
    b, t, h, hd = q.shape
    s, kh = k.shape[1], k.shape[2]
    g = h // kh
    qb = min(qb, t)
    kb = min(kb, s)
    assert t % qb == 0 and s % kb == 0
    nq, nk = t // qb, s // kb
    scale = 1.0 / (hd ** 0.5)

    # GQA-native layout: [B*K, T*G?]. We fuse G into the row dim per
    # q block: rows = g * qb. Rearrange q -> [B*K, nq, G*QB, hd].
    qr = (q.reshape(b, t, kh, g, hd).transpose(0, 2, 3, 1, 4)
          .reshape(b * kh, g, t, hd))
    kr = k.transpose(0, 2, 1, 3).reshape(b * kh, s, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(b * kh, s, hd)

    def q_map(bh, iq, ik):
        return (bh, 0, iq, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          window=window, qb=qb, kb=kb, nk=nk, g=g),
        grid=(b * kh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, g, qb, hd), q_map),
            pl.BlockSpec((1, kb, hd), lambda bh, iq, ik: (bh, ik, 0)),
            pl.BlockSpec((1, kb, hd), lambda bh, iq, ik: (bh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, qb, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((b * kh, g, t, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((g * qb, hd), jnp.float32),
                        pltpu.VMEM((g * qb,), jnp.float32),
                        pltpu.VMEM((g * qb,), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(qr, kr, vr)
    return (out.reshape(b, kh, g, t, hd).transpose(0, 3, 1, 2, 4)
            .reshape(b, t, h, hd))

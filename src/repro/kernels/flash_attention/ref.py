"""Pure-jnp oracle for the flash-attention (prefill) kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int = 0) -> jnp.ndarray:
    """q: [B, T, H, hd]; k, v: [B, S, K, hd] (GQA: H multiple of K)."""
    b, t, h, hd = q.shape
    s, kh = k.shape[1], k.shape[2]
    g = h // kh
    qr = q.reshape(b, t, kh, g, hd).astype(jnp.float32)
    lg = jnp.einsum("btkgh,bskh->bkgts", qr, k.astype(jnp.float32))
    lg = lg / np.sqrt(hd)
    qpos = jnp.arange(t)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    lg = jnp.where(mask[None, None, None], lg, -1e30)
    w = jax.nn.softmax(lg, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", w, v.astype(jnp.float32))
    return out.reshape(b, t, h, hd).astype(q.dtype)

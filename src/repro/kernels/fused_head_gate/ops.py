"""Jit-friendly public wrapper for the fused local-head -> gate kernel.

On TPU dispatches to the fused Pallas kernel (logits tiles live only in
VMEM; just ``(conf, pred, idx)`` leaves the device); elsewhere (this CPU
container) falls back to the jnp oracle so the serving engine uses one
API everywhere. Padding mirrors ``confidence_gate``: vocab padding adds
zero weight columns with ``-1e30`` bias (so padded logits carry no
softmax mass and never win the argmax); batch padding adds zero rows
excluded from selection via ``n_valid``.

``FusedLocalHead`` is the engine-facing carrier: a local model split as
``trunk`` (inputs -> hidden [B, D]) plus the final projection ``(w
[D, C], bias [C])``. ``CascadeEngine`` accepts it anywhere a plain
``local_apply`` is accepted and routes the gate through this fused op.

Early emit composes the same way as the standalone gate: pass ``emit``/
``emit_tag`` and the triple is surfaced through ``io_callback`` the
moment it lands (see confidence_gate.ops).
"""

from __future__ import annotations

from collections.abc import Callable
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.kernels.confidence_gate.kernel import SUPERVISORS
from repro.kernels.confidence_gate.ops import _emit_gate, _on_tpu
from repro.kernels.fused_head_gate.kernel import fused_head_gate_pallas
from repro.kernels.fused_head_gate.ref import fused_head_gate_ref

NEG = -1e30


@dataclass(frozen=True)
class FusedLocalHead:
    """Local model split for head->gate fusion: ``trunk`` maps the local
    input batch to hidden states [B, D]; ``(w, bias)`` is the final
    projection the fused kernel folds into the gate's scoring pass.

    Calling it composes the pieces (useful for oracles/tests): it is a
    drop-in ``local_apply`` that materialises full logits.
    """

    trunk: Callable[[jnp.ndarray], jnp.ndarray]
    w: jnp.ndarray                                         # [D, C]
    bias: jnp.ndarray | None = None                        # [C]

    def __call__(self, local_batch) -> jnp.ndarray:
        h = self.trunk(local_batch)
        logits = jnp.dot(h.astype(jnp.float32), self.w.astype(jnp.float32))
        if self.bias is not None:
            logits = logits + self.bias.astype(jnp.float32)[None, :]
        return logits


def fused_head_gate(hidden: jnp.ndarray, w: jnp.ndarray,
                    bias: jnp.ndarray | None = None, t_local=None,
                    n_valid=None, *, supervisor="max_softmax",
                    k: int | None = None, bb: int = 8, vb: int = 128,
                    force_pallas: bool = False, interpret: bool = False,
                    emit=None, emit_tag=None) -> dict[str, jnp.ndarray]:
    """hidden [B, D], w [D, C], bias [C]|None -> {conf [B], pred [B],
    idx [k]} without materialising the [B, C] logits in HBM.

    Same contract as ``confidence_gate`` (idx: ascending-confidence
    escalation candidates below ``t_local`` among rows ``< n_valid``,
    -1-padded); ``emit``/``emit_tag`` opt into the early-emit host
    callback.
    """
    b, d = hidden.shape
    dw, v = w.shape
    if d != dw:
        raise ValueError(f"hidden dim {d} != head dim {dw}")
    k = b if k is None else min(int(k), b)
    if callable(supervisor) or not (force_pallas or _on_tpu()):
        out = fused_head_gate_ref(hidden, w, bias, t_local, n_valid,
                                  supervisor=supervisor, k=k)
        if emit is not None:
            _emit_gate(emit, emit_tag, out)
        return out
    if supervisor not in SUPERVISORS:
        raise ValueError(f"unknown supervisor {supervisor!r}; "
                         f"expected one of {SUPERVISORS}")
    t = jnp.float32(jnp.inf) if t_local is None else \
        jnp.asarray(t_local, jnp.float32)
    n = jnp.int32(b) if n_valid is None else jnp.asarray(n_valid, jnp.int32)
    bias = jnp.zeros((v,), jnp.float32) if bias is None else \
        jnp.asarray(bias, jnp.float32)
    pad_b = (-b) % bb
    pad_v = (-v) % vb
    if pad_v:                     # zero weights + NEG bias: logits = -1e30
        w = jnp.pad(w, ((0, 0), (0, pad_v)))
        bias = jnp.pad(bias, (0, pad_v), constant_values=NEG)
    if pad_b:
        hidden = jnp.pad(hidden, ((0, pad_b), (0, 0)))
        n = jnp.minimum(n, b)                  # padded rows never escalate
    out = fused_head_gate_pallas(hidden, w, bias, t, n,
                                 supervisor=supervisor, k=k, bb=bb, vb=vb,
                                 interpret=interpret or not _on_tpu())
    if pad_b:
        out = {"conf": out["conf"][:b], "pred": out["pred"][:b],
               "idx": out["idx"]}
    if emit is not None:
        _emit_gate(emit, emit_tag, out)
    return out

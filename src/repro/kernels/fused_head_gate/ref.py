"""jnp oracle for the fused local-head -> confidence-gate op.

The fused kernel is algebraically the composition "project then gate":
materialise the logits with one matmul and delegate to the gate oracle.
The Pallas kernel must match this bitwise on the prediction/idx outputs
and to float tolerance on conf (same online-softmax rescaling algebra,
different summation order only across vocab blocks).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.confidence_gate.ref import confidence_gate_ref


def fused_head_gate_ref(hidden: jnp.ndarray, w: jnp.ndarray,
                        bias: jnp.ndarray | None = None, t_local=None,
                        n_valid=None, *, supervisor="max_softmax",
                        k: int | None = None) -> dict[str, jnp.ndarray]:
    """hidden [B, D], w [D, C], bias [C] or None -> {conf, pred, idx}."""
    logits = jnp.dot(hidden.astype(jnp.float32), w.astype(jnp.float32))
    if bias is not None:
        logits = logits + bias.astype(jnp.float32)[None, :]
    return confidence_gate_ref(logits, t_local, n_valid,
                               supervisor=supervisor, k=k)

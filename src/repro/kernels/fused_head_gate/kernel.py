"""Fused local-head -> confidence-gate Pallas TPU kernel.

The local tier's final projection produces ``[B, C]`` logits whose only
consumer is the confidence gate (``kernels/confidence_gate``): one
supervisor score, one argmax, one thresholded bottom-k. Materialising
those logits in HBM just to stream them back into the gate's scoring
pass doubles the hot path's HBM traffic for a tensor nothing else ever
reads. This kernel fuses the two: each grid step loads one ``[BB, D]``
hidden block and one ``[D, VB]`` slice of the head weight, computes the
``[BB, VB]`` logits tile on the MXU *in VMEM*, and folds it straight
into the same online-softmax running statistics the standalone gate
keeps (``_fold_stats`` — exact rescaling on every new running max). The
full-vocab logits never exist outside a VMEM tile; only the compact
``(conf [B], pred [B], idx [k])`` triple leaves the device.

Grid: (batch blocks, vocab blocks) with the vocab dimension innermost
("arbitrary") so the per-row scratch carries across vocab steps —
identical to the score kernel's schedule, plus one ``[BB, D] x [D, VB]``
dot per step (``preferred_element_type=f32`` keeps the MXU accumulator
in full precision). Selection reuses the gate's ``_select_kernel``
unchanged: thresholded ascending bottom-k over the [B] confidences with
SMEM-scalar ``t_local``/``n_valid``, so runtime retuning (paper §4.5)
never recompiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.kernels.confidence_gate.kernel import (_fold_stats, _init_stats,
                                                  _select_kernel,
                                                  _stats_epilogue)


def _head_gate_kernel(h_ref, w_ref, b_ref, conf_ref, pred_ref,
                      m1, m2, s, t, s2, a1, *, nv: int, vb: int,
                      supervisor: str):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        _init_stats(m1, m2, s, t, s2, a1)

    h = h_ref[...].astype(jnp.float32)                     # [BB, D]
    w = w_ref[...].astype(jnp.float32)                     # [D, VB]
    x = jnp.dot(h, w, preferred_element_type=jnp.float32)  # logits tile
    x = x + b_ref[...][None, :]
    _fold_stats(x, j * vb, m1, m2, s, t, s2, a1)

    @pl.when(j == nv - 1)
    def _finish():
        _stats_epilogue(conf_ref, pred_ref, m1, m2, s, t, s2, a1,
                        supervisor=supervisor)


@functools.partial(jax.jit, static_argnames=("supervisor", "k", "bb", "vb",
                                             "interpret"))
def fused_head_gate_pallas(hidden: jnp.ndarray, w: jnp.ndarray,
                           bias: jnp.ndarray, t_local: jnp.ndarray,
                           n_valid: jnp.ndarray, *, supervisor: str,
                           k: int, bb: int = 8, vb: int = 128,
                           interpret: bool = False
                           ) -> dict[str, jnp.ndarray]:
    """hidden [B, D] (B % bb == 0), w [D, C] (C % vb == 0), bias [C],
    t_local f32 scalar (+inf = no threshold), n_valid i32 scalar ->
    {conf, pred, idx}."""
    b, d = hidden.shape
    dw, v = w.shape
    assert d == dw and bias.shape == (v,), (hidden.shape, w.shape,
                                            bias.shape)
    assert b % bb == 0 and v % vb == 0, (b, v, bb, vb)
    nb, nv = b // bb, v // vb

    row_spec = pl.BlockSpec((bb,), lambda i, j: (i,))
    conf, pred = pl.pallas_call(
        functools.partial(_head_gate_kernel, nv=nv, vb=vb,
                          supervisor=supervisor),
        grid=(nb, nv),
        in_specs=[pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
                  pl.BlockSpec((d, vb), lambda i, j: (0, j)),
                  pl.BlockSpec((vb,), lambda i, j: (j,))],
        out_specs=(row_spec, row_spec),
        out_shape=(jax.ShapeDtypeStruct((b,), jnp.float32),
                   jax.ShapeDtypeStruct((b,), jnp.int32)),
        scratch_shapes=[pltpu.VMEM((bb,), jnp.float32)] * 5
                       + [pltpu.VMEM((bb,), jnp.int32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(hidden, w, bias)

    bp = b + (-b) % 128                                    # lane-align rows
    conf_row = jnp.full((1, bp), jnp.inf, jnp.float32).at[0, :b].set(conf)
    idx = pl.pallas_call(
        functools.partial(_select_kernel, k=k, bp=bp),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec(memory_space=pltpu.VMEM)],
        out_specs=pl.BlockSpec(memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((k,), jnp.int32),
        interpret=interpret,
    )(jnp.asarray(t_local, jnp.float32).reshape(1),
      jnp.asarray(n_valid, jnp.int32).reshape(1), conf_row)
    return {"conf": conf, "pred": pred, "idx": idx}

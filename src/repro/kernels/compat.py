"""Pallas version compatibility shims.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``;
kernels import the name from here so they build on both sides of the
rename (this container ships the older spelling).
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))

"""RWKV6 time-mix recurrence Pallas TPU kernel (chunked scan).

The Finch recurrence is sequential in T but embarrassingly parallel over
(batch, head): grid = (B*H, time chunks) with the [M, M] state resident in
VMEM scratch across chunks — HBM sees each input element exactly once and
the state never spills (M=64 -> 16 KiB fp32). Inside a chunk a
`fori_loop` applies the per-token update:

    y_t = r_t S + (r_t . (u o k_t)) v_t ;  S <- w_t o_rows S + k_t v_t^T

This is the TPU-native analogue of the paper-adjacent CUDA kernels RWKV
ships: the (M x M) outer products map to VPU/MXU ops and the chunk length
trades VMEM residency against grid overhead (long_500k path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref,
            state, *, tb: int, nt: int):
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        state[...] = s0_ref[...].reshape(state.shape)

    def step(t, _):
        r_t = r_ref[0, t, :].astype(jnp.float32)            # [M]
        k_t = k_ref[0, t, :].astype(jnp.float32)
        v_t = v_ref[0, t, :].astype(jnp.float32)
        w_t = w_ref[0, t, :].astype(jnp.float32)
        u = u_ref[0, :].astype(jnp.float32)
        s = state[...]
        y = (r_t[None, :] @ s)[0] + jnp.sum(r_t * u * k_t) * v_t
        y_ref[0, t, :] = y
        state[...] = w_t[:, None] * s + k_t[:, None] * v_t[None, :]
        return 0

    jax.lax.fori_loop(0, tb, step, 0)

    @pl.when(it == nt - 1)
    def _finish():
        sT_ref[...] = state[...].reshape(sT_ref.shape)


@functools.partial(jax.jit, static_argnames=("tb", "interpret"))
def rwkv6_scan(r, k, v, w, u, s0, *, tb: int = 128,
               interpret: bool = False):
    """r,k,v,w: [B,T,H,M]; u: [H,M]; s0: [B,H,M,M] fp32.
    Returns (y [B,T,H,M] fp32, s_T [B,H,M,M] fp32)."""
    b, t, h, m = r.shape
    tb = min(tb, t)
    assert t % tb == 0
    nt = t // tb

    def to_bh(z):
        return z.transpose(0, 2, 1, 3).reshape(b * h, t, m)

    rr, kk, vv, ww = map(to_bh, (r, k, v, w))
    uu = jnp.broadcast_to(u[None], (b, h, m)).reshape(b * h, m)
    ss = s0.reshape(b * h, m, m).astype(jnp.float32)

    y, s_t = pl.pallas_call(
        functools.partial(_kernel, tb=tb, nt=nt),
        grid=(b * h, nt),
        in_specs=[
            pl.BlockSpec((1, tb, m), lambda bh, it: (bh, it, 0)),
            pl.BlockSpec((1, tb, m), lambda bh, it: (bh, it, 0)),
            pl.BlockSpec((1, tb, m), lambda bh, it: (bh, it, 0)),
            pl.BlockSpec((1, tb, m), lambda bh, it: (bh, it, 0)),
            pl.BlockSpec((1, m), lambda bh, it: (bh, 0)),
            pl.BlockSpec((1, m, m), lambda bh, it: (bh, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, tb, m), lambda bh, it: (bh, it, 0)),
            pl.BlockSpec((1, m, m), lambda bh, it: (bh, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b * h, t, m), jnp.float32),
            jax.ShapeDtypeStruct((b * h, m, m), jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((m, m), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(rr, kk, vv, ww, uu, ss)
    y = y.reshape(b, h, t, m).transpose(0, 2, 1, 3)
    return y, s_t.reshape(b, h, m, m)

"""Jit'd wrapper for the RWKV6 scan (TPU Pallas / CPU jnp fallback)."""

from __future__ import annotations

import jax

from repro.kernels.rwkv6_scan.kernel import rwkv6_scan
from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref


def rwkv6_time_mix_scan(r, k, v, w, u, s0, *, tb: int = 128,
                        force_pallas: bool = False,
                        interpret: bool = False):
    on_tpu = jax.default_backend() == "tpu"
    if not (force_pallas or on_tpu):
        return rwkv6_scan_ref(r, k, v, w, u, s0)
    return rwkv6_scan(r, k, v, w, u, s0, tb=tb,
                      interpret=interpret or not on_tpu)

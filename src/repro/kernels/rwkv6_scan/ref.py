"""Pure-jnp oracle for the RWKV6 time-mix recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_scan_ref(r, k, v, w, u, s0):
    """Per-head Finch recurrence.

    r,k,v,w: [B, T, H, M]; u: [H, M]; s0: [B, H, M, M] fp32.
      y_t[j] = sum_i r[i] * (S[i,j] + u[i] k[i] v[j])
      S     <- diag(w_t) S + k_t v_t^T
    Returns (y [B, T, H, M] fp32, s_T [B, H, M, M]).
    """
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]
        att = s + u[None, :, :, None] * kv
        y = jnp.einsum("bhm,bhmn->bhn", r_t, att)
        s = w_t[..., :, None] * s + kv
        return s, y

    seq = tuple(jnp.moveaxis(z.astype(jnp.float32), 1, 0)
                for z in (r, k, v, w))
    s_t, ys = jax.lax.scan(step, s0.astype(jnp.float32), seq)
    return jnp.moveaxis(ys, 0, 1), s_t

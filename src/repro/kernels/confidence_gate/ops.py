"""Jit'd public wrapper for the fused confidence-gate kernel.

On TPU dispatches to the Pallas kernels; elsewhere (this CPU container)
falls back to the jnp oracle, so the serving engine uses one API
everywhere. Pads the batch/class dims to block multiples when needed
(class padding uses -1e30 so softmax mass and argmax are unaffected;
batch padding is excluded from selection via ``n_valid``).

Callable supervisors (e.g. a bound MDSA, paper §4.2) always take the
jnp path — the Pallas scoring kernel is specialised to the softmax
family it can compute from online statistics.

In-kernel early emit (DESIGN.md §11): pass ``emit`` (a host callback
``emit(tag, conf, pred, idx) -> None``) and the gate surfaces its output
triple to the host the moment the scoring/selection pass lands — via
``jax.experimental.io_callback`` from inside the enclosing jit — so a
streaming consumer can hand locally-trusted rows back at *gate* time
instead of waiting for the window's host half to fetch the device
buffer. ``emit_tag`` (an i32 scalar, typically the window sequence
number) rides along so the callback can route the triple. The callback
is effectful, not a value dependency: the op's return value is the same
device triple with or without it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import io_callback

from repro.kernels.confidence_gate.kernel import (SUPERVISORS,
                                                  confidence_gate_pallas)
from repro.kernels.confidence_gate.ref import confidence_gate_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _emit_gate(emit, emit_tag, out) -> None:
    """Surface the gate triple through the host callback (early emit)."""
    tag = (jnp.int32(0) if emit_tag is None
           else jnp.asarray(emit_tag, jnp.int32))
    io_callback(emit, None, tag, out["conf"], out["pred"], out["idx"],
                ordered=False)


def confidence_gate(logits: jnp.ndarray, t_local=None, n_valid=None, *,
                    supervisor="max_softmax", k: int | None = None,
                    bb: int = 8, vb: int = 128, force_pallas: bool = False,
                    interpret: bool = False, emit=None,
                    emit_tag=None) -> dict[str, jnp.ndarray]:
    """logits [B, C] -> {conf [B], pred [B], idx [k]}.

    ``idx`` holds up to ``k`` escalation candidates: row indices ascending
    by confidence, only rows ``< n_valid`` with ``conf < t_local``
    (``t_local=None`` disables the threshold); unused slots are -1.
    ``t_local``/``n_valid`` may be traced values — retuning never
    recompiles. ``emit``/``emit_tag`` opt into the in-kernel early-emit
    host callback (module docstring).
    """
    b, v = logits.shape
    k = b if k is None else min(int(k), b)
    if callable(supervisor) or not (force_pallas or _on_tpu()):
        out = confidence_gate_ref(logits, t_local, n_valid,
                                  supervisor=supervisor, k=k)
        if emit is not None:
            _emit_gate(emit, emit_tag, out)
        return out
    if supervisor not in SUPERVISORS:
        raise ValueError(f"unknown supervisor {supervisor!r}; "
                         f"expected one of {SUPERVISORS}")
    t = jnp.float32(jnp.inf) if t_local is None else \
        jnp.asarray(t_local, jnp.float32)
    n = jnp.int32(b) if n_valid is None else jnp.asarray(n_valid, jnp.int32)
    pad_b = (-b) % bb
    pad_v = (-v) % vb
    if pad_v:
        logits = jnp.pad(logits, ((0, 0), (0, pad_v)), constant_values=-1e30)
    if pad_b:
        logits = jnp.pad(logits, ((0, pad_b), (0, 0)))
        n = jnp.minimum(n, b)                  # padded rows never escalate
    out = confidence_gate_pallas(logits, t, n, supervisor=supervisor, k=k,
                                 bb=bb, vb=vb,
                                 interpret=interpret or not _on_tpu())
    if pad_b:
        out = {"conf": out["conf"][:b], "pred": out["pred"][:b],
               "idx": out["idx"]}
    if emit is not None:
        _emit_gate(emit, emit_tag, out)
    return out

"""Pure-jnp oracle for the fused confidence-gate kernel.

Semantics shared with the Pallas kernel (kernel.py):

  * score every row of a logits batch with one softmax-family supervisor
    (or any callable ``logits -> confidence``) and take its argmax;
  * select up to ``k`` escalation candidates: the lowest-confidence rows,
    ascending by confidence (ties broken by lowest row index, matching a
    stable sort), restricted to rows ``< n_valid`` (padded scheduler
    replicas are never escalated) and to ``conf < t_local`` when a
    threshold is given; unused slots are ``-1``.

Only the compact ``(conf [B], pred [B], idx [k])`` triple leaves the
device — never the full logits.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.supervisors import SOFTMAX_SUPERVISORS


def confidence_gate_ref(logits: jnp.ndarray, t_local=None, n_valid=None, *,
                        supervisor="max_softmax",
                        k: int | None = None) -> dict[str, jnp.ndarray]:
    """logits [B, C] -> {conf [B] f32, pred [B] i32, idx [k] i32}."""
    b = logits.shape[0]
    k = b if k is None else min(int(k), b)
    sup = (supervisor if callable(supervisor)
           else SOFTMAX_SUPERVISORS[supervisor])
    conf = sup(logits).astype(jnp.float32)
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    t = jnp.float32(jnp.inf) if t_local is None else \
        jnp.asarray(t_local, jnp.float32)
    n = jnp.int32(b) if n_valid is None else jnp.asarray(n_valid, jnp.int32)

    rows = jnp.arange(b, dtype=jnp.int32)
    masked = jnp.where(rows < n, conf, jnp.inf)
    order = jnp.argsort(masked).astype(jnp.int32)        # stable ascending
    # eligible rows form a prefix of the ascending order
    count = jnp.sum((masked[order[:k]] < t).astype(jnp.int32))
    idx = jnp.where(jnp.arange(k, dtype=jnp.int32) < count, order[:k], -1)
    return {"conf": conf, "pred": pred, "idx": idx}

"""Fused confidence-gate Pallas TPU kernels (sibling of kernels/maxconf).

The pipelined serving hot path (DESIGN.md §5) must decide *on device*
which rows of a local-tier logits batch escalate to the remote tier, so
that only the compact ``(conf, pred, idx)`` triple crosses the host
boundary instead of the full ``[B, C]`` logits.

Two kernels compose:

  * ``_score_kernel`` — one streaming pass over class blocks HBM->VMEM,
    maintaining online-softmax running statistics per row (exact
    rescaling on every new running max, flash-attention algebra):

        m1, a1 : running max logit + index  -> prediction, max-softmax
        m2     : running second-max logit   -> PCS
        s      : running sum exp(x - m1)    -> normaliser
        t      : running sum exp(x - m1)*x  -> entropy
        s2     : running sum exp(2(x - m1)) -> Gini (sum p^2 = s2 / s^2)

    The epilogue emits the confidence of the *one* supervisor the gate
    was built for (static arg), so a supervisor swap is a recompile, not
    a second pass.

  * ``_select_kernel`` — thresholded ascending top-k over the [B]
    confidence vector: k iterations of masked argmin (first-index tie
    break, matching a stable sort). Rows ``>= n_valid`` (padding) are
    excluded; once the running min reaches ``t_local`` every remaining
    slot is ``-1``. ``t_local``/``n_valid`` are SMEM scalars so runtime
    retuning (paper §4.5) never recompiles.

Grid: scoring is (batch blocks, class blocks) with the class dimension
innermost ("arbitrary") so per-row scratch carries across class steps;
selection is a single program over the padded row vector.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG = -1e30

SUPERVISORS = ("max_softmax", "pcs", "neg_entropy", "gini")


def _init_stats(m1, m2, s, t, s2, a1) -> None:
    """Reset the per-row online-softmax scratch at class block 0."""
    m1[...] = jnp.full_like(m1, NEG)
    m2[...] = jnp.full_like(m2, NEG)
    s[...] = jnp.zeros_like(s)
    t[...] = jnp.zeros_like(t)
    s2[...] = jnp.zeros_like(s2)
    a1[...] = jnp.zeros_like(a1)


def _fold_stats(x, col0, m1, m2, s, t, s2, a1) -> None:
    """Fold one ``[BB, VB]`` logits block (global column offset ``col0``)
    into the running statistics, rescaling on every new running max
    (flash-attention algebra). Shared by the logits-input score kernel
    and the fused head->gate kernel, which materialises ``x`` from the
    projection inside the same VMEM tile."""
    col = col0 + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)

    bm1 = jnp.max(x, axis=1)                               # block max
    ba1 = jnp.argmax(x, axis=1).astype(jnp.int32) + col0
    xm = jnp.where(col == ba1[:, None], NEG, x)
    bm2 = jnp.max(xm, axis=1)                              # block 2nd max
    e = jnp.exp(x - bm1[:, None])
    bs = jnp.sum(e, axis=1)
    bt = jnp.sum(e * x, axis=1)
    bs2 = jnp.sum(e * e, axis=1)

    om1, om2, os, ot, os2, oa1 = (m1[...], m2[...], s[...], t[...],
                                  s2[...], a1[...])
    nm1 = jnp.maximum(om1, bm1)
    # merged 2nd max: best of (loser of the two maxes, both second maxes)
    nm2 = jnp.maximum(jnp.minimum(om1, bm1), jnp.maximum(om2, bm2))
    c_old = jnp.exp(om1 - nm1)
    c_new = jnp.exp(bm1 - nm1)
    m1[...] = nm1
    m2[...] = nm2
    s[...] = os * c_old + bs * c_new
    t[...] = ot * c_old + bt * c_new
    s2[...] = os2 * c_old * c_old + bs2 * c_new * c_new
    a1[...] = jnp.where(bm1 > om1, ba1, oa1)


def _stats_epilogue(conf_ref, pred_ref, m1, m2, s, t, s2, a1, *,
                    supervisor: str) -> None:
    """Emit the one supervisor's confidence + prediction from the final
    running statistics (static supervisor: a swap is a recompile)."""
    zf = s[...]
    pred_ref[...] = a1[...]
    if supervisor == "max_softmax":
        conf_ref[...] = 1.0 / zf                           # exp(m1-m1)/s
    elif supervisor == "pcs":
        conf_ref[...] = (1.0 - jnp.exp(m2[...] - m1[...])) / zf
    elif supervisor == "neg_entropy":
        conf_ref[...] = t[...] / zf - (m1[...] + jnp.log(zf))
    elif supervisor == "gini":
        conf_ref[...] = s2[...] / (zf * zf)
    else:  # pragma: no cover - guarded in ops.py
        raise ValueError(f"unknown supervisor {supervisor!r}")


def _score_kernel(x_ref, conf_ref, pred_ref, m1, m2, s, t, s2, a1, *,
                  nv: int, vb: int, supervisor: str):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        _init_stats(m1, m2, s, t, s2, a1)

    x = x_ref[...].astype(jnp.float32)                     # [BB, VB]
    _fold_stats(x, j * vb, m1, m2, s, t, s2, a1)

    @pl.when(j == nv - 1)
    def _finish():
        _stats_epilogue(conf_ref, pred_ref, m1, m2, s, t, s2, a1,
                        supervisor=supervisor)


def _select_kernel(t_ref, n_ref, conf_ref, idx_ref, *, k: int, bp: int):
    t = t_ref[0]
    n = n_ref[0]
    conf = conf_ref[...]                                   # [1, BP]
    cols = jax.lax.broadcasted_iota(jnp.int32, conf.shape, 1)
    conf = jnp.where(cols < n, conf, jnp.inf)              # mask padding

    def body(i, c):
        mv = jnp.min(c)
        sel = jnp.min(jnp.where(c == mv, cols, bp))        # first-index tie
        take = mv < t
        idx_ref[i] = jnp.where(take, sel, -1)
        return jnp.where((cols == sel) & take, jnp.inf, c)

    jax.lax.fori_loop(0, k, body, conf)


@functools.partial(jax.jit, static_argnames=("supervisor", "k", "bb", "vb",
                                             "interpret"))
def confidence_gate_pallas(logits: jnp.ndarray, t_local: jnp.ndarray,
                           n_valid: jnp.ndarray, *, supervisor: str,
                           k: int, bb: int = 8, vb: int = 128,
                           interpret: bool = False) -> dict[str, jnp.ndarray]:
    """logits [B, C] (B % bb == 0, C % vb == 0), t_local f32 scalar
    (+inf = no threshold), n_valid i32 scalar -> {conf, pred, idx}."""
    b, v = logits.shape
    assert b % bb == 0 and v % vb == 0, (b, v, bb, vb)
    assert supervisor in SUPERVISORS, supervisor
    nb, nv = b // bb, v // vb

    row_spec = pl.BlockSpec((bb,), lambda i, j: (i,))
    conf, pred = pl.pallas_call(
        functools.partial(_score_kernel, nv=nv, vb=vb, supervisor=supervisor),
        grid=(nb, nv),
        in_specs=[pl.BlockSpec((bb, vb), lambda i, j: (i, j))],
        out_specs=(row_spec, row_spec),
        out_shape=(jax.ShapeDtypeStruct((b,), jnp.float32),
                   jax.ShapeDtypeStruct((b,), jnp.int32)),
        scratch_shapes=[pltpu.VMEM((bb,), jnp.float32)] * 5
                       + [pltpu.VMEM((bb,), jnp.int32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(logits)

    bp = b + (-b) % 128                                    # lane-align rows
    conf_row = jnp.full((1, bp), jnp.inf, jnp.float32).at[0, :b].set(conf)
    idx = pl.pallas_call(
        functools.partial(_select_kernel, k=k, bp=bp),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec(memory_space=pltpu.VMEM)],
        out_specs=pl.BlockSpec(memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((k,), jnp.int32),
        interpret=interpret,
    )(jnp.asarray(t_local, jnp.float32).reshape(1),
      jnp.asarray(n_valid, jnp.int32).reshape(1), conf_row)
    return {"conf": conf, "pred": pred, "idx": idx}

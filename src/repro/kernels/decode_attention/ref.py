"""Pure-jnp oracle for single-token GQA decode attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def decode_attention_ref(q: jnp.ndarray, k_cache: jnp.ndarray,
                         v_cache: jnp.ndarray,
                         kv_len: jnp.ndarray) -> jnp.ndarray:
    """q: [B, H, hd] (one token); caches: [B, S, K, hd]; kv_len: [B] valid
    slots per sequence. Returns [B, H, hd]."""
    b, h, hd = q.shape
    s, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    qr = q.reshape(b, kh, g, hd).astype(jnp.float32)
    lg = jnp.einsum("bkgh,bskh->bkgs", qr, k_cache.astype(jnp.float32))
    lg = lg / np.sqrt(hd)
    valid = jnp.arange(s)[None, :] < kv_len[:, None]       # [B, S]
    lg = jnp.where(valid[:, None, None, :], lg, -1e30)
    w = jax.nn.softmax(lg, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", w, v_cache.astype(jnp.float32))
    return out.reshape(b, h, hd).astype(q.dtype)

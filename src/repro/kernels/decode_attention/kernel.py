"""Single-token GQA decode-attention Pallas TPU kernel.

decode_32k / long_500k are *memory-bound*: each step streams the whole KV
cache (up to 500k tokens) from HBM for one query token. The kernel keeps
the full query head block resident in VMEM and streams KV in blocks with
the online-softmax recurrence; per-sequence `kv_len` masks invalid slots
(ring buffers / partially-filled caches).

Grid: (batch * kv-head, kv blocks), kv innermost; scratch acc [G, hd],
m/l [G]. The [G, KB] score tile is one MXU matmul per block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc, m, l, *,
            scale: float, kb: int, nk: int, kh: int):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m[...] = jnp.full_like(m, NEG)
        l[...] = jnp.zeros_like(l)

    kv_len = len_ref[0]
    k_start = ik * kb

    @pl.when(k_start < kv_len)
    def _block():
        g, hd = q_ref.shape[-2], q_ref.shape[-1]
        q = q_ref[...].astype(jnp.float32).reshape(g, hd)
        k = k_ref[...].astype(jnp.float32).reshape(kb, hd)
        v = v_ref[...].astype(jnp.float32).reshape(kb, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                precision=jax.lax.Precision.HIGHEST) * scale
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols < kv_len, s, NEG)
        m_prev, l_prev = m[...], l[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l[...] = l_prev * alpha + jnp.sum(p, axis=1)
        acc[...] = acc[...] * alpha[:, None] + jax.lax.dot(
            p, v, precision=jax.lax.Precision.HIGHEST)
        m[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        out = acc[...] / jnp.maximum(l[...], 1e-30)[:, None]
        o_ref[...] = out.reshape(o_ref.shape).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("kb", "interpret"))
def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, kv_len: jnp.ndarray, *,
                     kb: int = 512, interpret: bool = False) -> jnp.ndarray:
    """q: [B, H, hd]; caches: [B, S, K, hd]; kv_len: [B] int32."""
    b, h, hd = q.shape
    s, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    kb = min(kb, s)
    assert s % kb == 0
    nk = s // kb
    scale = 1.0 / (hd ** 0.5)

    qr = q.reshape(b, kh, g, hd).reshape(b * kh, g, hd)
    kr = k_cache.transpose(0, 2, 1, 3).reshape(b * kh, s, hd)
    vr = v_cache.transpose(0, 2, 1, 3).reshape(b * kh, s, hd)
    lens = jnp.repeat(kv_len.astype(jnp.int32), kh)          # [B*K]

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, kb=kb, nk=nk, kh=kh),
        grid=(b * kh, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda bh, ik: (bh,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, g, hd), lambda bh, ik: (bh, 0, 0)),
            pl.BlockSpec((1, kb, hd), lambda bh, ik: (bh, ik, 0)),
            pl.BlockSpec((1, kb, hd), lambda bh, ik: (bh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, hd), lambda bh, ik: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kh, g, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((g, hd), jnp.float32),
                        pltpu.VMEM((g,), jnp.float32),
                        pltpu.VMEM((g,), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(lens, qr, kr, vr)
    return out.reshape(b, kh, g, hd).reshape(b, h, hd)

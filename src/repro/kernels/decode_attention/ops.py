"""Jit'd wrapper for decode attention (TPU Pallas / CPU jnp fallback)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


def decode_attn(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                kv_len: jnp.ndarray, *, kb: int = 512,
                force_pallas: bool = False,
                interpret: bool = False) -> jnp.ndarray:
    on_tpu = jax.default_backend() == "tpu"
    if not (force_pallas or on_tpu):
        return decode_attention_ref(q, k_cache, v_cache, kv_len)
    return decode_attention(q, k_cache, v_cache, kv_len, kb=kb,
                            interpret=interpret or not on_tpu)

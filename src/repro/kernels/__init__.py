"""Pallas TPU kernels for the cascade's compute hot spots.

Each kernel package ships kernel.py (pl.pallas_call + BlockSpec),
ops.py (jit'd wrapper with CPU fallback) and ref.py (pure-jnp oracle).
Validated in interpret mode on CPU; TPU v5e is the compile target.
"""

from repro.kernels.confidence_gate.ops import confidence_gate
from repro.kernels.decode_attention.ops import decode_attn
from repro.kernels.flash_attention.ops import attention
from repro.kernels.fused_head_gate.ops import FusedLocalHead, fused_head_gate
from repro.kernels.maxconf.ops import maxconf
from repro.kernels.mdsa.ops import mdsa_distance
from repro.kernels.rwkv6_scan.ops import rwkv6_time_mix_scan

__all__ = ["confidence_gate", "fused_head_gate", "FusedLocalHead",
           "maxconf", "mdsa_distance", "attention", "decode_attn",
           "rwkv6_time_mix_scan"]

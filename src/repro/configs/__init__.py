"""Config registry: ``get_config("<arch-id>")`` / ``--arch <id>``.

One module per assigned architecture; each cites its source in the config's
``citation`` field. ``list_archs()`` enumerates the pool.
"""

from __future__ import annotations

import importlib

from repro.configs.base import (INPUT_SHAPES, ModelConfig, ShapeConfig,
                                shape_applicable)

ARCH_MODULES = {
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "hubert-xlarge": "hubert_xlarge",
    "yi-6b": "yi_6b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "qwen2-7b": "qwen2_7b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "deepseek-67b": "deepseek_67b",
    "pixtral-12b": "pixtral_12b",
    "zamba2-7b": "zamba2_7b",
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[arch]}")
    return mod.CONFIG


def list_archs() -> list[str]:
    return sorted(ARCH_MODULES)


__all__ = ["get_config", "list_archs", "ModelConfig", "ShapeConfig",
           "INPUT_SHAPES", "shape_applicable", "ARCH_MODULES"]

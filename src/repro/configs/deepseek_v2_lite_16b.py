"""DeepSeek-V2-Lite 16B [arXiv:2405.04434] — MoE with Multi-head Latent
Attention (kv_lora_rank=512), 2 shared + 64 routed experts, top-6, first
layer dense (the assignment line also mentions "160 routed", which is full
V2 — see DESIGN.md config-discrepancy note)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=10944,          # dense-layer FFN (layer 0)
    vocab_size=102400,
    use_mla=True,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    num_experts=64,
    num_experts_per_tok=6,
    num_shared_experts=2,
    moe_d_ff=1408,
    first_dense_layers=1,
    citation="arXiv:2405.04434",
)

"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409] — mistral-nemo decoder
backbone; the Pixtral-ViT vision encoder + projector is a stub
(input_specs provides patch embeddings)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000.0,
    frontend="vision",
    citation="hf:mistralai/Pixtral-12B-2409",
)

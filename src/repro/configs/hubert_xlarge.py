"""HuBERT X-Large [arXiv:2106.07447] — encoder-only audio backbone (same
arch as wav2vec2). The conv/mel frontend is a stub: input_specs provides
precomputed frame embeddings. vocab 504 = frame-classification targets."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    is_encoder=True,
    num_classes=504,
    frontend="audio",
    citation="arXiv:2106.07447",
)

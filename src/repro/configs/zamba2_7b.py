"""Zamba2-7B [arXiv:2411.15242] — Mamba2 backbone + shared attention
blocks. 81 mamba2 layers; one weight-shared attention+MLP block applied
every 9 layers (real model: ~every 6; 9 divides 81 and keeps the group
scan uniform — see DESIGN.md deviations). ssm_state=64."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,       # shared block is MHA
    d_ff=14336,
    vocab_size=32000,
    block_type="mamba2",
    ssm_state_dim=64,
    shared_attn_period=9,
    citation="arXiv:2411.15242",
)

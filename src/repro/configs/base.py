"""Model and input-shape configuration dataclasses.

Every assigned architecture is described by a single `ModelConfig`; the four
assignment input shapes by `ShapeConfig`. Configs are plain frozen dataclasses
so they hash/compare and can key jit caches.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # ---- attention variants ----
    attn_bias: bool = False            # qwen2-style QKV bias
    sliding_window: int = 0            # 0 = full attention; >0 = SWA window
    rope_theta: float = 10_000.0

    # ---- MLA (DeepSeek-V2 multi-head latent attention) ----
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # ---- MoE ----
    num_experts: int = 0               # routed experts (0 = dense MLP)
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                  # per-expert hidden dim
    first_dense_layers: int = 0        # leading layers that use a dense MLP
    router_aux_loss_coef: float = 0.001
    capacity_factor: float = 1.25

    # ---- block family ----
    block_type: str = "attn"           # attn | rwkv6 | mamba2
    ssm_state_dim: int = 0             # mamba2 N
    rwkv_head_dim: int = 64

    # ---- hybrid (zamba2): shared attention block every k mamba layers ----
    shared_attn_period: int = 0

    # ---- encoder-only / classification ----
    is_encoder: bool = False
    num_classes: int = 0               # >0 -> classification head on top

    # ---- modality frontend stub ----
    frontend: str = ""                 # "" | "audio" | "vision"

    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False

    # provenance (source paper / model card)
    citation: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_group_size(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def supports_decode(self) -> bool:
        return not self.is_encoder

    @property
    def subquadratic(self) -> bool:
        """True if a 500k-token decode context is tractable (per assignment)."""
        if self.block_type in ("rwkv6", "mamba2"):
            return True
        return self.sliding_window > 0

    @property
    def takes_embeddings(self) -> bool:
        """Modality-frontend archs consume precomputed embeddings (stub)."""
        return self.frontend != ""

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant of the same family: <=2 layers, d_model<=512,
        <=4 experts — runs a real forward/train step on CPU."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.num_heads, 4)
        n_kv = max(1, min(self.num_kv_heads, n_heads))
        # keep GQA grouping valid
        while n_heads % n_kv:
            n_kv -= 1
        changes = dict(
            name=self.name + "-smoke",
            num_layers=2,
            d_model=d_model,
            num_heads=n_heads,
            num_kv_heads=n_kv,
            head_dim=64 if self.head_dim else 0,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            dtype="float32",
        )
        if self.is_moe:
            changes.update(
                num_experts=4,
                num_experts_per_tok=min(2, self.num_experts_per_tok),
                num_shared_experts=min(1, self.num_shared_experts),
                moe_d_ff=128,
                first_dense_layers=min(1, self.first_dense_layers),
            )
        if self.use_mla:
            changes.update(kv_lora_rank=64, qk_nope_head_dim=32,
                           qk_rope_head_dim=16, v_head_dim=32)
        if self.sliding_window:
            changes.update(sliding_window=64)
        if self.shared_attn_period:
            changes.update(shared_attn_period=2)
        if self.num_classes:
            changes.update(num_classes=min(self.num_classes, 32))
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def with_sliding_window(cfg: ModelConfig, window: int) -> ModelConfig:
    """Beyond-paper variant: retrofit sliding-window attention onto a dense
    arch so the long_500k decode shape becomes sub-quadratic/O(window)
    (DESIGN.md §4 extension). The KV cache becomes a `window`-slot ring
    buffer; all other dims unchanged."""
    assert not cfg.is_encoder and cfg.block_type == "attn"
    return dataclasses.replace(cfg, name=f"{cfg.name}-swa{window}",
                               sliding_window=window)


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(applicable?, reason-if-not). Mirrors DESIGN.md's skip table."""
    if shape.kind == "decode":
        if not model.supports_decode:
            return False, "encoder-only architecture has no decode step"
        if shape.seq_len > 65_536 and not model.subquadratic:
            return False, "long_500k requires sub-quadratic attention"
    if model.is_encoder and shape.kind == "prefill":
        # encoders "prefill" == full forward; allowed.
        return True, ""
    return True, ""

"""Qwen3-MoE-235B-A22B [hf:Qwen/Qwen3-30B-A3B family] — 94L, 128 routed
experts top-8, GQA kv=4, head_dim 128."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,           # unused for MoE layers (moe_d_ff); kept for parity
    vocab_size=151936,
    rope_theta=1_000_000.0,
    num_experts=128,
    num_experts_per_tok=8,
    moe_d_ff=1536,
    citation="hf:Qwen/Qwen3-30B-A3B",
)

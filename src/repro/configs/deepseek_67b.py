"""DeepSeek-67B [arXiv:2401.02954] — llama-architecture dense, 95L, GQA kv=8."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
    citation="arXiv:2401.02954",
)

"""RWKV6 "Finch" 1.6B [arXiv:2404.05892] — attention-free, data-dependent
decay linear attention. O(1) state -> native long_500k."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,          # d_model / rwkv_head_dim
    num_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    block_type="rwkv6",
    rwkv_head_dim=64,
    citation="arXiv:2404.05892",
)

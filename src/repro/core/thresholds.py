"""Threshold selection strategies for supervisors (paper §4.5).

Three calibration modes:
  * nominal-distribution fit (Stocco et al. [54]): threshold at a target
    false-alarm quantile of NOMINAL validation confidences;
  * two-distribution separation (Dola et al. [10]): best separator between
    a nominal and an invalid confidence sample;
  * escalation-rate targeting (ours, for the runtime cascade): threshold
    whose expected remote fraction equals a budget rho — this is how the
    paper's "percentage of remote predictions" axis is hit in production.

All return plain floats; the runtime treats thresholds as *runtime-tunable
configuration* (paper §4.5 "Runtime Configuration"), see serving.scheduler.
"""

from __future__ import annotations

import numpy as np


def nominal_quantile_threshold(nominal_conf: np.ndarray,
                               false_alarm_rate: float) -> float:
    """Threshold so that `false_alarm_rate` of nominal inputs are rejected."""
    conf = np.sort(np.asarray(nominal_conf, np.float64))
    k = int(np.floor(false_alarm_rate * conf.size))
    if k <= 0:
        return float(conf[0]) - 1e-9
    return float(conf[k - 1])


def separation_threshold(nominal_conf: np.ndarray,
                         invalid_conf: np.ndarray) -> float:
    """Dola et al.: threshold maximising balanced accuracy of separating
    nominal (should be accepted) from invalid (should be rejected)."""
    nominal = np.asarray(nominal_conf, np.float64)
    invalid = np.asarray(invalid_conf, np.float64)
    cand = np.unique(np.concatenate([nominal, invalid]))
    best_t, best_sc = float(cand[0]) - 1e-9, -1.0
    for t in cand:
        tpr = np.mean(nominal > t)          # nominal accepted
        tnr = np.mean(invalid <= t)         # invalid rejected
        sc = 0.5 * (tpr + tnr)
        if sc > best_sc:
            best_sc, best_t = sc, float(t)
    return best_t


def escalation_rate_threshold(conf: np.ndarray, remote_fraction: float) -> float:
    """Threshold whose escalation rate (conf <= t) equals remote_fraction."""
    conf = np.sort(np.asarray(conf, np.float64))
    k = int(round(remote_fraction * conf.size))
    if k <= 0:
        return float(conf[0]) - 1e-9
    if k >= conf.size:
        return float(conf[-1]) + 1e-9
    return float(conf[k - 1])

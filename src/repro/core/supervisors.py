"""DNN supervisors — confidence monitors for BiSupervised (paper §3.2/§4.2).

Every supervisor maps model metadata to a scalar *confidence* per input
(higher = more trustworthy); a prediction is trusted iff confidence > t.
Uncertainty scores are negated into confidences so thresholding is uniform
(paper: "confidence and uncertainty are perfect complements" [45]).

All functions are jit-compatible and batched.

Implemented (paper §3.2.1):
  softmax family : MaxSoftmax (vanilla), PCS, negative entropy, Gini
  sampling family: MC-Dropout / Ensemble reducers (variation ratio,
                   mutual information, mean max-softmax)
  surprise family: MDSA (Mahalanobis-distance surprise adequacy)
  black-box      : autoencoder reconstruction error
  sequence       : per-token likelihood reducers (min — the paper's pick —
                   and product) for free-text QA / generative decode
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# softmax-based supervisors (metadata = logits [B, C])
# --------------------------------------------------------------------------

def max_softmax(logits: jnp.ndarray) -> jnp.ndarray:
    """Vanilla softmax / MaxSoftmax [Hendrycks & Gimpel 2016]."""
    return jnp.max(jax.nn.softmax(logits.astype(jnp.float32), -1), axis=-1)


def prediction_confidence_score(logits: jnp.ndarray) -> jnp.ndarray:
    """PCS: difference between the two highest likelihoods [Zhang et al.]."""
    sm = jax.nn.softmax(logits.astype(jnp.float32), -1)
    top2 = jax.lax.top_k(sm, 2)[0]
    return top2[..., 0] - top2[..., 1]


def negative_entropy(logits: jnp.ndarray) -> jnp.ndarray:
    """Confidence = -H(softmax) [Weiss & Tonella 2021]."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    return jnp.sum(jnp.exp(logp) * logp, axis=-1)


def gini_confidence(logits: jnp.ndarray) -> jnp.ndarray:
    """Confidence = sum p^2 (1 - Gini impurity) [DeepGini, Feng et al.]."""
    sm = jax.nn.softmax(logits.astype(jnp.float32), -1)
    return jnp.sum(sm * sm, axis=-1)


SOFTMAX_SUPERVISORS = {
    "max_softmax": max_softmax,
    "pcs": prediction_confidence_score,
    "neg_entropy": negative_entropy,
    "gini": gini_confidence,
}


# --------------------------------------------------------------------------
# sampling-based supervisors (metadata = logits [S, B, C] over S samples,
# from MC-Dropout passes or an ensemble — same quantifiers, per paper)
# --------------------------------------------------------------------------

def variation_ratio(sample_logits: jnp.ndarray) -> jnp.ndarray:
    """Confidence = fraction of samples agreeing with the modal class."""
    preds = jnp.argmax(sample_logits, axis=-1)                  # [S, B]
    s, b = preds.shape
    c = sample_logits.shape[-1]
    counts = jnp.sum(jax.nn.one_hot(preds, c, dtype=jnp.float32), axis=0)
    return jnp.max(counts, axis=-1) / s


def mutual_information(sample_logits: jnp.ndarray) -> jnp.ndarray:
    """Confidence = -MI = -(H[mean p] - mean H[p])  (BALD score, negated)."""
    logp = jax.nn.log_softmax(sample_logits.astype(jnp.float32), -1)
    p = jnp.exp(logp)
    p_mean = jnp.mean(p, axis=0)
    h_mean = -jnp.sum(p_mean * jnp.log(p_mean + 1e-12), axis=-1)
    mean_h = jnp.mean(-jnp.sum(p * logp, axis=-1), axis=0)
    return -(h_mean - mean_h)


def mean_max_softmax(sample_logits: jnp.ndarray) -> jnp.ndarray:
    """Confidence = max of the mean predictive distribution."""
    p = jax.nn.softmax(sample_logits.astype(jnp.float32), -1)
    return jnp.max(jnp.mean(p, axis=0), axis=-1)


SAMPLING_SUPERVISORS = {
    "variation_ratio": variation_ratio,
    "mutual_information": mutual_information,
    "mean_max_softmax": mean_max_softmax,
}


# --------------------------------------------------------------------------
# MDSA — Mahalanobis-distance surprise adequacy [Kim et al. 2020]
# metadata = activation trace (penultimate hidden) [B, D]
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class MDSAState:
    mean: jnp.ndarray       # [D]
    prec: jnp.ndarray       # [D, D] inverse covariance (precision)


def fit_mdsa(train_activations: jnp.ndarray, ridge: float = 1e-3) -> MDSAState:
    """Fit mean/precision on *training-set* activation traces."""
    a = train_activations.astype(jnp.float32)
    mu = jnp.mean(a, axis=0)
    x = a - mu
    cov = (x.T @ x) / a.shape[0]
    cov = cov + ridge * jnp.eye(cov.shape[0], dtype=jnp.float32)
    return MDSAState(mean=mu, prec=jnp.linalg.inv(cov))


def mdsa_confidence(state: MDSAState, activations: jnp.ndarray) -> jnp.ndarray:
    """Confidence = -sqrt((x-mu)^T Sigma^-1 (x-mu)) (low surprise = trusted)."""
    x = activations.astype(jnp.float32) - state.mean
    d2 = jnp.einsum("bd,de,be->b", x, state.prec, x)
    return -jnp.sqrt(jnp.maximum(d2, 0.0))


# --------------------------------------------------------------------------
# autoencoder supervisor (black-box) [Stocco et al. 2020]
# --------------------------------------------------------------------------

def autoencoder_confidence(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Tiny linear AE: confidence = -reconstruction MSE. params from
    fit_autoencoder. x: [B, D] (input features or embeddings)."""
    z = jnp.tanh(x @ params["enc"] + params["enc_b"])
    rec = z @ params["dec"] + params["dec_b"]
    return -jnp.mean(jnp.square(rec - x), axis=-1)


def fit_autoencoder(key, x: jnp.ndarray, latent: int = 16, steps: int = 200,
                    lr: float = 1e-2) -> dict:
    """Closed-loop gradient fit of the linear AE on nominal data."""
    d = x.shape[-1]
    k1, k2 = jax.random.split(key)
    params = {
        "enc": jax.random.normal(k1, (d, latent)) * (1.0 / jnp.sqrt(d)),
        "enc_b": jnp.zeros((latent,)),
        "dec": jax.random.normal(k2, (latent, d)) * (1.0 / jnp.sqrt(latent)),
        "dec_b": jnp.zeros((d,)),
    }

    def loss(p):
        return -jnp.mean(autoencoder_confidence(p, x))

    @jax.jit
    def step(p):
        g = jax.grad(loss)(p)
        return jax.tree.map(lambda a, b: a - lr * b, p, g)

    for _ in range(steps):
        params = step(params)
    return params


# --------------------------------------------------------------------------
# sequence reducers (free-text QA; metadata = per-token likelihood [B, T])
# --------------------------------------------------------------------------

def seq_min_likelihood(token_likelihoods: jnp.ndarray,
                       mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Paper's recommended reducer: min over predicted-token likelihoods
    (length-robust, unlike the product)."""
    lk = token_likelihoods.astype(jnp.float32)
    if mask is not None:
        lk = jnp.where(mask > 0, lk, 1.0)
    return jnp.min(lk, axis=-1)


def seq_prod_likelihood(token_likelihoods: jnp.ndarray,
                        mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Product reducer (literature default; length-biased — see paper §5.3.4)."""
    lk = jnp.log(jnp.clip(token_likelihoods.astype(jnp.float32), 1e-12, 1.0))
    if mask is not None:
        lk = lk * (mask > 0)
    return jnp.exp(jnp.sum(lk, axis=-1))


def equivalent_token_confidence(logits: jnp.ndarray,
                                groups: jnp.ndarray) -> jnp.ndarray:
    """IMDB-style 2nd-level supervisor: sum softmax mass over hard-coded
    equivalent tokens (e.g. "Negative"/"negative"/"bad").

    logits: [B, V]; groups: [G, V] 0/1 membership. Returns the mass of the
    best group (the remote model's effective class confidence)."""
    sm = jax.nn.softmax(logits.astype(jnp.float32), -1)
    group_mass = sm @ groups.T.astype(jnp.float32)         # [B, G]
    return jnp.max(group_mass, axis=-1)

"""Evaluation metrics from the paper (§5.1, §5.2).

RQ1: Request-Accuracy Curve (RAC) + AUC-RAC (Eq. 1).
RQ2: supervised accuracy, acceptance rate Delta, S-beta score
     [Weiss & Tonella 2021].
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class RAC:
    """Request-Accuracy Curve: system accuracy as a function of the remote
    fraction r, sweeping the 1st-level supervisor threshold over every
    input's confidence value (threshold-agnostic, as in §5.1)."""
    remote_fraction: np.ndarray   # [n+1] in [0, 1]
    accuracy: np.ndarray          # [n+1] system accuracy at that fraction

    @property
    def local_only(self) -> float:
        return float(self.accuracy[0])

    @property
    def remote_only(self) -> float:
        return float(self.accuracy[-1])

    def knee_points(self) -> dict[str, float]:
        """Named operating points used in §5.4.3: the best fraction and the
        remote-even fraction (fewest remote calls matching remote-only)."""
        best_i = int(np.argmax(self.accuracy))
        even = np.nonzero(self.accuracy >= self.remote_only - 1e-12)[0]
        even_i = int(even[0]) if len(even) else len(self.accuracy) - 1
        return {
            "best": float(self.remote_fraction[best_i]),
            "best_accuracy": float(self.accuracy[best_i]),
            "remote_even": float(self.remote_fraction[even_i]),
            "remote_even_accuracy": float(self.accuracy[even_i]),
        }


def request_accuracy_curve(local_conf: np.ndarray, local_correct: np.ndarray,
                           remote_correct: np.ndarray) -> RAC:
    """Exact paper semantics: for each i in 0..n, escalate the i inputs with
    the LOWEST local confidence to the remote model and measure system
    accuracy.

    local_conf: [n] 1st-level supervisor confidences,
    local_correct / remote_correct: [n] 0/1 per-input correctness.
    """
    n = local_conf.shape[0]
    order = np.argsort(local_conf, kind="stable")  # ascending: escalate first
    lc = np.asarray(local_correct, np.float64)[order]
    rc = np.asarray(remote_correct, np.float64)[order]
    # prefix i escalated -> remote; suffix -> local
    gain = np.concatenate([[0.0], np.cumsum(rc - lc)])
    acc = (np.sum(lc) + gain) / n
    return RAC(remote_fraction=np.arange(n + 1) / n, accuracy=acc)


def auc_rac(rac: RAC) -> float:
    """Eq. 1: mean accuracy over all thresholds, normalised to the
    local-only/remote-only accuracies. Random supervision -> 0.5; can
    exceed 1 under strong superaccuracy, or go below 0."""
    mean_acc = float(np.mean(rac.accuracy))
    denom = rac.remote_only - rac.local_only
    if abs(denom) < 1e-12:
        return float("nan")
    return (mean_acc - rac.local_only) / denom


# --------------------------------------------------------------------------
# RQ2 metrics
# --------------------------------------------------------------------------

def supervised_metrics(accepted: np.ndarray, correct: np.ndarray,
                       betas: tuple[float, ...] = (0.5, 1.0, 2.0)) -> dict:
    """Supervised accuracy (ACC-bar), acceptance rate (Delta) and S-beta.

    accepted: [n] bool — inputs the (two-level) supervisor trusts;
    correct:  [n] bool — correctness of the prediction the system returns.
    S_beta = (1+beta^2) * (acc * delta) / (beta^2 * acc + delta) —
    the weighted harmonic mean of supervised accuracy and acceptance rate
    [Weiss & Tonella 2021]; beta>1 weighs acceptance more.
    """
    accepted = np.asarray(accepted, bool)
    correct = np.asarray(correct, bool)
    n = accepted.shape[0]
    delta = float(np.mean(accepted)) if n else 0.0
    acc = float(np.mean(correct[accepted])) if accepted.any() else 0.0
    out = {"acc_supervised": acc, "delta": delta}
    for b in betas:
        b2 = b * b
        denom = b2 * acc + delta
        out[f"s_{b}"] = (1 + b2) * acc * delta / denom if denom > 0 else 0.0
    return out


def threshold_for_fpr(conf: np.ndarray, correct: np.ndarray,
                      target_fpr: float) -> float:
    """Pick a threshold such that the false-positive rate — correct
    predictions that get REJECTED — equals target_fpr (paper §5.2, in line
    with Stocco et al. / Catak et al.).

    Returns t such that P(conf <= t | correct) ~= target_fpr.
    """
    conf_correct = np.sort(np.asarray(conf)[np.asarray(correct, bool)])
    if conf_correct.size == 0:
        return float("-inf")
    k = int(np.floor(target_fpr * conf_correct.size))
    if k <= 0:
        return float(conf_correct[0]) - 1e-9
    return float(conf_correct[k - 1])

"""BiSupervised core — the paper's contribution as composable JAX modules."""

from repro.core.cascade import (CascadeThresholds, bisupervised_batch,
                                combine_escalated, escalation_capacity,
                                gather_requests, select_escalations)
from repro.core.metrics import (RAC, auc_rac, request_accuracy_curve,
                                supervised_metrics, threshold_for_fpr)
from repro.core.supervisors import (SAMPLING_SUPERVISORS,
                                    SOFTMAX_SUPERVISORS, fit_mdsa,
                                    max_softmax, mdsa_confidence,
                                    seq_min_likelihood)
from repro.core.thresholds import (escalation_rate_threshold,
                                   nominal_quantile_threshold,
                                   separation_threshold)

__all__ = [
    "CascadeThresholds", "bisupervised_batch", "select_escalations",
    "gather_requests", "combine_escalated", "escalation_capacity",
    "RAC", "request_accuracy_curve", "auc_rac", "supervised_metrics",
    "threshold_for_fpr", "max_softmax", "SOFTMAX_SUPERVISORS",
    "SAMPLING_SUPERVISORS", "fit_mdsa", "mdsa_confidence",
    "seq_min_likelihood", "nominal_quantile_threshold",
    "separation_threshold", "escalation_rate_threshold",
]

"""BiSupervised cascade orchestration (paper §4, Algorithm 1).

Two execution modes (see DESIGN.md §2):

* ``bisupervised_batch`` — exact Algorithm-1 semantics, vectorised over a
  batch (threshold branches become masks). Used for offline evaluation
  (RQ1/RQ2) where both tiers' outputs are available.

* ``select_escalations`` / ``combine_escalated`` — the jit-native serving
  adaptation: a fixed escalation capacity k per batch; the k
  lowest-confidence requests are gathered into a static-shape sub-batch for
  the remote tier (MoE-style token dropping, but for requests). Thresholds
  are recovered in expectation by calibrating k = ceil(rho * B) from the
  1st-level threshold's escalation rate.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

LOCAL, REMOTE, REJECTED = 0, 1, 2


@dataclass(frozen=True)
class CascadeThresholds:
    """Runtime-tunable supervisor thresholds (paper §4.5)."""
    t_local: float
    t_remote: float


def bisupervised_batch(local_pred: jnp.ndarray, local_conf: jnp.ndarray,
                       remote_pred: jnp.ndarray, remote_conf: jnp.ndarray,
                       th: CascadeThresholds) -> dict[str, jnp.ndarray]:
    """Vectorised Algorithm 1.

    Returns dict with:
      prediction [B]   — local where trusted, else remote
      source     [B]   — LOCAL / REMOTE / REJECTED per input
      accepted   [B]   — bool, False = "raise Exception" (fallback)
      remote_called [B]— bool, True where the remote model was invoked
    """
    use_local = local_conf > th.t_local
    remote_ok = remote_conf > th.t_remote
    prediction = jnp.where(use_local, local_pred, remote_pred)
    source = jnp.where(use_local, LOCAL,
                       jnp.where(remote_ok, REMOTE, REJECTED))
    return {
        "prediction": prediction,
        "source": source,
        "accepted": use_local | remote_ok,
        "remote_called": ~use_local,
    }


# --------------------------------------------------------------------------
# capacity-based escalation (jit-native serving mode)
# --------------------------------------------------------------------------

def escalation_capacity(batch: int, rho: float) -> int:
    """k = ceil(rho * B), clipped to [1, B]."""
    return max(1, min(batch, int(-(-rho * batch // 1))))


def select_escalations(local_conf: jnp.ndarray, k: int):
    """Pick the k lowest-confidence requests.

    Returns (idx [k] int32 — ascending by confidence, escalate these;
             escalate_mask [B] bool).
    """
    b = local_conf.shape[0]
    _, idx = jax.lax.top_k(-local_conf, k)
    mask = jnp.zeros((b,), bool).at[idx].set(True)
    return idx, mask


def gather_requests(batch: Any, idx: jnp.ndarray) -> Any:
    """Gather a static-shape escalated sub-batch from a request pytree."""
    return jax.tree.map(lambda a: a[idx], batch)


def combine_escalated(local_pred: jnp.ndarray, idx: jnp.ndarray,
                      remote_pred: jnp.ndarray) -> jnp.ndarray:
    """Scatter remote predictions for the escalated indices over the local
    predictions (static shapes throughout)."""
    return local_pred.at[idx].set(remote_pred)


def scatter_field(base: jnp.ndarray, idx: jnp.ndarray,
                  values: jnp.ndarray) -> jnp.ndarray:
    return base.at[idx].set(values)


# --------------------------------------------------------------------------
# paper §4.6 extensions: TriSupervised (edge tier) + active learning
# --------------------------------------------------------------------------

EDGE = 3


@dataclass(frozen=True)
class TriThresholds:
    """Three-tier thresholds: local -> edge -> remote -> fallback."""
    t_local: float
    t_edge: float
    t_remote: float


def trisupervised_batch(local_pred, local_conf, edge_pred, edge_conf,
                        remote_pred, remote_conf,
                        th: TriThresholds) -> dict[str, jnp.ndarray]:
    """Paper §4.6: "BISUPERVISED would effectively become TRISUPERVISED" —
    an edge node between the local device and the remote model. Vectorised
    like bisupervised_batch; each tier is consulted only when every
    cheaper tier's supervisor rejected."""
    use_local = local_conf > th.t_local
    use_edge = ~use_local & (edge_conf > th.t_edge)
    remote_ok = remote_conf > th.t_remote
    prediction = jnp.where(use_local, local_pred,
                           jnp.where(use_edge, edge_pred, remote_pred))
    source = jnp.where(use_local, LOCAL,
                       jnp.where(use_edge, EDGE,
                                 jnp.where(remote_ok, REMOTE, REJECTED)))
    return {
        "prediction": prediction,
        "source": source,
        "accepted": use_local | use_edge | remote_ok,
        "edge_called": ~use_local,
        "remote_called": ~use_local & ~use_edge,
    }


def select_for_labeling(local_conf: jnp.ndarray, budget: int):
    """Paper §4.6 active learning: the 1st-level supervisor doubles as an
    acquisition function — collect the `budget` least-confident inputs
    (to be labelled, possibly by the remote model itself) for the next
    local-model training round. Returns (idx [budget], mask [B])."""
    return select_escalations(local_conf, budget)

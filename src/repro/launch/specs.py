"""Dry-run lowering helpers: ShapeDtypeStruct input specs + step builders.

This module is import-safe (it never touches jax device state); the
``dryrun.py`` entrypoint sets XLA_FLAGS for 512 host devices BEFORE
importing it. Everything here operates on abstract shapes, so lowering and
compiling never allocates model-sized buffers.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.base import INPUT_SHAPES, ModelConfig, ShapeConfig
from repro.launch import sharding as sh
from repro.launch.mesh import use_abstract_mesh
from repro.models import transformer as T
from repro.train.loop import make_train_step
from repro.train.optimizer import AdamWConfig, init_opt_state


# --------------------------------------------------------------------------
# input specs (assignment §Multi-pod dry-run item 2)
# --------------------------------------------------------------------------

def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this
    (arch, input-shape) pair — weak-type-correct, shardable, no device
    allocation.

    train/prefill: the full-sequence batch; decode: ONE new token plus a
    KV cache of seq_len slots (per assignment: decode shapes lower
    ``serve_step`` with a seq_len cache, not ``train_step``).
    """
    b, t = shape.global_batch, shape.seq_len
    dt = cfg.dtype
    if shape.kind in ("train", "prefill"):
        if cfg.family == "vlm":
            # half image patches (frontend stub), half text
            batch = {"embeds": _sds((b, t // 2, cfg.d_model), dt),
                     "tokens": _sds((b, t // 2), jnp.int32)}
        elif cfg.takes_embeddings:
            batch = {"embeds": _sds((b, t, cfg.d_model), dt)}
        else:
            batch = {"tokens": _sds((b, t), jnp.int32)}
        if cfg.is_encoder and shape.kind == "train":
            batch["labels"] = _sds((b, t), jnp.int32)
        return batch
    # decode: one token against a seq_len cache
    assert cfg.supports_decode, cfg.name
    cache = jax.eval_shape(lambda: T.make_cache(cfg, b, t))
    return {"token": _sds((b,), jnp.int32),
            "cache": cache,
            "pos": _sds((), jnp.int32)}


def params_specs(cfg: ModelConfig) -> Any:
    return jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))


# --------------------------------------------------------------------------
# step functions (what gets lowered)
# --------------------------------------------------------------------------

def make_steps(cfg: ModelConfig):
    """(train_step, prefill_step, decode_step) pure functions for cfg."""
    opt_cfg = AdamWConfig()
    train_step = make_train_step(cfg, opt_cfg, remat=True)

    def prefill_step(params, batch):
        if cfg.is_encoder:
            # encoder "prefill" == full forward + per-frame classification
            x, _ = T.forward(cfg, params, batch)
            from repro.models.layers import dense
            return dense(params["head"], x).astype(jnp.float32)
        return T.prefill(cfg, params, batch)

    def decode_step(params, token, cache, pos):
        return T.decode_step(cfg, params, token, cache, pos)

    return train_step, prefill_step, decode_step


# --------------------------------------------------------------------------
# lowering
# --------------------------------------------------------------------------

def _tp_param_bytes_per_chip(cfg: ModelConfig, mesh) -> float:
    """Per-chip weight bytes under pure tensor parallelism (no FSDP).
    Works with any mesh-like object exposing .shape/.axis_names (the
    PartitionSpec rules never touch device state)."""
    shapes = params_specs(cfg)
    total = 0.0

    def visit(path, leaf):
        nonlocal total
        spec = sh.param_spec(path, leaf, mesh, fsdp=False)
        frac = 1.0
        for ax in spec:
            if ax is not None:
                frac /= mesh.shape[ax]
        total += leaf.size * leaf.dtype.itemsize * frac

    jax.tree_util.tree_map_with_path(visit, shapes)
    return total


def lower_step(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
               fsdp: bool | None = None, remat: bool = True,
               donate: bool = True):
    """Build shardings and ``jit(...).lower(...)`` the right step for this
    (arch, shape) on ``mesh``. Returns the Lowered object.

    fsdp=None picks the policy: training always FSDPs (optimizer moments
    triple the weight footprint); serving (prefill/decode) uses pure TP
    whenever the TP-sharded weights fit comfortably per chip — FSDP at
    decode costs a full weight all-gather per TOKEN (§Perf iteration A1:
    60x collective reduction on deepseek-67b decode_32k)."""
    if fsdp is None:
        if shape.kind == "train":
            fsdp = True
        else:
            fsdp = _tp_param_bytes_per_chip(cfg, mesh) > 12e9
    with use_abstract_mesh(mesh.abstract_mesh):
        pshapes = params_specs(cfg)
        pshard = sh.params_shardings(cfg, mesh, fsdp=fsdp)
        ins = input_specs(cfg, shape)
        train_step, prefill_step, decode_step = make_steps(cfg)

        if shape.kind == "train":
            oshapes = jax.eval_shape(init_opt_state, pshapes)
            oshard = sh.opt_shardings(cfg, mesh, pshard)
            bshard = sh.input_shardings(cfg, mesh, ins)
            fn = jax.jit(
                train_step,
                in_shardings=(pshard, oshard, bshard),
                out_shardings=(pshard, oshard, sh.replicated(mesh)),
                donate_argnums=(0, 1) if donate else ())
            return fn.lower(pshapes, oshapes, ins)

        if shape.kind == "prefill":
            bshard = sh.input_shardings(cfg, mesh, ins)
            if cfg.is_encoder:
                out_sh = NamedSharding(
                    mesh, sh.batch_spec(mesh,
                                        (shape.global_batch, shape.seq_len,
                                         cfg.num_classes)))
                fn = jax.jit(prefill_step,
                             in_shardings=(pshard, bshard),
                             out_shardings=out_sh)
            else:
                cshard = sh.cache_shardings(cfg, mesh, shape.global_batch,
                                            shape.seq_len)
                lshard = sh.logits_sharding(cfg, mesh, shape.global_batch)
                fn = jax.jit(prefill_step,
                             in_shardings=(pshard, bshard),
                             out_shardings=(lshard, cshard))
            return fn.lower(pshapes, ins)

        # decode
        cshard = sh.cache_shardings(cfg, mesh, shape.global_batch,
                                    shape.seq_len)
        tshard = NamedSharding(mesh,
                               sh.batch_spec(mesh, (shape.global_batch,)))
        lshard = sh.logits_sharding(cfg, mesh, shape.global_batch)
        fn = jax.jit(decode_step,
                     in_shardings=(pshard, tshard, cshard,
                                   sh.replicated(mesh)),
                     out_shardings=(lshard, cshard),
                     donate_argnums=(2,) if donate else ())
        return fn.lower(pshapes, ins["token"], ins["cache"], ins["pos"])


def shape_by_name(name: str) -> ShapeConfig:
    return INPUT_SHAPES[name]

"""Production mesh builders (assignment §Multi-pod dry-run).

Functions, not module-level constants, so importing this module never
touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def batch_axes(mesh) -> tuple[str, ...]:
    """Axes the global batch is sharded over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def make_host_mesh():
    """Single-device mesh with the same axis names (CPU tests/examples)."""
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)

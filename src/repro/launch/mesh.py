"""Production mesh builders (assignment §Multi-pod dry-run).

Functions, not module-level constants, so importing this module never
touches jax device state.
"""

from __future__ import annotations

import jax


def axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types=`` kwarg for jax.make_mesh, empty on older jax
    releases that predate ``jax.sharding.AxisType`` (e.g. 0.4.x)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def use_abstract_mesh(abstract_mesh):
    """`jax.sharding.use_abstract_mesh`, falling back to the internal
    context manager on older releases where it is not yet public."""
    fn = getattr(jax.sharding, "use_abstract_mesh", None)
    if fn is None:
        from jax._src.mesh import set_abstract_mesh as fn
    return fn(abstract_mesh)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **axis_type_kwargs(len(axes)))


def batch_axes(mesh) -> tuple[str, ...]:
    """Axes the global batch is sharded over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def make_host_mesh():
    """Single-device mesh with the same axis names (CPU tests/examples)."""
    return jax.make_mesh((1, 1), ("data", "model"), **axis_type_kwargs(2))


def make_serving_mesh():
    """Data-parallel mesh over every local device (DESIGN.md §12).

    The serving engine shards the *batch* axis of the local forward over
    all addressable devices and keeps parameters replicated — the right
    first shape for cascade replicas, where throughput scales with rows
    and the local model is small by construction. On a single-device
    host this degenerates to ``make_host_mesh`` and the sharded forward
    is numerically identical to the unsharded one.
    """
    n = jax.local_device_count()
    return jax.make_mesh((n, 1), ("data", "model"), **axis_type_kwargs(2))

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST run before any jax import (jax locks the device
# count on first init) — the dry-run, and ONLY the dry-run, needs 512
# placeholder host devices so jax.make_mesh can build the production mesh.

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture x input shape) pair this lowers + compiles the
appropriate step (train_step / prefill / serve_step) against

  * the single-pod mesh  (16, 16)    = 256 chips, axes ("data", "model")
  * the multi-pod mesh   (2, 16, 16) = 512 chips, axes ("pod", "data",
    "model")

and prints compiled.memory_analysis() (proves it fits) plus
cost_analysis() FLOPs/bytes and the collective-byte tally used by the
roofline (EXPERIMENTS.md §Roofline).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] \
        [--json out.json]
"""

import argparse
import json
import sys
import time
import traceback


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            fsdp: bool | None = None, remat: bool = True,
            swa_window: int = 0, verbose: bool = True) -> dict:
    """Lower + compile one (arch, shape, mesh) combination; returns the
    record for EXPERIMENTS.md §Dry-run."""
    from repro.analysis.roofline import (roofline_extrapolated,
                                         roofline_from_lowered)
    from repro.configs import INPUT_SHAPES, get_config, shape_applicable
    from repro.configs.base import with_sliding_window
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import lower_step

    cfg = get_config(arch)
    if swa_window:
        cfg = with_sliding_window(cfg, swa_window)
    shape = INPUT_SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skip", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.perf_counter()
    lowered = lower_step(cfg, shape, mesh, fsdp=fsdp, remat=remat)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    rec = {"arch": cfg.name, "shape": shape_name,
           "mesh": "multi" if multi_pod else "single",
           "n_devices": mesh.size, "status": "ok",
           "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1)}
    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:        # CPU backend may not expose everything
        rec["memory"] = {"error": str(e)}
    # roofline: depth-extrapolated unrolled lowering (accurate — the
    # scanned module above under-reports while-body cost); fall back to the
    # scanned artifact if the unrolled lowering fails.
    try:
        rec["roofline"] = roofline_extrapolated(cfg, shape, mesh, fsdp=fsdp,
                                                remat=remat)
    except Exception as e:
        rec["roofline"] = roofline_from_lowered(lowered, compiled, cfg,
                                                shape, mesh)
        rec["roofline"]["method"] = f"scanned-fallback ({e})"
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x "
              f"{'multi(512)' if multi_pod else 'single(256)'}: OK "
              f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s)")
        print(f"  memory: {rec['memory']}")
        r = rec["roofline"]
        print(f"  terms(s): compute={r['compute_s']:.3e} "
              f"memory={r['memory_s']:.3e} "
              f"collective={r['collective_s']:.3e} "
              f"-> bottleneck={r['bottleneck']}")
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--swa", type=int, default=0,
                    help="beyond-paper: retrofit sliding-window attention "
                         "of this width (lights up long_500k for dense "
                         "archs)")
    ap.add_argument("--json", help="append JSONL records here")
    args = ap.parse_args(argv)

    from repro.configs import INPUT_SHAPES, list_archs

    if args.all:
        combos = [(a, s) for a in list_archs() for s in INPUT_SHAPES]
    else:
        if not (args.arch and args.shape):
            ap.error("--arch/--shape or --all required")
        combos = [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    records = []
    for arch, shape in combos:
        for mp in meshes:
            try:
                rec = run_one(arch, shape, multi_pod=mp,
                              fsdp=False if args.no_fsdp else None,
                              swa_window=args.swa)
            except Exception:
                failures += 1
                rec = {"arch": arch, "shape": shape,
                       "mesh": "multi" if mp else "single",
                       "status": "fail",
                       "error": traceback.format_exc(limit=4)}
                print(f"[dryrun] {arch} x {shape} FAILED:\n"
                      f"{rec['error']}", file=sys.stderr)
            records.append(rec)
            if args.json:
                with open(args.json, "a") as f:
                    f.write(json.dumps(rec) + "\n")

    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skip" for r in records)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} principled skips, "
          f"{failures} failures / {len(records)} combos")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

"""Cascade serving driver — BiSupervised as a deployable two-tier runtime.

Local tier: a trained surrogate classifier (replicated, cheap).
Remote tier: a sharded in-framework model of any assigned architecture
(``--remote-arch``). The 1st-level supervisor escalates the capacity-k
lowest-confidence requests; the 2nd-level supervisor filters untrusted
remote predictions (fallback). Prints the paper's cost/latency accounting.

On this CPU container use ``--smoke`` (reduced remote config).

Usage:
    PYTHONPATH=src python -m repro.launch.serve --remote-arch yi-6b \
        --smoke --requests 256 --remote-budget 0.3
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.thresholds import nominal_quantile_threshold
from repro.data.synthetic import make_classification_task
from repro.models import surrogate as S
from repro.models import transformer as T
from repro.serving.engine import CascadeEngine, CostModel
from repro.serving.scheduler import MicrobatchScheduler, Request
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


def train_surrogate(cfg, toks, labels, steps=60, lr=3e-3, seed=0):
    params = S.init_params(cfg, jax.random.PRNGKey(seed))
    opt = init_opt_state(params)
    ocfg = AdamWConfig(lr=lr, warmup_steps=5, weight_decay=0.0)

    @jax.jit
    def step(p, o, tk, lb):
        (l, m), g = jax.value_and_grad(
            lambda p: S.loss_fn(cfg, p, tk, lb, jax.random.PRNGKey(1)),
            has_aux=True)(p)
        p, o, _ = adamw_update(ocfg, p, g, o)
        return p, o, l

    for i in range(steps):
        params, opt, loss = step(params, opt, toks, labels)
    return params, float(loss)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--remote-arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--remote-budget", type=float, default=0.3,
                    help="capacity fraction escalated to the remote tier")
    ap.add_argument("--fpr", type=float, default=0.05,
                    help="2nd-level supervisor nominal false-alarm rate")
    args = ap.parse_args(argv)

    # ---- task + local surrogate (paper §4.1: input-domain-reduced) ----
    vocab, seq, ncls = 512, 48, 8
    n = max(args.requests, 512)
    toks, labels, _ = make_classification_task(
        1, n=n, vocab=vocab, seq_len=seq, num_classes=ncls)
    scfg = S.SurrogateConfig("local", vocab_size=vocab // 4, max_len=seq // 2,
                             d_model=32, num_heads=2, d_ff=32,
                             num_classes=ncls, dropout=0.0)
    # input-domain reduction: clipped seq, folded vocab
    local_toks = (toks[:, : seq // 2] % (vocab // 4)).astype(np.int32)
    sparams, sloss = train_surrogate(scfg, jnp.asarray(local_toks[:512]),
                                     jnp.asarray(labels[:512]))
    print(f"[serve] local surrogate trained (final loss {sloss:.3f})")

    # ---- remote tier: a sharded in-framework model ----
    rcfg = get_config(args.remote_arch)
    if args.smoke:
        rcfg = rcfg.reduced()
    ndev = len(jax.devices())
    mesh = jax.make_mesh(
        (1, ndev), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
    rparams = T.init_params(rcfg, jax.random.PRNGKey(7))
    print(f"[serve] remote tier {rcfg.name} on {ndev} device(s)")

    # the remote model consumes the FULL input (no domain reduction); its
    # last-position hidden is decoded by a task head. For the demo the head
    # is an oracle readout so the remote tier is accurate (stands in for a
    # GPT-3-quality model, as in the paper's case studies).
    oracle = jax.nn.one_hot(jnp.asarray(labels), ncls) * 8.0

    def remote_apply(batch):
        toks_full, idx = batch["tokens"], batch["idx"]
        logits, _ = T.prefill(rcfg, rparams, {"tokens": toks_full})
        # project LM logits to task classes via oracle head (+ tiny noise
        # from the real hidden state so confidences vary per input)
        jitter = 0.01 * logits[:, :ncls].astype(jnp.float32)
        return oracle[idx] + jitter

    def local_apply(tk):
        return S.apply(scfg, sparams, tk)

    # ---- 2nd-level threshold: nominal-quantile calibration (§4.5) ----
    cal_logits = np.asarray(remote_apply(
        {"tokens": jnp.asarray(toks[:128] % rcfg.vocab_size),
         "idx": jnp.arange(128)}))
    cal_conf = np.max(
        np.exp(cal_logits) / np.exp(cal_logits).sum(-1, keepdims=True), -1)
    t_remote = nominal_quantile_threshold(cal_conf, args.fpr)

    eng = CascadeEngine(local_apply, remote_apply, batch_size=args.batch,
                        remote_fraction_budget=args.remote_budget,
                        t_remote=t_remote, cost=CostModel())
    sched = MicrobatchScheduler(eng, fallback=lambda r: -1)

    t0 = time.perf_counter()
    for i in range(args.requests):
        sched.submit(Request(
            uid=i, local_input=local_toks[i],
            remote_input={"tokens": toks[i] % rcfg.vocab_size,
                          "idx": np.int32(i)}))
    responses = sched.flush()
    wall = time.perf_counter() - t0

    correct = sum(r.prediction == labels[r.uid] for r in responses
                  if r.source != "fallback")
    srcs = {s: sum(r.source == s for r in responses)
            for s in ("local", "remote", "fallback")}
    st = eng.stats
    print(f"[serve] {len(responses)} requests in {wall:.1f}s wall")
    print(f"[serve] routing: {srcs}")
    print(f"[serve] accepted accuracy: "
          f"{correct / max(len(responses) - srcs['fallback'], 1):.3f}")
    print(f"[serve] remote fraction: {st.remote_fraction:.2f} "
          f"(budget {args.remote_budget})")
    print(f"[serve] modelled cost: ${st.total_cost:.4f} "
          f"(${st.total_cost / max(st.requests, 1):.5f}/req; remote-only "
          f"would be ${st.requests * eng.cost.remote_cost_per_request:.4f})")
    print(f"[serve] modelled mean latency: {st.mean_latency_s * 1e3:.0f} ms "
          f"(remote-only {eng.cost.remote_latency_s * 1e3:.0f} ms)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Cascade serving driver — BiSupervised as a deployable two-tier runtime.

Local tier: a trained surrogate classifier (replicated, cheap).
Remote tier: a sharded in-framework model of any assigned architecture
(``--remote-arch``), reached through the fault-aware ``repro.runtime``
transport (windows / retries / circuit breaker) with a content-keyed
response cache. The 1st-level supervisor escalates the lowest-confidence
requests; the 2nd-level supervisor filters untrusted remote predictions
(fallback). Prints the paper's cost/latency accounting plus transport,
cache, controller and per-request policy telemetry.

The serving surface is ONE object (DESIGN.md §8): the driver builds a
single ``repro.serving.ServeConfig`` and every runtime component — the
engine, scheduler, remote registry/router, budget controller and cache —
is constructed from it. The per-knob CLI flags of earlier PRs are gone;
any ``ServeConfig`` field (including nested ``transport.*``, ``cost.*``
and ``default_policy.*`` fields) is set with a repeatable

    --set key=value

override (migration table in DESIGN.md §8), e.g.::

    --set pipeline_depth=8 --set completion_mode=streaming \
    --set transport.timeout_s=1.0 --set route_policy=weighted \
    --set remotes=cheap:0.002:0.4;fast:0.008:0.1 \
    --set default_policy.deadline_s=0.5 --set packing=policy

An N-tier ladder (DESIGN.md §13) replaces the flat registry: the tier
specs chain into one routed ``CascadeStage`` head — each hop answers
what its supervisor trusts and escalates the residual, e.g.::

    --set "tiers=edge:0.001:0.1:0.6;cloud:0.0048:0.8"

Workload-level knobs keep first-class flags:
  --remote-budget   target remote fraction (capacity / controller target)
  --fpr             2nd-level supervisor nominal false-alarm rate
  --adaptive        enable the online budget controller (EMA/PID + drift)
  --calibrate       offline Pareto sweep picking (t_local, t_remote, k)
  --fused           bypass the transport: seed-style fully-jitted cascade

Observability (DESIGN.md §9): ``--metrics-dump`` / ``--metrics-interval``
snapshot the metrics registry (JSON or Prometheus text by extension),
``--metrics-port`` serves the LIVE registry over HTTP while the loop
runs (``GET /metrics`` Prometheus text, ``/metrics.json`` snapshot,
``/healthz``), ``--trace`` writes per-request span timelines as JSONL
and ``--trace-chrome`` exports Chrome ``trace_event`` JSON for perfetto.
Any of these implies ``observability=True`` on the ``ServeConfig``.

On this CPU container use ``--smoke`` (reduced remote config).

Usage:
    PYTHONPATH=src python -m repro.launch.serve --remote-arch yi-6b \
        --smoke --requests 256 --remote-budget 0.3 --adaptive --calibrate \
        --set pipeline_depth=4 --set completion_mode=streaming
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import threading
import time
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.thresholds import nominal_quantile_threshold
from repro.data.synthetic import make_classification_task
from repro.models import surrogate as S
from repro.models import transformer as T
from repro.runtime import calibrate, content_key, content_keys
from repro.serving import Request, ServeConfig
from repro.serving.engine import CostModel
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


def train_surrogate(cfg, toks, labels, steps=60, lr=3e-3, seed=0):
    params = S.init_params(cfg, jax.random.PRNGKey(seed))
    opt = init_opt_state(params)
    ocfg = AdamWConfig(lr=lr, warmup_steps=5, weight_decay=0.0)

    @jax.jit
    def step(p, o, tk, lb):
        (l, m), g = jax.value_and_grad(
            lambda p: S.loss_fn(cfg, p, tk, lb, jax.random.PRNGKey(1)),
            has_aux=True)(p)
        p, o, _ = adamw_update(ocfg, p, g, o)
        return p, o, l

    for i in range(steps):
        params, opt, loss = step(params, opt, toks, labels)
    return params, float(loss)


def build_serve_config(args) -> ServeConfig:
    """One ``ServeConfig`` from the CLI: first-class workload flags, then
    the repeatable ``--set key=value`` field overrides (DESIGN.md §8)."""
    cfg = ServeConfig(
        batch_size=args.batch,
        remote_fraction_budget=args.remote_budget,
        target_rejection_rate=args.fpr,
        adaptive=args.adaptive,
        fused=args.fused,
        cost=CostModel())
    return cfg.with_overrides(args.set or [])


def _serve_cluster(args, cfg, router, local_apply, toks, local_toks,
                   labels, rcfg) -> int:
    """Replicated serving (DESIGN.md §12): ``cfg.replicas`` engines
    behind one logical cascade — one shared router, a single-fill
    shared response cache and a cluster budget reconciler re-weighting
    per-replica targets. Requests round-robin across replicas."""
    from repro.runtime.cluster import ClusterHarness

    harness = ClusterHarness(
        cfg, local_apply, transport=router, fallback=lambda r: -1,
        clock=time.perf_counter, reconcile_interval_s=1.0,
        cache_key_fn=lambda row: content_key(row["tokens"]),
        cache_key_batch_fn=lambda b, n: content_keys(b["tokens"], n))
    names = harness.names
    print(f"[serve] cluster: {cfg.replicas} replicas {names}, shared "
          f"cache {'on' if harness.shared_cache is not None else 'off'}, "
          f"reconcile every {harness.reconcile_interval_s:.1f}s")

    # the fleet shares ONE MetricsRegistry (replica-labelled series), so
    # the live scrape endpoint and the interval pump serve the merged
    # snapshot directly — no per-replica aggregation pass needed
    metrics_server = None
    if harness.metrics is not None and args.metrics_port is not None:
        from repro.runtime.observability import MetricsServer
        metrics_server = MetricsServer(harness.metrics,
                                       port=args.metrics_port)
        print(f"[serve] metrics endpoint: {metrics_server.url} "
              f"(merged fleet registry)")
    stop_pump = threading.Event()

    def pump():
        while not stop_pump.wait(args.metrics_interval):
            c = harness.metrics.snapshot()["counters"]
            print(f"[serve] fleet metrics: "
                  f"{c.get('cascade_requests_total', 0):.0f} requests, "
                  f"{c.get('cascade_escalations_total', 0):.0f} "
                  f"escalated, "
                  f"${c.get('cascade_cost_dollars_total', 0.0):.4f}")

    pump_thread = None
    if harness.metrics is not None and args.metrics_interval:
        pump_thread = threading.Thread(target=pump, daemon=True)
        pump_thread.start()

    t0 = time.perf_counter()
    responses = []
    flush_every = max(cfg.batch_size, 1) * len(names)
    try:
        for i in range(args.requests):
            shed = harness.submit(names[i % len(names)], Request(
                uid=i, local_input=local_toks[i],
                remote_input={"tokens": toks[i] % rcfg.vocab_size,
                              "idx": np.int32(i)}))
            if shed is not None:
                responses.append(shed)
            if (i + 1) % flush_every == 0:
                for batch in harness.flush().values():
                    responses.extend(batch)
        for batch in harness.flush().values():
            responses.extend(batch)
        # short runs can finish inside one cadence interval: force a
        # final reconcile so the budget summary below is always live
        harness.cluster.reconcile(time.perf_counter())
    finally:
        harness.close()
        if pump_thread is not None:
            stop_pump.set()
            pump_thread.join(timeout=5.0)
        if metrics_server is not None:
            metrics_server.close()
    wall = time.perf_counter() - t0

    correct = sum(r.prediction == labels[r.uid] for r in responses
                  if r.source != "fallback")
    nfall = sum(r.source == "fallback" for r in responses)
    print(f"[serve] cluster: {len(responses)} responses in "
          f"{wall:.1f}s wall "
          f"({len(responses) / max(wall, 1e-9):.0f} req/s)")
    print(f"[serve] accepted accuracy: "
          f"{correct / max(len(responses) - nfall, 1):.3f}")
    for name in names:
        rep = harness.replica(name)
        st, ad = rep.engine.stats, rep.scheduler.admission
        line = (f"[serve]   {name}: {st.requests} requests, remote "
                f"fraction {st.remote_fraction:.2f} "
                f"(target {harness.cluster.target(name):.2f}), "
                f"shed {ad.shed}, degraded {ad.degraded}")
        if rep.cache is not None:
            line += (f", cache {rep.cache.stats.hits} hits "
                     f"({rep.cache.stats.cross_hits} cross-replica)")
        print(line)
    b = harness.global_billing()["billing"]
    print(f"[serve] cluster billing: {b['requests']} requests, "
          f"{b['escalations']} escalations, {b['remote_calls']} remote "
          f"calls, {b['cache_hits']} cache hits, "
          f"${b['total_cost']:.4f} total")
    cst = harness.cluster.state
    gt = cst.global_target
    gf = cst.global_ema_fraction
    print(f"[serve] cluster budget: {cst.reconciles} reconciles "
          f"(mode {cst.mode}), global target "
          f"{'n/a' if gt is None else f'{gt:.3f}'}, realised fleet "
          f"fraction {'n/a' if gf is None else f'{gf:.3f}'}, "
          f"stale {list(cst.stale)}")
    if harness.shared_cache is not None:
        scs = harness.shared_cache.stats
        print(f"[serve] shared cache: {scs.fills} fills, "
              f"{scs.duplicate_fills} duplicate fills, "
              f"{scs.waits} waits, {scs.steals} steals "
              f"({len(harness.shared_cache)} entries)")
    if harness.events is not None:
        evc = harness.events.counts()
        if evc:
            print(f"[serve] events: {dict(sorted(evc.items()))}")
    if harness.metrics is not None and args.metrics_dump:
        if args.metrics_dump.endswith(".json"):
            text = json.dumps(harness.metrics.snapshot(), indent=2,
                              sort_keys=True) + "\n"
        else:
            text = harness.metrics.render_prometheus()
        with open(args.metrics_dump, "w") as f:
            f.write(text)
        print(f"[serve] wrote metrics snapshot -> {args.metrics_dump}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--remote-arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--remote-budget", type=float, default=0.3,
                    help="capacity fraction escalated to the remote tier")
    ap.add_argument("--fpr", type=float, default=0.05,
                    help="2nd-level supervisor nominal false-alarm rate")
    ap.add_argument("--fused", action="store_true",
                    help="seed-style fully-jitted cascade (no transport)")
    ap.add_argument("--adaptive", action="store_true",
                    help="online EMA/PID budget controller")
    ap.add_argument("--calibrate", action="store_true",
                    help="offline Pareto sweep for (t_local, t_remote, k)")
    ap.add_argument("--set", action="append", default=None,
                    metavar="KEY=VALUE",
                    help="ServeConfig field override, repeatable — any "
                         "field incl. nested transport.* / cost.* / "
                         "default_policy.* (DESIGN.md §8 migration "
                         "table), e.g. --set pipeline_depth=8 "
                         "--set default_policy.deadline_s=0.5")
    ap.add_argument("--metrics-dump", metavar="PATH",
                    help="write the final metrics snapshot here: JSON "
                         "for *.json, Prometheus exposition text "
                         "otherwise (implies observability)")
    ap.add_argument("--metrics-interval", type=float, metavar="S",
                    help="re-dump/print metrics every S seconds while "
                         "serving (implies observability)")
    ap.add_argument("--metrics-port", type=int, metavar="PORT",
                    help="serve the live metrics registry over HTTP on "
                         "this port (GET /metrics = Prometheus text, "
                         "/metrics.json = JSON snapshot, /healthz; 0 = "
                         "ephemeral; implies observability)")
    ap.add_argument("--trace", metavar="PATH",
                    help="write per-request span timelines as JSONL "
                         "(implies observability)")
    ap.add_argument("--trace-chrome", metavar="PATH",
                    help="write Chrome trace_event JSON for perfetto / "
                         "chrome://tracing (implies observability)")
    args = ap.parse_args(argv)
    want_obs = (args.metrics_dump or args.metrics_interval
                or args.metrics_port is not None
                or args.trace or args.trace_chrome)
    try:
        cfg = build_serve_config(args)
        if want_obs:
            if cfg.fused:
                ap.error("--metrics-dump/--metrics-interval/--trace "
                         "require the transport path (not --fused)")
            cfg = dataclasses.replace(cfg, observability=True)
    except ValueError as e:
        ap.error(str(e))
    if (cfg.cost_budget is not None and not cfg.adaptive
            and not args.calibrate):
        ap.error("cost_budget is only enforced by the controller or the "
                 "offline sweep; add --adaptive and/or --calibrate")
    if cfg.replicas > 1 and (args.trace or args.trace_chrome):
        ap.error("replicas>1 supports the metrics surface "
                 "(--metrics-dump / --metrics-interval / --metrics-port "
                 "serve the merged fleet registry, replica-labelled); "
                 "per-replica tracing is a follow-on (DESIGN.md §12)")

    # ---- task + local surrogate (paper §4.1: input-domain-reduced) ----
    vocab, seq, ncls = 512, 48, 8
    n = max(args.requests, 512)
    toks, labels, _ = make_classification_task(
        1, n=n, vocab=vocab, seq_len=seq, num_classes=ncls)
    scfg = S.SurrogateConfig("local", vocab_size=vocab // 4, max_len=seq // 2,
                             d_model=32, num_heads=2, d_ff=32,
                             num_classes=ncls, dropout=0.0)
    # input-domain reduction: clipped seq, folded vocab
    local_toks = (toks[:, : seq // 2] % (vocab // 4)).astype(np.int32)
    sparams, sloss = train_surrogate(scfg, jnp.asarray(local_toks[:512]),
                                     jnp.asarray(labels[:512]))
    print(f"[serve] local surrogate trained (final loss {sloss:.3f})")

    # ---- remote tier: a sharded in-framework model ----
    rcfg = get_config(args.remote_arch)
    if args.smoke:
        rcfg = rcfg.reduced()
    ndev = len(jax.devices())
    rparams = T.init_params(rcfg, jax.random.PRNGKey(7))
    print(f"[serve] remote tier {rcfg.name} on {ndev} device(s)")

    # the remote model consumes the FULL input (no domain reduction); its
    # last-position hidden is decoded by a task head. For the demo the head
    # is an oracle readout so the remote tier is accurate (stands in for a
    # GPT-3-quality model, as in the paper's case studies).
    oracle = jax.nn.one_hot(jnp.asarray(labels), ncls) * 8.0

    def remote_apply(batch):
        toks_full, idx = batch["tokens"], batch["idx"]
        logits, _ = T.prefill(rcfg, rparams, {"tokens": toks_full})
        # project LM logits to task classes via oracle head (+ tiny noise
        # from the real hidden state so confidences vary per input)
        jitter = 0.01 * logits[:, :ncls].astype(jnp.float32)
        return oracle[idx] + jitter

    def local_apply(tk):
        return S.apply(scfg, sparams, tk)

    # an explicit --set t_remote/t_local always wins over the computed
    # thresholds below ("any ServeConfig field is settable" must hold)
    user_set = {item.partition("=")[0].strip() for item in (args.set or [])}

    # ---- 2nd-level threshold: nominal-quantile calibration (§4.5) ----
    cal_logits = np.asarray(remote_apply(
        {"tokens": jnp.asarray(toks[:128] % rcfg.vocab_size),
         "idx": jnp.arange(128)}))
    cal_conf = np.max(
        np.exp(cal_logits) / np.exp(cal_logits).sum(-1, keepdims=True), -1)
    if "t_remote" not in user_set:
        cfg = dataclasses.replace(
            cfg, t_remote=nominal_quantile_threshold(cal_conf, args.fpr))

    # ---- remote registry / cache from the one ServeConfig ----
    router = cache = None
    if not cfg.fused:
        router = cfg.build_router(remote_apply)
        print(f"[serve] remote registry: "
              f"{[b.name for b in router.candidates()]} "
              f"(policy {router.policy})")
        if cfg.tiers:
            head = router.candidates()[0]
            print("[serve] tier ladder: " + " -> ".join(
                f"{s.name}(t={s.threshold:g})" for s in head.chain()))
        # key on token content only: the per-request "idx" (oracle-head
        # plumbing) would make every key unique and the cache cold
        cache = cfg.build_cache(
            key_fn=lambda row: content_key(row["tokens"]),
            key_batch_fn=lambda batch, n: content_keys(batch["tokens"], n))

    if args.calibrate:
        # offline Pareto sweep on a labelled validation slice (DESIGN.md §1)
        # — priced at the policy-preferred backend's per-call cost when a
        # registry is configured, selected by $ when cost_budget is set
        nval = cal_logits.shape[0]
        val_logits = np.asarray(local_apply(jnp.asarray(local_toks[:nval])))
        val_sm = np.exp(val_logits) / np.exp(val_logits).sum(-1, keepdims=1)
        esc_cost = (cfg.cost or CostModel()).remote_cost_per_request
        if router is not None:
            esc_cost = router.expected_cost_per_escalation(esc_cost)
        point, k, front = calibrate(
            local_conf=val_sm.max(-1),
            local_correct=val_logits.argmax(-1) == labels[:nval],
            remote_conf=cal_conf,
            remote_correct=cal_logits.argmax(-1) == labels[:nval],
            budget=(None if cfg.cost_budget is not None
                    else cfg.remote_fraction_budget),
            cost_budget=cfg.cost_budget, batch_size=cfg.batch_size,
            max_rejection_rate=args.fpr, remote_cost_per_request=esc_cost)
        cal_updates = {}
        if "t_local" not in user_set:
            cal_updates["t_local"] = point.t_local
        if "t_remote" not in user_set:
            cal_updates["t_remote"] = point.t_remote
        cfg = dataclasses.replace(cfg, **cal_updates)
        print(f"[serve] calibrated operating point: "
              f"t_local={point.t_local:.4f} "
              f"t_remote={point.t_remote:.4f} k={k} "
              f"(val remote fraction {point.remote_fraction:.2f}, "
              f"${point.cost_per_request:.5f}/req, "
              f"accepted acc {point.accuracy:.3f}; "
              f"frontier has {len(front)} points)")

    # ---- replicated serving: N engines, one logical cascade ----
    if cfg.replicas > 1:
        return _serve_cluster(args, cfg, router, local_apply, toks,
                              local_toks, labels, rcfg)

    # ---- the whole serving stack from the one ServeConfig ----
    if cfg.fused:
        eng, sched = cfg.build(local_apply, remote_apply,
                               fallback=lambda r: -1)
    else:
        eng, sched = cfg.build(local_apply, transport=router, cache=cache,
                               fallback=lambda r: -1)

    obs = eng.observability

    def dump_metrics(path):
        # JSON snapshot for *.json, Prometheus exposition text otherwise
        if path.endswith(".json"):
            text = json.dumps(obs.metrics.snapshot(), indent=2,
                              sort_keys=True) + "\n"
        else:
            text = obs.metrics.render_prometheus()
        with open(path, "w") as f:
            f.write(text)

    stop_pump = threading.Event()

    def pump():
        while not stop_pump.wait(args.metrics_interval):
            if args.metrics_dump:
                dump_metrics(args.metrics_dump)
            else:
                c = obs.metrics.snapshot()["counters"]
                print(f"[serve] metrics: "
                      f"{c.get('cascade_requests_total', 0):.0f} requests, "
                      f"{c.get('cascade_escalations_total', 0):.0f} "
                      f"escalated, "
                      f"${c.get('cascade_cost_dollars_total', 0.0):.4f}")

    pump_thread = None
    if obs is not None and args.metrics_interval:
        pump_thread = threading.Thread(target=pump, daemon=True)
        pump_thread.start()

    # live HTTP scrape endpoint (DESIGN.md §9): Prometheus polls the
    # registry while the serve loop runs, no file dumps required
    metrics_server = None
    if obs is not None and args.metrics_port is not None:
        from repro.runtime.observability import MetricsServer
        metrics_server = MetricsServer(obs.metrics, port=args.metrics_port)
        print(f"[serve] metrics endpoint: {metrics_server.url}")

    t0 = time.perf_counter()
    try:
        for i in range(args.requests):
            sched.submit(Request(
                uid=i, local_input=local_toks[i],
                remote_input={"tokens": toks[i] % rcfg.vocab_size,
                              "idx": np.int32(i)}))
        responses = sched.flush()
    finally:
        eng.close()     # drain windows + shut down every backend pool
        if pump_thread is not None:
            stop_pump.set()
            pump_thread.join(timeout=5.0)
        if metrics_server is not None:
            metrics_server.close()
    wall = time.perf_counter() - t0

    correct = sum(r.prediction == labels[r.uid] for r in responses
                  if r.source != "fallback")
    srcs = {s: sum(r.source == s for r in responses)
            for s in ("local", "remote", "fallback")}
    st = eng.stats
    print(f"[serve] {len(responses)} requests in {wall:.1f}s wall")
    print(f"[serve] routing: {srcs}")
    print(f"[serve] dispositions: "
          f"{dict(Counter(r.disposition for r in responses))}")
    print(f"[serve] accepted accuracy: "
          f"{correct / max(len(responses) - srcs['fallback'], 1):.3f}")
    print(f"[serve] remote fraction: {st.remote_fraction:.2f} "
          f"(budget {cfg.remote_fraction_budget})")
    print(f"[serve] modelled cost: ${st.total_cost:.4f} "
          f"(${st.total_cost / max(st.requests, 1):.5f}/req; remote-only "
          f"would be ${st.requests * eng.cost.remote_cost_per_request:.4f})")
    if st.mean_latency_s is not None:
        print(f"[serve] modelled mean latency: "
              f"{st.mean_latency_s * 1e3:.0f} ms "
              f"(remote-only {eng.cost.remote_latency_s * 1e3:.0f} ms)")
    p50, p95 = st.wall_percentile(50), st.wall_percentile(95)
    if p50 is not None:
        print(f"[serve] measured wall latency: "
              f"p50 {p50 * 1e3:.0f} ms, p95 {p95 * 1e3:.0f} ms "
              f"(throughput {len(responses) / max(wall, 1e-9):.0f} req/s, "
              f"pipeline depth {cfg.pipeline_depth}, "
              f"completion mode {cfg.completion_mode})")
    # per-request hand-back latency, split trusted-local vs escalated
    # (the streaming mode's value proposition — DESIGN.md §7)
    if sched.first_response_s is not None:
        print(f"[serve] first response: "
              f"{sched.first_response_s * 1e3:.0f} ms after flush start")
    lat_local = [r.latency_s for r in responses if r.source == "local"]
    lat_esc = [r.latency_s for r in responses if r.source != "local"]
    for tag, lat in (("trusted-local", lat_local), ("escalated", lat_esc)):
        if lat:
            print(f"[serve] {tag} hand-back latency: "
                  f"p50 {np.percentile(lat, 50) * 1e3:.0f} ms, "
                  f"p95 {np.percentile(lat, 95) * 1e3:.0f} ms "
                  f"({len(lat)} requests)")
    if cfg.packing != "none":
        ps = sched.packing_stats
        pure = ps["cold"] + ps["hot"]
        print(f"[serve] window packing: {ps} "
              f"(purity {pure / max(ps['windows'], 1):.2f})")
    if cfg.admission_limit:
        ad = sched.admission
        print(f"[serve] admission: {ad.submitted} submitted, "
              f"{ad.shed} shed {ad.shed_reasons}, "
              f"{ad.degraded} degraded {ad.degrade_reasons} "
              f"(queue limit {sched.admission_limit}, "
              f"soft {sched.admission_soft})")
    if router is not None:
        rs = router.stats
        print(f"[serve] router: picks {rs.picks}, "
              f"failovers {rs.failovers}, unrouted {rs.unrouted}, "
              f"replays {rs.replay_served}/{rs.replay_enqueued} served")
        for b in router:
            ts, u = b.stats, st.per_backend.get(b.name)
            p95r = ts.latency_percentile(95)
            line = (f"[serve]   {b.name}: {ts.windows} windows, "
                    f"{ts.failed_requests} failed reqs, "
                    f"{ts.retries} retries, "
                    f"breaker opens {ts.breaker_opens}, "
                    f"p95 remote "
                    f"{'n/a' if p95r is None else f'{p95r * 1e3:.0f} ms'}")
            if u is not None:
                line += (f"; billed ${u.cost:.4f} "
                         f"({u.remote_calls} calls, {u.cache_hits} hits, "
                         f"{u.transport_failures} failures)")
            print(line)
    if eng.cache is not None:
        hr = eng.cache.stats.hit_rate
        print(f"[serve] cache: {eng.cache.stats.hits} hits / "
              f"{eng.cache.stats.misses} misses "
              f"(hit rate {'n/a' if hr is None else f'{hr:.2f}'})")
    if eng.controller is not None:
        cs = eng.controller.state
        print(f"[serve] controller: {cs.windows} windows, "
              f"ema remote fraction {cs.ema_fraction:.3f}, "
              f"t_local={cs.t_local}, t_remote={cs.t_remote}, "
              f"{cs.drift_events} drift events")
        if cfg.cost_budget is not None:
            per_esc = cs.ema_cost_per_escalation
            print(f"[serve] dollar budget: target "
                  f"${cfg.cost_budget:.5f}/req, realised "
                  f"${st.total_cost / max(st.requests, 1):.5f}/req "
                  f"(learned $/escalation "
                  f"{'n/a' if per_esc is None else f'{per_esc:.5f}'}, "
                  f"effective target fraction {cs.effective_target})")
    if obs is not None:
        evc = obs.events.counts()
        if evc:
            drop = (f" ({obs.events.dropped} dropped)"
                    if obs.events.dropped else "")
            print(f"[serve] events: {dict(sorted(evc.items()))}{drop}")
        if obs.trace is not None and obs.trace.dropped:
            print(f"[serve] trace: {obs.trace.dropped} spans dropped "
                  f"(capacity {obs.trace.capacity})")
        if args.trace:
            n = obs.trace.write_jsonl(args.trace)
            print(f"[serve] wrote {n} spans -> {args.trace}")
        if args.trace_chrome:
            n = obs.trace.write_chrome_trace(args.trace_chrome)
            print(f"[serve] wrote {n} trace events -> {args.trace_chrome}")
        if args.metrics_dump:
            dump_metrics(args.metrics_dump)
            print(f"[serve] wrote metrics snapshot -> {args.metrics_dump}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Cascade serving driver — BiSupervised as a deployable two-tier runtime.

Local tier: a trained surrogate classifier (replicated, cheap).
Remote tier: a sharded in-framework model of any assigned architecture
(``--remote-arch``), reached through the fault-aware ``repro.runtime``
transport (windows / retries / circuit breaker) with a content-keyed
response cache. The 1st-level supervisor escalates the lowest-confidence
requests; the 2nd-level supervisor filters untrusted remote predictions
(fallback). Prints the paper's cost/latency accounting plus transport,
cache and controller telemetry.

Runtime control plane (DESIGN.md):
  --adaptive        enable the online budget controller (EMA/PID + drift)
  --calibrate       offline Pareto sweep picking (t_local, t_remote, k)
  --fused           bypass the transport: seed-style fully-jitted cascade
  --pipeline-depth  overlap local compute with remote round trips
                    (N microbatches in flight, FIFO drain — DESIGN.md §5)
  --completion-mode fifo: windows drain strictly in submission order;
                    streaming: per-request completion — locally-trusted
                    requests return the moment the confidence gate
                    clears, escalations stream back as their remote
                    futures resolve (DESIGN.md §7)
  --replay-max      bounded replay queue for (unrouted) escalation
                    windows (served if a breaker half-opens before the
                    drain — DESIGN.md §7)
  --remote          repeatable "name:cost:latency" backend spec building a
                    multi-remote registry (cost $/req, latency modelled s;
                    either may be empty for the CostModel default) —
                    DESIGN.md §6
  --route-policy    primary-failover | cheapest-available | latency-ema
  --cost-budget     hold a dollar budget ($/req) instead of a remote
                    fraction (controller + calibration)

On this CPU container use ``--smoke`` (reduced remote config).

Usage:
    PYTHONPATH=src python -m repro.launch.serve --remote-arch yi-6b \
        --smoke --requests 256 --remote-budget 0.3 --adaptive --calibrate
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.thresholds import nominal_quantile_threshold
from repro.data.synthetic import make_classification_task
from repro.models import surrogate as S
from repro.models import transformer as T
from repro.runtime import (ROUTE_POLICIES, AdaptiveController,
                           ControllerConfig, RemoteBackend,
                           RemoteResponseCache, RemoteRouter,
                           TransportConfig, calibrate, content_key,
                           content_keys)
from repro.serving.engine import CascadeEngine, CostModel
from repro.serving.scheduler import (COMPLETION_MODES, MicrobatchScheduler,
                                     Request)
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


def train_surrogate(cfg, toks, labels, steps=60, lr=3e-3, seed=0):
    params = S.init_params(cfg, jax.random.PRNGKey(seed))
    opt = init_opt_state(params)
    ocfg = AdamWConfig(lr=lr, warmup_steps=5, weight_decay=0.0)

    @jax.jit
    def step(p, o, tk, lb):
        (l, m), g = jax.value_and_grad(
            lambda p: S.loss_fn(cfg, p, tk, lb, jax.random.PRNGKey(1)),
            has_aux=True)(p)
        p, o, _ = adamw_update(ocfg, p, g, o)
        return p, o, l

    for i in range(steps):
        params, opt, loss = step(params, opt, toks, labels)
    return params, float(loss)


def parse_remote_spec(spec: str) -> tuple[str, float | None, float | None]:
    """One ``--remote`` spec: ``name[:cost[:latency]]`` — cost in $/call,
    latency in modelled round-trip seconds; empty fields fall back to the
    ``CostModel`` defaults."""
    parts = spec.split(":")
    if len(parts) > 3 or not parts[0]:
        raise ValueError(f"bad --remote spec {spec!r}; "
                         f"expected name[:cost[:latency]]")
    cost = float(parts[1]) if len(parts) > 1 and parts[1] else None
    latency = float(parts[2]) if len(parts) > 2 and parts[2] else None
    return parts[0], cost, latency


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--remote-arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--remote-budget", type=float, default=0.3,
                    help="capacity fraction escalated to the remote tier")
    ap.add_argument("--fpr", type=float, default=0.05,
                    help="2nd-level supervisor nominal false-alarm rate")
    # ---- runtime control plane knobs (DESIGN.md) ----
    ap.add_argument("--fused", action="store_true",
                    help="seed-style fully-jitted cascade (no transport)")
    ap.add_argument("--adaptive", action="store_true",
                    help="online EMA/PID budget controller")
    ap.add_argument("--control-window", type=int, default=128,
                    help="requests per controller update")
    ap.add_argument("--calibrate", action="store_true",
                    help="offline Pareto sweep for (t_local, t_remote, k)")
    ap.add_argument("--cache-size", type=int, default=4096,
                    help="remote response cache entries (0 disables)")
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    help="in-flight microbatches (>1 overlaps the local "
                         "tier with remote round trips — DESIGN.md §5)")
    ap.add_argument("--completion-mode", default="fifo",
                    choices=COMPLETION_MODES,
                    help="fifo: FIFO window drain; streaming: per-request "
                         "completion the moment each answer is trusted "
                         "(DESIGN.md §7)")
    ap.add_argument("--replay-max", type=int, default=8,
                    help="max (unrouted) escalation windows parked for a "
                         "half-open replay instead of REJECTED "
                         "(DESIGN.md §7)")
    ap.add_argument("--max-in-flight", type=int, default=8,
                    help="remote transport window size")
    ap.add_argument("--remote-timeout", type=float, default=2.0,
                    help="per-window remote deadline (s)")
    ap.add_argument("--remote-retries", type=int, default=2,
                    help="retries per remote window")
    ap.add_argument("--breaker-failures", type=int, default=3,
                    help="consecutive window failures that open the breaker")
    ap.add_argument("--breaker-reset", type=float, default=5.0,
                    help="seconds before the open breaker half-opens")
    # ---- multi-remote registry (DESIGN.md §6) ----
    ap.add_argument("--remote", action="append", default=None,
                    metavar="NAME:COST:LATENCY",
                    help="remote backend spec, repeatable: per-call $ and "
                         "modelled round-trip s (empty fields = CostModel "
                         "defaults), e.g. --remote cheap:0.002:0.4 "
                         "--remote fast:0.008:0.1")
    ap.add_argument("--route-policy", default="primary-failover",
                    choices=ROUTE_POLICIES,
                    help="backend preference order for each escalation "
                         "window")
    ap.add_argument("--cost-budget", type=float, default=None,
                    help="dollar budget ($/request): controller and "
                         "--calibrate hold realised spend here instead of "
                         "the remote fraction")
    args = ap.parse_args(argv)
    if args.fused and args.adaptive:
        ap.error("--adaptive needs the transport serve path; drop --fused")
    if args.fused and args.pipeline_depth > 1:
        ap.error("--pipeline-depth needs the transport serve path; "
                 "drop --fused")
    if args.fused and args.completion_mode == "streaming":
        ap.error("--completion-mode streaming needs the transport serve "
                 "path; drop --fused")
    if args.fused and (args.remote or args.cost_budget is not None):
        ap.error("--remote/--cost-budget need the transport serve path; "
                 "drop --fused")
    if (args.cost_budget is not None and not args.adaptive
            and not args.calibrate):
        ap.error("--cost-budget is only enforced by the controller or the "
                 "offline sweep; add --adaptive and/or --calibrate")

    # ---- task + local surrogate (paper §4.1: input-domain-reduced) ----
    vocab, seq, ncls = 512, 48, 8
    n = max(args.requests, 512)
    toks, labels, _ = make_classification_task(
        1, n=n, vocab=vocab, seq_len=seq, num_classes=ncls)
    scfg = S.SurrogateConfig("local", vocab_size=vocab // 4, max_len=seq // 2,
                             d_model=32, num_heads=2, d_ff=32,
                             num_classes=ncls, dropout=0.0)
    # input-domain reduction: clipped seq, folded vocab
    local_toks = (toks[:, : seq // 2] % (vocab // 4)).astype(np.int32)
    sparams, sloss = train_surrogate(scfg, jnp.asarray(local_toks[:512]),
                                     jnp.asarray(labels[:512]))
    print(f"[serve] local surrogate trained (final loss {sloss:.3f})")

    # ---- remote tier: a sharded in-framework model ----
    rcfg = get_config(args.remote_arch)
    if args.smoke:
        rcfg = rcfg.reduced()
    ndev = len(jax.devices())
    rparams = T.init_params(rcfg, jax.random.PRNGKey(7))
    print(f"[serve] remote tier {rcfg.name} on {ndev} device(s)")

    # the remote model consumes the FULL input (no domain reduction); its
    # last-position hidden is decoded by a task head. For the demo the head
    # is an oracle readout so the remote tier is accurate (stands in for a
    # GPT-3-quality model, as in the paper's case studies).
    oracle = jax.nn.one_hot(jnp.asarray(labels), ncls) * 8.0

    def remote_apply(batch):
        toks_full, idx = batch["tokens"], batch["idx"]
        logits, _ = T.prefill(rcfg, rparams, {"tokens": toks_full})
        # project LM logits to task classes via oracle head (+ tiny noise
        # from the real hidden state so confidences vary per input)
        jitter = 0.01 * logits[:, :ncls].astype(jnp.float32)
        return oracle[idx] + jitter

    def local_apply(tk):
        return S.apply(scfg, sparams, tk)

    # ---- 2nd-level threshold: nominal-quantile calibration (§4.5) ----
    cal_logits = np.asarray(remote_apply(
        {"tokens": jnp.asarray(toks[:128] % rcfg.vocab_size),
         "idx": jnp.arange(128)}))
    cal_conf = np.max(
        np.exp(cal_logits) / np.exp(cal_logits).sum(-1, keepdims=True), -1)
    t_remote = nominal_quantile_threshold(cal_conf, args.fpr)

    # ---- multi-remote registry + routing policy (DESIGN.md §6) ----
    router = controller = cache = None
    if not args.fused:
        tconf = TransportConfig(
            max_in_flight=args.max_in_flight, timeout_s=args.remote_timeout,
            max_retries=args.remote_retries,
            breaker_failures=args.breaker_failures,
            breaker_reset_s=args.breaker_reset)
        specs = [parse_remote_spec(s) for s in (args.remote or ["remote"])]
        router = RemoteRouter(
            [RemoteBackend(name, remote_apply, tconf, cost_per_request=c,
                           latency_s=l) for name, c, l in specs],
            policy=args.route_policy, replay_max=args.replay_max)
        print(f"[serve] remote registry: "
              f"{[b.name for b in router.candidates()]} "
              f"(policy {router.policy})")
        if args.cache_size > 0:
            # key on token content only: the per-request "idx" (oracle-head
            # plumbing) would make every key unique and the cache cold
            cache = RemoteResponseCache(
                args.cache_size,
                key_fn=lambda row: content_key(row["tokens"]),
                key_batch_fn=lambda batch, n: content_keys(batch["tokens"],
                                                           n))
    if args.adaptive:
        controller = AdaptiveController(ControllerConfig(
            target_remote_fraction=args.remote_budget,
            window=args.control_window, target_rejection_rate=args.fpr,
            cost_budget_per_request=args.cost_budget))

    t_local = None
    if args.calibrate:
        # offline Pareto sweep on a labelled validation slice (DESIGN.md §1)
        # — priced at the policy-preferred backend's per-call cost when a
        # registry is configured, selected by $ when --cost-budget is set
        nval = cal_logits.shape[0]
        val_logits = np.asarray(local_apply(jnp.asarray(local_toks[:nval])))
        val_sm = np.exp(val_logits) / np.exp(val_logits).sum(-1, keepdims=1)
        esc_cost = CostModel().remote_cost_per_request
        if router is not None:
            esc_cost = router.expected_cost_per_escalation(esc_cost)
        point, k, front = calibrate(
            local_conf=val_sm.max(-1),
            local_correct=val_logits.argmax(-1) == labels[:nval],
            remote_conf=cal_conf,
            remote_correct=cal_logits.argmax(-1) == labels[:nval],
            budget=(None if args.cost_budget is not None
                    else args.remote_budget),
            cost_budget=args.cost_budget, batch_size=args.batch,
            max_rejection_rate=args.fpr, remote_cost_per_request=esc_cost)
        t_local, t_remote = point.t_local, point.t_remote
        print(f"[serve] calibrated operating point: t_local={t_local:.4f} "
              f"t_remote={t_remote:.4f} k={k} "
              f"(val remote fraction {point.remote_fraction:.2f}, "
              f"${point.cost_per_request:.5f}/req, "
              f"accepted acc {point.accuracy:.3f}; "
              f"frontier has {len(front)} points)")

    eng = CascadeEngine(local_apply,
                        remote_apply if router is None else None,
                        batch_size=args.batch,
                        remote_fraction_budget=args.remote_budget,
                        t_remote=t_remote, cost=CostModel(),
                        transport=router, controller=controller,
                        cache=cache)
    if t_local is not None:
        eng.set_local_threshold(t_local)
    sched = MicrobatchScheduler(eng, fallback=lambda r: -1,
                                pipeline_depth=args.pipeline_depth,
                                completion_mode=args.completion_mode)

    t0 = time.perf_counter()
    try:
        for i in range(args.requests):
            sched.submit(Request(
                uid=i, local_input=local_toks[i],
                remote_input={"tokens": toks[i] % rcfg.vocab_size,
                              "idx": np.int32(i)}))
        responses = sched.flush()
    finally:
        eng.close()     # drain windows + shut down every backend pool
    wall = time.perf_counter() - t0

    correct = sum(r.prediction == labels[r.uid] for r in responses
                  if r.source != "fallback")
    srcs = {s: sum(r.source == s for r in responses)
            for s in ("local", "remote", "fallback")}
    st = eng.stats
    print(f"[serve] {len(responses)} requests in {wall:.1f}s wall")
    print(f"[serve] routing: {srcs}")
    print(f"[serve] accepted accuracy: "
          f"{correct / max(len(responses) - srcs['fallback'], 1):.3f}")
    print(f"[serve] remote fraction: {st.remote_fraction:.2f} "
          f"(budget {args.remote_budget})")
    print(f"[serve] modelled cost: ${st.total_cost:.4f} "
          f"(${st.total_cost / max(st.requests, 1):.5f}/req; remote-only "
          f"would be ${st.requests * eng.cost.remote_cost_per_request:.4f})")
    print(f"[serve] modelled mean latency: {st.mean_latency_s * 1e3:.0f} ms "
          f"(remote-only {eng.cost.remote_latency_s * 1e3:.0f} ms)")
    print(f"[serve] measured wall latency: "
          f"p50 {st.wall_percentile(50) * 1e3:.0f} ms, "
          f"p95 {st.wall_percentile(95) * 1e3:.0f} ms "
          f"(throughput {len(responses) / max(wall, 1e-9):.0f} req/s, "
          f"pipeline depth {args.pipeline_depth}, "
          f"completion mode {args.completion_mode})")
    # per-request hand-back latency, split trusted-local vs escalated
    # (the streaming mode's value proposition — DESIGN.md §7)
    if sched.first_response_s is not None:
        print(f"[serve] first response: "
              f"{sched.first_response_s * 1e3:.0f} ms after flush start")
    lat_local = [r.latency_s for r in responses if r.source == "local"]
    lat_esc = [r.latency_s for r in responses if r.source != "local"]
    for tag, lat in (("trusted-local", lat_local), ("escalated", lat_esc)):
        if lat:
            print(f"[serve] {tag} hand-back latency: "
                  f"p50 {np.percentile(lat, 50) * 1e3:.0f} ms, "
                  f"p95 {np.percentile(lat, 95) * 1e3:.0f} ms "
                  f"({len(lat)} requests)")
    if router is not None:
        rs = router.stats
        print(f"[serve] router: picks {rs.picks}, "
              f"failovers {rs.failovers}, unrouted {rs.unrouted}, "
              f"replays {rs.replay_served}/{rs.replay_enqueued} served")
        for b in router:
            ts, u = b.stats, st.per_backend.get(b.name)
            line = (f"[serve]   {b.name}: {ts.windows} windows, "
                    f"{ts.failed_requests} failed reqs, "
                    f"{ts.retries} retries, "
                    f"breaker opens {ts.breaker_opens}, "
                    f"p95 remote {ts.latency_percentile(95) * 1e3:.0f} ms")
            if u is not None:
                line += (f"; billed ${u.cost:.4f} "
                         f"({u.remote_calls} calls, {u.cache_hits} hits, "
                         f"{u.transport_failures} failures)")
            print(line)
    if cache is not None:
        print(f"[serve] cache: {cache.stats.hits} hits / "
              f"{cache.stats.misses} misses "
              f"(hit rate {cache.stats.hit_rate:.2f})")
    if controller is not None:
        cs = controller.state
        print(f"[serve] controller: {cs.windows} windows, "
              f"ema remote fraction {cs.ema_fraction:.3f}, "
              f"t_local={cs.t_local}, t_remote={cs.t_remote}, "
              f"{cs.drift_events} drift events")
        if args.cost_budget is not None:
            per_esc = cs.ema_cost_per_escalation
            print(f"[serve] dollar budget: target "
                  f"${args.cost_budget:.5f}/req, realised "
                  f"${st.total_cost / max(st.requests, 1):.5f}/req "
                  f"(learned $/escalation "
                  f"{'n/a' if per_esc is None else f'{per_esc:.5f}'}, "
                  f"effective target fraction {cs.effective_target})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

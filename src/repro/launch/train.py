"""Distributed training driver.

Jits the same ``train_step`` the dry-run lowers, with the same sharding
plan, against whatever devices are actually available:

  * on a real TPU slice this is the production launcher
    (``--mesh data,model`` sizes must multiply to the device count);
  * on this CPU container it runs the REDUCED config end-to-end (the
    ``--smoke`` path used by examples and CI).

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
        --steps 50 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch import sharding as sh
from repro.launch.mesh import axis_type_kwargs
from repro.models import transformer as T
from repro.models.frontend import frontend_embeddings
from repro.train.checkpoint import save_checkpoint
from repro.train.loop import make_train_step
from repro.train.optimizer import AdamWConfig, init_opt_state


def make_batches(cfg, batch: int, seq: int, seed: int = 0):
    """Synthetic LM / classification batch stream for the smoke path."""
    rng = np.random.default_rng(seed)
    while True:
        if cfg.family == "vlm":
            half = seq // 2
            yield {"embeds": frontend_embeddings(cfg, batch, half, seed),
                   "tokens": jnp.asarray(
                       rng.integers(1, cfg.vocab_size, (batch, half)),
                       jnp.int32)}
        elif cfg.takes_embeddings:
            b = {"embeds": frontend_embeddings(cfg, batch, seq, seed)}
            if cfg.is_encoder:
                b["labels"] = jnp.asarray(
                    rng.integers(0, cfg.num_classes, (batch, seq)),
                    jnp.int32)
            yield b
        else:
            yield {"tokens": jnp.asarray(
                rng.integers(1, cfg.vocab_size, (batch, seq)), jnp.int32)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mesh", default="",
                    help="'data,model' sizes, e.g. '16,16' (default: all "
                         "devices on 'data')")
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    ndev = len(jax.devices())
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
    else:
        shape = (ndev, 1)
    mesh = jax.make_mesh(shape, ("data", "model"),
                         **axis_type_kwargs(2))
    print(f"[train] {cfg.name}: mesh {dict(zip(mesh.axis_names, shape))} "
          f"on {ndev} device(s)")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps)
    step_fn = make_train_step(cfg, opt_cfg, remat=True)

    pshard = sh.params_shardings(cfg, mesh, fsdp=ndev > 8)
    oshard = sh.opt_shardings(cfg, mesh, pshard)
    with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
        params = jax.jit(
            lambda k: T.init_params(cfg, k),
            out_shardings=pshard)(jax.random.PRNGKey(0))
    opt_state = jax.jit(init_opt_state, out_shardings=oshard)(params)

    jstep = jax.jit(step_fn, in_shardings=(pshard, oshard, None),
                    out_shardings=(pshard, oshard, None),
                    donate_argnums=(0, 1))

    batches = make_batches(cfg, args.batch, args.seq)
    t0 = time.perf_counter()
    for i in range(args.steps):
        params, opt_state, metrics = jstep(params, opt_state, next(batches))
        if (i + 1) % args.log_every == 0 or i == 0:
            m = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            print(f"[train] step {i + 1:5d} loss={m['loss']:.4f} "
                  f"ce={m['ce']:.4f} acc={m['acc']:.3f} "
                  f"gnorm={m['grad_norm']:.2f} lr={m['lr']:.2e} "
                  f"({dt / (i + 1):.2f}s/step)")
    if args.checkpoint:
        save_checkpoint(args.checkpoint, params, step=args.steps)
        print(f"[train] saved {args.checkpoint}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

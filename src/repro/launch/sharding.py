"""Sharding rules for the production mesh (DESIGN.md §7).

Megatron-style 2-way tensor parallelism over the ``model`` axis:

* column-parallel in-projections  -> P(..., "model")          (last dim)
* row-parallel out-projections    -> P(..., "model", None)    (contracting)
* vocab-parallel LM head; embedding sharded over d_model
* MoE expert weights sharded expert-major over ``model``      (EP)
* batch over ("pod", "data"); long_500k (batch=1) shards KV-cache slots
  over ``data`` instead

Every candidate dim is sharded only if divisible by the mesh axis size
(e.g. HuBERT's 504-class head stays replicated); this keeps one rule set
valid for all 10 assigned architectures.

All functions operate on ShapeDtypeStruct pytrees (via ``jax.eval_shape``)
so building a sharding plan never allocates device memory.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

from repro.configs.base import ModelConfig
from repro.launch.mesh import batch_axes

# parents whose "w" (and "b") leaves are column-parallel (shard output dim)
_COL = {"wq", "wk", "wv", "wg", "wr", "w_gate", "w_up", "w_in", "w_zx",
        "w_bc", "w_dt", "w_dkv", "w_uk", "w_uv", "cm_wk", "cm_wr", "hidden"}
# parents whose "w" leaves are row-parallel (shard contracting dim)
_ROW = {"wo", "w_down", "w_out", "cm_wv", "out"}
# MoE stacked expert tensors (leaf IS the weight, expert dim leading)
_MOE_EXPERT = {"w_gate", "w_up", "w_down"}


def _names(path) -> list[str]:
    out = []
    for k in path:
        if isinstance(k, DictKey):
            out.append(str(k.key))
        elif isinstance(k, SequenceKey):
            out.append(str(k.idx))
    return out


def _axis(mesh, name: str) -> int:
    return mesh.shape[name]


def _put(spec: list, dim: int, axis: str, shape, axis_size: int) -> None:
    """Assign `axis` to `dim` if the dim size divides evenly."""
    if shape[dim] % axis_size == 0 and spec[dim] is None:
        spec[dim] = axis


_FSDP_MIN_ELEMS = 1 << 20       # only FSDP-shard leaves >= 1M elements


def param_spec(path, leaf, mesh, *, fsdp: bool = False) -> P:
    """PartitionSpec for one parameter leaf (works for layer-stacked
    leaves: rules index dims from the right).

    With ``fsdp=True``, large 2D+ weights are additionally sharded over the
    ``data`` axis on their non-``model`` matmul dim (ZeRO-3 style) — needed
    to fit e.g. qwen3-235B (470 GB of bf16 weights) on 256 x 16 GB chips,
    where 16-way tensor parallelism alone leaves 29 GB/chip.
    """
    names = _names(path)
    last = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    shape = leaf.shape
    nd = len(shape)
    spec: list = [None] * nd
    msize = _axis(mesh, "model")
    fsdp_dim = None                 # secondary (data-axis) shard candidate

    in_moe = "moe" in names
    if in_moe and last in _MOE_EXPERT and nd >= 3:
        # stacked experts [(L,) E, d, f] -> expert parallelism
        _put(spec, nd - 3, "model", shape, msize)
        fsdp_dim = nd - 2
    elif last == "embed":
        _put(spec, nd - 1, "model", shape, msize)       # d_model sharded
        fsdp_dim = nd - 2                               # vocab over data
    elif last == "w" and parent in _COL:
        _put(spec, nd - 1, "model", shape, msize)
        fsdp_dim = nd - 2
    elif last == "b" and parent in _COL:
        _put(spec, nd - 1, "model", shape, msize)
    elif last == "w" and parent == "head":
        _put(spec, nd - 1, "model", shape, msize)       # vocab-parallel
        fsdp_dim = nd - 2
    elif last == "b" and parent == "head":
        _put(spec, nd - 1, "model", shape, msize)
    elif last == "w" and parent in _ROW:
        _put(spec, nd - 2, "model", shape, msize)
        fsdp_dim = nd - 1
    # everything else (norms, router, loras, conv, decay, biases of
    # row-parallel projections) stays replicated
    if fsdp and fsdp_dim is not None and leaf.size >= _FSDP_MIN_ELEMS \
            and "data" in mesh.axis_names:
        _put(spec, fsdp_dim, "data", shape, _axis(mesh, "data"))
    return P(*spec)


def params_shardings(cfg: ModelConfig, mesh, *, fsdp: bool = False) -> Any:
    """NamedSharding pytree for init_params(cfg) — via eval_shape."""
    from repro.models import transformer as T
    shapes = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, param_spec(p, l, mesh, fsdp=fsdp)),
        shapes)


def opt_shardings(cfg: ModelConfig, mesh, pspec: Any) -> dict:
    """Optimizer state inherits the params' shardings (moments are
    params-shaped; step is a replicated scalar)."""
    return {"m": pspec, "v": pspec,
            "step": NamedSharding(mesh, P())}


# --------------------------------------------------------------------------
# activations / inputs
# --------------------------------------------------------------------------

def batch_spec(mesh, shape: tuple, *, batch_dim: int = 0) -> P:
    """Shard the batch dim over ("pod","data") when divisible."""
    ba = batch_axes(mesh)
    total = 1
    for a in ba:
        total *= _axis(mesh, a)
    spec: list = [None] * len(shape)
    if shape[batch_dim] % total == 0:
        spec[batch_dim] = ba if len(ba) > 1 else ba[0]
    return P(*spec)


def input_shardings(cfg: ModelConfig, mesh, batch_shapes: Any) -> Any:
    """NamedSharding pytree for a batch pytree of ShapeDtypeStructs."""
    return jax.tree.map(
        lambda l: NamedSharding(mesh, batch_spec(mesh, l.shape)),
        batch_shapes)


def cache_spec(path, leaf, mesh, *, seq_len: int) -> P:
    """KV/state cache leaf spec. Leaves are [L_or_G, B, ...]:

    * batch dim (1) over ("pod","data") when divisible;
    * attention KV caches additionally shard kv-heads over ``model`` when
      divisible, else the slot dim (long-context sequence sharding);
    * MLA latent caches shard the lora rank over ``model``;
    * recurrent states shard their head dim over ``model``.
    """
    names = _names(path)
    last = names[-1]
    shape = leaf.shape
    nd = len(shape)
    spec: list = [None] * nd
    msize = _axis(mesh, "model")
    ba = batch_axes(mesh)
    bsize = 1
    for a in ba:
        bsize *= _axis(mesh, a)
    if nd >= 2 and shape[1] % bsize == 0:
        spec[1] = ba if len(ba) > 1 else ba[0]

    if last in ("k", "v", "attn_k", "attn_v") and nd == 5:
        # [L, B, S, K, hd]
        if shape[3] % msize == 0:
            spec[3] = "model"
        elif shape[2] % msize == 0:
            spec[2] = "model"           # sequence-shard the cache
    elif last == "c_kv" and nd == 4:    # [L, B, S, r] MLA latent
        _put(spec, 3, "model", shape, msize)
    elif last == "wkv" and nd == 5:     # [L, B, H, M, M] rwkv state
        _put(spec, 2, "model", shape, msize)
    elif last == "ssm" and nd == 5:     # [L, B, h, p, n] mamba state
        _put(spec, 3, "model", shape, msize)   # P=128 divides; h may not
    return P(*spec)


def cache_shardings(cfg: ModelConfig, mesh, batch: int, max_len: int) -> Any:
    from repro.models import transformer as T
    shapes = jax.eval_shape(lambda: T.make_cache(cfg, batch, max_len))
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, cache_spec(p, l, mesh,
                                                    seq_len=max_len)),
        shapes)


def logits_sharding(cfg: ModelConfig, mesh, batch: int) -> NamedSharding:
    out_dim = cfg.num_classes or cfg.vocab_size
    spec = batch_spec(mesh, (batch, out_dim))
    s = list(spec) + [None] * (2 - len(spec))
    if out_dim % _axis(mesh, "model") == 0:
        s[1] = "model"
    return NamedSharding(mesh, P(*s))


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# --------------------------------------------------------------------------
# serving (DESIGN.md §12): data-parallel local forward
# --------------------------------------------------------------------------

def shard_batch(batch: Any, mesh) -> Any:
    """Constrain every leaf of a stacked request pytree to batch-dim
    data parallelism on ``mesh`` (leading dim over ("pod","data") when
    divisible, replicated otherwise). Safe inside ``jit`` — leaves are
    tracers and only their static shapes are inspected."""
    return jax.tree.map(
        lambda x: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, batch_spec(mesh, x.shape))),
        batch)


def shard_local_step(step: Any, mesh) -> Any:
    """Wrap a gated local step so its input batch is data-parallel on
    ``mesh``. The wrapper preserves the step signature (positional
    ``(local_batch, t_local, n_valid, ...)``); thresholds and row counts
    stay replicated scalars. On a 1-device mesh this is a no-op
    constraint and the compiled computation is unchanged."""
    def sharded_step(local_batch, *rest):
        return step(shard_batch(local_batch, mesh), *rest)
    return sharded_step

"""Perf regression gate for the serving/routing/chaos/kernels/cluster/
hierarchy benchmarks (ISSUE 4, ISSUE 7, ISSUE 9, ISSUE 10).

Compares freshly produced ``BENCH_serving.json`` / ``BENCH_routing.json``
/ ``BENCH_chaos.json`` / ``BENCH_kernels.json`` / ``BENCH_cluster.json``
/ ``BENCH_hierarchy.json`` against the committed baselines in
``benchmarks/baselines/`` and FAILS (exit 1) when a tracked metric
regresses past tolerance — the ``BENCH_*.json`` family stops being
informational-only and starts gating merges.

Two kinds of checks:

  * tolerance — throughput may drop at most ``--throughput-tol`` (default
    15%) below baseline; p95 latency may rise at most ``--p95-tol``
    (default 25%) above baseline, with a small absolute floor
    (``--p95-floor``) so millisecond-scale numbers don't flap on noise.
    The fake remotes sleep() their round trips, so these numbers are
    dominated by pipeline math rather than host speed and travel well
    between machines.
  * hard — correctness invariants read from the FRESH report itself:
    zero dropped requests, bitwise-identical predictions/billing across
    serial / pipelined / streaming, and per-backend billing summing
    exactly to the total. These fail regardless of tolerances.

In GitHub Actions the script emits ``::error`` / ``::notice`` workflow
annotations (visible on the PR) instead of silently uploading artifacts,
and appends a markdown verdict to the job's step summary
(``GITHUB_STEP_SUMMARY``). ``--all`` checks every bench tag at once
(filling the default ``BENCH_*.json`` path for any not given);
``--verdict-json`` additionally writes a machine-readable verdict.
``--update-baselines`` rewrites the committed baselines from the fresh
JSONs (run locally after an intentional perf change, and commit).

    PYTHONPATH=src python -m benchmarks.check_regression --all \
        [--verdict-json BENCH_verdict.json] \
        [--baseline-dir benchmarks/baselines] [--update-baselines]
"""

from __future__ import annotations

import argparse
import json
import os
import shutil

BASELINE_DIR = os.path.join(os.path.dirname(__file__), "baselines")
THROUGHPUT_TOL = 0.15           # allowed fractional throughput drop
P95_TOL = 0.25                  # allowed fractional p95 rise
P95_FLOOR_S = 0.020             # absolute p95 slack (ms-scale noise)


def _annotate(level: str, msg: str) -> None:
    """Human line + GitHub workflow annotation (PR-visible in CI)."""
    print(f"[check_regression] {level.upper()}: {msg}")
    if os.environ.get("GITHUB_ACTIONS") == "true":
        print(f"::{'error' if level == 'error' else 'notice'}"
              f" title=bench regression gate::{msg}")


def _get(d: dict, path: str):
    for part in path.split("."):
        if not isinstance(d, dict) or part not in d:
            return None
        d = d[part]
    return d


class Gate:
    def __init__(self, throughput_tol: float, p95_tol: float,
                 p95_floor_s: float):
        self.throughput_tol = throughput_tol
        self.p95_tol = p95_tol
        self.p95_floor_s = p95_floor_s
        self.failures: list[str] = []
        self.passes: list[str] = []

    def hard(self, report: dict, path: str, label: str) -> None:
        """A correctness flag in the fresh report that must be True."""
        val = _get(report, path)
        if val is True:
            self.passes.append(label)
        else:
            self.failures.append(f"{label}: expected True, got {val!r}")

    def throughput(self, fresh: dict, base: dict, path: str,
                   label: str) -> None:
        f, b = _get(fresh, path), _get(base, path)
        if f is None or b is None:
            self.failures.append(f"{label}: metric {path!r} missing "
                                 f"(fresh={f!r}, baseline={b!r})")
            return
        floor = b * (1.0 - self.throughput_tol)
        if f >= floor:
            self.passes.append(f"{label} ({f:.1f} >= {floor:.1f} rps)")
        else:
            self.failures.append(
                f"{label}: throughput {f:.1f} rps fell more than "
                f"{self.throughput_tol:.0%} below baseline {b:.1f} rps")

    def p95(self, fresh: dict, base: dict, path: str, label: str) -> None:
        f, b = _get(fresh, path), _get(base, path)
        if f is None or b is None:
            self.failures.append(f"{label}: metric {path!r} missing "
                                 f"(fresh={f!r}, baseline={b!r})")
            return
        ceil = b * (1.0 + self.p95_tol) + self.p95_floor_s
        if f <= ceil:
            self.passes.append(f"{label} ({f*1e3:.1f} <= {ceil*1e3:.1f} ms)")
        else:
            self.failures.append(
                f"{label}: p95 {f*1e3:.1f} ms rose more than "
                f"{self.p95_tol:.0%} (+{self.p95_floor_s*1e3:.0f} ms floor)"
                f" above baseline {b*1e3:.1f} ms")


def check_serving(gate: Gate, fresh: dict, base: dict) -> None:
    # hard correctness invariants from the fresh run
    gate.hard(fresh, "predictions_identical",
              "serving: serial/pipelined predictions identical")
    gate.hard(fresh, "billing_identical",
              "serving: serial/pipelined billing identical")
    _check_policy_section(gate, fresh, base)
    _check_observability_section(gate, fresh, base)
    _check_continuous_section(gate, fresh, base)
    if ("streaming" in fresh) != ("streaming" in base):
        # a FIFO-mode re-baseline (or a FIFO-mode CI run) must not
        # silently disable every streaming invariant
        gate.failures.append(
            "serving: 'streaming' section present in "
            f"{'fresh' if 'streaming' in fresh else 'baseline'} only — "
            "run both with --completion-mode streaming (and re-baseline "
            "with --update-baselines if intentional)")
        return
    if "streaming" in base:
        gate.hard(fresh, "streaming.checks.zero_dropped",
                  "serving: streaming zero dropped requests")
        gate.hard(fresh, "streaming.checks.predictions_identical",
                  "serving: streaming predictions identical to FIFO")
        gate.hard(fresh, "streaming.checks.billing_identical",
                  "serving: streaming billing sums identical to FIFO")
        gate.hard(fresh, "streaming.checks.trusted_local_p95_halved",
                  "serving: streaming trusted-local p95 <= 0.5x FIFO p95")
    # perf tolerances vs the committed baseline
    for path_ in ("serial", "pipelined"):
        gate.throughput(fresh, base, f"{path_}.throughput_rps",
                        f"serving: {path_} throughput")
        gate.p95(fresh, base, f"{path_}.p95_wall_latency_s",
                 f"serving: {path_} window p95")
    if "streaming" in base:
        gate.throughput(fresh, base, "streaming.throughput_rps",
                        "serving: streaming throughput")
        gate.p95(fresh, base, "streaming.trusted_local.p95_latency_s",
                 "serving: streaming trusted-local p95")
        gate.p95(fresh, base, "streaming.escalated.p95_latency_s",
                 "serving: streaming escalated p95")


def _check_policy_section(gate: Gate, fresh: dict, base: dict) -> None:
    """Mixed-SLA policy gate (DESIGN.md §8): deadline-hit-rate and
    packed-window purity are hard invariants of the fresh run; tight-
    deadline p95 and section throughput track the baseline."""
    if ("policy" in fresh) != ("policy" in base):
        gate.failures.append(
            "serving: 'policy' section present in "
            f"{'fresh' if 'policy' in fresh else 'baseline'} only — "
            "rerun the serving bench (and --update-baselines if "
            "intentional)")
        return
    if "policy" not in base:
        return
    gate.hard(fresh, "policy.checks.deadline_hit_rate_ok",
              "serving: >=95% of tight-deadline requests met their SLA")
    gate.hard(fresh, "policy.checks.zero_dropped",
              "serving: policy section zero dropped requests")
    gate.hard(fresh, "policy.checks.windows_pure",
              "serving: packed windows never mix hot/cold rows")
    gate.hard(fresh, "policy.checks.response_costs_sum_to_total",
              "serving: per-response costs sum to billed total")
    gate.hard(fresh, "policy.checks.billing_invariant",
              "serving: policy section escalation billing invariant")
    gate.throughput(fresh, base, "policy.throughput_rps",
                    "serving: mixed-SLA throughput")
    gate.p95(fresh, base, "policy.tight.p95_latency_s",
             "serving: tight-deadline p95")


def _check_continuous_section(gate: Gate, fresh: dict, base: dict) -> None:
    """Continuous-batching gate (ISSUE 8, DESIGN.md §11): slot-map
    scheduling must keep answers/billing bitwise identical to fixed-
    window streaming, and the trusted-local SERVICE p95 (net of queue
    wait) must stay at most half of window streaming's."""
    if ("continuous" in fresh) != ("continuous" in base):
        gate.failures.append(
            "serving: 'continuous' section present in "
            f"{'fresh' if 'continuous' in fresh else 'baseline'} only — "
            "run both with --completion-mode streaming (and re-baseline "
            "with --update-baselines if intentional)")
        return
    if "continuous" not in base:
        return
    gate.hard(fresh, "continuous.checks.predictions_identical",
              "serving: continuous predictions identical to window")
    gate.hard(fresh, "continuous.checks.billing_identical",
              "serving: continuous billing identical to window")
    gate.hard(fresh, "continuous.checks.zero_dropped",
              "serving: continuous zero dropped requests")
    gate.hard(fresh, "continuous.checks.trusted_local_service_halved",
              "serving: continuous trusted-local service p95 <= 0.5x "
              "window streaming")
    gate.throughput(fresh, base, "continuous.throughput_rps",
                    "serving: continuous throughput")
    gate.p95(fresh, base,
             "continuous.trusted_local.service_p95_latency_s",
             "serving: continuous trusted-local service p95")
    gate.p95(fresh, base, "continuous.escalated.p95_latency_s",
             "serving: continuous escalated p95")


def _check_observability_section(gate: Gate, fresh: dict,
                                 base: dict) -> None:
    """Observability gate (DESIGN.md §9): the traced twin must keep
    answers/billing identical, reconcile spans and metric counters with
    the billing stats, and cost at most 3% throughput (the bench's own
    ``overhead_ok`` bar)."""
    if ("observability" in fresh) != ("observability" in base):
        gate.failures.append(
            "serving: 'observability' section present in "
            f"{'fresh' if 'observability' in fresh else 'baseline'} only "
            "— rerun the serving bench (and --update-baselines if "
            "intentional)")
        return
    if "observability" not in base:
        return
    gate.hard(fresh, "observability.checks.overhead_ok",
              "serving: traced throughput within 3% of untraced")
    gate.hard(fresh, "observability.checks.predictions_identical",
              "serving: tracing does not change predictions")
    gate.hard(fresh, "observability.checks.billing_identical",
              "serving: tracing does not change billing")
    gate.hard(fresh, "observability.checks.one_span_per_request",
              "serving: exactly one trace span per request")
    gate.hard(fresh, "observability.checks.spans_monotonic",
              "serving: span stage timestamps monotonic")
    gate.hard(fresh, "observability.checks.span_costs_match_billing",
              "serving: span costs/dispositions match billing")
    gate.hard(fresh, "observability.checks.metrics_match_stats",
              "serving: metric counters reconcile with CascadeStats")


def check_chaos(gate: Gate, fresh: dict, base: dict) -> None:
    """Chaos/load gate (DESIGN.md §10): the bench runs on a virtual
    clock, so everything here is a hard correctness invariant of the
    fresh run — there is no host-speed-dependent tolerance to track.
    The baseline still documents the scenario's expected shape."""
    gate.hard(fresh, "checks.deterministic_replay",
              "chaos: seeded scenario replays bit-identically")
    gate.hard(fresh, "checks.zero_silent_drop",
              "chaos: every submitted uid answered exactly once")
    gate.hard(fresh, "checks.sheds_answered_at_zero_cost",
              "chaos: shed responses cost $0 with source 'shed'")
    gate.hard(fresh, "checks.admission_reconciles",
              "chaos: submitted = admitted + shed, counters agree")
    gate.hard(fresh, "checks.billing_reconciles",
              "chaos: escalation/billing sums reconcile bitwise")
    gate.hard(fresh, "checks.events_causal",
              "chaos: episode begin < breaker open < failover; "
              "open < half_open < close; failover < failback")
    gate.hard(fresh, "checks.episodes_all_marked",
              "chaos: every episode has begin/end markers")
    gate.hard(fresh, "checks.faults_injected",
              "chaos: every scripted fault episode actually fired")
    gate.hard(fresh, "checks.breaker_opens_all_logged",
              "chaos: every breaker open transition logged")
    gate.hard(fresh, "checks.no_events_dropped",
              "chaos: event log dropped nothing")
    gate.hard(fresh, "checks.sheds_exercised",
              "chaos: overload produced sheds and degrades")
    gate.hard(fresh, "checks.majority_served",
              "chaos: >=50% of offered load served despite chaos")
    gate.hard(fresh, "checks.breakers_recovered",
              "chaos: no breaker stuck open after the scenario")


KERNEL_TOL_X = 3.0              # allowed us/call multiple vs baseline
KERNEL_FLOOR_US = 200.0         # absolute slack (scheduler jitter)


def check_kernels(gate: Gate, fresh: dict, base: dict) -> None:
    """Kernel microbench gate (ISSUE 8): the functional checks (fused
    head->gate parity, interpret-mode Pallas parity, early-emit firing)
    are hard invariants of the fresh run; per-kernel us/call tracks the
    baseline with a generous multiple — CPU ref-path timings are noisy
    across runners, but an order-of-magnitude blowup (e.g. the fused
    path silently falling back to a per-row loop) must not land."""
    for path, label in (
            ("checks.fused_matches_composed",
             "kernels: fused head->gate matches composed head+gate"),
            ("checks.fused_pallas_interpret_parity",
             "kernels: fused Pallas body matches ref (interpret mode)"),
            ("checks.early_emit_fired",
             "kernels: early-emit callback fires from inside jit")):
        gate.hard(fresh, path, label)

    fresh_rows = {(r["kernel"], r["shape"]): r
                  for r in fresh.get("rows", [])}
    base_rows = {(r["kernel"], r["shape"]): r
                 for r in base.get("rows", [])}
    missing = sorted(set(base_rows) - set(fresh_rows))
    if missing:
        gate.failures.append(
            f"kernels: baseline rows missing from fresh run: {missing} — "
            "a benched kernel/shape silently disappeared")
    for key in sorted(set(base_rows) & set(fresh_rows)):
        f = fresh_rows[key]["us_per_call"]
        b = base_rows[key]["us_per_call"]
        ceil = b * KERNEL_TOL_X + KERNEL_FLOOR_US
        label = f"kernels: {key[0]} {key[1]} us/call"
        if f <= ceil:
            gate.passes.append(f"{label} ({f:.0f} <= {ceil:.0f} us)")
        else:
            gate.failures.append(
                f"{label}: {f:.0f} us exceeds {KERNEL_TOL_X:.0f}x "
                f"baseline {b:.0f} us (+{KERNEL_FLOOR_US:.0f} us floor)")


def check_cluster(gate: Gate, fresh: dict, base: dict) -> None:
    """Cluster gate (DESIGN.md §12, ISSUE 9): N replicas behind one
    logical cascade, on a virtual clock — every check is a hard
    correctness invariant of the fresh run. The baseline additionally
    pins the fleet geometry so the scenario cannot silently shrink."""
    for key in ("replicas", "target_remote_fraction"):
        f, b = fresh.get(key), base.get(key)
        if f == b:
            gate.passes.append(f"cluster: {key} matches baseline ({f})")
        else:
            gate.failures.append(
                f"cluster: {key} changed from baseline {b!r} to {f!r} — "
                "re-baseline with --update-baselines if intentional")
    gate.hard(fresh, "checks.deterministic_replay",
              "cluster: double run replays bit-identically")
    gate.hard(fresh, "checks.zero_silent_drop",
              "cluster: every uid answered exactly once across the fleet")
    gate.hard(fresh, "checks.single_fill",
              "cluster: no content key fetched remotely twice")
    gate.hard(fresh, "checks.cross_replica_sharing",
              "cluster: peers serve hits from other replicas' fills")
    gate.hard(fresh, "checks.global_budget_holds",
              "cluster: fleet remote fraction within global tolerance")
    gate.hard(fresh, "checks.replica_skew_far_outside",
              "cluster: worst single replica far outside the tolerance")
    gate.hard(fresh, "checks.targets_reweighted",
              "cluster: reconcile spread per-replica targets under skew")
    gate.hard(fresh, "checks.admission_reconciles",
              "cluster: per-replica submitted = admitted + shed")
    gate.hard(fresh, "checks.billing_reconciles",
              "cluster: per-replica billing sums bitwise to fleet total")
    gate.hard(fresh, "checks.sheds_exercised",
              "cluster: overload produced sheds")
    gate.hard(fresh, "checks.faults_injected",
              "cluster: scripted chaos episode actually fired")
    gate.hard(fresh, "checks.breakers_recovered",
              "cluster: no breaker stuck open after the scenario")
    gate.hard(fresh, "checks.majority_served",
              "cluster: >=50% of offered load served")
    gate.hard(fresh, "checks.no_events_dropped",
              "cluster: shared event log dropped nothing")
    gate.hard(fresh, "checks.reconcile_events_logged",
              "cluster: one event per budget reconcile, none missing")


def check_hierarchy(gate: Gate, fresh: dict, base: dict) -> None:
    """Hierarchy gate (DESIGN.md §13, ISSUE 10): the N-tier bench runs a
    planted synthetic workload with a pinned seed, so every check is a
    hard correctness invariant of the fresh run. The baseline pins the
    scenario shape (rows/grid/seed/stage costs) so the 3-tier dominance
    claim cannot silently weaken by shrinking the sweep."""
    for key in ("rows", "grid", "seed", "stage_costs"):
        f, b = fresh.get(key), base.get(key)
        if f == b:
            gate.passes.append(f"hierarchy: {key} matches baseline ({f})")
        else:
            gate.failures.append(
                f"hierarchy: {key} changed from baseline {b!r} to {f!r} — "
                "re-baseline with --update-baselines if intentional")
    gate.hard(fresh, "checks.three_tier_dominates",
              "hierarchy: best 3-tier point strictly cheaper than best "
              "2-tier at equal-or-better accuracy")
    gate.hard(fresh, "checks.deterministic_replay",
              "hierarchy: calibration + runtime double run replays "
              "bit-identically")
    gate.hard(fresh, "checks.two_tier_engine_identity",
              "hierarchy: terminal CascadeStage bitwise-identical to "
              "plain RemoteBackend through the engine")
    gate.hard(fresh, "checks.frontier_monotone",
              "hierarchy: joint Pareto frontier monotone in cost and "
              "accuracy")
    gate.hard(fresh, "checks.calibration_generalizes",
              "hierarchy: held-out accuracy within tolerance of the "
              "calibrated operating point")
    gate.hard(fresh, "checks.mid_tier_carries_load",
              "hierarchy: edge tier answers a real share of escalations")
    gate.hard(fresh, "checks.billing_reconciles",
              "hierarchy: per-stage costs sum to the cascade total")
    gate.hard(fresh, "checks.per_stage_attribution",
              "hierarchy: chained engine splits billing per stage")
    gate.hard(fresh, "checks.tier_budget_tracks",
              "hierarchy: per-tier budget controller reconciles to the "
              "global escalation budget")


def check_routing(gate: Gate, fresh: dict, base: dict) -> None:
    gate.hard(fresh, "checks.zero_dropped",
              "routing: zero dropped requests across outage")
    gate.hard(fresh, "checks.billing_sums_to_total",
              "routing: per-backend billing sums to total")
    gate.hard(fresh, "checks.escalations_attributed",
              "routing: every escalation attributed to a backend")
    gate.hard(fresh, "checks.failover_to_secondary",
              "routing: failover to secondary during outage")
    gate.hard(fresh, "checks.failback_to_primary",
              "routing: fail-back to primary after recovery")
    if "observability" in fresh or "observability" in base:
        gate.hard(fresh, "checks.event_log_ordered",
                  "routing: breaker/failover events in causal seq order")
        gate.hard(fresh, "checks.breaker_opens_all_logged",
                  "routing: every breaker open transition logged")
        gate.hard(fresh, "checks.failovers_all_logged",
                  "routing: every router failover logged, none dropped")
        gate.hard(fresh, "checks.one_span_per_request",
                  "routing: exactly one trace span per request")
        gate.hard(fresh, "checks.span_costs_match_billing",
                  "routing: span costs match billed total")
    gate.throughput(fresh, base, "routed.throughput_rps",
                    "routing: routed throughput")


def _load(path: str, what: str) -> dict | None:
    if not os.path.exists(path):
        _annotate("error", f"{what} JSON missing: {path}")
        return None
    with open(path) as f:
        return json.load(f)


def _write_verdict(path: str, gate: Gate, tags: list[str],
                   passed: bool) -> None:
    """Machine-readable gate verdict (consumed by CI dashboards)."""
    verdict = {
        "passed": passed,
        "checked": tags,
        "counts": {"passed": len(gate.passes),
                   "failed": len(gate.failures)},
        "passes": gate.passes,
        "failures": gate.failures,
    }
    with open(path, "w") as f:
        json.dump(verdict, f, indent=1)
    print(f"[check_regression] verdict -> {path}")


def _step_summary(gate: Gate, tags: list[str], passed: bool) -> None:
    """Append a markdown verdict to the GitHub Actions step summary."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    lines = [
        f"## Bench regression gate: {'PASS' if passed else 'FAIL'}",
        "",
        f"{len(gate.passes)} passed, {len(gate.failures)} failed "
        f"({', '.join(tags)})",
        "",
    ]
    if gate.failures:
        lines += ["### Failures", ""]
        lines += [f"- :x: {m}" for m in gate.failures]
        lines += [""]
    lines += ["<details><summary>Passed checks "
              f"({len(gate.passes)})</summary>", ""]
    lines += [f"- {m}" for m in gate.passes]
    lines += ["", "</details>", ""]
    with open(path, "a") as f:
        f.write("\n".join(lines))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--serving", default="BENCH_serving.json",
                    help="fresh serving bench JSON ('' skips)")
    ap.add_argument("--routing", default="BENCH_routing.json",
                    help="fresh routing bench JSON ('' skips)")
    ap.add_argument("--chaos", default="BENCH_chaos.json",
                    help="fresh chaos bench JSON ('' skips)")
    ap.add_argument("--kernels", default="",
                    help="fresh kernels bench JSON ('' skips)")
    ap.add_argument("--cluster", default="",
                    help="fresh cluster bench JSON ('' skips)")
    ap.add_argument("--hierarchy", default="",
                    help="fresh hierarchy bench JSON ('' skips)")
    ap.add_argument("--all", action="store_true",
                    help="check every bench tag, filling the default "
                         "BENCH_<tag>.json path for any not given")
    ap.add_argument("--verdict-json", default="", metavar="PATH",
                    help="also write a machine-readable verdict here")
    ap.add_argument("--baseline-dir", default=BASELINE_DIR)
    ap.add_argument("--throughput-tol", type=float, default=THROUGHPUT_TOL)
    ap.add_argument("--p95-tol", type=float, default=P95_TOL)
    ap.add_argument("--p95-floor", type=float, default=P95_FLOOR_S,
                    help="absolute p95 slack in seconds")
    ap.add_argument("--update-baselines", action="store_true",
                    help="copy the fresh JSONs over the committed "
                         "baselines instead of checking")
    args = ap.parse_args(argv)
    if args.all:
        for tag in ("serving", "routing", "chaos", "kernels", "cluster",
                    "hierarchy"):
            if not getattr(args, tag):
                setattr(args, tag, f"BENCH_{tag}.json")

    pairs = []          # (fresh path, baseline path, checker, tag)
    if args.serving:
        pairs.append((args.serving,
                      os.path.join(args.baseline_dir, "BENCH_serving.json"),
                      check_serving, "serving"))
    if args.routing:
        pairs.append((args.routing,
                      os.path.join(args.baseline_dir, "BENCH_routing.json"),
                      check_routing, "routing"))
    if args.chaos:
        pairs.append((args.chaos,
                      os.path.join(args.baseline_dir, "BENCH_chaos.json"),
                      check_chaos, "chaos"))
    if args.kernels:
        pairs.append((args.kernels,
                      os.path.join(args.baseline_dir, "BENCH_kernels.json"),
                      check_kernels, "kernels"))
    if args.cluster:
        pairs.append((args.cluster,
                      os.path.join(args.baseline_dir, "BENCH_cluster.json"),
                      check_cluster, "cluster"))
    if args.hierarchy:
        pairs.append((args.hierarchy,
                      os.path.join(args.baseline_dir,
                                   "BENCH_hierarchy.json"),
                      check_hierarchy, "hierarchy"))
    if not pairs:
        _annotate("error", "nothing to check (--serving, --routing, "
                  "--chaos, --kernels, --cluster and --hierarchy all "
                  "empty)")
        return 2

    if args.update_baselines:
        os.makedirs(args.baseline_dir, exist_ok=True)
        for fresh_path, base_path, _, tag in pairs:
            if not os.path.exists(fresh_path):
                _annotate("error", f"cannot update {tag} baseline: "
                          f"{fresh_path} missing")
                return 2
            shutil.copyfile(fresh_path, base_path)
            print(f"[check_regression] baseline updated: {base_path}")
        return 0

    gate = Gate(args.throughput_tol, args.p95_tol, args.p95_floor)
    for fresh_path, base_path, checker, tag in pairs:
        fresh = _load(fresh_path, f"fresh {tag}")
        base = _load(base_path, f"baseline {tag}")
        if fresh is None or base is None:
            gate.failures.append(f"{tag}: missing input (see above)")
            continue
        checker(gate, fresh, base)

    for msg in gate.passes:
        print(f"[check_regression] ok: {msg}")
    passed = not gate.failures
    tags = [tag for _, _, _, tag in pairs]
    if args.verdict_json:
        _write_verdict(args.verdict_json, gate, tags, passed)
    _step_summary(gate, tags, passed)
    if not passed:
        for msg in gate.failures:
            _annotate("error", msg)
        _annotate("error", f"{len(gate.failures)} regression check(s) "
                  f"FAILED ({len(gate.passes)} passed)")
        return 1
    _annotate("notice", f"all {len(gate.passes)} regression checks passed "
              f"against committed baselines")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

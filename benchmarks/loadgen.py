"""Open-loop trace generator for the load/chaos bench (DESIGN.md §10).

Arrivals are OPEN-LOOP: the trace fixes every request's arrival time
before serving starts, so offered load never slows down because the
system is struggling — exactly the regime where a closed-loop driver
would hide overload (coordinated omission). Three arrival processes:

  poisson       — memoryless arrivals at a constant rate (the classic
                  open-loop baseline);
  diurnal       — inhomogeneous Poisson whose rate follows a raised
                  cosine between ``rate`` and ``peak_rate`` (a traffic
                  day compressed into ``period_s``), sampled by
                  thinning against the peak;
  pareto_burst  — renewal process with Pareto inter-arrival gaps scaled
                  to mean ``1/rate``: most gaps are tiny (bursts), a
                  heavy tail of long lulls separates them.

Each request also draws a difficulty (``hard`` rows produce low local
confidence and escalate) and a ``RequestPolicy`` from a weighted mix,
so admission control sees the full ``on_miss`` vocabulary under load.
Everything is derived from one integer seed — the same seed replays the
same trace bit-for-bit, which the chaos bench's determinism check
relies on.

    trace = generate_trace(7, pattern="diurnal", rate=24.0,
                           peak_rate=96.0, duration_s=60.0)
    xs, labels = make_features(trace)
    for t_end, batch in segments(trace, every_s=1.0):
        ...submit batch, advance the virtual clock to t_end, flush...
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from repro.serving import RequestPolicy

ARRIVAL_PATTERNS = ("poisson", "diurnal", "pareto_burst")


@dataclass(frozen=True)
class PolicySpec:
    """One arm of the policy mix: ``weight`` is relative, not
    normalised; ``policy=None`` is the unpolicied fast path."""
    name: str
    weight: float
    policy: RequestPolicy | None = None


@dataclass(frozen=True)
class TraceRequest:
    uid: int
    t_arrival_s: float
    hard: bool                  # escalates (low local margin) if True
    policy_name: str
    policy: RequestPolicy | None


@dataclass
class LoadTrace:
    """A fully materialised open-loop request trace."""
    requests: list = field(default_factory=list)
    duration_s: float = 0.0
    pattern: str = "poisson"
    seed: int = 0

    def __len__(self) -> int:
        return len(self.requests)

    def policy_counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for r in self.requests:
            out[r.policy_name] = out.get(r.policy_name, 0) + 1
        return out


def default_policy_mix() -> tuple[PolicySpec, ...]:
    """A mix exercising every admission-control arm (DESIGN.md §10):
    unpolicied traffic degrades under overload, ``on_miss="reject"``
    traffic sheds, tight deadlines trip the feasibility rule, and
    ``escalation="never"`` rows are local either way."""
    return (
        PolicySpec("default", 0.55, None),
        PolicySpec("tight", 0.15,
                   RequestPolicy(deadline_s=0.15)),
        PolicySpec("tight-reject", 0.10,
                   RequestPolicy(deadline_s=0.15, on_miss="reject")),
        PolicySpec("local-only", 0.10,
                   RequestPolicy(escalation="never")),
        PolicySpec("strict", 0.10,
                   RequestPolicy(on_miss="reject")),
    )


# -- arrival processes ----------------------------------------------------

def _poisson_times(rng: np.random.Generator, rate: float,
                   duration_s: float) -> np.ndarray:
    n = max(1, int(rate * duration_s * 1.5) + 16)
    t = np.cumsum(rng.exponential(1.0 / rate, n))
    while t[-1] < duration_s:                       # top up the tail
        t = np.concatenate([t, t[-1] + np.cumsum(
            rng.exponential(1.0 / rate, n))])
    return t[t < duration_s]


def _diurnal_times(rng: np.random.Generator, rate: float,
                   peak_rate: float, period_s: float,
                   duration_s: float) -> np.ndarray:
    """Inhomogeneous Poisson by thinning: simulate at ``peak_rate``,
    keep each arrival with probability ``rate(t) / peak_rate`` where
    ``rate(t)`` is a raised cosine valley->peak->valley per period."""
    if peak_rate < rate:
        raise ValueError("peak_rate must be >= rate")
    cand = _poisson_times(rng, peak_rate, duration_s)
    phase = 0.5 * (1.0 - np.cos(2.0 * math.pi * cand / period_s))
    accept = rng.random(len(cand)) < (
        (rate + (peak_rate - rate) * phase) / peak_rate)
    return cand[accept]


def _pareto_burst_times(rng: np.random.Generator, rate: float,
                        duration_s: float,
                        alpha: float = 1.5) -> np.ndarray:
    """Heavy-tail renewal gaps: Pareto(alpha) scaled to mean
    ``1/rate`` (alpha > 1 so the mean exists). Low alpha = burstier."""
    if alpha <= 1.0:
        raise ValueError("alpha must be > 1 (finite mean)")
    scale = (alpha - 1.0) / alpha / rate             # mean = 1/rate
    n = max(1, int(rate * duration_s * 1.5) + 16)
    t = np.cumsum(scale * (rng.pareto(alpha, n) + 1.0))
    while t[-1] < duration_s:
        t = np.concatenate([t, t[-1] + np.cumsum(
            scale * (rng.pareto(alpha, n) + 1.0))])
    return t[t < duration_s]


def arrival_times(rng: np.random.Generator, pattern: str, rate: float,
                  duration_s: float, *, peak_rate: float | None = None,
                  period_s: float | None = None,
                  alpha: float = 1.5) -> np.ndarray:
    if pattern == "poisson":
        return _poisson_times(rng, rate, duration_s)
    if pattern == "diurnal":
        return _diurnal_times(rng, rate, peak_rate or 4.0 * rate,
                              period_s or duration_s, duration_s)
    if pattern == "pareto_burst":
        return _pareto_burst_times(rng, rate, duration_s, alpha)
    raise ValueError(f"unknown arrival pattern {pattern!r}; "
                     f"choose from {ARRIVAL_PATTERNS}")


# -- trace ----------------------------------------------------------------

def generate_trace(seed: int, *, pattern: str = "poisson",
                   rate: float = 32.0, duration_s: float = 30.0,
                   hard_frac: float = 0.3,
                   policy_mix: tuple[PolicySpec, ...] | None = None,
                   peak_rate: float | None = None,
                   period_s: float | None = None,
                   alpha: float = 1.5) -> LoadTrace:
    """Materialise one deterministic open-loop trace from ``seed``."""
    rng = np.random.default_rng(seed)
    times = arrival_times(rng, pattern, rate, duration_s,
                          peak_rate=peak_rate, period_s=period_s,
                          alpha=alpha)
    mix = policy_mix if policy_mix is not None else default_policy_mix()
    weights = np.array([m.weight for m in mix], float)
    weights = weights / weights.sum()
    arms = rng.choice(len(mix), size=len(times), p=weights)
    hard = rng.random(len(times)) < hard_frac
    reqs = [TraceRequest(uid=i, t_arrival_s=float(times[i]),
                         hard=bool(hard[i]),
                         policy_name=mix[arms[i]].name,
                         policy=mix[arms[i]].policy)
            for i in range(len(times))]
    return LoadTrace(requests=reqs, duration_s=duration_s,
                     pattern=pattern, seed=seed)


def make_features(trace: LoadTrace, ncls: int = 8,
                  seed: int | None = None
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Feature rows matched to the trace's difficulty labels: easy rows
    get a wide logit margin (trusted locally), hard rows a narrow one
    (escalate). Deterministic from the trace seed unless overridden."""
    rng = np.random.default_rng(trace.seed + 1 if seed is None else seed)
    n = len(trace)
    labels = rng.integers(0, ncls, n)
    x = rng.normal(0, 0.05, (n, ncls))
    hard = np.array([r.hard for r in trace.requests], bool)
    margin = np.where(hard, rng.uniform(0.05, 0.4, n),
                      rng.uniform(2.0, 4.0, n))
    x[np.arange(n), labels] += margin
    return np.float32(x), labels


def segments(trace: LoadTrace, every_s: float):
    """Yield ``(t_end, requests)`` per fixed virtual-time segment — the
    drive-loop unit: submit the segment's arrivals, advance the clock
    to ``t_end``, flush. Empty segments are yielded too (the clock must
    advance across lulls so breaker resets and episode ends fire)."""
    if every_s <= 0:
        raise ValueError("every_s must be > 0")
    nseg = max(1, int(math.ceil(trace.duration_s / every_s)))
    buckets: list[list[TraceRequest]] = [[] for _ in range(nseg)]
    for r in trace.requests:
        buckets[min(nseg - 1, int(r.t_arrival_s / every_s))].append(r)
    for i, bucket in enumerate(buckets):
        yield min((i + 1) * every_s, trace.duration_s), bucket

"""RQ1 benchmark — Request-Accuracy Curves + AUC-RAC (paper Figs 2-5).

One curve per case study on the calibrated synthetic analogues; reports
local-only / remote-only accuracy, knee points (best, remote-even), the
cost saving at remote-even, and AUC-RAC vs the 0.5 random baseline.
Renders an ASCII RAC per case study.
"""

from __future__ import annotations

import numpy as np

from repro.core.metrics import auc_rac, request_accuracy_curve
from repro.data.synthetic import CASE_STUDIES, sample_case_study

N = 50_000


def ascii_curve(rac, width=60, height=12) -> str:
    xs = rac.remote_fraction
    ys = rac.accuracy
    lo, hi = ys.min(), ys.max()
    if hi - lo < 1e-9:
        hi = lo + 1e-9
    grid = [[" "] * width for _ in range(height)]
    for i in range(width):
        x = i / (width - 1)
        y = np.interp(x, xs, ys)
        r = int((y - lo) / (hi - lo) * (height - 1))
        grid[height - 1 - r][i] = "*"
    # random-baseline diagonal
    for i in range(width):
        x = i / (width - 1)
        y = ys[0] + x * (ys[-1] - ys[0])
        r = int((y - lo) / (hi - lo) * (height - 1))
        if grid[height - 1 - r][i] == " ":
            grid[height - 1 - r][i] = "."
    lines = ["".join(row) for row in grid]
    lines.append(f"{'0%':<{width - 4}}100%")
    return "\n".join(lines)


def run(verbose: bool = True) -> list[dict]:
    rows = []
    for name in sorted(CASE_STUDIES):
        cs = CASE_STUDIES[name]
        s = sample_case_study(cs, N)
        valid = ~s.invalid
        rac = request_accuracy_curve(s.local_conf[valid],
                                     s.local_correct[valid],
                                     s.remote_correct[valid])
        knees = rac.knee_points()
        auc = auc_rac(rac)
        row = {
            "case_study": name,
            "metric": cs.metric,
            "local_only": round(rac.local_only, 4),
            "remote_only": round(rac.remote_only, 4),
            "auc_rac": round(auc, 4),
            "best_fraction": round(knees["best"], 3),
            "best_accuracy": round(knees["best_accuracy"], 4),
            "remote_even_fraction": round(knees["remote_even"], 3),
            "cost_saving_at_even": round(1 - knees["remote_even"], 3),
            "superaccurate": bool(knees["best_accuracy"]
                                  > rac.remote_only + 1e-4),
        }
        rows.append(row)
        if verbose:
            print(f"\n--- RAC: {name} ({cs.metric}) ---")
            print(ascii_curve(rac))
            print(f"local={row['local_only']:.3f} "
                  f"remote={row['remote_only']:.3f} "
                  f"AUC-RAC={row['auc_rac']:.3f} (random=0.5) "
                  f"| remote-even @ {row['remote_even_fraction']:.0%} "
                  f"remote calls -> {row['cost_saving_at_even']:.0%} saved"
                  f"{' | SUPERACCURATE' if row['superaccurate'] else ''}")
    return rows


if __name__ == "__main__":
    run()

"""Latency benchmark (paper Table 7 / Eq. 2).

Measures REAL local-tier latency (trained surrogate on this CPU) and uses
the paper's measured remote latencies as the network-bound constants (a
remote GPT-3-class call cannot be measured offline). Reports the
break-even remote fraction  r* = 1 - t_l / t_r  and the expected latency
at the paper's evaluation points, mirroring Table 7's structure.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.data.synthetic import make_classification_task
from repro.models import surrogate as S

# paper Table 7 remote-only latencies (s)
REMOTE_LATENCY = {"imdb": 0.32, "issues": 1.08, "imagenet": 0.68,
                  "squadv2": 0.71, "squadv2_all": 0.74}
EVAL_POINTS = {"imdb": (0.55, 0.67), "issues": (0.3, 0.5, 0.7),
               "imagenet": (0.3, 0.5, 0.7), "squadv2": (0.33, 0.59),
               "squadv2_all": (0.49, 0.71)}


def measure_local_latency(batch: int = 1, iters: int = 50) -> float:
    """Wall time of one local prediction + 1st-level supervision."""
    vocab, seq, ncls = 512, 50, 4
    toks, _, _ = make_classification_task(0, n=max(batch, 64), vocab=vocab,
                                          seq_len=seq, num_classes=ncls)
    cfg = S.SurrogateConfig("lat", vocab_size=vocab, max_len=seq,
                            d_model=64, num_heads=4, d_ff=64,
                            num_classes=ncls, dropout=0.0)
    params = S.init_params(cfg, jax.random.PRNGKey(0))

    @jax.jit
    def predict(tk):
        logits = S.apply(cfg, params, tk)
        conf = jnp.max(jax.nn.softmax(logits, -1), -1)   # MaxSoftmax
        return jnp.argmax(logits, -1), conf

    x = jnp.asarray(toks[:batch])
    jax.block_until_ready(predict(x))                    # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(predict(x))
    return (time.perf_counter() - t0) / iters


def run(verbose: bool = True) -> list[dict]:
    t_l = measure_local_latency()
    rows = []
    if verbose:
        print("\n--- Latency (Eq. 2: t_l + r*t_r < t_r) ---")
        print(f"measured local latency t_l = {t_l * 1e3:.2f} ms "
              f"(surrogate fwd + MaxSoftmax, batch=1, this CPU)")
        print(f"{'case':>12} {'t_r(s)':>7} {'break-even':>10} "
              f"{'eval points (latency vs remote-only)':<44}")
    for name, t_r in REMOTE_LATENCY.items():
        be = 1.0 - t_l / t_r
        pts = []
        for r in EVAL_POINTS[name]:
            lat = t_l + r * t_r
            pts.append(f"{r:.0%}:{lat:.2f}s({(lat / t_r - 1) * 100:+.0f}%)")
        rows.append({"case_study": name, "t_local_s": t_l, "t_remote_s": t_r,
                     "break_even": be,
                     "eval_points": {r: t_l + r * t_r
                                     for r in EVAL_POINTS[name]}})
        if verbose:
            print(f"{name:>12} {t_r:7.2f} {be:10.2%} {' '.join(pts):<44}")
    if verbose:
        print("All paper evaluation points sit below break-even -> the "
              "cascade reduces mean latency as well as cost.")
    return rows


if __name__ == "__main__":
    run()

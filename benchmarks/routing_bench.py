"""Multi-remote routing benchmark (ISSUE 3 acceptance; DESIGN.md §6).

Two fake remote backends serve the SAME pipelined request stream:

  primary   — cheap and slow  ($0.002/call, 80 ms round trip);
  secondary — expensive, fast ($0.008/call, 20 ms round trip).

Policy ``cheapest-available`` prefers the primary. Mid-run the primary
suffers an outage: its breaker opens and the router speculatively fails
over to the secondary *at submit time*; after the outage ends the
half-open probe closes the breaker and traffic fails back to the cheap
backend automatically. A single-remote baseline (primary only, same
outage) shows what the registry buys: escalations that the baseline
degrades to fallback are instead served — at the secondary's price.

The run VERIFIES the routing acceptance criteria:
  * zero dropped requests in all phases;
  * failover to the secondary while the primary breaker is open;
  * automatic fail-back after half-open recovery;
  * per-backend billing sums exactly to ``total_cost``
    (``escalations = Σ_backends remote_calls + cache_hits + failures``).

Machine-readable results (throughput, realised $ cost, per-backend
calls / p95 latency / latency EMA, fallback counts vs the single-remote
baseline) are written to ``BENCH_routing.json``.

    PYTHONPATH=src python -m benchmarks.routing_bench \
        [--requests 576] [--depth 4] [--json BENCH_routing.json]
"""

from __future__ import annotations

import argparse
import json
import time

import jax.numpy as jnp
import numpy as np

from repro.runtime import Observability, RemoteBackend, RemoteRouter, \
    RemoteTimeout, TransportConfig
from repro.serving import ServeConfig
from repro.serving.scheduler import Request

BATCH = 32
NCLS = 8
TARGET = 0.20                   # escalation fraction (capacity-k)
PRIMARY_COST, PRIMARY_LAT = 0.002, 0.08
SECONDARY_COST, SECONDARY_LAT = 0.008, 0.02
BREAKER_RESET_S = 0.4


def local_apply(x):
    return x + 0.3 * jnp.sin(17.0 * x)


def make_load(rng, n, hard_frac=0.3):
    labels = rng.integers(0, NCLS, n)
    x = rng.normal(0, 0.05, (n, NCLS))
    margin = np.where(rng.random(n) < hard_frac,
                      rng.uniform(0.05, 0.4, n), rng.uniform(2.0, 4.0, n))
    x[np.arange(n), labels] += margin
    return np.float32(x), labels


def make_backends(outage):
    def primary_fn(x):
        if outage["on"]:
            raise RemoteTimeout("primary outage")
        time.sleep(PRIMARY_LAT)
        return 5.0 * np.asarray(x)

    def secondary_fn(x):
        time.sleep(SECONDARY_LAT)
        return 5.0 * np.asarray(x)

    tconf = TransportConfig(max_in_flight=BATCH, max_retries=0,
                            retry_backoff_s=0.0, timeout_s=10.0,
                            breaker_failures=2,
                            breaker_reset_s=BREAKER_RESET_S)
    primary = RemoteBackend("primary", primary_fn, tconf,
                            cost_per_request=PRIMARY_COST,
                            latency_s=PRIMARY_LAT)
    secondary = RemoteBackend("secondary", secondary_fn, tconf,
                              cost_per_request=SECONDARY_COST,
                              latency_s=SECONDARY_LAT)
    return primary, secondary


def _run(xs_phases, outage, router, depth, observe=False):
    """Serve three phases (pre / outage / post) through one engine."""
    cfg = ServeConfig(batch_size=BATCH, remote_fraction_budget=TARGET,
                      t_remote=0.0, pipeline_depth=depth, cache_size=0)
    engine, sched = cfg.build(local_apply, transport=router,
                              fallback=lambda r: -1)
    # warm the jit cache out of band, then reset accounting
    engine.serve({"local": xs_phases[0][:BATCH],
                  "remote": xs_phases[0][:BATCH]})
    engine.stats = type(engine.stats)()
    # observability after the warm-up reset (DESIGN.md §9): every breaker
    # / failover transition of the outage lands in the shared event log
    obs = Observability.enabled().install(engine) if observe else None

    uid = 0
    answered = 0
    fallbacks = {}
    calls_after = {}
    t0 = time.perf_counter()
    for phase, xs in zip(("pre", "outage", "post"), xs_phases):
        outage["on"] = phase == "outage"
        if phase == "post":
            time.sleep(BREAKER_RESET_S + 0.1)   # let the breaker half-open
        for row in xs:
            sched.submit(Request(uid=uid, local_input=row, remote_input=row))
            uid += 1
        responses = sched.flush()
        answered += len(responses)
        fallbacks[phase] = sum(r.source == "fallback" for r in responses)
        calls_after[phase] = {
            u: engine.stats.per_backend[u].remote_calls
            if u in engine.stats.per_backend else 0
            for u in ("primary", "secondary")}
    wall = time.perf_counter() - t0
    engine.close()
    return {"engine": engine, "obs": obs, "wall": wall, "submitted": uid,
            "answered": answered, "fallbacks": fallbacks,
            "calls_after_phase": calls_after}


def run(verbose: bool = True, requests: int = 576, depth: int = 4,
        json_path: str | None = "BENCH_routing.json") -> dict:
    rng = np.random.default_rng(0)
    per_phase = max(requests // 3, BATCH)
    xs_phases = [make_load(rng, per_phase)[0] for _ in range(3)]

    # --- routed: two-backend registry, cheapest-available ---
    outage = {"on": False}
    primary, secondary = make_backends(outage)
    router = RemoteRouter([primary, secondary],
                          policy="cheapest-available")
    routed = _run(xs_phases, outage, router, depth, observe=True)

    # --- baseline: single remote (primary only), same outage ---
    outage_b = {"on": False}
    primary_b, _ = make_backends(outage_b)
    router_b = RemoteRouter([primary_b])
    baseline = _run(xs_phases, outage_b, router_b, depth)

    st = routed["engine"].stats
    ca = routed["calls_after_phase"]
    backends = {}
    for b in router:
        u = st.per_backend.get(b.name)
        backends[b.name] = {
            "cost_per_request": b.cost_per_request,
            "remote_calls": u.remote_calls if u else 0,
            "cache_hits": u.cache_hits if u else 0,
            "transport_failures": u.transport_failures if u else 0,
            "billed_cost": u.cost if u else 0.0,
            "p95_remote_latency_s": b.stats.latency_percentile(95),
            "latency_ema_s": b.stats.latency_ema_s,
            "breaker_opens": b.stats.breaker_opens,
        }
    attributed = sum(u.remote_calls + u.cache_hits + u.transport_failures
                     for u in st.per_backend.values())

    # --- event log / trace reconciliation (DESIGN.md §9) ---
    obs = routed["obs"]
    ev = obs.events
    first = {e: ev.first_seq(e) for e in
             ("breaker_open", "breaker_half_open", "breaker_close",
              "router_failover", "router_failback")}
    spans = obs.trace.spans()
    span_cost = sum(s["cost"] for s in spans)
    ordered = (
        # pick only skips the primary once its breaker is OPEN, so the
        # first failover must be sequenced after the first open; the
        # breaker lifecycle and fail-back follow in order
        None not in first.values()
        and first["breaker_open"] < first["router_failover"]
        and (first["breaker_open"] < first["breaker_half_open"]
             < first["breaker_close"])
        and first["router_failover"] < first["router_failback"])
    checks = {
        "event_log_ordered": ordered,
        # every silent transition is in the log, not a sample of them
        "breaker_opens_all_logged":
            len(ev.events("breaker_open", "primary"))
            == backends["primary"]["breaker_opens"],
        "failovers_all_logged":
            len(ev.events("router_failover")) == router.stats.failovers
            and ev.dropped == 0,
        "one_span_per_request":
            sorted(s["uid"] for s in spans)
            == list(range(routed["submitted"])),
        "span_costs_match_billing":
            abs(span_cost - st.total_cost) < 1e-9,
        "zero_dropped": (routed["answered"] == routed["submitted"]
                         and baseline["answered"] == baseline["submitted"]),
        # the secondary only serves while the primary breaker is open
        "failover_to_secondary": (ca["outage"]["secondary"]
                                  > ca["pre"]["secondary"] == 0),
        # the primary serves again after half-open recovery
        "failback_to_primary": (ca["post"]["primary"]
                                > ca["outage"]["primary"]),
        "billing_sums_to_total": abs(
            st.total_cost - sum(v["billed_cost"]
                                for v in backends.values())) < 1e-9,
        "escalations_attributed": attributed == st.escalations,
        # escalations the baseline lost to fallback, the router served
        "fewer_fallbacks_than_baseline": (
            routed["fallbacks"]["outage"]
            < baseline["fallbacks"]["outage"]),
    }
    st_b = baseline["engine"].stats
    report = {
        "batch_size": BATCH,
        "pipeline_depth": depth,
        "target_escalation_fraction": TARGET,
        "requests": routed["submitted"],
        "routed": {
            "policy": router.policy,
            "wall_s": routed["wall"],
            "throughput_rps": routed["submitted"] / routed["wall"],
            "total_cost": st.total_cost,
            "remote_calls": st.remote_calls,
            "transport_failures": st.transport_failures,
            "fallbacks": routed["fallbacks"],
            "router_failovers": router.stats.failovers,
            "router_unrouted": router.stats.unrouted,
            "backends": backends,
        },
        "single_remote_baseline": {
            "wall_s": baseline["wall"],
            "throughput_rps": baseline["submitted"] / baseline["wall"],
            "total_cost": st_b.total_cost,
            "remote_calls": st_b.remote_calls,
            "transport_failures": st_b.transport_failures,
            "fallbacks": baseline["fallbacks"],
        },
        "observability": {
            "events": dict(sorted(ev.counts().items())),
            "events_dropped": ev.dropped,
            "first_seq": first,
            "spans": len(spans),
        },
        "checks": checks,
        "passed": all(checks.values()),
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=1)
    if verbose:
        print(f"\n--- Routing: failover vs single remote "
              f"({routed['submitted']} requests, {TARGET:.0%} escalation, "
              f"mid-run primary outage, depth {depth}) ---")
        print(f"{'path':>10} {'req/s':>8} {'cost':>9} {'fallbacks':>22}")
        print(f"{'routed':>10} {report['routed']['throughput_rps']:8.1f} "
              f"${st.total_cost:8.4f} {str(routed['fallbacks']):>22}")
        print(f"{'baseline':>10} "
              f"{report['single_remote_baseline']['throughput_rps']:8.1f} "
              f"${st_b.total_cost:8.4f} {str(baseline['fallbacks']):>22}")
        for name, v in backends.items():
            print(f"  {name}: {v['remote_calls']} calls "
                  f"(${v['billed_cost']:.4f}), "
                  f"{v['transport_failures']} failures, "
                  f"p95 {v['p95_remote_latency_s'] * 1e3:.0f} ms, "
                  f"ema {0.0 if v['latency_ema_s'] is None else v['latency_ema_s'] * 1e3:.0f} ms, "
                  f"breaker opens {v['breaker_opens']}")
        print(f"events: {report['observability']['events']} "
              f"(first seq {first})")
        print(f"checks: {checks}"
              + (f"; JSON -> {json_path}" if json_path else ""))
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=576)
    ap.add_argument("--depth", type=int, default=4,
                    help="pipelined in-flight microbatch window")
    ap.add_argument("--json", default="BENCH_routing.json",
                    help="machine-readable output path ('' disables)")
    args = ap.parse_args(argv)
    report = run(requests=args.requests, depth=args.depth,
                 json_path=args.json or None)
    return 0 if report["passed"] else 1


if __name__ == "__main__":
    raise SystemExit(main())

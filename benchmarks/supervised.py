"""RQ2 benchmark — system-level supervised assessment (paper Tables 2-6).

For each case study and target FPR in {0.01, 0.05, 0.1}: the standalone
supervised local model (baseline) vs BiSupervised at the RQ1 knee points
(superaccurate cases: remote-even + best; others: 30/50/70% remote),
reporting Delta (acceptance rate), supervised accuracy and S_beta."""

from __future__ import annotations

import numpy as np

from repro.core.metrics import (request_accuracy_curve, supervised_metrics,
                                threshold_for_fpr)
from repro.data.synthetic import CASE_STUDIES, sample_case_study

N = 50_000
FPRS = (0.01, 0.05, 0.1)


def _eval_cascade(s, remote_fraction: float, fpr: float) -> dict:
    """BiSupervised at a 1st-level threshold hitting `remote_fraction`,
    2nd-level threshold tuned to `fpr` on the escalated subset."""
    t1 = np.quantile(s.local_conf, remote_fraction)
    use_local = s.local_conf > t1
    sys_correct = np.where(use_local, s.local_correct, s.remote_correct) > 0
    t2 = threshold_for_fpr(s.remote_conf[~use_local],
                           s.remote_correct[~use_local] > 0, fpr)
    accepted = use_local | (s.remote_conf > t2)
    m = supervised_metrics(accepted, sys_correct)
    m["remote_delta"] = float(np.mean(s.remote_conf[~use_local] > t2)) \
        if (~use_local).any() else float("nan")
    return m


def _knee_fractions(s) -> list[tuple[str, float]]:
    valid = ~s.invalid
    rac = request_accuracy_curve(s.local_conf[valid], s.local_correct[valid],
                                 s.remote_correct[valid])
    k = rac.knee_points()
    if k["best_accuracy"] > rac.remote_only + 1e-4:
        return [("remote-even", k["remote_even"]), ("best", k["best"])]
    return [("30%", 0.3), ("50%", 0.5), ("70%", 0.7)]


def run(verbose: bool = True) -> list[dict]:
    rows = []
    for name in sorted(CASE_STUDIES):
        s = sample_case_study(CASE_STUDIES[name], N)
        fracs = _knee_fractions(s)
        if verbose:
            print(f"\n--- Supervised assessment: {name} ---")
            print(f"{'FPR':>5} {'config':>12} {'Δ':>6} {'ACC̄':>6} "
                  f"{'S0.5':>6} {'S1':>6} {'S2':>6}")
        for fpr in FPRS:
            t_base = threshold_for_fpr(s.local_conf, s.local_correct > 0,
                                       fpr)
            base = supervised_metrics(s.local_conf > t_base,
                                      s.local_correct > 0)
            rows.append({"case_study": name, "fpr": fpr,
                         "config": "baseline(local)", **base})
            if verbose:
                print(f"{fpr:>5} {'baseline':>12} {base['delta']:6.3f} "
                      f"{base['acc_supervised']:6.3f} {base['s_0.5']:6.3f} "
                      f"{base['s_1.0']:6.3f} {base['s_2.0']:6.3f}")
            for label, frac in fracs:
                m = _eval_cascade(s, frac, fpr)
                wins = sum(m[k] >= base[k] - 1e-9
                           for k in ("s_0.5", "s_1.0", "s_2.0"))
                rows.append({"case_study": name, "fpr": fpr,
                             "config": f"cascade@{label}",
                             "sbeta_wins": wins, **m})
                if verbose:
                    print(f"{fpr:>5} {label:>12} {m['delta']:6.3f} "
                          f"{m['acc_supervised']:6.3f} {m['s_0.5']:6.3f} "
                          f"{m['s_1.0']:6.3f} {m['s_2.0']:6.3f} "
                          f"(wins {wins}/3 S_β)")
    total = sum(r.get("sbeta_wins", 0) for r in rows)
    possible = 3 * sum(1 for r in rows if "sbeta_wins" in r)
    if verbose:
        print(f"\nS_β wins vs baseline: {total}/{possible} "
              f"({total / possible:.0%}) — paper finds a majority too")
    return rows


if __name__ == "__main__":
    run()

"""Pallas-kernel microbenchmarks.

On this CPU container the kernels dispatch to their jnp reference path (the
Pallas bodies are validated in interpret mode by tests/test_kernels.py);
the numbers here time the REFERENCE path at serving-relevant shapes and
derive the kernels' arithmetic intensity — the quantity the BlockSpec
tiling was designed around (see kernels/*/kernel.py docstrings).

The confidence-gate family (ISSUE 8) is benched in three forms at the
same serving shapes: the plain gate over precomputed logits, the gate
with the in-kernel early-emit host callback armed, and the fused local
head -> gate path (``fused_head_gate``) that composes the final
projection with gate scoring so full-vocab logits never round-trip
through HBM. The ``checks`` dict verifies fused-vs-composed parity,
interpret-mode Pallas parity and that the early-emit callback actually
fires from inside jit — so the bench gate catches functional breakage,
not just slowdowns.

Machine-readable results go to ``BENCH_kernels.json``
(``{"rows": [...], "checks": {...}}``) and are gated across PRs by
``benchmarks/check_regression.py --kernels``.

    PYTHONPATH=src python -m benchmarks.kernels_bench \
        [--json BENCH_kernels.json]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.confidence_gate.ops import confidence_gate
from repro.kernels.confidence_gate.ref import confidence_gate_ref
from repro.kernels.decode_attention.ops import decode_attn
from repro.kernels.flash_attention.ops import attention
from repro.kernels.fused_head_gate.ops import fused_head_gate
from repro.kernels.fused_head_gate.ref import fused_head_gate_ref
from repro.kernels.maxconf.ops import maxconf
from repro.kernels.mdsa.ops import mdsa_distance
from repro.kernels.rwkv6_scan.ops import rwkv6_time_mix_scan


def _time(fn, *args, iters=3, **kw):
    jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / iters


def _gate_rows(key) -> list[dict]:
    """Confidence-gate family at serving shapes (ISSUE 8).

    The fused rows time hidden@W + gate in ONE call; the `AI` column is
    the fused path's arithmetic intensity (the matmul flops over the
    hidden + weight traffic — the logits [b,v] never hit HBM), which is
    the quantity the fusion exists to raise: gate-only AI is O(1)."""
    rows = []
    for b, v in ((32, 8_192), (64, 102_400)):
        lg = jax.random.normal(key, (b, v), jnp.float32)
        us = _time(confidence_gate, lg, 0.5, supervisor="max_softmax",
                   k=b) * 1e6
        # softmax + max + threshold select ~ 6 passes over the logits
        rows.append({"kernel": "confidence_gate", "shape": f"[{b},{v}]",
                     "us_per_call": us,
                     "arith_intensity": 6 * b * v / (4 * b * v)})

        # same gate with the early-emit host callback armed: the row
        # prices the io_callback tax paid per dispatch in continuous
        # batching (engine hands trusted rows back at gate time)
        fired = []
        us = _time(confidence_gate, lg, 0.5, supervisor="max_softmax",
                   k=b, emit=lambda *a: fired.append(a)) * 1e6
        rows.append({"kernel": "confidence_gate_emit",
                     "shape": f"[{b},{v}]", "us_per_call": us,
                     "arith_intensity": 6 * b * v / (4 * b * v)})

    for b, d, v in ((32, 1_024, 8_192), (32, 1_024, 102_400)):
        h = jax.random.normal(key, (b, d), jnp.float32)
        w = jax.random.normal(key, (d, v), jnp.float32) / np.sqrt(d)
        us = _time(fused_head_gate, h, w, None, 0.5,
                   supervisor="max_softmax", k=b) * 1e6
        flops = 2 * b * d * v
        rows.append({"kernel": "fused_head_gate",
                     "shape": f"[{b},{d}]x[{d},{v}]", "us_per_call": us,
                     "arith_intensity": flops / (4 * (b * d + d * v))})
    return rows


def _gate_checks(key) -> dict:
    """Functional gates for the fused/early-emit path (ISSUE 8):
    fused == composed (head then gate), Pallas body == ref in interpret
    mode, and the early-emit callback fires from inside jit with the
    same pred the gate returns."""
    b, d, v = 24, 96, 640           # non-aligned batch, vb|v for pallas
    h = jax.random.normal(key, (b, d), jnp.float32)
    w = jax.random.normal(key, (d, v), jnp.float32) / np.sqrt(d)
    bias = jax.random.normal(key, (v,), jnp.float32) * 0.1

    fused = fused_head_gate_ref(h, w, bias, 0.5, supervisor="max_softmax",
                                k=b)
    logits = h.astype(jnp.float32) @ w.astype(jnp.float32) + bias
    composed = confidence_gate_ref(logits, 0.5, supervisor="max_softmax",
                                   k=b)
    fused_matches_composed = (
        bool(jnp.array_equal(fused["pred"], composed["pred"]))
        and bool(jnp.array_equal(fused["idx"], composed["idx"]))
        and bool(jnp.allclose(fused["conf"], composed["conf"],
                              rtol=2e-4, atol=1e-5)))

    pal = fused_head_gate(h, w, bias, 0.5, supervisor="max_softmax",
                          k=b, force_pallas=True, interpret=True)
    pallas_parity = (
        bool(jnp.array_equal(pal["pred"], fused["pred"]))
        and bool(jnp.array_equal(pal["idx"], fused["idx"]))
        and bool(jnp.allclose(pal["conf"], fused["conf"],
                              rtol=2e-4, atol=1e-5)))

    fired = []
    out = jax.jit(lambda x: confidence_gate(
        x, 0.5, supervisor="max_softmax", k=b,
        emit=lambda tag, conf, pred, idx: fired.append(
            (int(tag), np.asarray(pred))),
        emit_tag=7))(logits)
    jax.block_until_ready(out["pred"])
    early_emit_fired = (
        len(fired) == 1 and fired[0][0] == 7
        and bool(np.array_equal(fired[0][1], np.asarray(out["pred"]))))

    return {
        "fused_matches_composed": fused_matches_composed,
        "fused_pallas_interpret_parity": pallas_parity,
        "early_emit_fired": early_emit_fired,
    }


def run(verbose: bool = True,
        json_path: str | None = None) -> dict:
    key = jax.random.PRNGKey(0)
    rows = []

    # maxconf: supervisor over LM-head logits (vocab up to 152k)
    for b, v in ((32, 102_400), (64, 152_064)):
        lg = jax.random.normal(key, (b, v), jnp.float32)
        us = _time(jax.jit(maxconf), lg) * 1e6
        flops = 5 * b * v      # exp, 2 max-scans, sum, div (approx)
        rows.append({"kernel": "maxconf", "shape": f"[{b},{v}]",
                     "us_per_call": us,
                     "arith_intensity": flops / (4 * b * v)})

    # confidence gate + early emit + fused head->gate (ISSUE 8)
    rows.extend(_gate_rows(key))

    # mdsa: Mahalanobis distance, penultimate width 4096
    x = jax.random.normal(key, (256, 4096))
    mean = jnp.zeros((4096,))
    prec = jnp.eye(4096)
    us = _time(jax.jit(mdsa_distance), x, mean, prec) * 1e6
    rows.append({"kernel": "mdsa", "shape": "[256,4096]x[4096,4096]",
                 "us_per_call": us,
                 "arith_intensity": (2 * 256 * 4096 * 4096)
                 / (4 * (4096 * 4096 + 2 * 256 * 4096))})

    # flash attention: remote-tier prefill block
    q = jax.random.normal(key, (1, 1024, 8, 128), jnp.bfloat16)
    k = jax.random.normal(key, (1, 1024, 2, 128), jnp.bfloat16)
    us = _time(jax.jit(lambda q, k: attention(q, k, k, causal=True)),
               q, k) * 1e6
    rows.append({"kernel": "flash_attention", "shape": "[1,1024,8|2,128]",
                 "us_per_call": us,
                 "arith_intensity": 2 * 1024 / 2 / 2})   # ~T/2 per byte

    # decode attention: one token vs 32k cache
    q1 = jax.random.normal(key, (8, 32, 128), jnp.bfloat16)
    kc = jax.random.normal(key, (8, 16_384, 8, 128), jnp.bfloat16)
    kv_len = jnp.full((8,), 16_384, jnp.int32)
    us = _time(jax.jit(lambda a, b, c, d: decode_attn(a, b, c, d)),
               q1, kc, kc, kv_len) * 1e6
    rows.append({"kernel": "decode_attention", "shape": "[8,16k,8,128]",
                 "us_per_call": us, "arith_intensity": 32 / 8 / 2})

    # rwkv6 scan: long-context chunk
    b, t, h, m = 1, 1024, 32, 64
    r = jax.random.normal(key, (b, t, h, m)) * 0.3
    w = jax.nn.sigmoid(jax.random.normal(key, (b, t, h, m)))
    u = jax.random.normal(key, (h, m)) * 0.3
    s0 = jnp.zeros((b, h, m, m))
    us = _time(jax.jit(rwkv6_time_mix_scan), r, r, r, w, u, s0) * 1e6
    rows.append({"kernel": "rwkv6_scan", "shape": f"[{b},{t},{h},{m}]",
                 "us_per_call": us, "arith_intensity": m / 4})

    checks = _gate_checks(key)
    report = {"rows": rows, "checks": checks,
              "passed": all(checks.values())}

    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=1)
    if verbose:
        print("\n--- Kernel microbench (CPU ref path; Pallas bodies are "
              "interpret-validated in tests) ---")
        print(f"{'kernel':>20} {'shape':>24} {'us/call':>10} {'AI':>7}")
        for r_ in rows:
            print(f"{r_['kernel']:>20} {r_['shape']:>24} "
                  f"{r_['us_per_call']:10.0f} {r_['arith_intensity']:7.1f}")
        print(f"checks {checks}")
        if json_path:
            print(f"JSON -> {json_path}")
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default="BENCH_kernels.json",
                    help="machine-readable output path ('' disables)")
    args = ap.parse_args(argv)
    report = run(json_path=args.json or None)
    return 0 if report["passed"] else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""Pallas-kernel microbenchmarks.

On this CPU container the kernels dispatch to their jnp reference path (the
Pallas bodies are validated in interpret mode by tests/test_kernels.py);
the numbers here time the REFERENCE path at serving-relevant shapes and
derive the kernels' arithmetic intensity — the quantity the BlockSpec
tiling was designed around (see kernels/*/kernel.py docstrings).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.ops import decode_attn
from repro.kernels.flash_attention.ops import attention
from repro.kernels.maxconf.ops import maxconf
from repro.kernels.mdsa.ops import mdsa_distance
from repro.kernels.rwkv6_scan.ops import rwkv6_time_mix_scan


def _time(fn, *args, iters=3, **kw):
    jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / iters


def run(verbose: bool = True) -> list[dict]:
    key = jax.random.PRNGKey(0)
    rows = []

    # maxconf: supervisor over LM-head logits (vocab up to 152k)
    for b, v in ((32, 102_400), (64, 152_064)):
        lg = jax.random.normal(key, (b, v), jnp.float32)
        us = _time(jax.jit(maxconf), lg) * 1e6
        flops = 5 * b * v      # exp, 2 max-scans, sum, div (approx)
        rows.append({"kernel": "maxconf", "shape": f"[{b},{v}]",
                     "us_per_call": us,
                     "arith_intensity": flops / (4 * b * v)})

    # mdsa: Mahalanobis distance, penultimate width 4096
    x = jax.random.normal(key, (256, 4096))
    mean = jnp.zeros((4096,))
    prec = jnp.eye(4096)
    us = _time(jax.jit(mdsa_distance), x, mean, prec) * 1e6
    rows.append({"kernel": "mdsa", "shape": "[256,4096]x[4096,4096]",
                 "us_per_call": us,
                 "arith_intensity": (2 * 256 * 4096 * 4096)
                 / (4 * (4096 * 4096 + 2 * 256 * 4096))})

    # flash attention: remote-tier prefill block
    q = jax.random.normal(key, (1, 1024, 8, 128), jnp.bfloat16)
    k = jax.random.normal(key, (1, 1024, 2, 128), jnp.bfloat16)
    us = _time(jax.jit(lambda q, k: attention(q, k, k, causal=True)),
               q, k) * 1e6
    rows.append({"kernel": "flash_attention", "shape": "[1,1024,8|2,128]",
                 "us_per_call": us,
                 "arith_intensity": 2 * 1024 / 2 / 2})   # ~T/2 per byte

    # decode attention: one token vs 32k cache
    q1 = jax.random.normal(key, (8, 32, 128), jnp.bfloat16)
    kc = jax.random.normal(key, (8, 16_384, 8, 128), jnp.bfloat16)
    kv_len = jnp.full((8,), 16_384, jnp.int32)
    us = _time(jax.jit(lambda a, b, c, d: decode_attn(a, b, c, d)),
               q1, kc, kc, kv_len) * 1e6
    rows.append({"kernel": "decode_attention", "shape": "[8,16k,8,128]",
                 "us_per_call": us, "arith_intensity": 32 / 8 / 2})

    # rwkv6 scan: long-context chunk
    b, t, h, m = 1, 1024, 32, 64
    r = jax.random.normal(key, (b, t, h, m)) * 0.3
    w = jax.nn.sigmoid(jax.random.normal(key, (b, t, h, m)))
    u = jax.random.normal(key, (h, m)) * 0.3
    s0 = jnp.zeros((b, h, m, m))
    us = _time(jax.jit(rwkv6_time_mix_scan), r, r, r, w, u, s0) * 1e6
    rows.append({"kernel": "rwkv6_scan", "shape": f"[{b},{t},{h},{m}]",
                 "us_per_call": us, "arith_intensity": m / 4})

    if verbose:
        print("\n--- Kernel microbench (CPU ref path; Pallas bodies are "
              "interpret-validated in tests) ---")
        print(f"{'kernel':>18} {'shape':>24} {'us/call':>10} {'AI':>7}")
        for r_ in rows:
            print(f"{r_['kernel']:>18} {r_['shape']:>24} "
                  f"{r_['us_per_call']:10.0f} {r_['arith_intensity']:7.1f}")
    return rows


if __name__ == "__main__":
    run()

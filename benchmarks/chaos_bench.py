"""Trace-driven load + chaos bench (ISSUE 7 acceptance; DESIGN.md §10).

A diurnal open-loop trace (valley 24 rps, peak 128 rps — the peak lands
mid-run) is served through the full runtime stack — two-backend router,
admission control, observability — while a seeded ``ChaosSchedule``
scripts five episodes of remote-tier misbehaviour on a virtual clock:

    10-16 s  brownout-primary   80% of primary calls fail
    20-26 s  ramp-primary       +30 ms latency, ramping in
    30-34 s  blackout           BOTH backends hard down (peak load!)
    40-46 s  flap-primary       1 s down / 1 s up link flapping
    50-54 s  storm-primary      every primary call times out (+20 ms)

Everything runs in virtual time (``VirtualClock`` drives the engine,
both transports and the chaos wrapper), with ``pipeline_depth=1`` so
window completion is serialised behind the driver: the whole scenario
— arrivals, fault draws, breaker transitions, sheds — is a pure
function of the seeds. The bench VERIFIES exactly that, plus the ISSUE
7 acceptance criteria:

  * deterministic replay — the full scenario runs TWICE and every
    response (prediction/disposition/cost/latency), billing field,
    admission counter, chaos injection count and event-log count must
    match bit for bit;
  * causally ordered events — each scripted episode's
    ``chaos_episode_begin`` precedes the breaker open it causes, which
    precedes the router failover; ``open < half_open < close`` and
    ``failover < failback`` per backend; replay tickets park only
    after the correlated blackout begins;
  * zero silent drops — every submitted uid is answered exactly once
    (shed requests included, at $0), and shed + served counts
    reconcile bitwise with ``CascadeStats`` billing;
  * recovery — no breaker is stuck open once chaos ends.

Machine-readable results go to ``BENCH_chaos.json`` (gated in CI by
``check_regression.py --chaos``); the full event log of run A goes to
``BENCH_chaos_events.jsonl`` (uploaded as a CI artifact).

    PYTHONPATH=src python -m benchmarks.chaos_bench \
        [--duration 60] [--seed 7] [--json BENCH_chaos.json] \
        [--events-jsonl BENCH_chaos_events.jsonl]
"""

from __future__ import annotations

import argparse
import json
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.loadgen import generate_trace, make_features, segments
from repro.runtime import (ChaosEpisode, ChaosSchedule, RemoteBackend,
                           RemoteRouter, TransportConfig, VirtualClock)
from repro.runtime.transport import CLOSED
from repro.serving import ServeConfig
from repro.serving.engine import BILLING_FIELDS
from repro.serving.policy import REJECTED, SHED
from repro.serving.scheduler import Request

BATCH = 32
NCLS = 8
TARGET = 0.4                    # escalation fraction (capacity-k)
SEGMENT_S = 1.0                 # drive-loop granularity (virtual)
BASE_RATE, PEAK_RATE = 24.0, 128.0
ADMISSION_LIMIT = 96            # 3 windows of queue, soft watermark 48
PRIMARY_COST, PRIMARY_LAT = 0.002, 0.08
SECONDARY_COST, SECONDARY_LAT = 0.008, 0.02
BREAKER_RESET_S = 1.0


def local_apply(x):
    return x + 0.3 * jnp.sin(17.0 * x)


def make_episodes(duration_s: float) -> tuple[ChaosEpisode, ...]:
    """The scripted scenario, scaled to fit a shortened ``--duration``
    (episodes keep their order and relative placement)."""
    s = duration_s / 60.0
    return (
        ChaosEpisode("brownout", 10.0 * s, 6.0 * s,
                     backends=("primary",), rate=0.8,
                     name="brownout-primary"),
        ChaosEpisode("latency_ramp", 20.0 * s, 6.0 * s,
                     backends=("primary",), extra_latency_s=0.030,
                     name="ramp-primary"),
        ChaosEpisode("outage", 30.0 * s, 4.0 * s, name="blackout"),
        ChaosEpisode("flap", 40.0 * s, 6.0 * s, backends=("primary",),
                     period_s=2.0 * s, name="flap-primary"),
        ChaosEpisode("timeout_storm", 50.0 * s, 4.0 * s,
                     backends=("primary",), extra_latency_s=0.020,
                     name="storm-primary"),
    )


def build_stack(clock: VirtualClock, seed: int, duration_s: float):
    """Fresh engine + scheduler + chaos-wrapped router on ``clock``."""
    def primary_fn(x):
        return 5.0 * np.asarray(x)

    def secondary_fn(x):
        return 5.0 * np.asarray(x)

    tconf = TransportConfig(max_in_flight=BATCH, max_retries=0,
                            retry_backoff_s=0.0, timeout_s=10.0,
                            breaker_failures=2,
                            breaker_reset_s=BREAKER_RESET_S)
    router = RemoteRouter(
        [RemoteBackend("primary", primary_fn, tconf,
                       cost_per_request=PRIMARY_COST,
                       latency_s=PRIMARY_LAT, clock=clock,
                       sleep=clock.sleep),
         RemoteBackend("secondary", secondary_fn, tconf,
                       cost_per_request=SECONDARY_COST,
                       latency_s=SECONDARY_LAT, clock=clock,
                       sleep=clock.sleep)],
        policy="cheapest-available")
    schedule = ChaosSchedule(make_episodes(duration_s), seed=seed)
    schedule.wrap_router(router)
    cfg = ServeConfig(batch_size=BATCH, remote_fraction_budget=TARGET,
                      t_remote=0.0, pipeline_depth=1, cache_size=0,
                      admission_limit=ADMISSION_LIMIT,
                      admission_soft_ratio=0.5,
                      observability=True, event_capacity=65536)
    engine, sched = cfg.build(local_apply, transport=router,
                              fallback=lambda r: -1, clock=clock)
    return engine, sched, router, schedule


def drive(trace, xs, seed: int):
    """One full scenario run: returns everything the checks compare."""
    clock = VirtualClock()
    engine, sched, router, schedule = build_stack(clock, seed,
                                                  trace.duration_s)
    responses = []
    t0 = time.perf_counter()
    for t_end, bucket in segments(trace, SEGMENT_S):
        for tr in bucket:
            clock.advance_to(tr.t_arrival_s)
            sched.submit(Request(uid=tr.uid, local_input=xs[tr.uid],
                                 remote_input=xs[tr.uid],
                                 policy=tr.policy))
            # (a shed response returned here is re-delivered by flush —
            # collecting flush output alone still covers every uid)
        clock.advance_to(t_end)
        responses.extend(sched.flush())
    wall = time.perf_counter() - t0
    ev = engine.observability.events
    schedule.finalize(ev, now=clock())
    breaker_states = {b.name: b.transport.breaker.state
                      for b in router.backends}
    engine.close()
    return {"engine": engine, "sched": sched, "router": router,
            "schedule": schedule, "events": ev, "wall": wall,
            "responses": responses, "breaker_states": breaker_states}


def _digest(run) -> dict:
    """Everything that must replay bit-identically across runs."""
    st = run["engine"].stats
    ad = run["sched"].admission
    ch = run["schedule"].stats
    return {
        "responses": [(r.uid, int(r.prediction), r.source, r.disposition,
                       r.backend, round(r.cost, 12),
                       round(r.latency_s, 9))
                      for r in sorted(run["responses"],
                                      key=lambda r: r.uid)],
        "billing": {f: getattr(st, f) for f in BILLING_FIELDS},
        "per_backend": {k: (v.remote_calls, v.cache_hits,
                            v.transport_failures, round(v.cost, 12))
                        for k, v in sorted(st.per_backend.items())},
        "admission": {"submitted": ad.submitted, "admitted": ad.admitted,
                      "degraded": ad.degraded, "shed": ad.shed,
                      "shed_reasons": dict(sorted(
                          ad.shed_reasons.items())),
                      "degrade_reasons": dict(sorted(
                          ad.degrade_reasons.items()))},
        "chaos": {"calls": ch.calls, "injected": ch.injected,
                  "delayed": ch.delayed,
                  "by_episode": dict(sorted(ch.by_episode.items())),
                  "by_kind": dict(sorted(ch.by_kind.items()))},
        "event_counts": dict(sorted(run["events"].counts().items())),
    }


def _causality(run) -> dict:
    """Per-episode cause-to-effect sequencing in the shared event log."""
    ev = run["events"]
    begin = {e["episode"]: e["seq"]
             for e in ev.events("chaos_episode_begin")}
    ended = {e["episode"] for e in ev.events("chaos_episode_end")}
    p_open = ev.first_seq("breaker_open", "primary")
    s_open = ev.first_seq("breaker_open", "secondary")
    p_half = ev.first_seq("breaker_half_open", "primary")
    p_close = ev.first_seq("breaker_close", "primary")
    failover = ev.first_seq("router_failover")
    failback = ev.first_seq("router_failback")
    parked = ev.first_seq("replay_parked")
    names = [ep.name for ep in run["schedule"].episodes]
    seqs = {"episode_begin": begin, "primary_open": p_open,
            "secondary_open": s_open, "primary_half_open": p_half,
            "primary_close": p_close, "router_failover": failover,
            "router_failback": failback, "replay_parked": parked}
    ok = (None not in (p_open, s_open, p_half, p_close,
                       failover, failback)
          # the brownout is the first scripted fault: its begin marker
          # must precede the open it causes, which precedes failover
          and begin.get("brownout-primary") is not None
          and begin["brownout-primary"] < p_open < failover
          and p_open < p_half < p_close
          and failover < failback
          # the secondary only fails under the correlated blackout
          and begin.get("blackout") is not None
          and begin["blackout"] < s_open
          # replay tickets park only once EVERY breaker is open, which
          # first happens under the blackout
          and (parked is None or parked > begin["blackout"]))
    return {"seqs": seqs, "ordered": ok,
            "all_begun": sorted(begin) == sorted(names),
            "all_ended": sorted(ended) == sorted(names)}


def run(verbose: bool = True, duration_s: float = 60.0, seed: int = 7,
        json_path: str | None = "BENCH_chaos.json",
        events_jsonl: str | None = "BENCH_chaos_events.jsonl") -> dict:
    trace = generate_trace(seed, pattern="diurnal", rate=BASE_RATE,
                           peak_rate=PEAK_RATE, duration_s=duration_s,
                           hard_frac=0.25)
    xs, _ = make_features(trace, NCLS)

    run_a = drive(trace, xs, seed)
    run_b = drive(trace, xs, seed)
    dig_a, dig_b = _digest(run_a), _digest(run_b)

    st = run_a["engine"].stats
    ad = run_a["sched"].admission
    ch = run_a["schedule"].stats
    ev = run_a["events"]
    causal = _causality(run_a)

    uids = sorted(r.uid for r in run_a["responses"])
    dispositions: dict[str, int] = {}
    for r in run_a["responses"]:
        dispositions[r.disposition] = dispositions.get(r.disposition,
                                                       0) + 1
    served = len(run_a["responses"]) - dispositions.get(SHED, 0) \
        - dispositions.get(REJECTED, 0)
    attributed = sum(u.remote_calls + u.cache_hits + u.transport_failures
                     for u in st.per_backend.values())
    fault_episodes = [ep.name for ep in run_a["schedule"].episodes
                      if ep.kind in ("brownout", "outage", "flap",
                                     "timeout_storm")]
    metrics = run_a["engine"].observability.metrics.snapshot()
    shed_counter = sum(v for k, v in metrics["counters"].items()
                       if k.startswith("cascade_admission_shed_total"))

    checks = {
        # -- ISSUE 7 acceptance: seeded replay is bit-identical --------
        "deterministic_replay": dig_a == dig_b,
        # -- zero silent drops across overload + chaos -----------------
        "zero_silent_drop": uids == list(range(len(trace))),
        "sheds_answered_at_zero_cost": all(
            r.cost == 0.0 and r.source == "shed"
            for r in run_a["responses"] if r.disposition == SHED),
        # -- shed + served reconcile bitwise with billing --------------
        "admission_reconciles": (
            ad.submitted == len(trace)
            and ad.submitted == st.requests + ad.shed
            and ad.admitted == st.requests
            and dispositions.get(SHED, 0) == ad.shed
            and shed_counter == ad.shed
            and len(ev.events("admission_shed")) == ad.shed),
        "billing_reconciles": (
            st.escalations == st.remote_calls + st.cache_hits
            + st.transport_failures
            and abs(st.total_cost - sum(u.cost for u in
                                        st.per_backend.values())) < 1e-9
            and attributed == st.escalations),
        # -- every scripted episode fired and is causally ordered ------
        "events_causal": causal["ordered"],
        "episodes_all_marked": (causal["all_begun"]
                                and causal["all_ended"]),
        "faults_injected": (all(ch.by_episode.get(n, 0) > 0
                                for n in fault_episodes)
                            and ch.delayed > 0),
        "breaker_opens_all_logged": all(
            len(ev.events("breaker_open", b.name))
            == b.stats.breaker_opens for b in run_a["router"].backends),
        "no_events_dropped": ev.dropped == 0,
        # -- overload actually exercised, system recovered -------------
        "sheds_exercised": ad.shed > 0 and ad.degraded > 0,
        "majority_served": served / max(1, len(trace)) >= 0.5,
        "breakers_recovered": all(
            s == CLOSED for s in run_a["breaker_states"].values()),
    }

    backends = {}
    for b in run_a["router"].backends:
        u = st.per_backend.get(b.name)
        backends[b.name] = {
            "cost_per_request": b.cost_per_request,
            "remote_calls": u.remote_calls if u else 0,
            "transport_failures": u.transport_failures if u else 0,
            "billed_cost": u.cost if u else 0.0,
            "breaker_opens": b.stats.breaker_opens,
            "final_breaker_state": run_a["breaker_states"][b.name],
        }
    report = {
        "batch_size": BATCH,
        "virtual_duration_s": trace.duration_s,
        "seed": seed,
        "requests": len(trace),
        "trace": {"pattern": trace.pattern,
                  "policy_mix": trace.policy_counts()},
        "wall_s": run_a["wall"],
        "throughput_rps": len(trace) / run_a["wall"],
        "admission": dig_a["admission"],
        "dispositions": dict(sorted(dispositions.items())),
        "served_fraction": served / max(1, len(trace)),
        "billing": dig_a["billing"],
        "backends": backends,
        "chaos": dig_a["chaos"],
        "episodes": [{"name": ep.name, "kind": ep.kind,
                      "start_s": ep.start_s, "end_s": ep.end_s,
                      "targets": list(ep.backends) or None,
                      "faults": ch.by_episode.get(ep.name, 0)}
                     for ep in run_a["schedule"].episodes],
        "observability": {"events": dig_a["event_counts"],
                          "events_dropped": ev.dropped,
                          "causality": causal["seqs"]},
        "checks": checks,
        "passed": all(checks.values()),
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=1)
    if events_jsonl:
        with open(events_jsonl, "w") as f:
            for e in ev.events():
                f.write(json.dumps(e) + "\n")
    if verbose:
        print(f"\n--- Chaos: {len(trace)} requests over "
              f"{trace.duration_s:g} virtual s (diurnal "
              f"{BASE_RATE:g}->{PEAK_RATE:g} rps, "
              f"{len(run_a['schedule'].episodes)} episodes, seed {seed}, "
              f"wall {run_a['wall']:.2f}s x2 runs) ---")
        print(f"admission: {ad.submitted} submitted = "
              f"{st.requests} admitted + {ad.shed} shed "
              f"{dict(sorted(ad.shed_reasons.items()))}; "
              f"{ad.degraded} degraded")
        print(f"dispositions: {report['dispositions']}")
        print(f"chaos: {ch.injected} faults "
              f"{dict(sorted(ch.by_kind.items()))}, "
              f"{ch.delayed} delayed (+{ch.extra_latency_s:.2f}s virtual)")
        for name, v in backends.items():
            print(f"  {name}: {v['remote_calls']} calls "
                  f"(${v['billed_cost']:.4f}), "
                  f"{v['transport_failures']} failures, "
                  f"breaker opens {v['breaker_opens']}, "
                  f"ends {v['final_breaker_state']}")
        print(f"events: {report['observability']['events']}")
        print(f"checks: {checks}"
              + (f"; JSON -> {json_path}" if json_path else ""))
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--duration", type=float, default=60.0,
                    help="virtual scenario length in seconds")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--json", default="BENCH_chaos.json",
                    help="machine-readable output path ('' disables)")
    ap.add_argument("--events-jsonl", default="BENCH_chaos_events.jsonl",
                    help="event-log artifact path ('' disables)")
    args = ap.parse_args(argv)
    report = run(duration_s=args.duration, seed=args.seed,
                 json_path=args.json or None,
                 events_jsonl=args.events_jsonl or None)
    return 0 if report["passed"] else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""Pipelined + streaming serving-path benchmark (ISSUE 2/4 acceptance;
DESIGN.md §5, §7).

Synthetic load at ~20% escalation against a fake remote with a real
0.3s round-trip latency. Three engines serve the SAME request stream:

  serial    — the runtime path, one microbatch at a time: local step,
              then block on the remote window before the next batch's
              local step can dispatch;
  pipelined — ``pipeline_depth`` microbatches in flight: batch i+1's
              local tier (fused confidence gate) runs while batch i's
              escalations are on the wire; windows drain in submission
              order (FIFO);
  streaming — the same pipeline with per-request completion: locally
              trusted requests hand back the moment the confidence gate
              clears, escalations stream back as their remote futures
              resolve (``--completion-mode streaming``).

Throughput is the headline FIFO metric; the streaming section reports
the per-request hand-back latency distribution split by trusted-local
vs escalated rows. The run VERIFIES that all paths produce bitwise-
identical predictions/routing and identical billing stats — overlap and
reordering must never change what the cascade answers or charges — and
that the streaming trusted-local p95 is at most half the FIFO-drain
per-request p95 (ISSUE 4 acceptance).

A fourth, mixed-SLA section (DESIGN.md §8) attaches a tight
``RequestPolicy`` deadline to half the stream: the policy-aware
scheduler packs likely-escalating rows into dedicated windows (purity is
reported and gated) and the engine downgrades deadline-infeasible
escalations to ``DEADLINE_LOCAL``, so tight-deadline requests meet their
SLA instead of inheriting the remote round trip. The section reports the
deadline-hit-rate, packed-window purity and per-disposition counts.

A continuous-batching section (DESIGN.md §11) re-serves the streaming
stream with ``batching="continuous"``: a slot map over the persistent
padded batch admits requests as slots free up and the in-kernel early
emit hands trusted-local rows back at gate time. Gated: predictions
and billing bitwise identical to fixed-window streaming, and the
trusted-local SERVICE p95 (net of queue wait) at most half of
window streaming's.

A fifth, observability section (DESIGN.md §9) re-runs the headline
stream with the full tracing/metrics/event stack enabled and gates:
traced throughput within 3% of untraced, answers and billing unchanged,
exactly one monotonic span per request, span costs and commit-time
metric counters reconciling (bitwise) with ``CascadeStats``.
``--trace-jsonl`` / ``--metrics-out`` export the traced run's spans and
metrics snapshot (CI uploads both as artifacts).

Machine-readable results are written to ``BENCH_serving.json`` so the
perf trajectory is tracked across PRs and gated by
``benchmarks/check_regression.py``.

    PYTHONPATH=src python -m benchmarks.serving_bench \
        [--requests 1024] [--depth 8] [--remote-latency 0.3] \
        [--completion-mode streaming] [--json BENCH_serving.json]
"""

from __future__ import annotations

import argparse
import json
import time
from collections import Counter

import jax.numpy as jnp
import numpy as np

from repro.runtime import (Observability, TransportConfig,
                           fit_escalation_prior)
from repro.serving import RemoteSpec, RequestPolicy, ServeConfig
from repro.serving.engine import BILLING_FIELDS
from repro.serving.scheduler import Request

BATCH = 32
NCLS = 8
TARGET = 0.20           # escalation fraction (capacity-k, no controller)
STREAMING_P95_RATIO = 0.5       # trusted-local p95 <= ratio * FIFO p95
CONTINUOUS_SERVICE_RATIO = 0.5  # continuous trusted-local service p95
                                # <= ratio * window-streaming's (ISSUE 8)
OVERHEAD_BAR = 0.97             # traced throughput >= 97% untraced (§9)
DEADLINE_HIT_BAR = 0.95         # tight rows meeting their SLA (§8)
PURITY_BAR = 0.95               # packed windows from one class only


def local_apply(x):
    return x + 0.3 * jnp.sin(17.0 * x)     # noisy view of the features


def make_remote(latency_s: float):
    def remote(x):
        time.sleep(latency_s)              # the wire + the big model
        return 5.0 * np.asarray(x)
    return remote


def make_load(rng, n, hard_frac=0.3):
    """Feature batches whose argmax is the label; hard rows have small
    margins -> low 1st-level confidence. All rows distinct (the cache
    must not blur the serial/pipelined billing comparison)."""
    labels = rng.integers(0, NCLS, n)
    x = rng.normal(0, 0.05, (n, NCLS))
    margin = np.where(rng.random(n) < hard_frac,
                      rng.uniform(0.05, 0.4, n), rng.uniform(2.0, 4.0, n))
    x[np.arange(n), labels] += margin
    return np.float32(x), labels


def _mk_config(depth: int, latency_s: float, completion_mode="fifo",
               packing="none", t_local=None,
               batching="window") -> ServeConfig:
    """The one ServeConfig every bench engine is built from (§8)."""
    return ServeConfig(
        batch_size=BATCH, remote_fraction_budget=TARGET, t_remote=0.0,
        t_local=t_local, pipeline_depth=depth,
        completion_mode=completion_mode, packing=packing, cache_size=0,
        batching=batching,
        transport=TransportConfig(max_in_flight=BATCH, retry_backoff_s=0.0,
                                  timeout_s=max(2.0, 10 * latency_s),
                                  max_concurrent=max(depth, 1)),
        remotes=(RemoteSpec("remote", None, latency_s),))


def _serve(xs, depth: int, latency_s: float, completion_mode="fifo",
           policies=None, packing="none", prior=None, t_local=None,
           observability=False, batching="window"):
    cfg = _mk_config(depth, latency_s, completion_mode, packing, t_local,
                     batching)
    engine, sched = cfg.build(local_apply, make_remote(latency_s),
                              fallback=lambda r: -1, prior=prior)
    # warm the jit cache with one out-of-band batch, then reset accounting
    engine.serve({"local": xs[:BATCH], "remote": xs[:BATCH]})
    engine.stats = type(engine.stats)()
    if observability:
        # installed AFTER the warm-up reset so the commit-time counters
        # stay bitwise-reconcilable with the (reset) CascadeStats
        Observability.enabled().install(engine)
    t0 = time.perf_counter()
    for i, row in enumerate(xs):
        sched.submit(Request(uid=i, local_input=row, remote_input=row,
                             policy=policies[i] if policies else None))
    responses = sched.flush()
    wall = time.perf_counter() - t0
    engine.close()
    return responses, engine, wall, sched


def _metrics(tag, responses, engine, wall, n) -> dict:
    st = engine.stats
    lat = [r.latency_s for r in responses]
    return {
        "path": tag,
        "requests": n,
        "wall_s": wall,
        "throughput_rps": n / wall,
        "p50_wall_latency_s": st.wall_percentile(50),
        "p95_wall_latency_s": st.wall_percentile(95),
        "mean_wall_latency_s": st.mean_wall_latency_s,
        # per-request hand-back latency (enqueue -> response, §8)
        "p50_request_latency_s": float(np.percentile(lat, 50)),
        "p95_request_latency_s": float(np.percentile(lat, 95)),
        "modelled_mean_latency_s": st.mean_latency_s,
        "remote_fraction": st.remote_fraction,
        "escalation_fraction": st.escalation_fraction,
        "remote_calls": st.remote_calls,
        "total_cost": st.total_cost,
        # per-backend measured remote latency (TransportStats), so the
        # latency-ema routing policy is observable in bench JSON
        "backend_remote_latency": {
            b.name: {"p95_s": b.stats.latency_percentile(95),
                     "ema_s": b.stats.latency_ema_s}
            for b in engine.router},
    }


def _service_lat(r) -> float:
    """Dispatch -> hand-back: latency net of load-dependent queue wait
    (Response.latency_s is enqueue-anchored since §8)."""
    return r.latency_s - r.queue_s


def _latency_split(responses) -> dict:
    """Per-request hand-back latency, split trusted-local vs escalated.
    Both the enqueue-anchored latency and the SERVICE latency (net of
    queue wait) are reported; the trusted-local-vs-FIFO ratio check uses
    the service numbers so an oversubscribed submit burst (shared queue
    wait on both sides) cannot mask a head-of-line regression."""
    out = {}
    for tag, rows in (
            ("trusted_local", [r for r in responses if r.source == "local"]),
            ("escalated", [r for r in responses if r.source != "local"])):
        lat = [r.latency_s for r in rows]
        svc = [_service_lat(r) for r in rows]
        out[tag] = {
            "count": len(rows),
            "p50_latency_s": float(np.percentile(lat, 50)) if lat else 0.0,
            "p95_latency_s": float(np.percentile(lat, 95)) if lat else 0.0,
            "mean_latency_s": float(np.mean(lat)) if lat else 0.0,
            "service_p95_latency_s":
                float(np.percentile(svc, 95)) if svc else 0.0,
        }
    return out


def _by_uid(responses):
    return {r.uid: (r.prediction, r.source) for r in responses}


def _margin(row: np.ndarray) -> float:
    """Cheap request-observable proxy score: top-1 vs top-2 feature gap
    (correlates with the 1st-level supervisor confidence)."""
    s = np.sort(np.asarray(row))
    return float(s[-1] - s[-2])


def _policy_section(xs, depth: int, latency_s: float) -> dict:
    """Mixed-SLA workload (DESIGN.md §8): 50% of the stream carries a
    tight per-request deadline equal to the remote round trip (so ANY
    escalation would blow the SLA once the window is in flight), 50% is
    relaxed (no policy). The calibration-table escalation prior +
    policy feasibility drive the scheduler's hot/cold window packing;
    the engine downgrades deadline-infeasible escalations to
    DEADLINE_LOCAL. Gated: deadline-hit-rate, packed-window purity, zero
    drops, per-response costs summing to the billed total."""
    n = len(xs)
    tight = RequestPolicy(deadline_s=latency_s)
    policies = [tight if i % 2 == 0 else None for i in range(n)]

    # calibration table (§8): offline 1st-level confidences on a slice
    # pick t_local at the TARGET quantile and fit the escalation prior
    # on the request-observable margin proxy
    n_cal = min(256, n)
    logits = np.asarray(local_apply(jnp.asarray(xs[:n_cal])))
    conf = np.max(np.exp(logits) / np.exp(logits).sum(-1, keepdims=True),
                  -1)
    t_local = float(np.quantile(conf, TARGET))
    prior = fit_escalation_prior(
        np.array([_margin(r) for r in xs[:n_cal]]), conf <= t_local)

    responses, engine, wall, sched = _serve(
        xs, depth=depth, latency_s=latency_s, completion_mode="streaming",
        policies=policies, packing="policy",
        prior=lambda req: prior(_margin(req.local_input)),
        t_local=t_local)

    tight_rows = [r for r in responses if r.uid % 2 == 0]
    hits = [r for r in tight_rows if r.latency_s <= latency_s]
    hit_rate = len(hits) / max(len(tight_rows), 1)
    ps = dict(sched.packing_stats)
    purity = (ps["cold"] + ps["hot"]) / max(ps["windows"], 1)
    st = engine.stats
    cost_sum = sum(r.cost for r in responses)
    dispositions = dict(Counter(r.disposition for r in responses))
    checks = {
        "deadline_hit_rate_ok": hit_rate >= DEADLINE_HIT_BAR,
        "zero_dropped": len(responses) == n,
        "windows_pure": ps["mixed"] == 0 and purity >= PURITY_BAR,
        "response_costs_sum_to_total":
            abs(cost_sum - st.total_cost) < 1e-9,
        "billing_invariant": (st.escalations == st.remote_calls
                              + st.cache_hits + st.transport_failures),
    }
    lat_tight = [r.latency_s for r in tight_rows]
    lat_rel = [r.latency_s for r in responses if r.uid % 2 == 1]
    return {
        "requests": n,
        "tight_fraction": 0.5,
        "tight_deadline_s": latency_s,
        "wall_s": wall,
        "throughput_rps": n / wall,
        "deadline_hit_rate": hit_rate,
        "packed_window_purity": purity,
        "packing_stats": ps,
        "dispositions": dispositions,
        "tight": {
            "count": len(tight_rows),
            "p50_latency_s": float(np.percentile(lat_tight, 50)),
            "p95_latency_s": float(np.percentile(lat_tight, 95)),
        },
        "relaxed": {
            "count": len(lat_rel),
            "p50_latency_s": float(np.percentile(lat_rel, 50)),
            "p95_latency_s": float(np.percentile(lat_rel, 95)),
        },
        "total_cost": st.total_cost,
        "remote_fraction": st.remote_fraction,
        "checks": checks,
        "passed": all(checks.values()),
    }


def _spans_monotonic(spans) -> bool:
    for s in spans:
        ts = [t for _, t in s["stages"]]
        if ts != sorted(ts):
            return False
    return True


def _observability_section(xs, depth, latency_s, completion_mode,
                           trace_jsonl=None, metrics_out=None) -> dict:
    """Traced twin of the headline run (DESIGN.md §9): the SAME stream
    against the same sleeping fake remote, with the full observability
    stack on. Both arms take the best of 5 walls — against a sleeping
    remote the wall clock quantises to whole round trips, so a single
    missed window overlap in one run would masquerade as ~50% overhead.
    Gated: tracing must not change answers or billing, must cost <=3%
    throughput, must produce exactly one monotonic span per request,
    and the commit-time metric counters must reconcile bitwise with
    ``CascadeStats``."""
    n = len(xs)

    def best_of(observability):
        best = None
        for _ in range(5):
            r, eng, w, _s = _serve(xs, depth=depth, latency_s=latency_s,
                                   completion_mode=completion_mode,
                                   observability=observability)
            if best is None or w < best[2]:
                best = (r, eng, w)
        return best

    r_base, eng_base, w_base = best_of(False)
    r_tr, eng_tr, w_tr = best_of(True)
    obs = eng_tr.observability
    st = eng_tr.stats
    spans = obs.trace.spans()
    counters = obs.metrics.snapshot()["counters"]
    span_cost = sum(s["cost"] for s in spans)
    span_disp = dict(Counter(s["disposition"] for s in spans))
    resp_disp = dict(Counter(r.disposition for r in r_tr))
    checks = {
        "overhead_ok": (n / w_tr) >= OVERHEAD_BAR * (n / w_base),
        "predictions_identical": _by_uid(r_tr) == _by_uid(r_base),
        "billing_identical": _billing_identical(eng_tr, eng_base),
        "one_span_per_request":
            sorted(s["uid"] for s in spans) == list(range(n)),
        "spans_monotonic": _spans_monotonic(spans),
        "span_costs_match_billing":
            abs(span_cost - st.total_cost) < 1e-9
            and span_disp == resp_disp,
        # commit-order counter updates reconcile BITWISE with the stats
        "metrics_match_stats": (
            counters.get("cascade_requests_total") == st.requests
            and counters.get("cascade_escalations_total") == st.escalations
            and counters.get("cascade_remote_calls_total") == st.remote_calls
            and counters.get("cascade_cost_dollars_total") == st.total_cost),
    }
    if trace_jsonl:
        obs.trace.write_jsonl(trace_jsonl)
    if metrics_out:
        with open(metrics_out, "w") as f:
            json.dump(obs.metrics.snapshot(), f, indent=1, sort_keys=True)
    return {
        "untraced_throughput_rps": n / w_base,
        "traced_throughput_rps": n / w_tr,
        "overhead_ratio": (n / w_tr) / (n / w_base),
        "spans": len(spans),
        "trace_dropped": obs.trace.dropped,
        "events": dict(sorted(obs.events.counts().items())),
        "dispositions": span_disp,
        "checks": checks,
        "passed": all(checks.values()),
    }


def _billing_identical(a, b) -> bool:
    if any(getattr(a.stats, f) != getattr(b.stats, f) for f in BILLING_FIELDS):
        return False
    cost = lambda e: {n: u.cost for n, u in e.stats.per_backend.items()}
    return cost(a) == cost(b)


def run(verbose: bool = True, requests: int = 1024, depth: int = 8,
        remote_latency_s: float = 0.3, completion_mode: str = "streaming",
        json_path: str | None = "BENCH_serving.json",
        trace_jsonl: str | None = None,
        metrics_out: str | None = None) -> dict:
    rng = np.random.default_rng(0)
    xs, _ = make_load(rng, requests)

    r_ser, eng_ser, w_ser, _ = _serve(xs, depth=1,
                                      latency_s=remote_latency_s)
    r_pip, eng_pip, w_pip, _ = _serve(xs, depth=depth,
                                      latency_s=remote_latency_s)

    identical = ([(r.uid, r.prediction, r.source) for r in r_ser]
                 == [(r.uid, r.prediction, r.source) for r in r_pip])
    billing_identical = _billing_identical(eng_ser, eng_pip)

    n = len(xs)
    serial = _metrics("serial", r_ser, eng_ser, w_ser, n)
    pipelined = _metrics("pipelined", r_pip, eng_pip, w_pip, n)
    report = {
        "batch_size": BATCH,
        "pipeline_depth": depth,
        "remote_latency_s": remote_latency_s,
        "target_escalation_fraction": TARGET,
        "serial": serial,
        "pipelined": pipelined,
        "speedup": serial["wall_s"] / pipelined["wall_s"],
        "predictions_identical": identical,
        "billing_identical": billing_identical,
        "passed_2x": (serial["wall_s"] / pipelined["wall_s"] >= 2.0
                      and identical and billing_identical),
    }

    # --- streaming completion mode (DESIGN.md §7) ---
    if completion_mode == "streaming":
        r_str, eng_str, w_str, s_str = _serve(
            xs, depth=depth, latency_s=remote_latency_s,
            completion_mode="streaming")
        fifo_p95 = float(np.percentile([_service_lat(r) for r in r_pip],
                                       95))
        split = _latency_split(r_str)
        local_p95 = split["trusted_local"]["service_p95_latency_s"]
        checks = {
            # reordering must never change answers, routing or billing
            "predictions_identical": _by_uid(r_str) == _by_uid(r_pip),
            "billing_identical": _billing_identical(eng_str, eng_pip),
            "zero_dropped": len(r_str) == n,
            # the point of streaming: cheap locally-trusted requests no
            # longer inherit the remote p95 (ISSUE 4 acceptance)
            "trusted_local_p95_halved":
                local_p95 <= STREAMING_P95_RATIO * fifo_p95,
        }
        report["streaming"] = {
            "wall_s": w_str,
            "throughput_rps": n / w_str,
            "first_response_s": s_str.first_response_s,
            "fifo_service_p95_latency_s": fifo_p95,
            "trusted_local_p95_ratio_vs_fifo":
                local_p95 / max(fifo_p95, 1e-12),
            **split,
            "checks": checks,
            "passed": all(checks.values()),
        }
        report["passed"] = report["passed_2x"] and all(checks.values())

        # --- continuous batching vs fixed-window streaming (ISSUE 8) ---
        # Same stream, same depth, batching="continuous": slot-map
        # admission + in-kernel early emit + host half at gate time.
        # Cohorts are drawn identically to the fixed-window packer, so
        # predictions AND billing must stay bitwise identical; the win
        # is emission timing — trusted-local SERVICE latency (net of
        # queue wait) must at least halve vs window streaming.
        r_cont, eng_cont, w_cont, s_cont = _serve(
            xs, depth=depth, latency_s=remote_latency_s,
            completion_mode="streaming", batching="continuous")
        split_cont = _latency_split(r_cont)
        win_local_p95 = split["trusted_local"]["service_p95_latency_s"]
        cont_local_p95 = split_cont["trusted_local"]["service_p95_latency_s"]
        slots = s_cont._slots
        cont_checks = {
            # slot-map scheduling must never change answers or billing
            "predictions_identical": _by_uid(r_cont) == _by_uid(r_str),
            "billing_identical": _billing_identical(eng_cont, eng_str),
            "zero_dropped": len(r_cont) == n,
            # the point of continuous batching: trusted-local rows hand
            # back at gate time, not at window-drain time
            "trusted_local_service_halved":
                cont_local_p95 <= CONTINUOUS_SERVICE_RATIO * win_local_p95,
        }
        report["continuous"] = {
            "wall_s": w_cont,
            "throughput_rps": n / w_cont,
            "first_response_s": s_cont.first_response_s,
            "window_trusted_local_service_p95_s": win_local_p95,
            "trusted_local_service_ratio_vs_window":
                cont_local_p95 / max(win_local_p95, 1e-12),
            "slot_stats": {
                "capacity": slots.capacity,
                "peak_occupied": slots.peak,
                "joins": slots.joins,
                "leaves": slots.leaves,
                "occupancy_ema": slots.occupancy_ema,
            },
            **split_cont,
            "checks": cont_checks,
            "passed": all(cont_checks.values()),
        }
        report["passed"] = report["passed"] and all(cont_checks.values())
    else:
        report["passed"] = report["passed_2x"]

    # --- mixed-SLA policy section (DESIGN.md §8) ---
    report["policy"] = _policy_section(xs, depth, remote_latency_s)
    report["passed"] = report["passed"] and report["policy"]["passed"]

    # --- observability overhead + trace/metric reconciliation (§9) ---
    report["observability"] = _observability_section(
        xs, depth, remote_latency_s, completion_mode, trace_jsonl,
        metrics_out)
    report["passed"] = (report["passed"]
                        and report["observability"]["passed"])

    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=1)
    if verbose:
        print(f"\n--- Serving: pipelined vs serial runtime path "
              f"({n} requests, {TARGET:.0%} escalation, "
              f"{remote_latency_s}s fake remote, depth {depth}) ---")
        print(f"{'path':>10} {'req/s':>8} {'wall':>7} {'p50':>7} {'p95':>7} "
              f"{'remote%':>8}")
        for m in (serial, pipelined):
            print(f"{m['path']:>10} {m['throughput_rps']:8.1f} "
                  f"{m['wall_s']:6.1f}s {m['p50_wall_latency_s']*1e3:6.0f}m "
                  f"{m['p95_wall_latency_s']*1e3:6.0f}m "
                  f"{m['remote_fraction']:8.2f}")
        print(f"speedup {report['speedup']:.2f}x; predictions identical: "
              f"{identical}; billing identical: {billing_identical}")
        if "streaming" in report:
            s = report["streaming"]
            print("--- Streaming completion (per-request hand-back) ---")
            print(f"trusted-local service p95 "
                  f"{s['trusted_local']['service_p95_latency_s']*1e3:7.1f} "
                  f"ms ({s['trusted_local']['count']} requests) vs FIFO "
                  f"service p95 {s['fifo_service_p95_latency_s']*1e3:.1f}"
                  f" ms -> ratio {s['trusted_local_p95_ratio_vs_fifo']:.3f}")
            print(f"escalated     p95 "
                  f"{s['escalated']['p95_latency_s']*1e3:7.1f} ms "
                  f"({s['escalated']['count']} requests); first response "
                  f"{s['first_response_s']*1e3:.1f} ms; checks {s['checks']}")
        if "continuous" in report:
            c = report["continuous"]
            print("--- Continuous batching (slot map + early emit) ---")
            print(f"trusted-local service p95 "
                  f"{c['trusted_local']['service_p95_latency_s']*1e3:7.2f} "
                  f"ms vs window-streaming "
                  f"{c['window_trusted_local_service_p95_s']*1e3:.2f} ms "
                  f"-> ratio {c['trusted_local_service_ratio_vs_window']:.3f}"
                  f" (bar {CONTINUOUS_SERVICE_RATIO})")
            print(f"slots {c['slot_stats']}; checks {c['checks']}")
        pol = report["policy"]
        print("--- Mixed-SLA policy section (DESIGN.md §8) ---")
        print(f"tight deadline {pol['tight_deadline_s']*1e3:.0f} ms: "
              f"hit rate {pol['deadline_hit_rate']:.3f} "
              f"(tight p95 {pol['tight']['p95_latency_s']*1e3:.1f} ms, "
              f"relaxed p95 {pol['relaxed']['p95_latency_s']*1e3:.1f} ms)")
        print(f"window packing {pol['packing_stats']} -> purity "
              f"{pol['packed_window_purity']:.2f}; dispositions "
              f"{pol['dispositions']}; checks {pol['checks']}")
        ob = report["observability"]
        print("--- Observability overhead (DESIGN.md §9) ---")
        print(f"traced {ob['traced_throughput_rps']:.1f} req/s vs "
              f"untraced {ob['untraced_throughput_rps']:.1f} req/s "
              f"-> ratio {ob['overhead_ratio']:.3f} "
              f"(bar {OVERHEAD_BAR}); {ob['spans']} spans "
              f"({ob['trace_dropped']} dropped); checks {ob['checks']}")
        if json_path:
            print(f"JSON -> {json_path}")
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=1024)
    ap.add_argument("--depth", type=int, default=8,
                    help="pipelined in-flight microbatch window")
    ap.add_argument("--remote-latency", type=float, default=0.3,
                    help="fake remote round-trip seconds")
    ap.add_argument("--completion-mode", default="streaming",
                    choices=("fifo", "streaming"),
                    help="streaming adds the per-request completion "
                         "section (DESIGN.md §7); fifo skips it")
    ap.add_argument("--json", default="BENCH_serving.json",
                    help="machine-readable output path ('' disables)")
    ap.add_argument("--trace-jsonl", default="",
                    help="write the traced run's span timelines here "
                         "(JSONL, one span per line; '' disables)")
    ap.add_argument("--metrics-out", default="",
                    help="write the traced run's metrics snapshot here "
                         "(JSON; '' disables)")
    args = ap.parse_args(argv)
    report = run(requests=args.requests, depth=args.depth,
                 remote_latency_s=args.remote_latency,
                 completion_mode=args.completion_mode,
                 json_path=args.json or None,
                 trace_jsonl=args.trace_jsonl or None,
                 metrics_out=args.metrics_out or None)
    return 0 if report["passed"] else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""Pipelined serving-path benchmark (ISSUE 2 acceptance; DESIGN.md §5).

Synthetic load at ~20% escalation against a fake remote with a real
0.3s round-trip latency. Two engines serve the SAME request stream:

  serial    — the runtime path, one microbatch at a time: local step,
              then block on the remote window before the next batch's
              local step can dispatch;
  pipelined — ``pipeline_depth`` microbatches in flight: batch i+1's
              local tier (fused confidence gate) runs while batch i's
              escalations are on the wire; windows drain in submission
              order.

Throughput is the headline metric; the run also VERIFIES the two paths
produce bitwise-identical predictions/routing and identical billing
stats — overlap must never change what the cascade answers or charges.

Machine-readable results (throughput, p50/p95 measured wall latency,
remote fraction, speedup) are written to ``BENCH_serving.json`` so the
perf trajectory is tracked across PRs.

    PYTHONPATH=src python -m benchmarks.serving_bench \
        [--requests 1024] [--depth 8] [--remote-latency 0.3] \
        [--json BENCH_serving.json]
"""

from __future__ import annotations

import argparse
import json
import time

import jax.numpy as jnp
import numpy as np

from repro.runtime import RemoteTransport, TransportConfig
from repro.serving.engine import CascadeEngine
from repro.serving.scheduler import MicrobatchScheduler, Request

BATCH = 32
NCLS = 8
TARGET = 0.20           # escalation fraction (capacity-k, no controller)


def local_apply(x):
    return x + 0.3 * jnp.sin(17.0 * x)     # noisy view of the features


def make_remote(latency_s: float):
    def remote(x):
        time.sleep(latency_s)              # the wire + the big model
        return 5.0 * np.asarray(x)
    return remote


def make_load(rng, n, hard_frac=0.3):
    """Feature batches whose argmax is the label; hard rows have small
    margins -> low 1st-level confidence. All rows distinct (the cache
    must not blur the serial/pipelined billing comparison)."""
    labels = rng.integers(0, NCLS, n)
    x = rng.normal(0, 0.05, (n, NCLS))
    margin = np.where(rng.random(n) < hard_frac,
                      rng.uniform(0.05, 0.4, n), rng.uniform(2.0, 4.0, n))
    x[np.arange(n), labels] += margin
    return np.float32(x), labels


def _serve(xs, depth: int, latency_s: float):
    transport = RemoteTransport(
        make_remote(latency_s),
        TransportConfig(max_in_flight=BATCH, retry_backoff_s=0.0,
                        timeout_s=max(2.0, 10 * latency_s),
                        max_concurrent=max(depth, 1)))
    engine = CascadeEngine(local_apply, batch_size=BATCH,
                           remote_fraction_budget=TARGET, t_remote=0.0,
                           transport=transport)
    sched = MicrobatchScheduler(engine, fallback=lambda r: -1,
                                pipeline_depth=depth)
    # warm the jit cache with one out-of-band batch, then reset accounting
    engine.serve({"local": xs[:BATCH], "remote": xs[:BATCH]})
    engine.stats = type(engine.stats)()
    t0 = time.perf_counter()
    for i, row in enumerate(xs):
        sched.submit(Request(uid=i, local_input=row, remote_input=row))
    responses = sched.flush()
    wall = time.perf_counter() - t0
    transport.shutdown()
    return responses, engine, wall


def _metrics(tag, responses, engine, wall, n) -> dict:
    st = engine.stats
    return {
        "path": tag,
        "requests": n,
        "wall_s": wall,
        "throughput_rps": n / wall,
        "p50_wall_latency_s": st.wall_percentile(50),
        "p95_wall_latency_s": st.wall_percentile(95),
        "mean_wall_latency_s": st.mean_wall_latency_s,
        "modelled_mean_latency_s": st.mean_latency_s,
        "remote_fraction": st.remote_fraction,
        "escalation_fraction": st.escalation_fraction,
        "remote_calls": st.remote_calls,
        "total_cost": st.total_cost,
        # per-backend measured remote latency (TransportStats), so the
        # latency-ema routing policy is observable in bench JSON
        "backend_remote_latency": {
            b.name: {"p95_s": b.stats.latency_percentile(95),
                     "ema_s": b.stats.latency_ema_s}
            for b in engine.router},
    }


def run(verbose: bool = True, requests: int = 1024, depth: int = 8,
        remote_latency_s: float = 0.3,
        json_path: str | None = "BENCH_serving.json") -> dict:
    rng = np.random.default_rng(0)
    xs, _ = make_load(rng, requests)

    r_ser, eng_ser, w_ser = _serve(xs, depth=1, latency_s=remote_latency_s)
    r_pip, eng_pip, w_pip = _serve(xs, depth=depth,
                                   latency_s=remote_latency_s)

    identical = ([(r.uid, r.prediction, r.source) for r in r_ser]
                 == [(r.uid, r.prediction, r.source) for r in r_pip])
    billing_fields = ("requests", "escalations", "remote_calls",
                      "cache_hits", "transport_failures", "rejected",
                      "total_cost")
    billing_identical = all(getattr(eng_ser.stats, f)
                            == getattr(eng_pip.stats, f)
                            for f in billing_fields)

    n = len(xs)
    serial = _metrics("serial", r_ser, eng_ser, w_ser, n)
    pipelined = _metrics("pipelined", r_pip, eng_pip, w_pip, n)
    report = {
        "batch_size": BATCH,
        "pipeline_depth": depth,
        "remote_latency_s": remote_latency_s,
        "target_escalation_fraction": TARGET,
        "serial": serial,
        "pipelined": pipelined,
        "speedup": serial["wall_s"] / pipelined["wall_s"],
        "predictions_identical": identical,
        "billing_identical": billing_identical,
        "passed_2x": (serial["wall_s"] / pipelined["wall_s"] >= 2.0
                      and identical and billing_identical),
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=1)
    if verbose:
        print(f"\n--- Serving: pipelined vs serial runtime path "
              f"({n} requests, {TARGET:.0%} escalation, "
              f"{remote_latency_s}s fake remote, depth {depth}) ---")
        print(f"{'path':>10} {'req/s':>8} {'wall':>7} {'p50':>7} {'p95':>7} "
              f"{'remote%':>8}")
        for m in (serial, pipelined):
            print(f"{m['path']:>10} {m['throughput_rps']:8.1f} "
                  f"{m['wall_s']:6.1f}s {m['p50_wall_latency_s']*1e3:6.0f}m "
                  f"{m['p95_wall_latency_s']*1e3:6.0f}m "
                  f"{m['remote_fraction']:8.2f}")
        print(f"speedup {report['speedup']:.2f}x; predictions identical: "
              f"{identical}; billing identical: {billing_identical}"
              + (f"; JSON -> {json_path}" if json_path else ""))
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=1024)
    ap.add_argument("--depth", type=int, default=8,
                    help="pipelined in-flight microbatch window")
    ap.add_argument("--remote-latency", type=float, default=0.3,
                    help="fake remote round-trip seconds")
    ap.add_argument("--json", default="BENCH_serving.json",
                    help="machine-readable output path ('' disables)")
    args = ap.parse_args(argv)
    report = run(requests=args.requests, depth=args.depth,
                 remote_latency_s=args.remote_latency,
                 json_path=args.json or None)
    return 0 if report["passed_2x"] else 1


if __name__ == "__main__":
    raise SystemExit(main())

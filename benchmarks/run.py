"""Benchmark runner — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only rac,supervised,...]

  rac         paper Figs 2-5  (RQ1: Request-Accuracy curves, AUC-RAC)
  supervised  paper Tables 2-6 (RQ2: supervised assessment, S_beta)
  supervisors paper §3.2.3    (supervisor comparison on a real model)
  latency     paper Table 7   (Eq. 2 break-even analysis)
  inventory   paper Table 1   (case studies + assigned-arch pool)
  kernels     kernel microbench (ours)
  runtime     adaptive cascade runtime (budget tracking under drift,
              circuit breaker, remote-response cache — DESIGN.md)
  serving     pipelined vs serial serving path + streaming per-request
              completion (throughput, p50/p95 wall latency, trusted-local
              vs escalated hand-back — DESIGN.md §5, §7; also writes
              BENCH_serving.json, gated in CI by check_regression.py)
  routing     multi-remote failover vs single remote under a primary
              outage (throughput, realised $ cost, per-backend p95 —
              DESIGN.md §6; also writes BENCH_routing.json)
  chaos       trace-driven load + fault injection on a virtual clock
              (DESIGN.md §10; also writes BENCH_chaos.json)
  cluster     replicated engines behind one logical cascade
              (DESIGN.md §12; also writes BENCH_cluster.json)
  hierarchy   N-tier device→edge→cloud cascade with joint threshold
              calibration and per-tier budgets (DESIGN.md §13; also
              writes BENCH_hierarchy.json)
  roofline    dry-run roofline summary (reads results/dryrun_matrix.jsonl
              if present)
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

from benchmarks import (chaos_bench, cluster_bench, hierarchy_bench,
                        inventory, kernels_bench, latency, rac,
                        routing_bench, runtime_bench, serving_bench,
                        supervised, supervisor_comparison)

ALL = ("inventory", "rac", "supervised", "supervisors", "latency",
       "kernels", "runtime", "serving", "routing", "chaos", "cluster",
       "hierarchy", "roofline")


def roofline_summary(verbose: bool = True) -> list[dict]:
    path = "results/dryrun_matrix.jsonl"
    if not os.path.exists(path):
        if verbose:
            print(f"\n--- Roofline: {path} not found; run "
                  f"`python -m repro.launch.dryrun --all --both-meshes "
                  f"--json {path}` first ---")
        return []
    rows = [json.loads(l) for l in open(path)]
    ok = [r for r in rows if r["status"] == "ok" and "roofline" in r]
    if verbose:
        print(f"\n--- Roofline summary ({len(ok)} compiled combos, "
              f"{sum(r['status'] == 'skip' for r in rows)} principled "
              f"skips) ---")
        print(f"{'arch':>22} {'shape':>12} {'mesh':>6} {'compute':>9} "
              f"{'memory':>9} {'coll':>9} {'bottleneck':>11} {'useful':>7}")
        for r in ok:
            rf = r["roofline"]
            print(f"{r['arch']:>22} {r['shape']:>12} {r['mesh']:>6} "
                  f"{rf['compute_s']:9.2e} {rf['memory_s']:9.2e} "
                  f"{rf['collective_s']:9.2e} {rf['bottleneck']:>11} "
                  f"{rf.get('useful_ratio', float('nan')):7.2f}")
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default="",
                    help=f"comma-separated subset of {ALL}")
    ap.add_argument("--json", default="results/benchmarks.json",
                    help="machine-readable results path")
    args = ap.parse_args(argv)
    which = args.only.split(",") if args.only else list(ALL)

    t0 = time.perf_counter()
    results = {}
    for name in which:
        if name == "inventory":
            results[name] = inventory.run()
        elif name == "rac":
            results[name] = rac.run()
        elif name == "supervised":
            results[name] = supervised.run()
        elif name == "supervisors":
            results[name] = supervisor_comparison.run()
        elif name == "latency":
            results[name] = latency.run()
        elif name == "kernels":
            results[name] = kernels_bench.run()
        elif name == "runtime":
            results[name] = runtime_bench.run()
        elif name == "serving":
            results[name] = serving_bench.run(requests=512)
        elif name == "routing":
            results[name] = routing_bench.run()
        elif name == "chaos":
            results[name] = chaos_bench.run(duration_s=60.0)
        elif name == "cluster":
            results[name] = cluster_bench.run(duration_s=60.0)
        elif name == "hierarchy":
            results[name] = hierarchy_bench.run()
        elif name == "roofline":
            results[name] = roofline_summary()
        else:
            print(f"unknown benchmark {name!r}", file=sys.stderr)
            return 2
    out_dir = os.path.dirname(args.json)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    with open(args.json, "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"\n[benchmarks] all done in {time.perf_counter() - t0:.1f}s; "
          f"JSON -> {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Multi-replica cluster bench (ISSUE 9 acceptance; DESIGN.md §12).

Four ``CascadeEngine`` replicas run behind ONE logical cascade — a
shared two-backend router, a shared single-fill response store and one
cluster budget reconciler — against a skewed diurnal trace (valley 32
rps, peak 128 rps). Traffic is deliberately unbalanced: hard requests
land mostly on r0/r1 and easy ones on r2/r3 (weighted seeded draw), so
no per-replica budget could hold the fleet target alone. Request
features come from shared prototype pools, so the same content key
recurs on different replicas and exercises cross-replica cache sharing.
A scripted chaos episode browns out the primary backend mid-run and
ramps its latency (seeded, on the virtual clock).

Everything is virtual-time and seed-driven; the whole scenario runs
TWICE and the bench gates on the ISSUE 9 acceptance criteria:

  * deterministic replay — every response, per-replica billing field,
    reconcile target, fill-feed record and event count matches bit for
    bit across the two runs;
  * single fill — no content key is ever fetched remotely twice
    (``duplicate_fills == 0`` and the fill feed holds unique keys;
    same-window duplicate rows ride the fill's own remote call);
  * global budget holds under skew — the traffic-weighted fleet remote
    fraction lands within ``GLOBAL_TOL`` of the target while the worst
    single replica is far outside it (the reconciler's re-weighted
    targets, not luck);
  * zero silent drops + billing reconciliation — every uid is answered
    exactly once across the fleet, and per-replica admission/billing
    counters reconcile bitwise with the cluster-summed billing.

Machine-readable results go to ``BENCH_cluster.json`` (gated in CI by
``check_regression.py --cluster``); the shared event log of run A goes
to ``BENCH_cluster_events.jsonl`` (uploaded as a CI artifact).

    PYTHONPATH=src python -m benchmarks.cluster_bench \
        [--duration 60] [--seed 7] [--json BENCH_cluster.json] \
        [--events-jsonl BENCH_cluster_events.jsonl]
"""

from __future__ import annotations

import argparse
import json
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.loadgen import generate_trace, segments
from repro.runtime import (ChaosEpisode, ChaosSchedule, ClusterHarness,
                           RemoteBackend, RemoteRouter, TransportConfig,
                           VirtualClock)
from repro.runtime.observability import EV_CLUSTER_RECONCILE
from repro.runtime.transport import CLOSED
from repro.serving import ServeConfig
from repro.serving.engine import BILLING_FIELDS
from repro.serving.policy import REJECTED, SHED
from repro.serving.scheduler import Request

REPLICAS = 4
BATCH = 16
NCLS = 8
TARGET = 0.25                   # global remote-fraction budget
SEGMENT_S = 1.0                 # drive-loop granularity (virtual)
BASE_RATE, PEAK_RATE = 32.0, 128.0
HARD_FRAC = 0.4
ADMISSION_LIMIT = 32            # per replica; soft watermark 16
RECONCILE_S = 2.0               # cluster budget cadence (virtual)
PROTOS = 48                     # shared content pool size per difficulty
# fraction of requests carrying FRESH (never-repeated) content: keeps
# billed remote demand alive after the shared cache warms — without it
# the 2*PROTOS-key space fills within ~20 virtual s and the remote tier
# (and the chaos episodes scripted on it) would go completely idle
FRESH_HARD, FRESH_EASY = 0.5, 0.3
# replica assignment weights: hard traffic piles onto r0/r1, easy onto
# r2/r3 — the skew the pooled reconcile has to absorb
HARD_W = (8.0, 4.0, 1.0, 1.0)
EASY_W = (1.0, 2.0, 4.0, 7.0)
PRIMARY_COST, PRIMARY_LAT = 0.002, 0.08
SECONDARY_COST, SECONDARY_LAT = 0.008, 0.02
GLOBAL_TOL = 0.08               # fleet |ema - target| bound
SKEW_MIN = 0.12                 # worst replica must exceed this


def local_apply(x):
    return x + 0.3 * jnp.sin(17.0 * x)


def make_episodes(duration_s: float) -> tuple[ChaosEpisode, ...]:
    s = duration_s / 60.0
    return (
        ChaosEpisode("brownout", 20.0 * s, 8.0 * s,
                     backends=("primary",), rate=0.8,
                     name="brownout-primary"),
        ChaosEpisode("latency_ramp", 36.0 * s, 8.0 * s,
                     backends=("primary",), extra_latency_s=0.030,
                     name="ramp-primary"),
    )


def make_workload(trace, seed: int):
    """Skewed replica assignment + shared prototype features.

    Most requests map to a prototype row from a difficulty-matched pool
    (cycled in arrival order), so identical content keys recur across
    the fleet; a seeded slice carries fresh one-off rows so billed
    remote demand never dries up. The replica draw is weighted by
    difficulty, so replicas see very different score distributions over
    the SAME shared key space."""
    rng = np.random.default_rng(seed + 13)
    margins = {"hard": (0.05, 0.4),         # narrow margin: escalates
               "easy": (2.5, 3.5)}          # wide margin: trusted

    def rows(n, lo, hi):
        labels = rng.integers(0, NCLS, n)
        x = rng.normal(0, 0.05, (n, NCLS))
        x[np.arange(n), labels] += rng.uniform(lo, hi, n)
        return np.float32(x)

    pools = {k: rows(PROTOS, *m) for k, m in margins.items()}
    fresh = {"hard": FRESH_HARD, "easy": FRESH_EASY}
    hw = np.asarray(HARD_W) / sum(HARD_W)
    ew = np.asarray(EASY_W) / sum(EASY_W)
    xs = np.empty((len(trace), NCLS), np.float32)
    assign = []
    seen = {"hard": 0, "easy": 0}
    for tr in trace.requests:
        kind = "hard" if tr.hard else "easy"
        if rng.random() < fresh[kind]:
            xs[tr.uid] = rows(1, *margins[kind])[0]
        else:
            xs[tr.uid] = pools[kind][seen[kind] % PROTOS]
            seen[kind] += 1
        assign.append(
            f"r{rng.choice(REPLICAS, p=hw if tr.hard else ew)}")
    return xs, assign


def build_stack(clock: VirtualClock, seed: int, duration_s: float):
    """Fresh harness + chaos-wrapped shared router on ``clock``."""
    def remote_fn(x):
        return 5.0 * np.asarray(x)

    tconf = TransportConfig(max_in_flight=BATCH, max_retries=0,
                            retry_backoff_s=0.0, timeout_s=10.0,
                            breaker_failures=2, breaker_reset_s=1.0)
    router = RemoteRouter(
        [RemoteBackend("primary", remote_fn, tconf,
                       cost_per_request=PRIMARY_COST,
                       latency_s=PRIMARY_LAT, clock=clock,
                       sleep=clock.sleep),
         RemoteBackend("secondary", remote_fn, tconf,
                       cost_per_request=SECONDARY_COST,
                       latency_s=SECONDARY_LAT, clock=clock,
                       sleep=clock.sleep)],
        policy="cheapest-available")
    schedule = ChaosSchedule(make_episodes(duration_s), seed=seed)
    schedule.wrap_router(router)
    cfg = ServeConfig(batch_size=BATCH, remote_fraction_budget=TARGET,
                      t_remote=0.0, pipeline_depth=1, cache_size=4096,
                      adaptive=True, control_window=48,
                      replicas=REPLICAS, admission_limit=ADMISSION_LIMIT,
                      admission_soft_ratio=0.5, observability=True,
                      event_capacity=65536)
    harness = ClusterHarness(cfg, local_apply, transport=router,
                             fallback=lambda r: -1, clock=clock,
                             seed=seed, reconcile_interval_s=RECONCILE_S)
    return harness, router, schedule


def drive(trace, xs, assign, seed: int):
    """One full scenario run: returns everything the checks compare."""
    clock = VirtualClock()
    harness, router, schedule = build_stack(clock, seed,
                                            trace.duration_s)
    responses = []
    t0 = time.perf_counter()
    for t_end, bucket in segments(trace, SEGMENT_S):
        for tr in bucket:
            clock.advance_to(tr.t_arrival_s)
            harness.submit(assign[tr.uid],
                           Request(uid=tr.uid, local_input=xs[tr.uid],
                                   remote_input=xs[tr.uid],
                                   policy=tr.policy))
        clock.advance_to(t_end)
        for batch in harness.flush().values():
            responses.extend(batch)
    wall = time.perf_counter() - t0
    schedule.finalize(harness.events, now=clock())
    breaker_states = {b.name: b.transport.breaker.state
                      for b in router.backends}
    harness.close()
    return {"harness": harness, "router": router, "schedule": schedule,
            "events": harness.events, "wall": wall,
            "responses": responses, "breaker_states": breaker_states}


def _digest(run) -> dict:
    """Everything that must replay bit-identically across runs."""
    h = run["harness"]
    ch = run["schedule"].stats
    per_replica = {}
    for name in h.names:
        rep = h.replica(name)
        st, ad = rep.engine.stats, rep.scheduler.admission
        per_replica[name] = {
            "billing": {f: getattr(st, f) for f in BILLING_FIELDS},
            "ema_fraction": rep.controller.state.ema_fraction,
            "target": h.cluster.target(name),
            "windows": rep.controller.state.windows,
            "admission": (ad.submitted, ad.admitted, ad.degraded,
                          ad.shed),
            "cache": (rep.cache.stats.hits, rep.cache.stats.misses,
                      rep.cache.stats.cross_hits),
        }
    return {
        "responses": [(r.uid, int(r.prediction), r.source,
                       r.disposition, r.backend, round(r.cost, 12),
                       round(r.latency_s, 9))
                      for r in sorted(run["responses"],
                                      key=lambda r: r.uid)],
        "per_replica": per_replica,
        "cluster_billing": h.global_billing(),
        "feed": [(u.key.hex(), u.source, u.replica)
                 for u in h.shared_cache.feed],
        "reconciles": [(e["window"], e["mode"], e["tau"],
                        tuple(sorted(e["targets"].items())),
                        tuple(e["stale"]))
                       for e in run["events"].events(
                           EV_CLUSTER_RECONCILE)],
        "chaos": {"calls": ch.calls, "injected": ch.injected,
                  "delayed": ch.delayed,
                  "by_episode": dict(sorted(ch.by_episode.items())),
                  "by_kind": dict(sorted(ch.by_kind.items()))},
        "event_counts": dict(sorted(run["events"].counts().items())),
    }


def run(verbose: bool = True, duration_s: float = 60.0, seed: int = 7,
        json_path: str | None = "BENCH_cluster.json",
        events_jsonl: str | None = "BENCH_cluster_events.jsonl") -> dict:
    trace = generate_trace(seed, pattern="diurnal", rate=BASE_RATE,
                           peak_rate=PEAK_RATE, duration_s=duration_s,
                           hard_frac=HARD_FRAC)
    xs, assign = make_workload(trace, seed)

    run_a = drive(trace, xs, assign, seed)
    run_b = drive(trace, xs, assign, seed)
    dig_a, dig_b = _digest(run_a), _digest(run_b)

    h = run_a["harness"]
    scs = h.shared_cache.stats
    ch = run_a["schedule"].stats
    ev = run_a["events"]
    cst = h.cluster.state
    per = dig_a["per_replica"]
    cb = dig_a["cluster_billing"]["billing"]
    per_backend = dig_a["cluster_billing"]["per_backend"]

    uids = sorted(r.uid for r in run_a["responses"])
    dispositions: dict[str, int] = {}
    for r in run_a["responses"]:
        dispositions[r.disposition] = dispositions.get(r.disposition,
                                                       0) + 1
    served = len(run_a["responses"]) - dispositions.get(SHED, 0) \
        - dispositions.get(REJECTED, 0)
    feed_keys = [k for k, _, _ in dig_a["feed"]]
    total_shed = sum(p["admission"][3] for p in per.values())
    cross_hits = sum(p["cache"][2] for p in per.values())
    # realised fleet remote fraction, weighted by eligible traffic (the
    # reconciler computes the same number at cadence — use its final)
    global_ema = cst.global_ema_fraction
    skews = {n: abs(per[n]["ema_fraction"] - TARGET) for n in per}
    pooled_rounds = sum(1 for r in dig_a["reconciles"]
                       if r[1] == "pooled")
    final_targets = {n: per[n]["target"] for n in per}

    checks = {
        # -- ISSUE 9 acceptance: double run is bit-identical -----------
        "deterministic_replay": dig_a == dig_b,
        # -- one logical cascade: every uid answered exactly once ------
        "zero_silent_drop": uids == list(range(len(trace))),
        # -- single fill: no content key fetched remotely twice --------
        "single_fill": (scs.duplicate_fills == 0
                        and len(feed_keys) == len(set(feed_keys))
                        and scs.evictions == 0),
        "cross_replica_sharing": (cross_hits > 0
                                  and cb["cache_hits"] > 0),
        # -- global budget holds while the worst replica is far out ----
        "global_budget_holds": (global_ema is not None
                                and abs(global_ema - TARGET)
                                <= GLOBAL_TOL),
        "replica_skew_far_outside": max(skews.values()) >= SKEW_MIN,
        "targets_reweighted": (pooled_rounds > 0
                               and max(final_targets.values())
                               - min(final_targets.values()) >= 0.1),
        # -- shed/billing reconciliation, per replica and summed -------
        "admission_reconciles": all(
            p["admission"][0] == p["billing"]["requests"]
            + p["admission"][3]
            and p["admission"][1] == p["billing"]["requests"]
            for p in per.values()),
        "billing_reconciles": (
            all(p["billing"]["escalations"]
                == p["billing"]["remote_calls"]
                + p["billing"]["cache_hits"]
                + p["billing"]["transport_failures"]
                for p in per.values())
            and all(cb[f] == sum(p["billing"][f] for p in per.values())
                    for f in BILLING_FIELDS)
            and abs(cb["total_cost"]
                    - sum(u["cost"] for u in per_backend.values()))
            < 1e-9),
        # -- overload + chaos actually exercised, system recovered -----
        "sheds_exercised": total_shed > 0,
        "faults_injected": ch.injected > 0 and ch.delayed > 0,
        "breakers_recovered": all(
            s == CLOSED for s in run_a["breaker_states"].values()),
        "majority_served": served / max(1, len(trace)) >= 0.5,
        "no_events_dropped": ev.dropped == 0,
        "reconcile_events_logged": (
            len(dig_a["reconciles"]) == cst.reconciles > 0),
    }

    report = {
        "replicas": REPLICAS,
        "batch_size": BATCH,
        "virtual_duration_s": trace.duration_s,
        "seed": seed,
        "requests": len(trace),
        "target_remote_fraction": TARGET,
        "global_tolerance": GLOBAL_TOL,
        "wall_s": run_a["wall"],
        "throughput_rps": len(trace) / run_a["wall"],
        "global_ema_fraction": global_ema,
        "replica_ema_fractions": {n: per[n]["ema_fraction"]
                                  for n in sorted(per)},
        "replica_targets": dict(sorted(final_targets.items())),
        "replica_skews": dict(sorted(skews.items())),
        "reconciles": {"count": cst.reconciles,
                       "pooled_rounds": pooled_rounds,
                       "final_mode": cst.mode, "final_tau": cst.tau,
                       "stale": list(cst.stale)},
        "per_replica": per,
        "cluster_billing": dig_a["cluster_billing"],
        "shared_cache": {"fills": scs.fills,
                         "duplicate_fills": scs.duplicate_fills,
                         "redundant_puts": scs.redundant_puts,
                         "cross_hits": cross_hits,
                         "waits": scs.waits, "steals": scs.steals,
                         "releases": scs.releases,
                         "evictions": scs.evictions},
        "dispositions": dict(sorted(dispositions.items())),
        "served_fraction": served / max(1, len(trace)),
        "chaos": dig_a["chaos"],
        "observability": {"events": dig_a["event_counts"],
                          "events_dropped": ev.dropped},
        "checks": checks,
        "passed": all(checks.values()),
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=1)
    if events_jsonl:
        with open(events_jsonl, "w") as f:
            for e in ev.events():
                f.write(json.dumps(e) + "\n")
    if verbose:
        print(f"\n--- Cluster: {REPLICAS} replicas, {len(trace)} "
              f"requests over {trace.duration_s:g} virtual s (diurnal "
              f"{BASE_RATE:g}->{PEAK_RATE:g} rps, seed {seed}, wall "
              f"{run_a['wall']:.2f}s x2 runs) ---")
        print(f"budget: global ema "
              f"{'n/a' if global_ema is None else f'{global_ema:.3f}'} "
              f"vs target {TARGET} (tol {GLOBAL_TOL}); per-replica ema "
              f"{ {n: round(per[n]['ema_fraction'], 3) for n in sorted(per)} }")
        tgt = {n: round(v, 3) for n, v in sorted(final_targets.items())}
        print(f"targets: {tgt} "
              f"({cst.reconciles} reconciles, {pooled_rounds} pooled)")
        print(f"cache: {scs.fills} fills, {cross_hits} cross-replica "
              f"hits, {cb['cache_hits']} billed hits, "
              f"{scs.duplicate_fills} duplicate fills, "
              f"{scs.redundant_puts} redundant puts")
        print(f"admission: {total_shed} shed across fleet; "
              f"dispositions {report['dispositions']}")
        print(f"chaos: {ch.injected} faults "
              f"{dict(sorted(ch.by_kind.items()))}, "
              f"{ch.delayed} delayed")
        print(f"events: {report['observability']['events']}")
        print(f"checks: {checks}"
              + (f"; JSON -> {json_path}" if json_path else ""))
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--duration", type=float, default=60.0,
                    help="virtual scenario length in seconds")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--json", default="BENCH_cluster.json",
                    help="machine-readable output path ('' disables)")
    ap.add_argument("--events-jsonl",
                    default="BENCH_cluster_events.jsonl",
                    help="event-log artifact path ('' disables)")
    args = ap.parse_args(argv)
    report = run(duration_s=args.duration, seed=args.seed,
                 json_path=args.json or None,
                 events_jsonl=args.events_jsonl or None)
    return 0 if report["passed"] else 1


if __name__ == "__main__":
    raise SystemExit(main())

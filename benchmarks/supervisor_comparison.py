"""Supervisor comparison (paper §3.2.3): misprediction-detection power of
every implemented supervisor on a REAL trained surrogate.

The paper's survey conclusion — "no single technique works as a dominant
supervisor", softmax-based ones are strong and cheap, MDSA is competitive
and modality-agnostic, ensembles often best — is checked empirically:
AUC-ROC of (confidence, correct?) per supervisor, plus the computational
overhead class from §3.2.2.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import supervisors as S
from repro.data.synthetic import make_classification_task
from repro.models import surrogate as M
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state

OVERHEAD = {"max_softmax": "~0 (1 read)", "pcs": "~0", "neg_entropy": "~0",
            "gini": "~0", "mdsa": "1 matvec", "autoencoder": "1 small fwd",
            "mc_dropout(vr)": "S extra fwds", "mc_dropout(mi)": "S extra fwds",
            "ensemble(mms)": "S models"}


def auc_roc(conf: np.ndarray, correct: np.ndarray) -> float:
    """P(conf_correct > conf_wrong) + 0.5 P(=) — Mann-Whitney with
    average ranks for ties (supervisors like variation-ratio emit heavily
    tied scores)."""
    pos, neg = conf[correct], conf[~correct]
    if len(pos) == 0 or len(neg) == 0:
        return float("nan")
    allc = np.concatenate([pos, neg])
    _, inv, counts = np.unique(allc, return_inverse=True,
                               return_counts=True)
    cum = np.cumsum(counts)
    avg_rank = cum - (counts - 1) / 2.0
    ranks = avg_rank[inv]
    r_pos = ranks[: len(pos)].sum()
    return (r_pos - len(pos) * (len(pos) + 1) / 2) / (len(pos) * len(neg))


def run(verbose: bool = True) -> list[dict]:
    vocab, seq, ncls = 256, 32, 6
    toks, labels, _ = make_classification_task(7, n=2048, vocab=vocab,
                                               seq_len=seq, num_classes=ncls)
    tk, lb = jnp.asarray(toks), jnp.asarray(labels)
    cfg = M.SurrogateConfig("cmp", vocab_size=vocab, max_len=seq, d_model=48,
                            num_heads=2, d_ff=64, num_classes=ncls,
                            dropout=0.1)

    def train(seed):
        params = M.init_params(cfg, jax.random.PRNGKey(seed))
        opt = init_opt_state(params)
        ocfg = AdamWConfig(lr=3e-3, warmup_steps=5, weight_decay=0.0)

        @jax.jit
        def step(p, o, k):
            (l, _), g = jax.value_and_grad(
                lambda p: M.loss_fn(cfg, p, tk[:1024], lb[:1024], k),
                has_aux=True)(p)
            return adamw_update(ocfg, p, g, o)[:2]

        for i in range(50):
            params, opt = step(params, opt, jax.random.PRNGKey(i))
        return params

    params = train(0)
    test = slice(1024, 2048)
    logits, hidden = M.apply(cfg, params, tk[test], return_hidden=True)
    correct = np.asarray(jnp.argmax(logits, -1) == lb[test])

    rows = []

    def add(name, conf):
        rows.append({"supervisor": name,
                     "auc_roc": round(auc_roc(np.asarray(conf), correct), 4),
                     "overhead": OVERHEAD.get(name, "?")})

    for name, fn in S.SOFTMAX_SUPERVISORS.items():
        add(name, fn(logits))

    # MDSA on the penultimate activations (train-set fit)
    _, train_hidden = M.apply(cfg, params, tk[:1024], return_hidden=True)
    st = S.fit_mdsa(train_hidden)
    add("mdsa", S.mdsa_confidence(st, hidden))

    # autoencoder on the penultimate activations
    ae = S.fit_autoencoder(jax.random.PRNGKey(1), train_hidden, latent=8,
                           steps=200)
    add("autoencoder", S.autoencoder_confidence(ae, hidden))

    # MC-Dropout (dropout live at inference)
    samples = jnp.stack([
        M.apply(cfg, params, tk[test], dropout_rng=jax.random.PRNGKey(i),
                mc_dropout=True) for i in range(8)])
    add("mc_dropout(vr)", S.variation_ratio(samples))
    add("mc_dropout(mi)", S.mutual_information(samples))

    # Ensemble (3 independently-initialised models)
    ens = jnp.stack([M.apply(cfg, train(s), tk[test]) for s in (0, 1, 2)])
    add("ensemble(mms)", S.mean_max_softmax(ens))

    if verbose:
        print("\n--- Supervisor comparison (paper §3.2.2/§3.2.3) ---")
        print(f"model accuracy on eval: {correct.mean():.3f}")
        print(f"{'supervisor':>16} {'AUC-ROC':>8}  overhead")
        for r in sorted(rows, key=lambda r: -r["auc_roc"]):
            print(f"{r['supervisor']:>16} {r['auc_roc']:8.3f}  "
                  f"{r['overhead']}")
        best = max(rows, key=lambda r: r["auc_roc"])
        soft = max(r["auc_roc"] for r in rows
                   if r["supervisor"] in S.SOFTMAX_SUPERVISORS)
        print(f"best: {best['supervisor']} — paper: softmax family is "
              f"near-dominant and ~free (ours within "
              f"{best['auc_roc'] - soft:+.3f} of best)")
    return rows


if __name__ == "__main__":
    run()

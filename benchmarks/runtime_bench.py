"""Runtime control-plane benchmark (DESIGN.md; ISSUE 1 acceptance).

Three episodes over a synthetic drifting workload:

  budget    — the adaptive controller must hold a 20% remote-fraction
              budget within +-3 points across a confidence-distribution
              drift (hard-input rate 10% -> 45% -> 25%), where a static
              threshold calibrated on the first phase drifts far off
              budget;
  faults    — a remote outage: every call times out for a stretch; the
              circuit breaker must open, convert escalations into
              fallback responses WITHOUT dropping a single request, then
              recover through the half-open probe when the outage ends;
  cache     — duplicate-heavy traffic: the content-keyed cache must keep
              billed remote calls well under the escalation count.

    PYTHONPATH=src python -m benchmarks.run --only runtime
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.runtime import (AdaptiveController, ControllerConfig,
                           RemoteResponseCache, RemoteTimeout,
                           RemoteTransport, TransportConfig)
from repro.serving import ServeConfig
from repro.serving.scheduler import Request

BATCH = 32
NCLS = 8
TARGET = 0.20
WINDOW = 256


def local_apply(x):
    return x + 0.3 * jnp.sin(17.0 * x)     # noisy view of the features


def perfect_remote(x):
    return 5.0 * np.asarray(x)


def make_phase(rng, n, hard_frac):
    """Feature batches whose argmax is the label; hard rows have small
    margins -> low 1st-level confidence. hard_frac is the drift knob."""
    labels = rng.integers(0, NCLS, n)
    x = rng.normal(0, 0.05, (n, NCLS))
    margin = np.where(rng.random(n) < hard_frac,
                      rng.uniform(0.05, 0.4, n), rng.uniform(2.0, 4.0, n))
    x[np.arange(n), labels] += margin
    return np.float32(x), labels


def _drive(engine, xs):
    """Serve xs through the engine in BATCH-sized chunks; return the
    per-window realised escalation fraction."""
    fractions = []
    esc0 = req0 = 0
    for lo in range(0, len(xs), BATCH):
        batch = xs[lo:lo + BATCH]
        if len(batch) < BATCH:
            break
        engine.serve({"local": batch, "remote": batch})
        if engine.stats.requests - req0 >= WINDOW:
            fractions.append((engine.stats.escalations - esc0)
                             / (engine.stats.requests - req0))
            esc0, req0 = engine.stats.escalations, engine.stats.requests
    return fractions


def budget_episode(verbose=True) -> dict:
    rng = np.random.default_rng(0)
    phases = [("easy", 0.10, 4096), ("hard", 0.45, 4096),
              ("mixed", 0.25, 4096)]

    def fresh(controller):
        cfg = ServeConfig(batch_size=BATCH, remote_fraction_budget=TARGET,
                          t_remote=0.0, cache_size=0)
        return cfg.build_engine(local_apply,
                                transport=RemoteTransport(perfect_remote),
                                controller=controller)

    # static baseline: threshold frozen at the first phase's 20% quantile
    cal, _ = make_phase(rng, 2048, phases[0][1])
    conf = np.asarray(jnp.max(jnp.exp(jnp.asarray(local_apply(cal)))
                              / jnp.sum(jnp.exp(jnp.asarray(
                                  local_apply(cal))), -1, keepdims=True), -1))
    static = fresh(None)
    static.set_local_threshold(float(np.quantile(conf, TARGET)))
    # capacity must not clip the static baseline's drift (we want to SHOW it)
    static.capacity = BATCH

    adaptive = fresh(AdaptiveController(ControllerConfig(
        target_remote_fraction=TARGET, window=WINDOW)))

    def rolling(fracs, w=4):
        """Mean over w consecutive control windows (~1k requests) — the
        granularity at which "holding the budget" is meaningful; a single
        256-request window has +-2.5 pts of pure binomial noise."""
        if len(fracs) < w:
            return [float(np.mean(fracs))]
        return [float(np.mean(fracs[i:i + w]))
                for i in range(len(fracs) - w + 1)]

    report = {"target": TARGET, "phases": {}}
    for name, hard_frac, n in phases:
        xs, _ = make_phase(rng, n, hard_frac)
        fr_a = _drive(adaptive, xs)
        fr_s = _drive(static, xs)
        settle = 4                      # windows of transient per phase
        steady_a = fr_a[settle:] or fr_a
        steady_s = fr_s[settle:] or fr_s
        report["phases"][name] = {
            "hard_frac": hard_frac,
            "adaptive_fraction": float(np.mean(steady_a)),
            "adaptive_dev": float(abs(np.mean(steady_a) - TARGET)),
            "adaptive_rolling_max_dev": float(
                max(abs(f - TARGET) for f in rolling(steady_a))),
            "static_fraction": float(np.mean(steady_s)),
            "static_dev": float(abs(np.mean(steady_s) - TARGET)),
        }
    report["drift_events"] = adaptive.controller.state.drift_events
    report["within_3pts"] = all(p["adaptive_dev"] <= 0.03
                                for p in report["phases"].values())
    if verbose:
        print(f"\n--- Runtime: budget tracking (target {TARGET:.0%}, "
              f"+-3 pts steady-state per phase) ---")
        print(f"{'phase':>8} {'hard%':>6} {'adaptive':>9} {'a-dev':>6} "
              f"{'a-roll':>7} {'static':>7} {'s-dev':>6}")
        for name, p in report["phases"].items():
            print(f"{name:>8} {p['hard_frac']:6.0%} "
                  f"{p['adaptive_fraction']:9.3f} {p['adaptive_dev']:6.3f} "
                  f"{p['adaptive_rolling_max_dev']:7.3f} "
                  f"{p['static_fraction']:7.3f} {p['static_dev']:6.3f}")
        print(f"controller drift events: {report['drift_events']}; "
              f"within +-3 pts: {report['within_3pts']}")
    return report


def fault_episode(verbose=True) -> dict:
    rng = np.random.default_rng(1)
    clock = {"t": 0.0}
    outage = {"on": False}

    def remote(x):
        clock["t"] += 0.01
        if outage["on"]:
            raise RemoteTimeout("simulated outage")
        return perfect_remote(x)

    transport = RemoteTransport(
        remote,
        TransportConfig(max_in_flight=8, timeout_s=1.0, max_retries=1,
                        retry_backoff_s=0.0, breaker_failures=2,
                        breaker_reset_s=0.5),
        clock=lambda: clock["t"], sleep=lambda s: None)
    cfg = ServeConfig(batch_size=BATCH, remote_fraction_budget=TARGET,
                      t_remote=0.0, cache_size=0)
    engine, sched = cfg.build(local_apply, transport=transport,
                              fallback=lambda r: -1)

    submitted = 0

    def run(n):
        nonlocal submitted
        xs, _ = make_phase(rng, n, 0.3)
        for row in xs:
            sched.submit(Request(uid=submitted, local_input=row,
                                 remote_input=row))
            submitted += 1
        return sched.flush()

    before = run(512)
    outage["on"] = True
    during = run(512)
    outage["on"] = False
    clock["t"] += 1.0                   # let the breaker half-open
    after = run(512)

    n_resp = len(before) + len(during) + len(after)
    fb = {"before": sum(r.source == "fallback" for r in before),
          "during": sum(r.source == "fallback" for r in during),
          "after": sum(r.source == "fallback" for r in after)}
    esc_during = sum(r.source in ("remote", "fallback") for r in during)
    report = {
        "submitted": submitted, "answered": n_resp,
        "dropped": submitted - n_resp,
        "fallbacks": fb,
        "escalations_during_outage": esc_during,
        "outage_converted_to_fallback": fb["during"] == esc_during
                                         and esc_during > 0,
        "breaker_opens": transport.stats.breaker_opens,
        "breaker_state_after": transport.breaker.state,
        "timeouts": transport.stats.timeouts,
        "short_circuited": transport.stats.short_circuited,
        "recovered": fb["after"] == 0,
    }
    if verbose:
        print("\n--- Runtime: outage / circuit breaker ---")
        print(f"answered {n_resp}/{submitted} (dropped "
              f"{report['dropped']}); fallbacks {fb}")
        print(f"breaker opened {report['breaker_opens']}x "
              f"({report['timeouts']} timeouts, "
              f"{report['short_circuited']} short-circuited), "
              f"state after recovery: {report['breaker_state_after']}")
        print(f"outage -> fallback w/o drops: "
              f"{report['outage_converted_to_fallback']}; "
              f"recovered: {report['recovered']}")
    return report


def cache_episode(verbose=True) -> dict:
    rng = np.random.default_rng(2)
    base, _ = make_phase(rng, 64, 1.0)   # all hard -> all escalate
    # zipf-ish duplicate-heavy stream over 64 distinct hard requests
    stream = base[rng.integers(0, 8, 4096 - 512)]
    stream = np.concatenate([base[rng.integers(0, 64, 512)], stream])

    cache = RemoteResponseCache(1024)
    engine = ServeConfig(batch_size=BATCH, remote_fraction_budget=0.5,
                         t_remote=0.0).build_engine(
        local_apply, transport=RemoteTransport(perfect_remote), cache=cache)
    for lo in range(0, len(stream), BATCH):
        chunk = stream[lo:lo + BATCH]
        engine.serve({"local": chunk, "remote": chunk})
    st = engine.stats
    naive_cost = st.escalations * engine.cost.remote_cost_per_request
    report = {
        "escalations": st.escalations, "billed_remote_calls": st.remote_calls,
        "cache_hits": st.cache_hits, "hit_rate": cache.stats.hit_rate,
        "billed_cost": st.total_cost, "uncached_cost": naive_cost,
        "savings_fraction": 1.0 - st.total_cost / max(naive_cost, 1e-12),
    }
    if verbose:
        print("\n--- Runtime: remote-response cache ---")
        print(f"escalations {st.escalations}, billed {st.remote_calls}, "
              f"hits {st.cache_hits} (hit rate {cache.stats.hit_rate or 0.0:.2f})")
        print(f"billed ${st.total_cost:.4f} vs uncached ${naive_cost:.4f} "
              f"({report['savings_fraction']:.0%} saved)")
    return report


def run(verbose: bool = True) -> dict:
    return {"budget": budget_episode(verbose),
            "faults": fault_episode(verbose),
            "cache": cache_episode(verbose)}


if __name__ == "__main__":
    run()

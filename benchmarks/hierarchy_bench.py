"""N-tier cascade hierarchy bench (ISSUE 10 acceptance; DESIGN.md §13).

A genuine 3-tier device → edge → cloud ladder on a synthetic workload
with planted difficulty structure: *easy* rows every tier answers
correctly and confidently, *medium* rows the device tier gets wrong (or
unsure) but the edge tier nails, *hard* rows only the cloud tier
answers correctly. The mid tier therefore has real work only a
hierarchy can monetise — it serves the medium band at a fraction of the
cloud price — which makes 3-tier dominance *structural*, not a tuning
accident.

The bench gates on the ISSUE 10 acceptance criteria:

  * three-tier dominance — the joint (t1, t2, t3) sweep contains an
    operating point with equal-or-better system accuracy than the best
    2-tier point (device→cloud and device→edge sweeps, the paper's
    shape) at STRICTLY lower $/request;
  * deterministic replay — the calibration sweep, the tiered runtime
    eval and the per-tier budget-controller phase all replay
    bit-identically across two runs;
  * degenerate 2-stage identity — an engine routed at a terminal
    ``CascadeStage`` reproduces the plain-``RemoteBackend`` engine path
    bitwise: responses, billing fields, per-backend attribution and
    controller state;
  * billing reconciliation — on the chained engine path the
    escalation identity holds per stage name and the per-stage cost
    split sums exactly to ``CascadeStats.total_cost``.

Machine-readable results go to ``BENCH_hierarchy.json`` (gated in CI by
``check_regression.py --hierarchy``).

    PYTHONPATH=src python -m benchmarks.hierarchy_bench \
        [--rows 2048] [--grid 9] [--seed 7] [--json BENCH_hierarchy.json]
"""

from __future__ import annotations

import argparse
import json
import time

import jax.numpy as jnp
import numpy as np

from repro.core.supervisors import SOFTMAX_SUPERVISORS
from repro.runtime import (AdaptiveController, CascadeStage,
                           ControllerConfig, RemoteBackend, RemoteRouter,
                           TieredBudgetController, TieredCascade,
                           TransportConfig, build_stage_chain,
                           joint_pareto_frontier,
                           select_joint_operating_point,
                           sweep_joint_operating_points,
                           sweep_operating_points)
from repro.serving.engine import BILLING_FIELDS, CascadeEngine
from repro.serving.scheduler import MicrobatchScheduler, Request

NCLS = 8
BATCH = 16
EDGE_COST, CLOUD_COST = 0.001, 0.005
EASY_FRAC, MEDIUM_FRAC = 0.55, 0.30     # remainder is hard
CONF_HI = (4.0, 6.0)                    # planted confident margin
CONF_LO = (0.2, 0.8)                    # planted unsure margin
REJ_MAX = 0.05                          # rejection ceiling for selection
TIER_TOL = 0.2                          # per-hop budget tracking bound
GEN_TOL = 0.05                          # calibration->eval accuracy drift

_score = SOFTMAX_SUPERVISORS["max_softmax"]


# ------------------------------------------------------------ workload

def make_workload(rows: int, seed: int) -> dict:
    """Per-tier logit LUTs with planted difficulty bands.

    Returns row-aligned arrays: ``labels``, ``band`` (0 easy / 1 medium
    / 2 hard) and one ``(rows, NCLS)`` logits table per tier. Tiers are
    cumulative in skill: device solves easy, edge solves easy+medium,
    cloud solves everything — each confidently on the rows it solves
    and unsure (and usually wrong) elsewhere."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, NCLS, rows)
    band = rng.choice(3, rows, p=[EASY_FRAC, MEDIUM_FRAC,
                                  1.0 - EASY_FRAC - MEDIUM_FRAC])

    def tier(solves_band: int) -> np.ndarray:
        solved = band <= solves_band
        wrong = (labels + rng.integers(1, NCLS, rows)) % NCLS
        target = np.where(solved, labels, wrong)
        margin = np.where(solved, rng.uniform(*CONF_HI, rows),
                          rng.uniform(*CONF_LO, rows))
        logits = rng.normal(0, 0.05, (rows, NCLS))
        logits[np.arange(rows), target] += margin
        return np.float32(logits)

    return {"labels": labels, "band": band,
            "device": tier(0), "edge": tier(1), "cloud": tier(2)}


def conf_correct(logits: np.ndarray, labels: np.ndarray):
    conf = np.asarray(_score(jnp.asarray(logits)), np.float64)
    return conf, logits.argmax(-1) == labels


# ----------------------------------------------- joint calibration phase

def calibration_phase(wl: dict, half: slice, grid: int) -> dict:
    labels = wl["labels"][half]
    confs, oks = [], []
    for tier in ("device", "edge", "cloud"):
        c, ok = conf_correct(wl[tier][half], labels)
        confs.append(c)
        oks.append(ok)
    t0 = time.perf_counter()
    pts3 = sweep_joint_operating_points(
        confs, oks, grid=grid, stage_costs=[0.0, EDGE_COST, CLOUD_COST])
    front3 = joint_pareto_frontier(pts3)
    # the paper's 2-tier shape, swept both ways the ladder could be
    # flattened: device->cloud and device->edge
    pts2 = (sweep_operating_points(confs[0], oks[0], confs[2], oks[2],
                                   grid=grid,
                                   remote_cost_per_request=CLOUD_COST)
            + sweep_operating_points(confs[0], oks[0], confs[1], oks[1],
                                     grid=grid,
                                     remote_cost_per_request=EDGE_COST))
    sweep_s = time.perf_counter() - t0

    best2 = max((p for p in pts2 if p.rejection_rate <= REJ_MAX),
                key=lambda p: (p.system_accuracy, -p.cost_per_request))
    elig3 = [p for p in pts3
             if p.system_accuracy >= best2.system_accuracy
             and p.rejection_rate <= REJ_MAX]
    best3 = (min(elig3, key=lambda p: p.cost_per_request)
             if elig3 else None)
    budget_pt = select_joint_operating_point(
        front3, cost_budget=CLOUD_COST / 2, max_rejection_rate=REJ_MAX)
    monotone = all(
        front3[i].cost_per_request > front3[i - 1].cost_per_request
        and front3[i].system_accuracy > front3[i - 1].system_accuracy
        for i in range(1, len(front3)))
    return {
        "points_swept": len(pts3), "frontier": len(front3),
        "sweep_s": sweep_s, "frontier_monotone": monotone,
        "best_2tier": {"thresholds": (best2.t_local, best2.t_remote),
                       "system_accuracy": best2.system_accuracy,
                       "cost_per_request": best2.cost_per_request},
        "best_3tier": None if best3 is None else {
            "thresholds": list(best3.thresholds),
            "stage_fractions": list(best3.stage_fractions),
            "system_accuracy": best3.system_accuracy,
            "cost_per_request": best3.cost_per_request},
        "budget_point": {"thresholds": list(budget_pt.thresholds),
                         "system_accuracy": budget_pt.system_accuracy,
                         "cost_per_request": budget_pt.cost_per_request},
        "dominates": (best3 is not None
                      and best3.cost_per_request
                      < best2.cost_per_request - 1e-12),
    }


# ------------------------------------------------- tiered runtime phase

def quiet_tconf() -> TransportConfig:
    return TransportConfig(retry_backoff_s=0.0, max_retries=0,
                           breaker_failures=10 ** 6, timeout_s=60.0)


def lut_apply(table: np.ndarray):
    return lambda batch: table[np.asarray(batch["idx"])]


def build_ladder(wl: dict, thresholds, tiered: TieredBudgetController
                 | None = None):
    """Device→edge→cloud chain over the workload LUTs; per-hop budget
    loops attach to the non-final hops when ``tiered`` is given."""
    loop = (lambda n: tiered.loop(n)) if tiered is not None else \
        (lambda n: None)
    return build_stage_chain([
        dict(name="device", apply=lut_apply(wl["device"]),
             config=quiet_tconf(), cost_per_request=0.0,
             threshold=float(thresholds[0]), controller=loop("device")),
        dict(name="edge", apply=lut_apply(wl["edge"]),
             config=quiet_tconf(), cost_per_request=EDGE_COST,
             threshold=float(thresholds[1]), controller=loop("edge")),
        dict(name="cloud", apply=lut_apply(wl["cloud"]),
             config=quiet_tconf(), cost_per_request=CLOUD_COST,
             threshold=float(thresholds[2])),
    ])


def runtime_phase(wl: dict, half: slice, thresholds,
                  hop_targets: dict | None = None) -> dict:
    """Drive the selected operating point through ``TieredCascade`` in
    windows of BATCH; optionally with per-hop budget loops reconciled
    by a ``TieredBudgetController``."""
    idx = np.arange(half.start, half.stop)
    labels = wl["labels"][half]
    tiered = None
    if hop_targets is not None:
        tiered = TieredBudgetController(
            hop_targets,
            base=ControllerConfig(window=2 * BATCH),
            reconcile_every=2)
    cascade = TieredCascade(build_ladder(wl, thresholds, tiered))
    preds, stages, accepted, costs = [], [], [], []
    for lo in range(0, len(idx), BATCH):
        out = cascade.serve({"idx": idx[lo:lo + BATCH]})
        preds.append(out.prediction)
        stages.append(out.stage_index)
        accepted.append(out.accepted)
        costs.append(out.cost)
        if tiered is not None:
            tiered.tick()       # hops observe via their own loop refs
    cascade.shutdown()
    pred = np.concatenate(preds)
    stage = np.concatenate(stages)
    acc = np.concatenate(accepted)
    cost = np.concatenate(costs)
    mix = {name: int((stage == i).sum())
           for i, name in enumerate(("device", "edge", "cloud"))}
    out = {
        "rows": len(idx),
        "system_accuracy": float((pred[acc] == labels[acc]).sum()
                                 / len(idx)),
        "rejection_rate": float(1.0 - acc.mean()),
        "cost_per_request": float(cost.mean()),
        "stage_mix": mix,
        "stage_stats": {n: vars(s).copy()
                        for n, s in cascade.stats().items()},
        "digest": [tuple(map(int, pred)), tuple(map(int, stage)),
                   tuple(map(bool, acc)),
                   tuple(round(float(c), 12) for c in cost)],
    }
    if tiered is not None:
        rec = tiered.reconcile()
        out["tier_budget"] = {
            "hop_targets": dict(hop_targets),
            "hop_fractions": tiered.hop_fractions(),
            "end_to_end_fraction": tiered.end_to_end_fraction(),
            "global_target": tiered.global_target,
            "reconciles": tiered.reconciles,
            "windows": {n: tiered.loop(n).state.windows
                        for n in tiered.loops},
            "final": rec,
        }
    return out


# ------------------------------------- degenerate 2-stage engine identity

def engine_run(terminal_stage: bool, rows: int, seed: int) -> dict:
    """One adaptive engine+scheduler run against a plain backend or a
    terminal ``CascadeStage`` — everything the identity check compares."""
    def local_apply(x):
        return x + 0.3 * jnp.sin(17.0 * x)

    def remote_apply(x):
        return 5.0 * np.asarray(x)

    cls = CascadeStage if terminal_stage else RemoteBackend
    router = RemoteRouter([cls("cloud", remote_apply, quiet_tconf(),
                               cost_per_request=CLOUD_COST)])
    engine = CascadeEngine(
        local_apply, batch_size=BATCH, remote_fraction_budget=0.5,
        t_remote=0.0, transport=router,
        controller=AdaptiveController(ControllerConfig(
            target_remote_fraction=0.4, window=2 * BATCH)))
    sched = MicrobatchScheduler(engine, fallback=lambda r: -7)
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 4, rows)
    xs = np.float32(rng.normal(0, 0.05, (rows, 4)))
    margin = np.where(rng.random(rows) < 0.5, 0.1, 3.0)
    xs[np.arange(rows), labels] += margin
    for i, row in enumerate(xs):
        sched.submit(Request(uid=i, local_input=row, remote_input=row))
    responses = sched.flush()
    engine.close()
    st, cs = engine.stats, engine.controller.state
    return {
        "responses": [(r.uid, int(r.prediction), r.source, r.disposition,
                       r.backend, round(float(r.cost), 12))
                      for r in responses],
        "billing": {f: getattr(st, f) for f in BILLING_FIELDS},
        "per_backend": {str(k): vars(v).copy()
                        for k, v in st.per_backend.items()},
        "controller": (cs.windows, cs.ema_fraction, cs.t_local,
                       cs.t_remote, cs.drift_events),
    }


def chained_engine_run(wl: dict, half: slice, thresholds, seed: int
                       ) -> dict:
    """Chained-ladder engine run for the billing-reconciliation check:
    the routed backend hides edge→cloud, the engine's local model is the
    device tier."""
    idx = np.arange(half.start, half.stop)
    dev_tbl = jnp.asarray(wl["device"])

    def local_apply(i):                 # runs under the engine's jit
        return jnp.take(dev_tbl, i, axis=0)

    chain = build_stage_chain([
        dict(name="edge", apply=lut_apply(wl["edge"]),
             config=quiet_tconf(), cost_per_request=EDGE_COST,
             threshold=float(thresholds[1])),
        dict(name="cloud", apply=lut_apply(wl["cloud"]),
             config=quiet_tconf(), cost_per_request=CLOUD_COST),
    ])
    engine = CascadeEngine(local_apply, batch_size=BATCH,
                           remote_fraction_budget=1.0,
                           t_remote=float(thresholds[2]),
                           transport=RemoteRouter([chain]))
    engine.t_local = float(thresholds[0])
    sched = MicrobatchScheduler(engine, fallback=lambda r: -7)
    for i in idx:
        sched.submit(Request(uid=int(i), local_input=np.int64(i),
                             remote_input={"idx": np.int64(i)}))
    responses = sched.flush()
    engine.close()
    st = engine.stats
    per = {str(k): vars(v).copy() for k, v in st.per_backend.items()}
    return {
        "billing": {f: getattr(st, f) for f in BILLING_FIELDS},
        "per_backend": per,
        "backends_seen": sorted(
            {r.backend for r in responses if r.backend}),
        "escalation_identity": st.escalations == sum(
            u["remote_calls"] + u["cache_hits"] + u["transport_failures"]
            for u in per.values()),
        "cost_reconciles": abs(st.total_cost - sum(
            u["cost"] for u in per.values())) < 1e-12,
        "agreement_tracked": all(
            u["agreement_ema"] is not None and u["agreement_rows"] > 0
            for u in per.values()),
    }


# --------------------------------------------------------------- driver

def run(verbose: bool = True, rows: int = 2048, grid: int = 9,
        seed: int = 7,
        json_path: str | None = "BENCH_hierarchy.json") -> dict:
    wl = make_workload(rows, seed)
    cal_half, eval_half = slice(0, rows // 2), slice(rows // 2, rows)

    t0 = time.perf_counter()
    cal_a = calibration_phase(wl, cal_half, grid)
    cal_b = calibration_phase(wl, cal_half, grid)
    thresholds = cal_a["best_3tier"]["thresholds"]

    # hop targets = the selected point's own escalation fractions, so
    # the per-tier loops track an achievable budget: hop i's target is
    # the fraction of its arrivals it should escalate
    sf = cal_a["best_3tier"]["stage_fractions"]
    hop_targets = {"device": sf[1] / sf[0], "edge": sf[2] / max(sf[1],
                                                                1e-9)}
    rt_a = runtime_phase(wl, eval_half, thresholds, hop_targets)
    rt_b = runtime_phase(wl, eval_half, thresholds, hop_targets)

    eng_plain = engine_run(False, rows // 2, seed)
    eng_stage = engine_run(True, rows // 2, seed)
    eng_chain = chained_engine_run(wl, eval_half, thresholds, seed)
    wall = time.perf_counter() - t0

    tb = rt_a["tier_budget"]
    hop_err = {n: abs(tb["hop_fractions"][n] - hop_targets[n])
               for n in hop_targets}
    checks = {
        # -- ISSUE 10 acceptance -------------------------------------
        "three_tier_dominates": cal_a["dominates"],
        "deterministic_replay": (
            {k: v for k, v in cal_a.items() if k != "sweep_s"}
            == {k: v for k, v in cal_b.items() if k != "sweep_s"}
            and rt_a["digest"] == rt_b["digest"]
            and rt_a["stage_stats"] == rt_b["stage_stats"]
            and {k: v for k, v in rt_a.items() if k != "digest"}
            == {k: v for k, v in rt_b.items() if k != "digest"}),
        "two_tier_engine_identity": eng_plain == eng_stage,
        # -- joint sweep sanity --------------------------------------
        "frontier_monotone": cal_a["frontier_monotone"],
        "calibration_generalizes": (
            abs(rt_a["system_accuracy"]
                - cal_a["best_3tier"]["system_accuracy"]) <= GEN_TOL),
        "mid_tier_carries_load": rt_a["stage_mix"]["edge"] > 0,
        # -- chained engine billing ----------------------------------
        "billing_reconciles": (eng_chain["escalation_identity"]
                               and eng_chain["cost_reconciles"]),
        "per_stage_attribution": (
            "edge" in eng_chain["per_backend"]
            and "cloud" in eng_chain["per_backend"]
            and eng_chain["agreement_tracked"]),
        # -- per-tier budget loops -----------------------------------
        "tier_budget_tracks": (tb["reconciles"] > 0
                               and all(v <= TIER_TOL
                                       for v in hop_err.values())),
    }

    report = {
        "rows": rows, "grid": grid, "seed": seed, "batch": BATCH,
        "stage_costs": [0.0, EDGE_COST, CLOUD_COST],
        "wall_s": wall,
        "calibration": cal_a,
        "runtime": {k: v for k, v in rt_a.items() if k != "digest"},
        "hop_targets": hop_targets,
        "hop_errors": hop_err,
        "engine_identity": {"billing": eng_plain["billing"],
                            "identical": eng_plain == eng_stage},
        "engine_chained": eng_chain,
        "checks": checks,
        "passed": all(checks.values()),
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=1, default=str)
    if verbose:
        b2, b3 = cal_a["best_2tier"], cal_a["best_3tier"]
        print(f"\n--- Hierarchy: 3-tier ladder over {rows} rows "
              f"(grid {grid}, seed {seed}, wall {wall:.2f}s) ---")
        print(f"joint sweep: {cal_a['points_swept']} points, frontier "
              f"{cal_a['frontier']} (swept twice in "
              f"{cal_a['sweep_s']:.2f}s each)")
        print(f"best 2-tier: acc {b2['system_accuracy']:.4f} at "
              f"${b2['cost_per_request']:.5f}/req")
        if b3 is not None:
            print(f"best 3-tier: acc {b3['system_accuracy']:.4f} at "
                  f"${b3['cost_per_request']:.5f}/req "
                  f"(thresholds {[round(t, 3) for t in b3['thresholds']]},"
                  f" stage fractions "
                  f"{[round(f, 3) for f in b3['stage_fractions']]})")
        print(f"eval: acc {rt_a['system_accuracy']:.4f}, "
              f"${rt_a['cost_per_request']:.5f}/req, stage mix "
              f"{rt_a['stage_mix']}, rejection "
              f"{rt_a['rejection_rate']:.3f}")
        print(f"tier budget: targets "
              f"{ {k: round(v, 3) for k, v in hop_targets.items()} }, "
              f"realised "
              f"{ {k: round(v, 3) for k, v in tb['hop_fractions'].items()} }"
              f" ({tb['reconciles']} reconciles, e2e "
              f"{tb['end_to_end_fraction']:.3f} vs global "
              f"{tb['global_target']:.3f})")
        print(f"chained engine: per-stage "
              f"{ {k: u['remote_calls'] for k, u in eng_chain['per_backend'].items()} }"
              f" calls, agreement "
              f"{ {k: None if u['agreement_ema'] is None else round(u['agreement_ema'], 3) for k, u in eng_chain['per_backend'].items()} }")
        print(f"checks: {checks}"
              + (f"; JSON -> {json_path}" if json_path else ""))
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rows", type=int, default=2048)
    ap.add_argument("--grid", type=int, default=9,
                    help="per-stage quantile grid for the joint sweep")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--json", default="BENCH_hierarchy.json",
                    help="machine-readable output path ('' disables)")
    args = ap.parse_args(argv)
    report = run(rows=args.rows, grid=args.grid, seed=args.seed,
                 json_path=args.json or None)
    return 0 if report["passed"] else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""Case-study + architecture inventory (paper Table 1 + assignment pool).

Verifies the synthetic case-study calibration against Table 1 and counts
real parameters of every assigned architecture config (via eval_shape —
no allocation)."""

from __future__ import annotations


from repro.analysis.roofline import param_counts
from repro.configs import INPUT_SHAPES, get_config, list_archs, \
    shape_applicable
from repro.data.synthetic import CASE_STUDIES, sample_case_study


def run(verbose: bool = True) -> dict:
    out = {"case_studies": [], "architectures": []}
    if verbose:
        print("\n--- Table 1: case-study calibration ---")
        print(f"{'case':>12} {'metric':>12} {'target L/R':>14} "
              f"{'calibrated L/R':>15}")
    for name in sorted(CASE_STUDIES):
        cs = CASE_STUDIES[name]
        s = sample_case_study(cs, 50_000)
        valid = ~s.invalid
        la, ra = s.local_correct[valid].mean(), s.remote_correct[valid].mean()
        out["case_studies"].append(
            {"name": name, "metric": cs.metric, "target_local": cs.local_acc,
             "target_remote": cs.remote_acc, "calibrated_local": round(la, 4),
             "calibrated_remote": round(ra, 4)})
        if verbose:
            print(f"{name:>12} {cs.metric:>12} "
                  f"{cs.local_acc:.3f}/{cs.remote_acc:.3f}  "
                  f"{la:14.3f}/{ra:.3f}")

    if verbose:
        print("\n--- Assigned architecture pool (10) ---")
        print(f"{'arch':>22} {'family':>7} {'params':>9} {'active':>9} "
              f"{'shapes':>22}")
    for arch in list_archs():
        cfg = get_config(arch)
        total, active = param_counts(cfg)
        shapes = [s for s in INPUT_SHAPES
                  if shape_applicable(cfg, INPUT_SHAPES[s])[0]]
        out["architectures"].append(
            {"arch": arch, "family": cfg.family, "params": total,
             "active_params": active, "applicable_shapes": shapes,
             "citation": cfg.citation})
        if verbose:
            print(f"{arch:>22} {cfg.family:>7} {total / 1e9:8.2f}B "
                  f"{active / 1e9:8.2f}B {len(shapes):>2}/4: "
                  f"{','.join(s.split('_')[0] for s in shapes)}")
    return out


if __name__ == "__main__":
    run()

"""N-tier cascade hierarchy tests (ISSUE 10, DESIGN.md §13).

Pins the properties the hierarchy subsystem is built on: the joint
(t_1, ..., t_n) sweep degenerates to the 2-level sweep point for point,
the joint Pareto frontier is non-dominated and strictly monotone, a
threshold above the supervisor's upper bound collapses a tier out of
the ladder, a terminal ``CascadeStage`` is bitwise-identical to a plain
``RemoteBackend`` through the engine, the chained path splits billing
per stage with cumulative hop pricing, and ``TieredBudgetController``
reconciles the per-hop loops back to the global escalation budget.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.supervisors import SOFTMAX_SUPERVISORS
from repro.runtime import (AdaptiveController, CascadeStage,
                           ControllerConfig, RemoteBackend, RemoteRouter,
                           TieredBudgetController, TieredCascade,
                           TransportConfig, build_stage_chain,
                           joint_pareto_frontier,
                           select_joint_operating_point,
                           sweep_joint_operating_points,
                           sweep_operating_points)
from repro.serving import ServeConfig, TierSpec
from repro.serving.engine import BILLING_FIELDS, CascadeEngine
from repro.serving.scheduler import MicrobatchScheduler, Request

NCLS = 6
_score = SOFTMAX_SUPERVISORS["max_softmax"]


def quiet_tconf() -> TransportConfig:
    return TransportConfig(retry_backoff_s=0.0, max_retries=0,
                           breaker_failures=10 ** 6, timeout_s=60.0)


def planted_tiers(rows: int, seed: int, n_tiers: int = 3):
    """Cumulative-skill logit LUTs: tier i solves difficulty bands
    <= i confidently, is unsure elsewhere (same planting as the bench)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, NCLS, rows)
    band = rng.choice(n_tiers, rows)
    tables = []
    for solves in range(n_tiers):
        solved = band <= solves
        wrong = (labels + rng.integers(1, NCLS, rows)) % NCLS
        target = np.where(solved, labels, wrong)
        margin = np.where(solved, rng.uniform(4.0, 6.0, rows),
                          rng.uniform(0.2, 0.8, rows))
        logits = rng.normal(0, 0.05, (rows, NCLS))
        logits[np.arange(rows), target] += margin
        tables.append(np.float32(logits))
    return labels, tables


def conf_correct(logits: np.ndarray, labels: np.ndarray):
    conf = np.asarray(_score(jnp.asarray(logits)), np.float64)
    return conf, logits.argmax(-1) == labels


def lut_apply(table: np.ndarray):
    return lambda batch: table[np.asarray(batch["idx"])]


def build_ladder(tables, thresholds, costs, controllers=None):
    controllers = controllers or [None] * len(tables)
    return build_stage_chain([
        dict(name=f"t{i}", apply=lut_apply(tbl), config=quiet_tconf(),
             cost_per_request=c, threshold=float(t), controller=ctl)
        for i, (tbl, t, c, ctl) in enumerate(
            zip(tables, thresholds, costs, controllers))])


# --------------------------------------------------- joint calibration

def test_joint_sweep_two_tier_reproduces_legacy_exactly():
    labels, (dev, _, cloud) = planted_tiers(400, seed=0)
    lc, lok = conf_correct(dev, labels)
    rc, rok = conf_correct(cloud, labels)
    legacy = sweep_operating_points(lc, lok, rc, rok, grid=9,
                                    remote_cost_per_request=0.0048)
    joint = sweep_joint_operating_points([lc, rc], [lok, rok], grid=9,
                                         stage_costs=[0.0, 0.0048])
    assert len(legacy) == len(joint) > 0
    for lp, jp in zip(legacy, joint):
        assert jp.thresholds == (lp.t_local, lp.t_remote)
        assert jp.stage_fractions[0] == 1.0
        assert jp.stage_fractions[1] == lp.remote_fraction
        assert jp.rejection_rate == lp.rejection_rate
        assert jp.accuracy == lp.accuracy
        assert jp.system_accuracy == lp.system_accuracy
        assert jp.cost_per_request == lp.cost_per_request


def test_joint_frontier_non_dominated_and_monotone():
    labels, tables = planted_tiers(400, seed=1)
    confs, oks = zip(*(conf_correct(t, labels) for t in tables))
    pts = sweep_joint_operating_points(list(confs), list(oks), grid=7,
                                       stage_costs=[0.0, 0.001, 0.005])
    front = joint_pareto_frontier(pts)
    assert front
    # no swept point dominates any frontier point
    for fp in front:
        for p in pts:
            dominates = (p.cost_per_request <= fp.cost_per_request
                         and p.system_accuracy >= fp.system_accuracy
                         and (p.cost_per_request < fp.cost_per_request
                              or p.system_accuracy > fp.system_accuracy))
            assert not dominates
    # strictly monotone in both axes, sorted by cost
    for a, b in zip(front, front[1:]):
        assert b.cost_per_request > a.cost_per_request
        assert b.system_accuracy > a.system_accuracy


def test_select_joint_respects_cost_budget():
    labels, tables = planted_tiers(400, seed=2)
    confs, oks = zip(*(conf_correct(t, labels) for t in tables))
    pts = sweep_joint_operating_points(list(confs), list(oks), grid=7,
                                       stage_costs=[0.0, 0.001, 0.005])
    budget = 0.002
    pick = select_joint_operating_point(pts, cost_budget=budget)
    assert pick.cost_per_request <= budget + 1e-12
    feasible = [p for p in pts if p.cost_per_request <= budget + 1e-12]
    assert pick.system_accuracy == max(p.system_accuracy
                                       for p in feasible)
    # infeasible dollar ceiling falls back to the cheapest point
    floor = select_joint_operating_point(pts, cost_budget=-1.0)
    assert floor.cost_per_request == min(p.cost_per_request for p in pts)


# ------------------------------------------------------ tiered cascade

def test_threshold_above_one_collapses_tier():
    """max_softmax is bounded by 1.0, so a mid-tier threshold above it
    never trusts a row — the 3-tier ladder serves exactly like the
    2-tier ladder that skips the tier (same answers, same stages), and
    the collapsed tier answers nothing."""
    rows = 256
    labels, tables = planted_tiers(rows, seed=3)
    batch = {"idx": np.arange(rows)}

    three = TieredCascade(build_ladder(
        tables, [0.7, 2.0, 0.0], [0.0, 0.001, 0.005]))
    out3 = three.serve(batch)
    stats3 = {n: vars(s).copy() for n, s in three.stats().items()}
    three.shutdown()

    two = TieredCascade(build_ladder(
        [tables[0], tables[2]], [0.7, 0.0], [0.0, 0.005]))
    out2 = two.serve(batch)
    two.shutdown()

    assert stats3["t1"]["answered"] == 0
    assert np.array_equal(out3.prediction, out2.prediction)
    assert np.array_equal(out3.accepted, out2.accepted)
    # stage indices map 0->0 (device) and 2->1 (terminal)
    assert np.array_equal(out3.stage_index == 0, out2.stage_index == 0)


def test_cumulative_hop_pricing():
    """A row answered at depth k pays every hop that served it — the
    cost model joint calibration prices (each reached stage bills its
    stage cost)."""
    rows = 256
    labels, tables = planted_tiers(rows, seed=4)
    cascade = TieredCascade(build_ladder(
        tables, [0.9, 0.9, 0.0], [0.0, 0.001, 0.005]))
    out = cascade.serve({"idx": np.arange(rows)})
    stats = {n: vars(s).copy() for n, s in cascade.stats().items()}
    cascade.shutdown()
    assert stats["t2"]["requests"] > 0          # ladder exercised
    by_stage = {0: 0.0, 1: 0.001, 2: 0.001 + 0.005}
    expect = np.array([by_stage[int(s)] for s in out.stage_index])
    expect[~out.accepted & (out.stage_index != 2)] = 0.0
    assert np.allclose(out.cost[out.accepted], expect[out.accepted])
    # per-stage stats bill every served row at the hop's own price
    assert stats["t1"]["cost"] == pytest.approx(
        0.001 * (stats["t1"]["answered"] + stats["t1"]["escalated"]))
    assert stats["t2"]["cost"] == pytest.approx(
        0.005 * stats["t2"]["requests"])


# ------------------------------------------------------- engine paths

def _engine_digest(terminal_stage: bool, rows: int = 128, seed: int = 5):
    def local_apply(x):
        return x + 0.3 * jnp.sin(17.0 * x)

    def remote_apply(x):
        return 5.0 * np.asarray(x)

    cls = CascadeStage if terminal_stage else RemoteBackend
    router = RemoteRouter([cls("cloud", remote_apply, quiet_tconf(),
                               cost_per_request=0.005)])
    engine = CascadeEngine(
        local_apply, batch_size=16, remote_fraction_budget=0.5,
        t_remote=0.0, transport=router,
        controller=AdaptiveController(ControllerConfig(
            target_remote_fraction=0.4, window=32)))
    sched = MicrobatchScheduler(engine, fallback=lambda r: -7)
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 4, rows)
    xs = np.float32(rng.normal(0, 0.05, (rows, 4)))
    xs[np.arange(rows), labels] += np.where(rng.random(rows) < 0.5,
                                            0.1, 3.0)
    for i, row in enumerate(xs):
        sched.submit(Request(uid=i, local_input=row, remote_input=row))
    responses = sched.flush()
    engine.close()
    st, cs = engine.stats, engine.controller.state
    return {
        "responses": [(r.uid, int(r.prediction), r.source,
                       r.disposition, r.backend,
                       round(float(r.cost), 12)) for r in responses],
        "billing": {f: getattr(st, f) for f in BILLING_FIELDS},
        "per_backend": {str(k): vars(v).copy()
                        for k, v in st.per_backend.items()},
        "controller": (cs.windows, cs.ema_fraction, cs.t_local,
                       cs.t_remote, cs.drift_events),
    }


def test_terminal_stage_engine_identity():
    """A terminal CascadeStage routed through the engine is
    bitwise-identical to the plain RemoteBackend path: responses,
    billing, per-backend attribution and controller state."""
    assert _engine_digest(False) == _engine_digest(True)


def test_chained_stage_per_backend_split_and_agreement():
    rows = 256
    labels, tables = planted_tiers(rows, seed=6)
    dev_tbl = jnp.asarray(tables[0])

    def local_apply(i):
        return jnp.take(dev_tbl, i, axis=0)

    chain = build_stage_chain([
        dict(name="edge", apply=lut_apply(tables[1]),
             config=quiet_tconf(), cost_per_request=0.001,
             threshold=0.9),
        dict(name="cloud", apply=lut_apply(tables[2]),
             config=quiet_tconf(), cost_per_request=0.005),
    ])
    engine = CascadeEngine(local_apply, batch_size=16,
                           remote_fraction_budget=1.0, t_remote=0.0,
                           transport=RemoteRouter([chain]))
    engine.t_local = 0.9
    sched = MicrobatchScheduler(engine, fallback=lambda r: -7)
    for i in range(rows):
        sched.submit(Request(uid=i, local_input=np.int64(i),
                             remote_input={"idx": np.int64(i)}))
    responses = sched.flush()
    engine.close()
    st = engine.stats
    per = {str(k): vars(v).copy() for k, v in st.per_backend.items()}
    assert set(per) == {"edge", "cloud"}
    assert per["edge"]["remote_calls"] > 0
    assert per["cloud"]["remote_calls"] > 0
    # escalation identity holds per stage name
    assert st.escalations == sum(
        u["remote_calls"] + u["cache_hits"] + u["transport_failures"]
        for u in per.values())
    # per-stage cost split sums exactly to the total; cloud rows pay
    # the edge hop too (cumulative pricing)
    assert abs(st.total_cost
               - sum(u["cost"] for u in per.values())) < 1e-12
    assert per["cloud"]["cost"] == pytest.approx(
        (0.001 + 0.005) * per["cloud"]["remote_calls"])
    # responses attribute the answering stage by name
    assert {r.backend for r in responses if r.backend} <= {"edge",
                                                           "cloud"}
    # agreement EMA tracked for every answering stage
    for u in per.values():
        assert u["agreement_ema"] is not None
        assert 0.0 <= u["agreement_ema"] <= 1.0
        assert u["agreement_rows"] > 0


# --------------------------------------------------- per-tier budgets

def test_tiered_budget_controller_reconciles():
    tiered = TieredBudgetController(
        {"device": 0.5, "edge": 0.5},
        base=ControllerConfig(window=8), reconcile_every=2)
    assert tiered.global_target == pytest.approx(0.25)
    # stable score distribution (no drift resets); device persistently
    # over-escalates, edge holds its target
    conf = np.linspace(0.1, 0.9, 8)
    for _ in range(12):
        tiered.observe("device", conf, escalated=6, requests=8)
        tiered.observe("edge", conf[:6], escalated=3, requests=6)
    assert tiered.reconciles > 0
    rec = tiered.reconcile()
    assert set(rec["targets"]) == {"device", "edge"}
    # observed end-to-end fraction sits above the global budget, so the
    # reconcile scales every hop target DOWN from its configured value
    assert rec["observed"] > tiered.global_target
    assert rec["targets"]["device"] < 0.5
    assert rec["targets"]["edge"] < 0.5
    # retarget actually landed on the live loops
    for name, t in rec["targets"].items():
        assert tiered.loop(name).config.target_remote_fraction == \
            pytest.approx(t)


def test_tiered_budget_controller_validates():
    with pytest.raises(ValueError):
        TieredBudgetController({})


# ------------------------------------------------- serving config face

def test_tierspec_parse():
    full = TierSpec.parse("edge:0.001:0.1:0.6:entropy")
    assert full == TierSpec("edge", 0.001, 0.1, 0.6, "entropy")
    sparse = TierSpec.parse("cloud:0.0048")
    assert sparse == TierSpec("cloud", 0.0048, None, 0.0, "max_softmax")
    skipped = TierSpec.parse("edge::0.25::")
    assert skipped == TierSpec("edge", None, 0.25, 0.0, "max_softmax")
    with pytest.raises(ValueError):
        TierSpec.parse(":0.1")
    with pytest.raises(ValueError):
        TierSpec.parse("a:1:2:3:4:5")


def test_serveconfig_tiers_exclusive_and_overridable():
    cfg = ServeConfig().with_overrides(
        ["tiers=edge:0.001:0.1:0.6;cloud:0.0048:0.8"])
    assert [t.name for t in cfg.tiers] == ["edge", "cloud"]
    assert cfg.tiers[0].threshold == 0.6
    with pytest.raises(ValueError):
        ServeConfig(tiers=(TierSpec("edge"),),
                    remotes=({"name": "a", "cost_per_request": 0.1},))


def test_serveconfig_tiers_build_chained_router():
    rows = 64
    labels, tables = planted_tiers(rows, seed=8)
    cfg = ServeConfig(tiers=(
        TierSpec("edge", 0.001, None, 0.9),
        TierSpec("cloud", 0.005)))
    router = cfg.build_router({"edge": lut_apply(tables[1]),
                               "cloud": lut_apply(tables[2])})
    head = router.backends[0]
    assert isinstance(head, CascadeStage)
    assert [s.name for s in head.chain()] == ["edge", "cloud"]
    logits, ok, detail = head.call_scored({"idx": np.arange(rows)}, 0)
    assert ok.all()
    assert set(np.unique(detail["stage"])) <= {"edge", "cloud"}
    head.shutdown()

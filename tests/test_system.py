"""End-to-end system tests: the paper's full pipeline on the calibrated
synthetic case studies — RQ1 cost-saving claims, RQ2 supervised claims —
plus a real two-model cascade (trained surrogate + larger remote) wired
through the serving engine."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.metrics import (auc_rac, request_accuracy_curve,
                                supervised_metrics, threshold_for_fpr)
from repro.data.synthetic import CASE_STUDIES, sample_case_study
from repro.serving.engine import CascadeEngine

N = 20_000


@pytest.fixture(scope="module", params=sorted(CASE_STUDIES))
def case(request):
    cs = CASE_STUDIES[request.param]
    return cs, sample_case_study(cs, N)


def test_case_study_calibration(case):
    """Synthetic analogues hit the paper's Table 1 accuracies (±2%)."""
    cs, s = case
    valid = ~s.invalid
    np.testing.assert_allclose(s.local_correct[valid].mean(), cs.local_acc,
                               atol=0.02)
    np.testing.assert_allclose(s.remote_correct[valid].mean(), cs.remote_acc,
                               atol=0.02)


def test_rq1_auc_rac_beats_random(case):
    """Paper RQ1: AUC-RAC substantially above the random baseline 0.5 in
    all case studies."""
    cs, s = case
    valid = ~s.invalid                      # RQ1 uses answerable inputs
    rac = request_accuracy_curve(s.local_conf[valid],
                                 s.local_correct[valid],
                                 s.remote_correct[valid])
    assert auc_rac(rac) > 0.6, cs.name


def test_rq1_half_cost_keeps_accuracy(case):
    """Paper abstract: at 50% remote-cost reduction the system accuracy is
    at most marginally below remote-only."""
    cs, s = case
    valid = ~s.invalid
    rac = request_accuracy_curve(s.local_conf[valid],
                                 s.local_correct[valid],
                                 s.remote_correct[valid])
    i50 = len(rac.accuracy) // 2
    assert rac.accuracy[i50] >= rac.remote_only - 0.03, cs.name


def test_rq1_superaccuracy_where_complementary():
    """IMDB and SQuADv2 (complementary tiers) peak above remote-only."""
    for name in ("imdb", "squadv2"):
        cs = CASE_STUDIES[name]
        s = sample_case_study(cs, N)
        valid = ~s.invalid
        rac = request_accuracy_curve(s.local_conf[valid],
                                     s.local_correct[valid],
                                     s.remote_correct[valid])
        knees = rac.knee_points()
        assert knees["best_accuracy"] > rac.remote_only, name
        assert knees["remote_even"] < 0.9, name    # real cost saving


def test_rq2_bisupervised_beats_supervised_local(case):
    """Paper RQ2: with 2nd-level threshold tuned to a target FPR, the
    cascade's S_beta matches/exceeds a standalone supervised local model in
    the (large) majority of configurations."""
    cs, s = case
    wins, total = 0, 0
    for fpr in (0.01, 0.05, 0.1):
        # baseline: standalone supervised local model
        t_base = threshold_for_fpr(s.local_conf, s.local_correct > 0, fpr)
        base = supervised_metrics(s.local_conf > t_base, s.local_correct > 0)
        # cascade at 50% remote budget
        t1 = np.quantile(s.local_conf, 0.5)
        use_local = s.local_conf > t1
        sys_correct = np.where(use_local, s.local_correct, s.remote_correct)
        sys_conf = np.where(use_local, np.inf, s.remote_conf)
        t2 = threshold_for_fpr(s.remote_conf[~use_local],
                               s.remote_correct[~use_local] > 0, fpr)
        accepted = use_local | (sys_conf > t2)
        ours = supervised_metrics(accepted, sys_correct > 0)
        for b in ("s_0.5", "s_1.0", "s_2.0"):
            total += 1
            if ours[b] >= base[b] - 1e-9:
                wins += 1
    # Paper §5.4.3: every case study wins the majority of configurations
    # EXCEPT SQuADv2-with-invalid-inputs, which is "not conclusively in
    # favor" (5 of 18 settings inferior) but shows a positive tendency.
    floor = 1 / 3 if cs.name == "squadv2_all" else 0.5
    assert wins / total >= floor, (cs.name, wins, total)


def test_rq2_invalid_inputs_get_rejected():
    """SQuADv2-all: the 2nd-level supervisor rejects unanswerable inputs at
    a much higher rate than answerable ones."""
    s = sample_case_study(CASE_STUDIES["squadv2_all"], N)
    t1 = np.quantile(s.local_conf, 0.4)
    use_local = s.local_conf > t1
    t2 = np.quantile(s.remote_conf[~use_local], 0.2)
    accepted = use_local | (s.remote_conf > t2)
    rej_invalid = (~accepted)[s.invalid].mean()
    rej_valid = (~accepted)[~s.invalid].mean()
    assert rej_invalid > 2 * rej_valid


def test_end_to_end_real_models_cascade():
    """A real (tiny) local JAX model + a 'remote' oracle through the
    engine: escalation budget respected, system accuracy between tiers."""
    from repro.data.synthetic import make_classification_task
    from repro.models import surrogate as S
    from repro.train.optimizer import AdamWConfig, adamw_update, \
        init_opt_state

    vocab, seq, ncls = 128, 32, 4
    toks, labels, difficulty = make_classification_task(
        0, n=512, vocab=vocab, seq_len=seq, num_classes=ncls)
    cfg = S.SurrogateConfig("t", vocab_size=vocab, max_len=seq, d_model=32,
                            num_heads=2, d_ff=32, num_classes=ncls,
                            dropout=0.0)
    params = S.init_params(cfg, jax.random.PRNGKey(0))

    @jax.jit
    def step(p, o, tk, lb):
        (l, m), g = jax.value_and_grad(
            lambda p: S.loss_fn(cfg, p, tk, lb, jax.random.PRNGKey(1)),
            has_aux=True)(p)
        p, o, _ = adamw_update(AdamWConfig(lr=3e-3, warmup_steps=5,
                                           weight_decay=0.0), p, g, o)
        return p, o, l

    opt = init_opt_state(params)
    tk, lb = jnp.asarray(toks), jnp.asarray(labels)
    for _ in range(30):
        params, opt, loss = step(params, opt, tk, lb)

    def local_apply(x):
        return S.apply(cfg, params, x)

    oracle = jax.nn.one_hot(lb, ncls) * 10.0

    def remote_apply(idx):        # remote view = row index -> oracle logits
        return oracle[idx[:, 0]]

    eng = CascadeEngine(local_apply, remote_apply, batch_size=128,
                        remote_fraction_budget=0.3, t_remote=0.5)
    idx = jnp.arange(512)[:, None]
    correct_local, correct_sys = [], []
    for i in range(0, 512, 128):
        out = eng.serve({"local": tk[i:i + 128], "remote": idx[i:i + 128]})
        correct_local.append(np.asarray(out["local_pred"])
                             == labels[i:i + 128])
        correct_sys.append(np.asarray(out["prediction"])
                           == labels[i:i + 128])
    acc_local = np.concatenate(correct_local).mean()
    acc_sys = np.concatenate(correct_sys).mean()
    assert eng.stats.remote_fraction == pytest.approx(0.3, abs=0.01)
    assert acc_sys >= acc_local     # remote help never hurts here
    assert acc_sys > 0.5

"""Cascade observability layer (DESIGN.md §9): metrics registry, event
log and trace-sink units; per-request span completeness across every
Response disposition path under FIFO, streaming and adversarial
completion orders; breaker / router / replay / controller / downgrade
event telemetry; and the disabled-mode zero-perturbation contract
(observability off must be bitwise-identical to the seed behaviour)."""

from __future__ import annotations

import json
import threading
import time

import jax.numpy as jnp
import numpy as np

from repro.runtime import (AdaptiveController, ControllerConfig,
                           RemoteBackend, RemoteResponseCache, RemoteRouter,
                           RemoteTimeout, TransportConfig)
from repro.runtime.observability import (EV_BREAKER_CLOSE,
                                         EV_BREAKER_HALF_OPEN,
                                         EV_BREAKER_OPEN,
                                         EV_CONTROLLER_DRIFT,
                                         EV_CONTROLLER_UPDATE,
                                         EV_DEADLINE_DOWNGRADE,
                                         EV_POLICY_DOWNGRADE,
                                         EV_REPLAY_PARKED, EV_REPLAY_SERVED,
                                         EV_ROUTER_FAILBACK,
                                         EV_ROUTER_FAILOVER, SPAN_STAGES,
                                         EventLog, MetricsRegistry,
                                         Observability, TraceSink)
from repro.serving import RequestPolicy, ServeConfig
from repro.serving.engine import BILLING_FIELDS
from repro.serving.policy import (CACHED, DEADLINE_LOCAL, LOCAL,
                                  POLICY_LOCAL, REJECTED, REMOTE)
from repro.serving.scheduler import Request

STAGE_ORDER = {s: i for i, s in enumerate(SPAN_STAGES)}


def local_apply(x):
    return x + 0.3 * jnp.sin(17.0 * x)


def remote_apply(x):
    return 5.0 * np.asarray(x)


def make_stream(rng, n, c=4, hard_frac=0.5):
    labels = rng.integers(0, c, n)
    x = rng.normal(0, 0.05, (n, c))
    margin = np.where(rng.random(n) < hard_frac, 0.1, 3.0)
    x[np.arange(n), labels] += margin
    return np.float32(x), labels


def quiet_tconf(**kw):
    base = dict(retry_backoff_s=0.0, max_retries=0, breaker_failures=10**6,
                timeout_s=60.0)
    base.update(kw)
    return TransportConfig(**base)


def build(remote=remote_apply, *, router=None, cache=None,
          observability=True, **cfg_kw):
    base = dict(batch_size=8, remote_fraction_budget=0.5, t_remote=0.0,
                pipeline_depth=2, cache_size=0, transport=quiet_tconf(),
                observability=observability)
    base.update(cfg_kw)
    cfg = ServeConfig(**base)
    kw = {}
    if router is not None:
        kw["transport"] = router
        remote = None
    if cache is not None:
        kw["cache"] = cache
    engine, sched = cfg.build(local_apply, remote, fallback=lambda r: -7,
                              **kw)
    return sched, engine


def serve_all(sched, xs, policies=None):
    for i, row in enumerate(xs):
        sched.submit(Request(uid=i, local_input=row, remote_input=row,
                             policy=policies[i] if policies else None))
    return sched.flush()


def assert_valid_spans(spans, responses):
    """Exactly one span per response; stage names in canonical SPAN_STAGES
    order with nondecreasing timestamps; disposition/cost agree with the
    Response the span describes."""
    assert sorted(s["uid"] for s in spans) \
        == sorted(r.uid for r in responses)
    by_uid = {r.uid: r for r in responses}
    for s in spans:
        names = [n for n, _ in s["stages"]]
        ts = [t for _, t in s["stages"]]
        assert len(set(names)) == len(names), s
        assert names == sorted(names, key=STAGE_ORDER.__getitem__), s
        assert ts == sorted(ts), s
        assert names[0] == "enqueue" and names[-1] == "handback", s
        r = by_uid[s["uid"]]
        assert s["disposition"] == r.disposition
        assert s["cost"] == r.cost
        assert s["source"] == r.source


def stages_of(spans, uid):
    (s,) = [s for s in spans if s["uid"] == uid]
    return [n for n, _ in s["stages"]]


# ------------------------------------------------------- metrics registry

def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    reg.counter("req_total").inc()
    reg.counter("req_total").inc(3)
    assert reg.counter("req_total").value == 4
    reg.counter("calls", backend="a").inc()
    reg.counter("calls", backend="b").inc(2)
    assert reg.counter("calls", backend="a").value == 1
    h = reg.histogram("lat", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    assert h.total == 3 and h.cumulative() == [1, 2]
    assert h.sum == 0.05 + 0.5 + 5.0
    snap = reg.snapshot()
    assert snap["counters"]['calls{backend="b"}'] == 2
    assert snap["histograms"]["lat"]["count"] == 3
    assert snap["histograms"]["lat"]["buckets"] == {"0.1": 1, "1.0": 2}


def test_snapshot_omits_unobserved_gauges():
    # the empty-stats contract: a gauge never set must be ABSENT from
    # snapshots and exposition, not a flattering 0.0
    reg = MetricsRegistry()
    reg.gauge("never_set")
    reg.gauge("set_then_cleared").set(1.0)
    reg.gauge("set_then_cleared").set(None)
    reg.gauge("observed").set(0.25)
    snap = reg.snapshot()
    assert snap["gauges"] == {"observed": 0.25}
    text = reg.render_prometheus()
    assert "never_set" not in text and "set_then_cleared" not in text
    assert "observed 0.25" in text


def test_prometheus_rendering():
    reg = MetricsRegistry()
    reg.counter("calls", backend="a").inc(2)
    reg.counter("calls", backend="b").inc()
    h = reg.histogram("lat", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(3.0)
    text = reg.render_prometheus()
    lines = text.splitlines()
    assert lines.count("# TYPE calls counter") == 1    # one header/name
    assert 'calls{backend="a"} 2' in lines
    assert "# TYPE lat histogram" in lines
    assert 'lat_bucket{le="0.1"} 1' in lines
    assert 'lat_bucket{le="1"} 1' in lines              # cumulative
    assert 'lat_bucket{le="+Inf"} 2' in lines
    assert "lat_count 2" in lines
    assert any(line.startswith("lat_sum ") for line in lines)


def test_collectors_sample_at_snapshot_time():
    reg = MetricsRegistry()
    live = {"v": 1.0}
    reg.register_collector(lambda r: r.gauge("live").set(live["v"]))
    assert reg.snapshot()["gauges"]["live"] == 1.0
    live["v"] = 7.0                     # hot path never touched the gauge
    assert reg.snapshot()["gauges"]["live"] == 7.0


# ------------------------------------------------------------- event log

def test_event_log_seq_order_filters_and_bound():
    log = EventLog(capacity=4, clock=time.monotonic)
    for i in range(6):
        log.emit("tick", window=i, backend="a" if i % 2 else "b")
    assert log.total == 6 and log.dropped == 2
    evs = log.events()
    assert [e["seq"] for e in evs] == [2, 3, 4, 5]   # oldest evicted
    assert all(e["window"] == e["seq"] for e in evs)
    assert [e["seq"] for e in log.events(backend="a")] == [3, 5]
    assert log.counts() == {"tick": 4}
    assert log.first_seq("tick", backend="b") == 2
    assert log.first_seq("nope") is None


def test_event_log_cross_thread_seq_unique():
    # the ordering contract: seq is a global monotonic counter assigned
    # under the log's lock, usable across pool + engine threads
    log = EventLog(capacity=4096)
    n_threads, per = 8, 50

    def emitter(tag):
        for _ in range(per):
            log.emit("e", backend=tag)

    threads = [threading.Thread(target=emitter, args=(str(i),))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    seqs = sorted(e["seq"] for e in log.events())
    assert seqs == list(range(n_threads * per))


# ------------------------------------------------------------ trace sink

def test_trace_sink_bounded_and_exports(tmp_path):
    sink = TraceSink(capacity=2)
    span = {"uid": 0, "window": 1, "disposition": "LOCAL", "cost": 0.0,
            "stages": [["enqueue", 1.0], ["dispatch", 2.0],
                       ["handback", 3.0]]}
    sink.emit(span)
    sink.emit({**span, "uid": 1})
    sink.emit({**span, "uid": 2})               # past capacity
    assert len(sink) == 2 and sink.dropped == 1

    jl = tmp_path / "t.jsonl"
    assert sink.write_jsonl(str(jl)) == 2
    rows = [json.loads(line) for line in jl.read_text().splitlines()]
    assert [r["uid"] for r in rows] == [0, 1]

    ch = tmp_path / "t.json"
    n_ev = sink.write_chrome_trace(str(ch))
    doc = json.loads(ch.read_text())
    # one complete "X" slice per consecutive stage pair
    assert n_ev == len(doc["traceEvents"]) == 2 * 2
    ev = doc["traceEvents"][0]
    assert ev["ph"] == "X" and ev["name"] == "dispatch"
    assert ev["ts"] == 0.0 and ev["dur"] == 1e6     # seconds -> µs
    assert ev["tid"] == 1


# ----------------------------------- span timelines per disposition path

def test_spans_trusted_local_and_escalated_fifo():
    rng = np.random.default_rng(0)
    xs, _ = make_stream(rng, 32)
    sched, engine = build(completion_mode="fifo")
    resp = serve_all(sched, xs)
    spans = engine.observability.trace.spans()
    assert_valid_spans(spans, resp)
    for s in spans:
        names = [n for n, _ in s["stages"]]
        if s["disposition"] == REMOTE:
            assert {"route", "remote", "commit"} <= set(names)
            assert s["backend"] == "remote"
            assert s["t_remote_gate"] is not None
        else:
            assert s["disposition"] == LOCAL
            assert "remote" not in names and "route" not in names
        assert "commit" in names            # FIFO: commit precedes drain
    engine.close()


def test_spans_streaming_and_adversarial_completion_orders():
    """Streaming hand-back with later windows completing FIRST: every
    request still gets exactly one monotonic span; trusted-local rows
    emitted ahead of their window's commit simply omit the commit
    stage (documented §9 caveat)."""
    rng = np.random.default_rng(1)
    xs, _ = make_stream(rng, 64)
    calls = {"n": 0}
    lock = threading.Lock()

    def reordering_remote(x):
        with lock:
            calls["n"] += 1
            i = calls["n"]
        time.sleep(0.03 * max(0, 4 - i))    # first windows finish last
        return remote_apply(x)

    sched, engine = build(reordering_remote, pipeline_depth=4,
                          completion_mode="streaming")
    resp = serve_all(sched, xs)
    spans = engine.observability.trace.spans()
    assert_valid_spans(spans, resp)
    remote_spans = [s for s in spans if s["disposition"] == REMOTE]
    assert remote_spans and all(
        "remote" in [n for n, _ in s["stages"]] for s in remote_spans)
    engine.close()


def test_spans_cache_hit_path():
    rng = np.random.default_rng(2)
    xs, _ = make_stream(rng, 8, hard_frac=1.0)
    cache = RemoteResponseCache(64)
    sched, engine = build(cache=cache)
    serve_all(sched, xs)                        # all miss, all billed
    engine.observability.trace._spans.clear()
    resp = serve_all(sched, xs)                 # identical content: hits
    hits = [r for r in resp if r.disposition == CACHED]
    assert hits
    spans = engine.observability.trace.spans()
    assert_valid_spans(spans, resp)
    for r in hits:
        names = stages_of(spans, r.uid)
        assert "cache_hit" in names and "remote" not in names
    engine.close()


def test_spans_policy_paths_and_downgrade_events():
    """POLICY_LOCAL / DEADLINE_LOCAL / REJECTED rows each produce one
    span that never touches route/remote, and every downgrade lands in
    the event log with its window and row."""
    rng = np.random.default_rng(3)
    xs, _ = make_stream(rng, 24, hard_frac=1.0)
    pol = ([RequestPolicy(escalation="never")] * 8
           + [RequestPolicy(deadline_s=1e-9)] * 8
           + [RequestPolicy(deadline_s=1e-9, on_miss="reject")] * 8)
    sched, engine = build(remote_fraction_budget=1.0)
    resp = serve_all(sched, xs, pol)
    spans = engine.observability.trace.spans()
    assert_valid_spans(spans, resp)
    disp = {r.uid: r.disposition for r in resp}
    assert {disp[u] for u in range(8)} == {POLICY_LOCAL}
    assert {disp[u] for u in range(8, 16)} == {DEADLINE_LOCAL}
    assert {disp[u] for u in range(16, 24)} == {REJECTED}
    for s in spans:
        names = [n for n, _ in s["stages"]]
        assert "remote" not in names and "route" not in names

    ev = engine.observability.events
    pol_ev = ev.events(EV_POLICY_DOWNGRADE)
    dl_ev = ev.events(EV_DEADLINE_DOWNGRADE)
    assert len(pol_ev) == 8 and len(dl_ev) == 8
    for e in pol_ev + dl_ev:
        assert e["window"] is not None and "row" in e
    assert {e["disposition"] for e in pol_ev} == {POLICY_LOCAL}
    assert {e["disposition"] for e in dl_ev} == {DEADLINE_LOCAL}
    engine.close()


def test_replay_redemption_events_and_window_trace():
    """The (unrouted) replay path: a window parked while every breaker
    is open must log replay_parked, then replay_served when the drain's
    half-open probe redeems it — and its window trace still carries the
    remote stage (the rows were billed and served)."""
    t = {"now": 0.0}
    down = {"on": True}

    def fn(x):
        if down["on"]:
            raise RemoteTimeout("outage")
        return remote_apply(x)

    backend = RemoteBackend(
        "only", fn, quiet_tconf(breaker_failures=1, breaker_reset_s=1.0),
        cost_per_request=0.004, clock=lambda: t["now"])
    router = RemoteRouter([backend])
    rng = np.random.default_rng(10)
    xs, _ = make_stream(rng, 8, hard_frac=1.0)
    _, engine = build(router=router)
    obs = engine.observability

    # window 1 fails on the wire -> breaker opens
    engine.begin_serve({"local": xs, "remote": xs}, real_rows=8)
    engine.flush_dispatch()
    assert engine.complete_ready(block=True)
    # window 2 submitted while open -> parked with a replay ticket
    fl = engine.begin_serve({"local": xs, "remote": xs}, real_rows=8)
    engine.flush_dispatch()
    assert fl.replay_ticket
    # outage ends, reset elapses mid-flight -> drain redeems the ticket
    down["on"] = False
    t["now"] += 2.0
    ((_, res),) = engine.complete_ready(block=True)
    assert bool(res["accepted"].all())
    assert "remote" in res["trace"]["stages"]
    stamps = res["trace"]["stages"]
    assert stamps["dispatch"] <= stamps["gate"] <= stamps["remote"] \
        <= stamps["commit"]

    ev = obs.events
    assert ev.first_seq(EV_BREAKER_OPEN, "only") is not None
    parked = ev.first_seq(EV_REPLAY_PARKED)
    served = ev.first_seq(EV_REPLAY_SERVED)
    assert parked is not None and served is not None
    assert ev.first_seq(EV_BREAKER_OPEN, "only") < parked < served
    assert ev.events(EV_REPLAY_SERVED)[0]["backend"] == "only"
    engine.close()


# ------------------------------------------- metrics <-> stats reconcile

def test_metrics_counters_bitwise_match_stats():
    rng = np.random.default_rng(4)
    xs, _ = make_stream(rng, 48)
    sched, engine = build(completion_mode="streaming")
    resp = serve_all(sched, xs)
    st = engine.stats
    snap = engine.observability.metrics.snapshot()
    c = snap["counters"]
    assert c["cascade_requests_total"] == st.requests
    assert c["cascade_windows_total"] == len(st.wall_samples)
    assert c["cascade_escalations_total"] == st.escalations
    assert c["cascade_remote_calls_total"] == st.remote_calls
    assert c["cascade_cache_hits_total"] == st.cache_hits
    assert c["cascade_transport_failures_total"] == st.transport_failures
    # commit-order accumulation: bitwise equality, not approx
    assert c["cascade_cost_dollars_total"] == st.total_cost
    disp = {k: v for k, v in c.items()
            if k.startswith("cascade_disposition_total")}
    assert sum(disp.values()) == st.requests
    hist = snap["histograms"]["cascade_request_latency_seconds"]
    assert hist["count"] == len(resp)
    assert snap["histograms"]["cascade_window_wall_seconds"]["count"] \
        == len(st.wall_samples)
    # per-request span costs also reconcile with billing
    spans = engine.observability.trace.spans()
    assert abs(sum(s["cost"] for s in spans) - st.total_cost) < 1e-9
    # derived gauges sampled at snapshot time
    g = snap["gauges"]
    assert g["cascade_escalation_fraction"] == st.escalation_fraction
    assert g['backend_breaker_state{backend="remote"}'] == 0
    assert g["cache_hit_ratio"] if engine.cache else True
    engine.close()


def test_observability_off_is_bitwise_identical_and_allocation_free():
    rng = np.random.default_rng(5)
    xs, _ = make_stream(rng, 32)
    s_off, e_off = build(observability=False)
    s_on, e_on = build(observability=True)
    r_off = serve_all(s_off, xs)
    r_on = serve_all(s_on, xs)
    assert [(r.uid, r.prediction, r.source, r.disposition, r.cost)
            for r in r_off] \
        == [(r.uid, r.prediction, r.source, r.disposition, r.cost)
            for r in r_on]
    for f in BILLING_FIELDS:
        assert getattr(e_off.stats, f) == getattr(e_on.stats, f), f
    assert e_off.stats.per_backend == e_on.stats.per_backend
    assert e_off.observability is None
    # disabled mode carries NO per-window trace state and the result
    # dict has no trace payload (zero per-row allocations on the hot
    # path — the engine guards on one attribute test)
    res_off = e_off.serve({"local": xs[:8], "remote": xs[:8]})
    res_on = e_on.serve({"local": xs[:8], "remote": xs[:8]})
    assert "trace" not in res_off and "trace" in res_on
    e_off.close()
    e_on.close()


# ------------------------------------------------- component event wiring

def test_breaker_transition_events_in_order():
    t = {"now": 0.0}
    down = {"on": True}

    def fn(x):
        if down["on"]:
            raise RemoteTimeout("down")
        return remote_apply(x)

    backend = RemoteBackend(
        "b0", fn, quiet_tconf(breaker_failures=2, breaker_reset_s=1.0),
        clock=lambda: t["now"])
    log = EventLog()
    backend.transport.events = log
    backend.transport.event_source = "b0"
    x = np.float32(np.eye(4))
    for _ in range(2):                      # 2 failures -> OPEN
        backend.call(x)
    down["on"] = False
    t["now"] += 2.0                         # reset elapses
    backend.call(x)                         # half-open probe -> CLOSED
    opens = log.events(EV_BREAKER_OPEN, "b0")
    halfs = log.events(EV_BREAKER_HALF_OPEN, "b0")
    closes = log.events(EV_BREAKER_CLOSE, "b0")
    assert len(opens) == len(halfs) == len(closes) == 1
    assert opens[0]["seq"] < halfs[0]["seq"] < closes[0]["seq"]
    assert opens[0]["prev"] == "closed" and opens[0]["failures"] >= 2
    assert halfs[0]["prev"] == "open"
    assert closes[0]["prev"] == "half_open"
    backend.transport.shutdown()


def test_router_failover_and_failback_events():
    a = RemoteBackend("a", remote_apply, quiet_tconf(breaker_failures=1),
                      cost_per_request=0.001)
    b = RemoteBackend("b", remote_apply, quiet_tconf(),
                      cost_per_request=0.009)
    router = RemoteRouter([a, b], policy="cheapest-available")
    log = EventLog()
    router.events = log
    assert router.pick(window=0) is a       # healthy: cheap primary
    a.breaker.record_failure()              # open the cheap breaker
    assert router.pick(window=1) is b
    a.breaker.record_success()              # recover
    assert router.pick(window=2) is a
    fo = log.events(EV_ROUTER_FAILOVER)
    fb = log.events(EV_ROUTER_FAILBACK)
    assert len(fo) == 1 and len(fb) == 1
    assert fo[0]["window"] == 1 and fo[0]["backend"] == "b"
    assert fb[0]["window"] == 2 and fb[0]["backend"] == "a"
    assert fo[0]["seq"] < fb[0]["seq"]
    a.transport.shutdown()
    b.transport.shutdown()


def test_controller_update_and_drift_events():
    rng = np.random.default_rng(6)
    ctl = AdaptiveController(ControllerConfig(
        target_remote_fraction=0.2, window=64))
    log = EventLog()
    ctl.events = log

    def run_phase(easy_frac, batches):
        for _ in range(batches):
            easy = rng.random(32) < easy_frac
            conf = np.where(easy, rng.uniform(0.8, 1.0, 32),
                            rng.uniform(0.3, 0.7, 32))
            t = ctl.t_local
            k = min(ctl.capacity(32),
                    32 if t is None else int((conf < t).sum()))
            ctl.observe(conf, k, 32)

    run_phase(0.9, 32)                  # settle
    run_phase(0.5, 32)                  # drift: harder mix
    updates = log.events(EV_CONTROLLER_UPDATE)
    drifts = log.events(EV_CONTROLLER_DRIFT)
    assert len(updates) == ctl.state.windows
    assert len(drifts) == ctl.state.drift_events >= 1
    d = drifts[0]
    assert d["psi"] > d["threshold"]
    # the drift is sequenced before the control update that absorbs it
    first_after = [u for u in updates if u["seq"] > d["seq"]]
    assert first_after
    engine_updates = [u["ema_fraction"] for u in updates]
    assert all(isinstance(v, float) for v in engine_updates)


def test_install_shares_one_event_log_across_components():
    rng = np.random.default_rng(7)
    xs, _ = make_stream(rng, 16)
    a = RemoteBackend("a", remote_apply, quiet_tconf())
    router = RemoteRouter([a])
    ctl = AdaptiveController(ControllerConfig(target_remote_fraction=0.3,
                                              window=8))
    cfg = ServeConfig(batch_size=8, remote_fraction_budget=0.5,
                      t_remote=0.0, pipeline_depth=1, cache_size=16,
                      observability=True, transport=quiet_tconf())
    engine, sched = cfg.build(local_apply, None, transport=router,
                              controller=ctl, fallback=lambda r: -7)
    obs = engine.observability
    assert obs is not None
    assert router.events is obs.events
    assert a.transport.events is obs.events
    assert a.transport.event_source == "a"
    assert ctl.events is obs.events
    serve_all(sched, xs)
    # controller updates landed in the shared log with the window id
    ups = obs.events.events(EV_CONTROLLER_UPDATE)
    assert ups and all(u["window"] is not None for u in ups)
    engine.close()

"""Paper §4.6 extensions: TriSupervised (edge tier) and the active-learning
acquisition loop."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.cascade import (EDGE, LOCAL, REJECTED, REMOTE,
                                TriThresholds, select_for_labeling,
                                trisupervised_batch)


def test_trisupervised_routing():
    th = TriThresholds(t_local=0.9, t_edge=0.7, t_remote=0.5)
    out = trisupervised_batch(
        local_pred=jnp.array([1, 1, 1, 1]),
        local_conf=jnp.array([0.95, 0.5, 0.5, 0.5]),   # only #0 local
        edge_pred=jnp.array([2, 2, 2, 2]),
        edge_conf=jnp.array([0.0, 0.8, 0.3, 0.3]),     # #1 edge
        remote_pred=jnp.array([3, 3, 3, 3]),
        remote_conf=jnp.array([0.0, 0.0, 0.6, 0.1]),   # #2 remote, #3 reject
        th=th)
    np.testing.assert_array_equal(np.asarray(out["prediction"]),
                                  [1, 2, 3, 3])
    np.testing.assert_array_equal(np.asarray(out["source"]),
                                  [LOCAL, EDGE, REMOTE, REJECTED])
    np.testing.assert_array_equal(np.asarray(out["accepted"]),
                                  [True, True, True, False])
    # cost model: edge consulted iff local rejected; remote iff edge too
    np.testing.assert_array_equal(np.asarray(out["edge_called"]),
                                  [False, True, True, True])
    np.testing.assert_array_equal(np.asarray(out["remote_called"]),
                                  [False, False, True, True])


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=30, deadline=None)
def test_trisupervised_never_worse_informed_than_bisupervised(seed):
    """With an accurate edge tier, three tiers route strictly fewer
    requests to the remote model than two tiers at the same local
    threshold (the paper's cost argument for the edge extension)."""
    rng = np.random.default_rng(seed)
    n = 256
    local_conf = jnp.asarray(rng.random(n), jnp.float32)
    edge_conf = jnp.asarray(rng.random(n), jnp.float32)
    th = TriThresholds(0.8, 0.5, 0.0)
    out = trisupervised_batch(
        jnp.zeros(n, jnp.int32), local_conf, jnp.ones(n, jnp.int32),
        edge_conf, jnp.full(n, 2, jnp.int32), jnp.ones(n), th)
    bi_remote = int(np.sum(np.asarray(local_conf) <= 0.8))
    tri_remote = int(np.asarray(out["remote_called"]).sum())
    assert tri_remote <= bi_remote


def test_active_learning_selects_least_confident():
    conf = jnp.array([0.9, 0.2, 0.8, 0.1, 0.5])
    idx, mask = select_for_labeling(conf, budget=2)
    assert set(np.asarray(idx).tolist()) == {1, 3}
    assert int(mask.sum()) == 2


def test_active_learning_loop_improves_local_model():
    """End-to-end §4.6: train on a seed set, use the 1st-level supervisor
    to acquire the hardest unlabelled inputs (labelled by the 'remote'
    oracle), retrain — accuracy on held-out data must improve over a
    random-acquisition baseline trained with the same budget."""
    from repro.data.synthetic import make_classification_task
    from repro.models import surrogate as S
    from repro.train.optimizer import AdamWConfig, adamw_update, \
        init_opt_state

    vocab, seq, ncls = 128, 24, 4
    toks, labels, _ = make_classification_task(5, n=1200, vocab=vocab,
                                               seq_len=seq, num_classes=ncls)
    tk = jnp.asarray(toks)
    lb = jnp.asarray(labels)
    seed_n, pool = 64, slice(64, 900)
    test = slice(900, 1200)
    cfg = S.SurrogateConfig("al", vocab_size=vocab, max_len=seq, d_model=32,
                            num_heads=2, d_ff=32, num_classes=ncls,
                            dropout=0.0)

    def train(train_tk, train_lb, steps=60, seed=0):
        params = S.init_params(cfg, jax.random.PRNGKey(seed))
        opt = init_opt_state(params)
        ocfg = AdamWConfig(lr=3e-3, warmup_steps=5, weight_decay=0.0)

        @jax.jit
        def step(p, o):
            (l, _), g = jax.value_and_grad(
                lambda p: S.loss_fn(cfg, p, train_tk, train_lb,
                                    jax.random.PRNGKey(1)),
                has_aux=True)(p)
            return adamw_update(ocfg, p, g, o)[:2]

        for _ in range(steps):
            params, opt = step(params, opt)
        return params

    def acc(params, sl):
        pred = jnp.argmax(S.apply(cfg, params, tk[sl]), -1)
        return float(jnp.mean(pred == lb[sl]))

    params0 = train(tk[:seed_n], lb[:seed_n])
    budget = 96

    # supervisor acquisition: least-confident pool inputs
    logits = S.apply(cfg, params0, tk[pool])
    conf = jnp.max(jax.nn.softmax(logits, -1), -1)
    idx, _ = select_for_labeling(conf, budget)
    al_tk = jnp.concatenate([tk[:seed_n], tk[pool][idx]])
    al_lb = jnp.concatenate([lb[:seed_n], lb[pool][idx]])
    acc_al = acc(train(al_tk, al_lb), test)

    # random acquisition baseline (same budget)
    rng = np.random.default_rng(0)
    ridx = rng.choice(900 - 64, budget, replace=False)
    r_tk = jnp.concatenate([tk[:seed_n], tk[pool][ridx]])
    r_lb = jnp.concatenate([lb[:seed_n], lb[pool][ridx]])
    acc_rand = acc(train(r_tk, r_lb), test)

    assert acc_al >= acc(params0, test) - 0.02   # more data never much worse
    # supervisor acquisition should be competitive with random (usually
    # better; small-model noise means we assert non-inferiority)
    assert acc_al >= acc_rand - 0.05, (acc_al, acc_rand)

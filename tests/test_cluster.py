"""Cluster runtime (DESIGN.md §12, ISSUE 9): the single-fill shared
response cache never fetches one content key remotely twice (concurrent
same-key misses block on the owner's fill and inherit its backend
attribution), adversarial replica merge-order permutations leave the
reconciled budget state and fleet billing bitwise identical, a replica
blackout degrades that replica to its base budget without silently
dropping it, and a full two-replica ``ClusterHarness`` run replays bit
for bit on a virtual clock."""

from __future__ import annotations

import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import (ClusterBudgetConfig, ClusterBudgetController,
                           ClusterHarness, RemoteBackend, RemoteRouter,
                           SharedResponseCache, TransportConfig,
                           VirtualClock, cluster_billing)
from repro.runtime.controller import AdaptiveController, ControllerConfig
from repro.serving import ServeConfig
from repro.serving.engine import BILLING_FIELDS
from repro.serving.scheduler import Request


def local_apply(x):
    return x + 0.3 * jnp.sin(17.0 * x)


def remote_fn(x):
    return 5.0 * np.asarray(x)


def make_stream(rng, n, c=4, hard_frac=0.5):
    labels = rng.integers(0, c, n)
    x = rng.normal(0, 0.05, (n, c))
    margin = np.where(rng.random(n) < hard_frac, 0.1, 3.0)
    x[np.arange(n), labels] += margin
    return np.float32(x), labels


def fresh_controller(*, window=32, target=0.25) -> AdaptiveController:
    return AdaptiveController(ControllerConfig(
        target_remote_fraction=target, window=window,
        drift_threshold=10.0, history=4096))


def feed(ctrl: AdaptiveController, scores) -> AdaptiveController:
    """Push ``scores`` through the controller's rolling buffer. Traffic
    must land AFTER ``register()`` — the reconciler weighs replicas by
    the eligible-request delta since the last reconcile (or since
    registration), so pre-registration traffic reads as blackout."""
    scores = np.asarray(scores, np.float64)
    ctrl.observe(scores, escalated=int((scores < 0.5).sum()),
                 requests=scores.size)
    return ctrl


# ----------------------------------------------------- shared cache

def test_shared_cache_single_fill_and_attribution():
    sc = SharedResponseCache(capacity=8)
    a, b = sc.view("a"), sc.view("b")
    val = np.arange(4.0)
    key = sc.key_fn(val)
    # first miss claims; the owner's own re-lookup misses again (dupe
    # rows inside one window), it does NOT deadlock on its own claim
    assert a.lookup(key) is None and a.lookup(key) is None
    a.put(key, val, source="primary")
    hit = b.lookup(key)
    assert hit is not None
    np.testing.assert_array_equal(hit[0], val)
    assert hit[1] == "primary"              # filler's attribution
    assert b.stats.cross_hits == 1 and a.stats.cross_hits == 0
    assert sc.stats.fills == 1 and sc.stats.duplicate_fills == 0
    # a duplicate fill is discarded: first value keeps being served
    b.put(key, val * 10, source="secondary")
    assert sc.stats.duplicate_fills == 1
    np.testing.assert_array_equal(a.lookup(key)[0], val)


def test_shared_cache_concurrent_misses_one_owner():
    sc = SharedResponseCache(capacity=8, wait_s=10.0)
    owner = sc.view("owner")
    val = np.float32([1.0, 2.0])
    key = sc.key_fn(val)
    assert owner.lookup(key) is None        # claim taken
    results = {}

    def peer(name):
        results[name] = sc.view(name).lookup(key)

    threads = [threading.Thread(target=peer, args=(f"p{i}",))
               for i in range(3)]
    for t in threads:
        t.start()
    # peers are parked on the condition variable until the fill lands
    owner.put(key, val, source="primary")
    for t in threads:
        t.join(timeout=10.0)
        assert not t.is_alive()
    for name in ("p0", "p1", "p2"):
        got = results[name]
        np.testing.assert_array_equal(got[0], val)
        assert got[1] == "primary"
    assert sc.stats.fills == 1              # exactly one remote fetch
    assert sc.stats.duplicate_fills == 0
    assert sc.stats.waits >= 3
    assert sum(sc.view(f"p{i}").stats.cross_hits for i in range(3)) == 3


def test_shared_cache_release_unfilled_hands_claim_over():
    sc = SharedResponseCache(capacity=8, wait_s=10.0)
    val = np.float32([3.0])
    key = sc.key_fn(val)
    assert sc.view("dead").lookup(key) is None      # claim, then die
    got = {}

    def peer():
        got["hit"] = sc.view("heir").lookup(key)

    t = threading.Thread(target=peer)
    t.start()
    while sc.stats.waits == 0:              # peer reached the wait
        pass
    assert sc.release_unfilled("dead") == 1
    t.join(timeout=10.0)
    assert got["hit"] is None               # heir now owns the claim
    sc.view("heir").put(key, val, source="s")
    assert sc.stats.fills == 1 and sc.stats.releases == 1


def test_shared_cache_materialize_is_permutation_invariant():
    sc = SharedResponseCache(capacity=32)
    for i in range(6):
        v = np.float32([i, i + 1])
        k = sc.key_fn(v)
        assert sc.view(f"r{i % 2}").lookup(k) is None
        sc.view(f"r{i % 2}").put(k, v, source=f"b{i % 3}")
    feed = list(sc.feed)
    base = SharedResponseCache.materialize(feed)
    rng = np.random.default_rng(0)
    for _ in range(5):
        perm = [feed[j] for j in rng.permutation(len(feed))]
        assert SharedResponseCache.materialize(perm) == base


# ------------------------------------------------- budget reconcile

def test_reconcile_pooled_holds_global_budget_under_skew():
    rng = np.random.default_rng(1)
    # r0 sees hard traffic (low scores), r1 easy — same volume
    hard = rng.uniform(0.0, 0.5, 200)
    easy = rng.uniform(0.5, 1.0, 200)
    cl = ClusterBudgetController(ClusterBudgetConfig(
        target_remote_fraction=0.25, min_pooled_scores=64))
    r0, r1 = fresh_controller(), fresh_controller()
    cl.register("r0", r0)
    cl.register("r1", r1)
    feed(r0, hard)
    feed(r1, easy)
    st = cl.reconcile(now=1.0)
    assert st.mode == "pooled" and st.tau is not None
    # skewed targets: hard replica far above target, easy far below
    assert st.targets["r0"] > 0.4 and st.targets["r1"] < 0.1
    # traffic-weighted mean of pushed targets == global target, up to
    # the per-replica target floor the easy replica clips to (0.02)
    mean = (st.targets["r0"] * 200 + st.targets["r1"] * 200) / 400
    assert mean == pytest.approx(0.25, abs=0.021)
    # targets were pushed down into the per-replica controllers
    assert (cl._replicas["r0"].config.target_remote_fraction
            == st.targets["r0"])
    # shed rule: squeezed replica sheds earlier, spender gets headroom
    assert cl.admission_scale("r1") < 1.0 < cl.admission_scale("r0")
    assert 0.25 <= cl.admission_scale("r1") <= 4.0


def test_reconcile_blackout_replica_degrades_to_base_budget():
    rng = np.random.default_rng(2)
    cl = ClusterBudgetController(ClusterBudgetConfig(
        target_remote_fraction=0.3, min_pooled_scores=64))
    up0, up1 = (fresh_controller(target=0.3) for _ in range(2))
    dead = fresh_controller(target=0.3)
    cl.register("up0", up0)
    cl.register("up1", up1)
    cl.register("dead", dead)               # never observes traffic
    feed(up0, rng.uniform(0, 1, 150))
    feed(up1, rng.uniform(0, 1, 150))
    st = cl.reconcile(now=1.0)
    assert st.mode == "pooled"
    assert st.stale == ("dead",)
    # the blackout replica is excluded from the pool but NOT dropped:
    # it is reset to the base per-replica budget
    assert st.targets["dead"] == 0.3
    assert dead.config.target_remote_fraction == 0.3
    # fewer than two live replicas -> everyone degrades to base
    cl2 = ClusterBudgetController(ClusterBudgetConfig(
        target_remote_fraction=0.3))
    solo = fresh_controller(target=0.3)
    cl2.register("solo", solo)
    feed(solo, rng.uniform(0, 1, 150))
    st2 = cl2.reconcile(now=1.0)
    assert st2.mode == "degraded" and st2.targets["solo"] == 0.3


def test_reconcile_is_registration_order_invariant():
    rng = np.random.default_rng(3)
    pools = {f"r{i}": rng.uniform(0, 1, 100 + 40 * i) for i in range(4)}
    states = []
    for order in ([0, 1, 2, 3], [3, 1, 0, 2], [2, 3, 1, 0]):
        cl = ClusterBudgetController(ClusterBudgetConfig(
            target_remote_fraction=0.25, min_pooled_scores=64))
        ctrls = {}
        for i in order:
            ctrls[i] = fresh_controller()
            cl.register(f"r{i}", ctrls[i])
        for i in order:
            feed(ctrls[i], pools[f"r{i}"])
        states.append(cl.reconcile(now=1.0))
    for st in states[1:]:
        assert st.mode == states[0].mode
        assert st.tau == states[0].tau                  # bitwise
        assert st.targets == states[0].targets          # bitwise
        assert st.global_ema_fraction == states[0].global_ema_fraction


def test_cluster_billing_is_merge_order_invariant():
    class U:
        def __init__(self, c):
            self.remote_calls, self.cache_hits = c, c + 1
            self.transport_failures, self.cost = c % 2, 0.1 * c + 0.007
            self.remote_latency_s = 0.003 * c

    class St:
        def __init__(self, c):
            for i, f in enumerate(BILLING_FIELDS):
                setattr(self, f, c + 0.1 * i if f == "total_cost" else
                        c + i)
            self.per_backend = {"a": U(c), "b": U(c + 3)}

    stats = {f"r{i}": St(i) for i in range(5)}
    base = cluster_billing(stats)
    for order in ([4, 2, 0, 3, 1], [1, 0, 4, 2, 3]):
        shuffled = {f"r{i}": stats[f"r{i}"] for i in order}
        assert cluster_billing(shuffled) == base        # bitwise


# --------------------------------------------------------- harness

def make_router(clock):
    tconf = TransportConfig(max_in_flight=16, max_retries=0,
                            retry_backoff_s=0.0, timeout_s=10.0,
                            breaker_failures=10**6)
    return RemoteRouter(
        [RemoteBackend("primary", remote_fn, tconf,
                       cost_per_request=0.002, latency_s=0.01,
                       clock=clock, sleep=clock.sleep)])


def drive_harness(seed=0, replicas=2, n=96):
    clock = VirtualClock()
    cfg = ServeConfig(batch_size=8, remote_fraction_budget=0.25,
                      t_remote=0.0, pipeline_depth=1, cache_size=256,
                      adaptive=True, control_window=16,
                      replicas=replicas, observability=True)
    h = ClusterHarness(cfg, local_apply, transport=make_router(clock),
                       fallback=lambda r: -1, clock=clock, seed=seed,
                       reconcile_interval_s=0.5)
    rng = np.random.default_rng(7)
    xs, labels = make_stream(rng, n)
    proto = xs[rng.integers(0, 24, n)]      # repeats -> cache traffic
    responses = []
    for i in range(n):
        clock.advance_to(0.05 * i)
        h.submit(h.names[i % replicas],
                 Request(uid=i, local_input=proto[i],
                         remote_input=proto[i]))
        if (i + 1) % (8 * replicas) == 0:
            for batch in h.flush().values():
                responses.extend(batch)
    for batch in h.flush().values():
        responses.extend(batch)
    digest = {
        "responses": [(r.uid, int(r.prediction), r.source,
                       r.disposition, r.backend, round(r.cost, 12))
                      for r in sorted(responses, key=lambda r: r.uid)],
        "billing": h.global_billing(),
        "feed": [(u.key.hex(), u.source, u.replica)
                 for u in h.shared_cache.feed],
        "reconciles": h.cluster.state.reconciles,
        "targets": dict(h.cluster.state.targets),
        "events": dict(sorted(h.events.counts().items())),
        "cross_hits": {name: h.replica(name).cache.stats.cross_hits
                       for name in h.names},
    }
    h.close()
    return h, digest, n


def test_harness_double_run_is_bit_identical():
    h1, d1, n = drive_harness(seed=3)
    h2, d2, _ = drive_harness(seed=3)
    assert d1 == d2
    # zero silent drops: every uid answered exactly once across the fleet
    uids = [r[0] for r in d1["responses"]]
    assert sorted(uids) == list(range(n))
    # single-fill: no content key fetched remotely twice
    assert h1.shared_cache.stats.duplicate_fills == 0
    keys = [k for k, _, _ in d1["feed"]]
    assert len(keys) == len(set(keys))
    # the prototype stream actually exercised cross-replica sharing
    assert sum(d1["cross_hits"].values()) > 0
    assert d1["reconciles"] > 0
    assert "cluster_reconcile" in d1["events"]
    # billing reconciles with the shared store: every billed remote row
    # produced a put — a first fill, or a same-window duplicate row that
    # rode the fill's own remote call (redundant put, not a re-fetch)
    scs = h1.shared_cache.stats
    b = d1["billing"]["billing"]
    assert b["remote_calls"] == scs.fills + scs.redundant_puts
    assert b["requests"] == n


def test_harness_admission_share_scales_soft_watermark():
    clock = VirtualClock()
    cfg = ServeConfig(batch_size=8, remote_fraction_budget=0.25,
                      t_remote=0.0, pipeline_depth=1, cache_size=0,
                      adaptive=True, control_window=16, replicas=2,
                      admission_limit=40, admission_soft_ratio=0.5,
                      observability=True)
    h = ClusterHarness(cfg, local_apply, transport=make_router(clock),
                       fallback=lambda r: -1, clock=clock)
    sched = h.replica("r0").scheduler
    assert sched._soft_watermark() == sched.admission_soft  # share 1.0
    h.cluster.state.global_target = 0.25
    h.cluster.state.targets = {"r0": 0.125, "r1": 0.375}
    assert sched._soft_watermark() == 10         # squeezed: sheds early
    h.cluster.state.targets = {"r0": 10.0, "r1": 0.375}
    # headroom is capped below the hard limit (hard bound still owns)
    assert sched._soft_watermark() == cfg.admission_limit - 1
    h.close()


def test_serveconfig_cluster_validation():
    with pytest.raises(ValueError, match="adaptive"):
        ServeConfig(replicas=2)
    with pytest.raises(ValueError, match="fused"):
        ServeConfig(fused=True, replicas=2, adaptive=True)
    with pytest.raises(ValueError, match="fused"):
        ServeConfig(fused=True, data_parallel=True)
    cfg = ServeConfig(replicas=3, adaptive=True)
    assert cfg.replicas == 3


def test_data_parallel_shard_is_numeric_noop():
    from repro.launch.mesh import make_serving_mesh
    from repro.launch.sharding import shard_local_step
    mesh = make_serving_mesh()

    def step(x):
        return jnp.tanh(x) * 2.0

    x = jnp.linspace(-1, 1, 32).reshape(8, 4)
    np.testing.assert_allclose(np.asarray(shard_local_step(step, mesh)(x)),
                               np.asarray(step(x)), rtol=0, atol=0)

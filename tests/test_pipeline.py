"""Pipelined serving path (DESIGN.md §5): transport futures, overlapped
engine windows, FIFO drain determinism — in-flight windows completing out
of order must produce the same per-request responses, stats and
controller state as serial execution — plus wall-clock latency tracking
and the batched cache-key fast path."""

from __future__ import annotations

import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import (AdaptiveController, ControllerConfig,
                           RemoteResponseCache, RemoteTimeout,
                           RemoteTransport, TransportConfig, content_key,
                           content_keys)
from repro.serving.engine import (BILLING_FIELDS, CascadeEngine,
                                  CascadeStats)
from repro.serving.scheduler import MicrobatchScheduler, Request


def local_apply(x):
    return x + 0.3 * jnp.sin(17.0 * x)


def remote_apply(x):
    return 5.0 * np.asarray(x)


def make_stream(rng, n, c=4, hard_frac=0.5):
    labels = rng.integers(0, c, n)
    x = rng.normal(0, 0.05, (n, c))
    margin = np.where(rng.random(n) < hard_frac, 0.1, 3.0)
    x[np.arange(n), labels] += margin
    return np.float32(x), labels


def build(remote=remote_apply, *, batch=8, budget=0.5, depth=1,
          controller=None, cache=None, tconf=None):
    transport = RemoteTransport(remote, tconf or TransportConfig(
        retry_backoff_s=0.0, max_retries=0, breaker_failures=10**6,
        timeout_s=60.0))
    engine = CascadeEngine(local_apply, batch_size=batch,
                           remote_fraction_budget=budget, t_remote=0.0,
                           transport=transport, controller=controller,
                           cache=cache)
    sched = MicrobatchScheduler(engine, fallback=lambda r: -7,
                                pipeline_depth=depth)
    return sched, engine, transport


def serve_all(sched, xs):
    for i, row in enumerate(xs):
        sched.submit(Request(uid=i, local_input=row, remote_input=row))
    return sched.flush()


def routing(responses):
    return [(r.uid, r.prediction, r.source) for r in responses]


# ---------------------------------------------------- transport futures

def test_transport_submit_returns_future_with_call_semantics():
    tr = RemoteTransport(remote_apply, TransportConfig(retry_backoff_s=0.0))
    x = np.float32(np.eye(4))
    fut = tr.submit(x)
    logits, ok = fut.result(timeout=10.0)
    assert ok.all() and fut.done()
    np.testing.assert_allclose(logits, 5.0 * np.eye(4))
    assert fut.n == 4
    l2, ok2 = tr.call(x)                    # sync path: same answers
    np.testing.assert_allclose(logits, l2)
    tr.shutdown()


def test_transport_submits_run_concurrently():
    gate = threading.Barrier(3, timeout=10.0)

    def slow_remote(x):
        gate.wait()                         # deadlocks unless concurrent
        return remote_apply(x)

    tr = RemoteTransport(slow_remote, TransportConfig(
        retry_backoff_s=0.0, max_retries=0, max_concurrent=3))
    futs = [tr.submit(np.float32(np.eye(4))) for _ in range(3)]
    for f in futs:
        _, ok = f.result(timeout=10.0)
        assert ok.all()
    tr.shutdown()


def test_transport_future_fault_surfaces_as_ok_false():
    def down(x):
        raise RemoteTimeout("down")

    tr = RemoteTransport(down, TransportConfig(retry_backoff_s=0.0,
                                               max_retries=0))
    logits, ok = tr.submit(np.float32(np.eye(4))).result(timeout=10.0)
    assert not ok.any() and logits is None   # never raises
    tr.shutdown()


# --------------------------------------- pipelined == serial equivalence

def test_pipelined_matches_serial_fixed_thresholds():
    """No controller: deep pipeline must be bitwise-identical to serial
    in responses AND billing, even when later windows complete first."""
    rng = np.random.default_rng(0)
    xs, _ = make_stream(rng, 64)

    calls = {"n": 0}

    def reordering_remote(x):
        # earlier submissions sleep longer -> completion order inverted
        calls["n"] += 1
        time.sleep(0.03 * max(0, 4 - calls["n"]))
        return remote_apply(x)

    s_ser, e_ser, _ = build(batch=8, depth=1)
    s_pip, e_pip, tr = build(reordering_remote, batch=8, depth=4)
    r_ser = serve_all(s_ser, xs)
    r_pip = serve_all(s_pip, xs)
    assert routing(r_ser) == routing(r_pip)
    for f in BILLING_FIELDS:
        assert getattr(e_ser.stats, f) == getattr(e_pip.stats, f), f
    tr.shutdown()


def test_pipelined_depth1_matches_serial_with_controller_and_faults():
    """depth=1 drains each window before the next submit, so even the
    controller's closed loop sees exactly the serial observation order
    under seeded per-content transport faults."""
    rng = np.random.default_rng(1)
    xs, _ = make_stream(rng, 96)

    def flaky(x):                 # deterministic per-content fault hook
        x = np.asarray(x)
        if float(x.sum()) % 1.0 < 0.25:
            raise RemoteTimeout("content-keyed fault")
        return remote_apply(x)

    def make(depth):
        ctl = AdaptiveController(ControllerConfig(
            target_remote_fraction=0.3, window=32))
        return build(flaky, batch=8, budget=0.5, depth=depth,
                     controller=ctl, tconf=TransportConfig(
                         retry_backoff_s=0.0, max_retries=0,
                         max_in_flight=2, breaker_failures=10**6,
                         timeout_s=60.0))

    s_ser, e_ser, _ = make(1)
    r_ser = serve_all(s_ser, xs)
    s_pip, e_pip, tr = make(1)
    for i, row in enumerate(xs):
        s_pip.submit(Request(uid=i, local_input=row, remote_input=row))
    r_pip = s_pip.flush(pipeline_depth=1)
    assert routing(r_ser) == routing(r_pip)
    for f in BILLING_FIELDS:
        assert getattr(e_ser.stats, f) == getattr(e_pip.stats, f), f
    assert e_ser.controller.state == e_pip.controller.state
    tr.shutdown()


def test_pipelined_deterministic_across_completion_orders():
    """Same stream, same depth, adversarially different remote completion
    orders: FIFO drain must make responses, stats AND controller state
    identical — completion order can never leak into accounting."""
    rng = np.random.default_rng(2)
    xs, _ = make_stream(rng, 96)

    def delays_a(i):
        return 0.002 * (i % 5)

    def delays_b(i):
        return 0.002 * (4 - i % 5)          # inverted completion order

    def run(delays):
        calls = {"n": 0}
        lock = threading.Lock()

        def remote(x):
            with lock:
                calls["n"] += 1
                i = calls["n"]
            time.sleep(delays(i))
            x = np.asarray(x)
            if float(x.sum()) % 1.0 < 0.2:  # seeded per-content faults
                raise RemoteTimeout("content-keyed fault")
            return remote_apply(x)

        ctl = AdaptiveController(ControllerConfig(
            target_remote_fraction=0.3, window=32))
        sched, engine, tr = build(remote, batch=8, budget=0.5, depth=4,
                                  controller=ctl)
        resp = serve_all(sched, xs)
        tr.shutdown()
        return resp, engine

    r_a, e_a = run(delays_a)
    r_b, e_b = run(delays_b)
    assert routing(r_a) == routing(r_b)
    for f in BILLING_FIELDS:
        assert getattr(e_a.stats, f) == getattr(e_b.stats, f), f
    assert e_a.controller.state == e_b.controller.state


def test_pipelined_outage_degrades_to_fallback_without_drops():
    rng = np.random.default_rng(3)
    xs, _ = make_stream(rng, 20)            # padding tail too

    def down(x):
        raise RemoteTimeout("down")

    sched, engine, tr = build(down, batch=8, depth=4)
    responses = serve_all(sched, xs)
    assert sorted(r.uid for r in responses) == list(range(20))  # no drops
    assert {r.source for r in responses} == {"local", "fallback"}
    for r in responses:
        if r.source == "fallback":
            assert r.prediction == -7
    assert engine.stats.remote_calls == 0 and engine.stats.total_cost == 0
    assert engine.stats.transport_failures == sched.fallbacks
    tr.shutdown()


def test_engine_rejects_serve_while_windows_in_flight():
    rng = np.random.default_rng(4)
    xs, _ = make_stream(rng, 8, hard_frac=1.0)
    _, engine, tr = build(batch=8)
    engine.begin_serve({"local": xs, "remote": xs}, real_rows=8)
    with pytest.raises(RuntimeError):
        engine.serve({"local": xs, "remote": xs})
    assert engine.inflight == 1
    assert engine.complete_next() is not None
    assert engine.complete_next() is None   # drained
    tr.shutdown()


# ------------------------------------------------ wall-clock latency

def test_wall_clock_latency_tracked_alongside_modelled():
    rng = np.random.default_rng(5)
    xs, _ = make_stream(rng, 32)

    def slow(x):
        time.sleep(0.01)
        return remote_apply(x)

    sched, engine, tr = build(slow, batch=8, depth=1)
    serve_all(sched, xs)
    st = engine.stats
    assert st.wall_latency_s > 0.0
    assert st.mean_wall_latency_s > 0.0
    assert len(st.wall_samples) == 4        # one per microbatch window
    assert st.wall_percentile(95) >= st.wall_percentile(50) > 0.0
    # modelled latency still follows the CostModel constants, untouched
    np.testing.assert_allclose(
        st.total_latency_s,
        st.requests * engine.cost.local_latency_s
        + st.remote_calls * engine.cost.remote_latency_s)
    tr.shutdown()


def test_wall_stats_empty_render_as_none():
    # empty stats must be ABSENT, not a flattering 0.0 (DESIGN.md §9)
    st = CascadeStats()
    assert st.wall_percentile(50) is None
    assert st.mean_wall_latency_s is None
    assert st.mean_latency_s is None


# ------------------------------------------------ batched cache keys

def test_content_keys_match_per_row_content_key():
    rng = np.random.default_rng(6)
    batch = {"tokens": rng.integers(0, 99, (5, 7)).astype(np.int32),
             "extra": [np.float32(rng.normal(0, 1, (5, 3))),
                       np.arange(5, dtype=np.int64)]}
    got = content_keys(batch, 5)
    want = [content_key({"tokens": batch["tokens"][i],
                         "extra": [batch["extra"][0][i],
                                   batch["extra"][1][i]]})
            for i in range(5)]
    assert got == want


def test_cache_keys_for_batched_and_fallback_agree():
    rng = np.random.default_rng(7)
    batch = rng.normal(0, 1, (6, 4)).astype(np.float32)
    fast = RemoteResponseCache(16)                   # content_key pairing
    slow = RemoteResponseCache(16, key_fn=content_key, key_batch_fn=None)
    slow.key_batch_fn = None                         # force per-row path
    assert fast.keys_for(batch, 6) == slow.keys_for(batch, 6)
    assert fast.keys_for(batch, 6) == [content_key(batch[i])
                                       for i in range(6)]


def test_pipelined_cache_still_dedups_within_drained_windows():
    """Serial-equivalent cache billing at depth=1; at depth>1 lookups may
    race puts from still-in-flight windows (documented bounded staleness)
    but repeats across already-drained windows must still hit."""
    rng = np.random.default_rng(8)
    xs, _ = make_stream(rng, 8, hard_frac=1.0)
    cache = RemoteResponseCache(64)
    sched, engine, tr = build(batch=8, depth=4, cache=cache)
    serve_all(sched, xs)                    # all escalate, all miss
    billed_first = engine.stats.remote_calls
    serve_all(sched, xs)                    # identical content again
    assert engine.stats.remote_calls == billed_first
    assert engine.stats.cache_hits >= 4
    tr.shutdown()


# ------------------------------------------------ scheduler queue drain

def test_flush_drains_large_queue_in_order():
    rng = np.random.default_rng(9)
    xs, _ = make_stream(rng, 203)           # non-multiple tail
    sched, engine, tr = build(batch=8, depth=4)
    responses = serve_all(sched, xs)
    assert [r.uid for r in responses] == list(range(203))
    assert engine.stats.requests == 203
    assert len(sched.queue) == 0
    tr.shutdown()

"""Cascade semantics (Algorithm 1) + RQ1/RQ2 metrics, incl. hypothesis
property tests of the system's invariants."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st
from hypothesis.extra.numpy import arrays

from repro.core import thresholds as TH
from repro.core.cascade import (LOCAL, REJECTED, REMOTE, CascadeThresholds,
                                bisupervised_batch, combine_escalated,
                                escalation_capacity, gather_requests,
                                select_escalations)
from repro.core.metrics import (auc_rac, request_accuracy_curve,
                                supervised_metrics, threshold_for_fpr)

# ------------------------------------------------------------ Algorithm 1


def test_bisupervised_batch_routing():
    th = CascadeThresholds(t_local=0.8, t_remote=0.6)
    out = bisupervised_batch(
        local_pred=jnp.array([1, 2, 3]),
        local_conf=jnp.array([0.9, 0.5, 0.4]),    # trust only input 0
        remote_pred=jnp.array([7, 8, 9]),
        remote_conf=jnp.array([0.0, 0.7, 0.3]),   # trust only input 1
        th=th)
    np.testing.assert_array_equal(np.asarray(out["prediction"]), [1, 8, 9])
    np.testing.assert_array_equal(np.asarray(out["source"]),
                                  [LOCAL, REMOTE, REJECTED])
    np.testing.assert_array_equal(np.asarray(out["accepted"]),
                                  [True, True, False])
    np.testing.assert_array_equal(np.asarray(out["remote_called"]),
                                  [False, True, True])


@given(conf_i=st.lists(st.integers(0, 64), min_size=4, max_size=64),
       t_i=st.integers(0, 128))
@settings(max_examples=50, deadline=None)
def test_remote_called_iff_local_untrusted(conf_i, t_i):
    """Algorithm-1 invariant: the remote model is consulted exactly for the
    inputs whose local confidence fails the threshold (the cost model).
    Values live on a coarse grid (exactly representable, off-boundary)."""
    conf = np.asarray(conf_i, np.float32) / 64.0
    t_local = t_i / 128.0 + 1 / 256.0       # never equal to any conf value
    n = conf.shape[0]
    out = bisupervised_batch(jnp.zeros(n, jnp.int32), jnp.asarray(conf),
                             jnp.ones(n, jnp.int32), jnp.ones(n),
                             CascadeThresholds(t_local, 0.5))
    want = ~(conf > t_local)
    np.testing.assert_array_equal(np.asarray(out["remote_called"]), want)


# --------------------------------------------------- capacity escalation


def test_escalation_capacity_bounds():
    assert escalation_capacity(128, 0.0) == 1
    assert escalation_capacity(128, 1.0) == 128
    assert escalation_capacity(128, 0.5) == 64
    assert escalation_capacity(10, 0.31) == 4   # ceil


def test_select_escalations_picks_lowest_confidence():
    conf = jnp.array([0.9, 0.1, 0.5, 0.2])
    idx, mask = select_escalations(conf, 2)
    assert set(np.asarray(idx).tolist()) == {1, 3}
    np.testing.assert_array_equal(np.asarray(mask),
                                  [False, True, False, True])


@given(conf=arrays(np.float32, st.integers(2, 40),
                   elements=st.floats(0, 1, width=32), unique=True),
       frac=st.floats(0.05, 1.0))
@settings(max_examples=50, deadline=None)
def test_capacity_escalation_equals_threshold_semantics(conf, frac):
    """Escalating the k lowest-confidence inputs == thresholding at the
    k-th order statistic (the DESIGN.md §2 equivalence)."""
    n = conf.shape[0]
    k = escalation_capacity(n, frac)
    idx, mask = select_escalations(jnp.asarray(conf), k)
    t = np.sort(conf)[k - 1]
    np.testing.assert_array_equal(np.asarray(mask), conf <= t)


def test_combine_scatter_roundtrip():
    local = jnp.array([10, 20, 30, 40])
    idx = jnp.array([2, 0])
    remote = jnp.array([77, 88])
    out = combine_escalated(local, idx, remote)
    np.testing.assert_array_equal(np.asarray(out), [88, 20, 77, 40])
    sub = gather_requests({"x": jnp.arange(4) * 10}, idx)
    np.testing.assert_array_equal(np.asarray(sub["x"]), [20, 0])


# ------------------------------------------------------------ RQ1 metrics


def test_rac_endpoints_are_pure_tiers():
    rng = np.random.default_rng(0)
    lc = rng.random(200) < 0.6
    rc = rng.random(200) < 0.9
    rac = request_accuracy_curve(rng.random(200), lc, rc)
    np.testing.assert_allclose(rac.local_only, lc.mean())
    np.testing.assert_allclose(rac.remote_only, rc.mean())
    assert rac.accuracy.shape == (201,)


def test_perfect_supervisor_beats_random():
    """A supervisor whose confidence == correctness yields the maximum
    possible AUC-RAC; a random one ~0.5."""
    rng = np.random.default_rng(1)
    n = 4000
    local_correct = rng.random(n) < 0.6
    remote_correct = rng.random(n) < 0.9
    perfect = request_accuracy_curve(
        local_correct.astype(float) + 0.1 * rng.random(n),
        local_correct, remote_correct)
    random = request_accuracy_curve(rng.random(n), local_correct,
                                    remote_correct)
    assert auc_rac(perfect) > 0.9
    assert abs(auc_rac(random) - 0.5) < 0.1


def test_superaccuracy_with_complementary_models():
    """If local and remote are correct on disjoint sets and the supervisor
    is informed, the RAC peaks above remote-only (paper §4.4)."""
    n = 1000
    local_correct = np.zeros(n, bool)
    local_correct[:500] = True          # local solves first half
    remote_correct = np.ones(n, bool)
    remote_correct[:200] = False        # remote fails 200 the local solves
    # informed supervisor: keeps local-right inputs local, and holds the
    # remote-wrong-but-local-right ones back the longest
    conf = local_correct.astype(float) + 0.5 * ~remote_correct
    rac = request_accuracy_curve(conf, local_correct, remote_correct)
    knees = rac.knee_points()
    assert knees["best_accuracy"] > rac.remote_only
    assert auc_rac(rac) > 1.0           # strong superaccuracy (paper §5.1)


@given(st.integers(10, 300), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=30, deadline=None)
def test_rac_accuracy_is_valid_probability(n, seed):
    rng = np.random.default_rng(seed)
    rac = request_accuracy_curve(rng.random(n), rng.random(n) < 0.5,
                                 rng.random(n) < 0.8)
    assert np.all((rac.accuracy >= 0) & (rac.accuracy <= 1))
    assert rac.remote_fraction[0] == 0.0 and rac.remote_fraction[-1] == 1.0


# ------------------------------------------------------------ RQ2 metrics


def test_supervised_metrics_formulas():
    accepted = np.array([True, True, False, True])
    correct = np.array([True, False, False, True])
    m = supervised_metrics(accepted, correct)
    np.testing.assert_allclose(m["delta"], 0.75)
    np.testing.assert_allclose(m["acc_supervised"], 2 / 3)
    # S_1 = harmonic mean
    np.testing.assert_allclose(
        m["s_1.0"], 2 * (2 / 3) * 0.75 / ((2 / 3) + 0.75))


@given(accepted=arrays(bool, 64), correct=arrays(bool, 64))
@settings(max_examples=50, deadline=None)
def test_sbeta_bounded(accepted, correct):
    m = supervised_metrics(accepted, correct)
    for k in ("s_0.5", "s_1.0", "s_2.0"):
        assert 0.0 <= m[k] <= 1.0
    assert m[k] <= max(m["acc_supervised"], m["delta"]) + 1e-12


def test_threshold_for_fpr_hits_target():
    rng = np.random.default_rng(2)
    conf = rng.random(10_000)
    correct = rng.random(10_000) < 0.7
    for fpr in (0.01, 0.05, 0.1):
        t = threshold_for_fpr(conf, correct, fpr)
        got = np.mean(conf[correct] <= t)
        assert abs(got - fpr) < 0.01, (fpr, got)


# ------------------------------------------------------------- thresholds


def test_nominal_quantile_threshold():
    conf = np.linspace(0, 1, 1001)
    t = TH.nominal_quantile_threshold(conf, 0.10)
    assert abs(np.mean(conf <= t) - 0.10) < 0.005


def test_separation_threshold_separates():
    rng = np.random.default_rng(3)
    nominal = rng.normal(1.0, 0.2, 500)
    invalid = rng.normal(-1.0, 0.2, 500)
    t = TH.separation_threshold(nominal, invalid)
    assert np.mean(nominal > t) > 0.95
    assert np.mean(invalid <= t) > 0.95


@given(frac=st.floats(0.0, 1.0), seed=st.integers(0, 2 ** 31 - 1))
@settings(max_examples=40, deadline=None)
def test_escalation_rate_threshold_matches_fraction(frac, seed):
    rng = np.random.default_rng(seed)
    conf = rng.random(500)
    t = TH.escalation_rate_threshold(conf, frac)
    got = np.mean(conf <= t)
    assert abs(got - frac) <= 1.5 / 500 + 1e-9

"""Training substrate: optimizer math, LR schedule, loop convergence,
checkpoint roundtrip."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.loop import make_train_step, train_loop
from repro.train.optimizer import (AdamWConfig, adamw_update, global_norm,
                                   init_opt_state, lr_schedule)


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, s)) for s in range(101)]
    assert lrs[0] == 0.0
    np.testing.assert_allclose(lrs[10], 1e-3, rtol=1e-5)     # warmup peak
    assert all(a >= b - 1e-12 for a, b in zip(lrs[10:], lrs[11:]))  # decay
    np.testing.assert_allclose(lrs[100], 1e-4, rtol=1e-4)    # floor


def test_grad_clipping():
    cfg = AdamWConfig(grad_clip=1.0, lr=1.0, warmup_steps=0, weight_decay=0)
    params = {"w": jnp.zeros((4, 4))}
    grads = {"w": jnp.full((4, 4), 100.0)}
    state = init_opt_state(params)
    new, state, stats = adamw_update(cfg, params, grads, state)
    assert float(stats["grad_norm"]) == pytest.approx(400.0)
    # post-clip effective grad has norm <= 1 -> Adam step magnitude bounded
    assert float(global_norm(new["w"])) < 10.0


def test_weight_decay_applies_to_matrices_only():
    cfg = AdamWConfig(lr=1e-2, weight_decay=0.5, warmup_steps=0,
                      grad_clip=1e9)
    params = {"w": jnp.ones((4, 4)), "norm": jnp.ones((4,))}
    grads = {"w": jnp.zeros((4, 4)), "norm": jnp.zeros((4,))}
    state = init_opt_state(params)
    new, _, _ = adamw_update(cfg, params, grads, state)
    assert float(new["w"][0, 0]) < 1.0        # decayed
    assert float(new["norm"][0]) == 1.0       # exempt


def test_train_loop_reduces_loss():
    cfg = get_config("yi-6b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    # a memorisable batch stream (8 fixed sequences)
    fixed = jnp.asarray(rng.integers(1, cfg.vocab_size, (8, 64)), jnp.int32)

    def batches():
        while True:
            yield {"tokens": fixed}

    opt = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60,
                      weight_decay=0.0)
    params, _, hist = train_loop(cfg, params, batches(), opt, steps=40,
                                 log_every=5)
    first, last = hist[0]["ce"], hist[-1]["ce"]
    assert last < first * 0.7, (first, last)
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_train_step_is_jittable_and_deterministic():
    cfg = get_config("qwen3-moe-235b-a22b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    step = jax.jit(make_train_step(cfg, AdamWConfig()))
    opt = init_opt_state(params)
    batch = {"tokens": jnp.ones((2, 64), jnp.int32)}
    p1, o1, m1 = step(params, opt, batch)
    p2, o2, m2 = step(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-6)


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("rwkv6-1.6b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(2))
    path = str(tmp_path / "ckpt.msgpack")
    save_checkpoint(path, params, step=42)
    restored, step = load_checkpoint(path, params)
    assert step == 42
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_bf16_exact(tmp_path):
    tree = {"w": (jax.random.normal(jax.random.PRNGKey(3), (16, 16))
                  .astype(jnp.bfloat16))}
    path = str(tmp_path / "bf16.msgpack")
    save_checkpoint(path, tree)
    restored, _ = load_checkpoint(path, tree)
    assert restored["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(tree["w"], np.float32),
                                  np.asarray(restored["w"], np.float32))
